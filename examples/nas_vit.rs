//! Neural-architecture evaluation — the paper's second workload (Table 2
//! row 2) at CPU-feasible scale: ViT-style patch classifiers of *different
//! architectures* (depth/width) trained together and ranked.
//!
//! Demonstrates heterogeneous multi-model training: the models have
//! different shard counts and unit costs, which is exactly the regime where
//! Sharded-LRTF's longest-remaining-first ordering matters (§4.7.2).
//!
//! ```bash
//! cargo run --release --example nas_vit [-- --steps 30]
//! ```

use hydra::coordinator::Cluster;
use hydra::exec::real::RealModelSpec;
use hydra::session::{Backend, Policy, Session};
use hydra::train::optimizer::OptKind;
use hydra::util::cli::Args;

const MIB: u64 = 1 << 20;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env(&[])?;
    let steps = args.opt_usize("steps", 30)? as u32;

    // two architectures x two learning rates = 4 candidates
    let candidates = [
        ("tiny-cls-b8", 0.08f32),
        ("tiny-cls-b8", 0.03),
        ("small-cls-b8", 0.08),
        ("small-cls-b8", 0.03),
    ];
    let cluster = Cluster::uniform(2, 3 * MIB, 8192 * MIB);
    let mut session = Session::builder(cluster)
        .backend(Backend::Real { manifest: "artifacts".into() })
        .policy(Policy::ShardedLrtf)
        .build()?;
    for (i, (config, lr)) in candidates.into_iter().enumerate() {
        session.submit(RealModelSpec {
            name: format!("{config}-lr{lr}"),
            config: config.into(),
            lr,
            opt: OptKind::Momentum { beta: 0.9 },
            epochs: 1,
            minibatches_per_epoch: steps,
            seed: 21 + i as u64,
            inference: false,
            arrival: 0.0,
        })?;
    }

    println!("evaluating {} ViT-style candidates for {steps} steps ...", candidates.len());
    let report = session.run()?;

    println!(
        "\nvirtual makespan {:.1}s | util {:.1}% | {} units | scheduler {}",
        report.run.makespan,
        100.0 * report.run.utilization,
        report.run.units_executed,
        report.run.scheduler
    );
    println!("{:<22} {:>9} {:>9}", "candidate", "loss@1", "final");
    let mut ranked: Vec<(usize, f32)> = report
        .losses
        .iter()
        .enumerate()
        .map(|(i, l)| (i, l.last().unwrap().1))
        .collect();
    for (i, (config, lr)) in candidates.into_iter().enumerate() {
        println!(
            "{:<22} {:>9.4} {:>9.4}",
            format!("{config}@{lr}"),
            report.losses[i][0].1,
            report.losses[i].last().unwrap().1
        );
    }
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let (w, wl) = ranked[0];
    println!(
        "\nbest architecture: {}@{} (final loss {wl:.4}, random baseline ln(10)=2.303)",
        candidates[w].0, candidates[w].1
    );
    assert!(wl < 2.303, "winner should beat random baseline");
    println!("nas_vit OK");
    Ok(())
}
