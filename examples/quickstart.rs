//! Quickstart: train two models at once through the `Session` front door.
//!
//! ```bash
//! make artifacts           # once: AOT-compile the JAX/Pallas shards
//! cargo run --release --example quickstart
//! ```
//!
//! Two byte-LM transformers (different learning rates) train concurrently on
//! two virtual devices whose memory is too small to hold a whole model —
//! Hydra partitions them (Algorithm 1), spills shards through DRAM, and
//! blends their schedules with SHARP + Sharded-LRTF + double buffering.

use hydra::coordinator::Cluster;
use hydra::exec::real::RealModelSpec;
use hydra::session::{Backend, Policy, Session};
use hydra::train::optimizer::OptKind;

const MIB: u64 = 1 << 20;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. describe the hardware: 2 devices x 1.5 MiB "GPU memory" (tiny on
    //    purpose: forces real multi-shard spilling), 4 GiB DRAM pool
    let cluster = Cluster::uniform(2, 1536 * 1024, 4096 * MIB);

    // 2. one typed builder picks the backend and policy
    let mut session = Session::builder(cluster)
        .backend(Backend::Real { manifest: "artifacts".into() })
        .policy(Policy::ShardedLrtf)
        .build()?;

    // 3. submit model tasks (the paper's ModelTask registration, Figure 4)
    let mut handles = Vec::new();
    for (i, lr) in [0.05f32, 0.02].into_iter().enumerate() {
        handles.push(session.submit(RealModelSpec {
            name: format!("bert-tiny-lr{lr}"),
            config: "tiny-lm-b8".into(),
            lr,
            opt: OptKind::Sgd,
            epochs: 1,
            minibatches_per_epoch: 8,
            seed: 42 + i as u64,
            inference: false,
            arrival: 0.0,
        })?);
    }

    // 4. train everything
    let report = session.run()?;

    println!("makespan (virtual): {:.2}s", report.run.makespan);
    println!("device utilization: {:.1}%", 100.0 * report.run.utilization);
    println!("shard units executed: {}", report.run.units_executed);
    for (i, h) in handles.iter().enumerate() {
        let losses = report.losses_for(*h).unwrap();
        let first = losses.first().unwrap().1;
        let last = losses.last().unwrap().1;
        println!(
            "model {i}: loss {first:.3} -> {last:.3} over {} minibatches",
            losses.len()
        );
        assert!(last < first, "loss should decrease");
    }
    println!("quickstart OK");
    Ok(())
}
