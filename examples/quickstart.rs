//! Quickstart: train two models at once with the Figure-4 style API.
//!
//! ```bash
//! make artifacts           # once: AOT-compile the JAX/Pallas shards
//! cargo run --release --example quickstart
//! ```
//!
//! Two byte-LM transformers (different learning rates) train concurrently on
//! two virtual devices whose memory is too small to hold a whole model —
//! Hydra partitions them (Algorithm 1), spills shards through DRAM, and
//! blends their schedules with SHARP + Sharded-LRTF + double buffering.

use hydra::coordinator::{Cluster, ModelOrchestrator};
use hydra::exec::real::RealModelSpec;
use hydra::train::optimizer::OptKind;

const MIB: u64 = 1 << 20;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. register model tasks (the paper's ModelTask/ModelOrchestrator API)
    let mut orchestra = ModelOrchestrator::new("artifacts");
    for (i, lr) in [0.05f32, 0.02].into_iter().enumerate() {
        orchestra.add_task(RealModelSpec {
            name: format!("bert-tiny-lr{lr}"),
            config: "tiny-lm-b8".into(),
            lr,
            opt: OptKind::Sgd,
            epochs: 1,
            minibatches_per_epoch: 8,
            seed: 42 + i as u64,
            inference: false,
            arrival: 0.0,
        });
    }

    // 2. describe the hardware: 2 devices x 1.5 MiB "GPU memory" (tiny on
    //    purpose: forces real multi-shard spilling), 4 GiB DRAM pool
    let cluster = Cluster::uniform(2, 1536 * 1024, 4096 * MIB);

    // 3. train everything
    let report = orchestra.train_models(&cluster)?;

    println!("makespan (virtual): {:.2}s", report.run.makespan);
    println!("device utilization: {:.1}%", 100.0 * report.run.utilization);
    println!("shard units executed: {}", report.run.units_executed);
    for (i, losses) in report.losses.iter().enumerate() {
        let first = losses.first().unwrap().1;
        let last = losses.last().unwrap().1;
        println!(
            "model {i}: loss {first:.3} -> {last:.3} over {} minibatches",
            losses.len()
        );
        assert!(last < first, "loss should decrease");
    }
    println!("quickstart OK");
    Ok(())
}
