//! Out-of-the-box model scalability (paper §4.2): train a model whose
//! footprint is ~20x the device's memory on a SINGLE device, purely through
//! model spilling — "even a trillion-parameter DL model can now be trained
//! on a single GPU out of the box, given sufficient DRAM".
//!
//! Uses the medium-lm config (~6.6M params, ~53 MiB of training state with
//! momentum) on a 12 MiB virtual device: Algorithm 1 cuts it into many shards; every unit
//! promotes its shard from DRAM, computes via PJRT, and demotes.
//!
//! ```bash
//! cargo run --release --example single_gpu_large_model [-- --steps 3]
//! ```

use hydra::coordinator::Cluster;
use hydra::exec::real::RealModelSpec;
use hydra::session::{Backend, Policy, Session};
use hydra::train::optimizer::OptKind;
use hydra::util::cli::Args;
use hydra::util::fmt_bytes;

const MIB: u64 = 1 << 20;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env(&[])?;
    let steps = args.opt_usize("steps", 3)? as u32;

    let device_mem = 12 * MIB;
    let cluster = Cluster::uniform(1, device_mem, 8192 * MIB);
    let mut session = Session::builder(cluster)
        .backend(Backend::Real { manifest: "artifacts".into() })
        .policy(Policy::ShardedLrtf)
        .build()?;
    let job = session.submit(RealModelSpec {
        name: "medium-lm".into(),
        config: "medium-lm-b8".into(),
        lr: 0.02,
        opt: OptKind::Momentum { beta: 0.9 },
        epochs: 1,
        minibatches_per_epoch: steps,
        seed: 5,
        inference: false,
        arrival: 0.0,
    })?;

    println!(
        "training one ~6.6M-param model on a single {} device ...",
        fmt_bytes(device_mem)
    );
    let report = session.run()?;

    let losses = report.losses_for(job).unwrap();
    println!(
        "shard units executed: {} ({} shards/pass)",
        report.run.units_executed,
        report.run.units_executed / (2 * steps as u64)
    );
    println!(
        "spill traffic: {} promoted / {} demoted across {} steps",
        fmt_bytes(report.run.promoted_bytes),
        fmt_bytes(report.run.demoted_bytes),
        losses.len()
    );
    println!(
        "loss: {:.4} -> {:.4}",
        losses[0].1,
        losses.last().unwrap().1
    );
    assert!(report.run.units_executed >= 2 * steps as u64 * 4,
        "expected a deeply sharded model");
    assert!(losses.last().unwrap().1 < losses[0].1);
    println!("single_gpu_large_model OK — a model ~5x device memory trained on one device");
    Ok(())
}
