//! Model selection — the paper's primary workload (Table 2 row 1) at
//! CPU-feasible scale, and this repo's END-TO-END VALIDATION driver
//! (DESIGN.md §4 "e2e real", recorded in EXPERIMENTS.md).
//!
//! A hyperparameter grid over a BERT-style byte-LM: {2 batch sizes} x
//! {3 learning rates} = 6 models trained TOGETHER on 2 memory-constrained
//! virtual devices, every shard unit executing the Pallas-bearing AOT HLO
//! via PJRT. Prints per-model loss curves and the winner.
//!
//! ```bash
//! cargo run --release --example model_selection [-- --steps 50]
//! ```

use hydra::coordinator::Cluster;
use hydra::exec::real::RealModelSpec;
use hydra::session::{Backend, Policy, Session};
use hydra::train::optimizer::OptKind;
use hydra::util::cli::Args;

const MIB: u64 = 1 << 20;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env(&[])?;
    let steps = args.opt_usize("steps", 40)? as u32;

    let cluster = Cluster::uniform(2, 1536 * 1024, 8192 * MIB);
    let mut session = Session::builder(cluster)
        .backend(Backend::Real { manifest: "artifacts".into() })
        .policy(Policy::ShardedLrtf)
        .build()?;

    // Table 2-style grid: batch {4, 8} x lr {0.08, 0.04, 0.01}
    let mut names = Vec::new();
    for (bi, config) in ["tiny-lm-b4", "tiny-lm-b8"].into_iter().enumerate() {
        for (li, lr) in [0.08f32, 0.04, 0.01].into_iter().enumerate() {
            let name = format!("{config}-lr{lr}");
            names.push(name.clone());
            session.submit(RealModelSpec {
                name,
                config: config.into(),
                lr,
                opt: OptKind::Momentum { beta: 0.9 },
                epochs: 1,
                minibatches_per_epoch: steps,
                seed: (bi * 3 + li) as u64 + 7,
                inference: false,
                arrival: 0.0,
            })?;
        }
    }

    println!("training {} models for {steps} steps each ...", names.len());
    let t0 = std::time::Instant::now();
    let report = session.run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "\nwallclock {wall:.0}s | virtual makespan {:.1}s | {} shard units | util {:.1}%",
        report.run.makespan,
        report.run.units_executed,
        100.0 * report.run.utilization
    );
    println!(
        "spill traffic: {} promoted / {} demoted\n",
        hydra::util::fmt_bytes(report.run.promoted_bytes),
        hydra::util::fmt_bytes(report.run.demoted_bytes)
    );

    println!(
        "{:<22} {:>9} {:>9} {:>9} {:>9}",
        "model", "loss@1", "loss@25%", "loss@50%", "final"
    );
    let mut best: Option<(usize, f32)> = None;
    for (i, losses) in report.losses.iter().enumerate() {
        let at = |f: f64| losses[((losses.len() - 1) as f64 * f) as usize].1;
        let last = losses.last().unwrap().1;
        println!(
            "{:<22} {:>9.4} {:>9.4} {:>9.4} {:>9.4}",
            names[i],
            losses[0].1,
            at(0.25),
            at(0.5),
            last
        );
        if best.map(|(_, b)| last < b).unwrap_or(true) {
            best = Some((i, last));
        }
    }
    let (wi, wl) = best.unwrap();
    println!("\nselected model: {} (final loss {wl:.4})", names[wi]);

    // e2e validation: the mean final loss must be meaningfully below the
    // random-prediction baseline ln(256) = 5.545
    let mean_final: f32 = report
        .losses
        .iter()
        .map(|l| l.last().unwrap().1)
        .sum::<f32>()
        / report.losses.len() as f32;
    println!("mean final loss {mean_final:.4} (random baseline 5.545)");
    assert!(mean_final < 4.5, "training failed to learn");
    println!("model_selection OK");
    Ok(())
}
