"""AOT pipeline: lower every shard function of every registered config to
HLO *text* and emit artifacts/manifest.json for the Rust runtime.

Interchange format is HLO text, NOT ``lowered.compiler_ir().serialize()``:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Python runs exactly once, at build time (`make artifacts`); the Rust binary
is self-contained afterwards.

Usage:  python -m compile.aot --out-dir ../artifacts [--configs a,b | --all]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import DEFAULT_SET, REGISTRY, ModelConfig
from .kernels.flash_attention import vmem_footprint_bytes as attn_vmem_bytes
from .kernels.fused_ffn import vmem_footprint_bytes as ffn_vmem_bytes

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dtype_str(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(dt).name]


def _io_entry(name, shape, dtype=F32):
    return dict(name=name, shape=list(shape), dtype=_dtype_str(dtype))


def _data_spec(cfg: ModelConfig):
    """(shape, dtype) of the embed shard's data input."""
    if cfg.kind == "lm":
        return (cfg.batch, cfg.seq), I32
    return (cfg.batch, cfg.seq, cfg.patch_dim), F32


def _targets_spec(cfg: ModelConfig):
    if cfg.kind == "lm":
        return (cfg.batch, cfg.seq), I32
    return (cfg.batch,), I32


def shard_entry_points(cfg: ModelConfig):
    """Yield (exe_name, flat_fn, example_args, input_io, output_io).

    Flat functions take/return positional arrays only — the ABI with Rust.
    Convention: parameters first, then data inputs, then cotangents.
    """
    specs = model.param_specs(cfg)
    h_shape = (cfg.batch, cfg.seq, cfg.d_model)
    data_shape, data_dt = _data_spec(cfg)
    tgt_shape, tgt_dt = _targets_spec(cfg)

    e_specs = specs["embed"]
    b_specs = specs["block"]
    h_specs = specs["head"]
    ne, nb, nh = len(e_specs), len(b_specs), len(h_specs)

    def pio(pspecs):
        return [_io_entry(p["name"], p["shape"]) for p in pspecs]

    def gio(pspecs):
        return [_io_entry("d_" + p["name"], p["shape"]) for p in pspecs]

    # -- embed ------------------------------------------------------------
    def embed_fwd_flat(*a):
        return (model.embed_fwd(cfg, a[:ne], a[ne]),)

    yield (
        "embed_fwd", embed_fwd_flat,
        [_spec(p["shape"]) for p in e_specs] + [_spec(data_shape, data_dt)],
        pio(e_specs) + [_io_entry("data", data_shape, data_dt)],
        [_io_entry("h", h_shape)],
    )

    def embed_bwd_flat(*a):
        return tuple(model.embed_bwd(cfg, a[:ne], a[ne], a[ne + 1]))

    yield (
        "embed_bwd", embed_bwd_flat,
        [_spec(p["shape"]) for p in e_specs]
        + [_spec(data_shape, data_dt), _spec(h_shape)],
        pio(e_specs) + [_io_entry("data", data_shape, data_dt),
                        _io_entry("d_h", h_shape)],
        gio(e_specs),
    )

    # -- block ------------------------------------------------------------
    def block_fwd_flat(*a):
        return (model.block_fwd(cfg, a[:nb], a[nb]),)

    yield (
        "block_fwd", block_fwd_flat,
        [_spec(p["shape"]) for p in b_specs] + [_spec(h_shape)],
        pio(b_specs) + [_io_entry("x", h_shape)],
        [_io_entry("y", h_shape)],
    )

    # Reference-ops forward, used ONLY for interior recompute inside a bwd
    # shard unit (EXPERIMENTS.md §Perf L2): numerically equal to block_fwd
    # within kernel==ref tolerance, but free of interpret-mode while-loops.
    def block_fwd_ref_flat(*a):
        return (model.block_fwd(cfg, a[:nb], a[nb], use_pallas=False),)

    yield (
        "block_fwd_ref", block_fwd_ref_flat,
        [_spec(p["shape"]) for p in b_specs] + [_spec(h_shape)],
        pio(b_specs) + [_io_entry("x", h_shape)],
        [_io_entry("y", h_shape)],
    )

    def block_bwd_flat(*a):
        d_x, d_params = model.block_bwd(cfg, a[:nb], a[nb], a[nb + 1])
        return (d_x,) + tuple(d_params)

    yield (
        "block_bwd", block_bwd_flat,
        [_spec(p["shape"]) for p in b_specs]
        + [_spec(h_shape), _spec(h_shape)],
        pio(b_specs) + [_io_entry("x", h_shape), _io_entry("d_y", h_shape)],
        [_io_entry("d_x", h_shape)] + gio(b_specs),
    )

    # -- head -------------------------------------------------------------
    def head_fwd_flat(*a):
        return (model.head_fwd(cfg, a[:nh], a[nh], a[nh + 1]),)

    yield (
        "head_fwd", head_fwd_flat,
        [_spec(p["shape"]) for p in h_specs]
        + [_spec(h_shape), _spec(tgt_shape, tgt_dt)],
        pio(h_specs) + [_io_entry("x", h_shape),
                        _io_entry("targets", tgt_shape, tgt_dt)],
        [_io_entry("loss", ())],
    )

    def head_bwd_flat(*a):
        loss, d_x, d_params = model.head_bwd(cfg, a[:nh], a[nh], a[nh + 1])
        return (loss, d_x) + tuple(d_params)

    yield (
        "head_bwd", head_bwd_flat,
        [_spec(p["shape"]) for p in h_specs]
        + [_spec(h_shape), _spec(tgt_shape, tgt_dt)],
        pio(h_specs) + [_io_entry("x", h_shape),
                        _io_entry("targets", tgt_shape, tgt_dt)],
        [_io_entry("loss", ()), _io_entry("d_x", h_shape)] + gio(h_specs),
    )


def compile_config(cfg: ModelConfig, out_dir: str) -> dict:
    """Lower all shard entry points of one config; return manifest entry."""
    executables = {}
    for name, fn, args, in_io, out_io in shard_entry_points(cfg):
        # keep_unused: gradients like d_tok_emb don't read tok_emb, but the
        # Rust ABI passes every declared input — argument elision would make
        # the compiled parameter list diverge from the manifest.
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}.{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        executables[name] = dict(
            file=fname,
            inputs=in_io,
            outputs=out_io,
            sha256=hashlib.sha256(text.encode()).hexdigest()[:16],
        )
        print(f"  {cfg.name}.{name}: {len(text)} chars, "
              f"{len(in_io)} in / {len(out_io)} out")

    return dict(
        config=cfg.to_dict(),
        params=model.param_specs(cfg),
        executables=executables,
        kernel_vmem_bytes=dict(
            flash_attention=attn_vmem_bytes(cfg.seq, cfg.head_dim),
            fused_ffn=ffn_vmem_bytes(cfg.d_model, cfg.d_ff),
        ),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=",".join(DEFAULT_SET),
                    help="comma-separated config names")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    names = sorted(REGISTRY) if args.all else args.configs.split(",")
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = dict(version=1, configs={})
    for name in names:
        cfg = REGISTRY[name]
        print(f"lowering {name} ...")
        manifest["configs"][name] = compile_config(cfg, args.out_dir)

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(names)} configs)")


if __name__ == "__main__":
    main()
