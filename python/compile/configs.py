"""Model configuration registry shared by the L2 model, the AOT pipeline,
and (via manifest.json) the Rust coordinator.

Each named config fully determines the shapes of every shard executable, so
one compiled artifact family serves every model instance (hyperparameter
grid point, NAS candidate, ...) that shares the config. Learning rate,
optimizer, epochs etc. are runtime-side knobs and never enter the HLO.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Architecture + batch geometry of one transformer family.

    kind:
      - "lm":  byte-level masked/causal-free LM. Inputs are i32 token ids
               of shape (batch, seq); the head computes mean cross-entropy
               against i32 targets of the same shape.
      - "cls": ViT-style classifier. Inputs are f32 patch vectors of shape
               (batch, seq, patch_dim); the head mean-pools and computes
               cross-entropy against i32 labels of shape (batch,).
    """

    name: str
    kind: str  # "lm" | "cls"
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    seq: int
    batch: int
    vocab: int = 256  # lm: vocabulary size; cls: number of classes
    patch_dim: int = 0  # cls only: flattened patch vector length

    def __post_init__(self):
        assert self.kind in ("lm", "cls"), self.kind
        assert self.d_model % self.n_heads == 0
        if self.kind == "cls":
            assert self.patch_dim > 0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_param_arrays_block(self) -> int:
        return 16  # see model.block_param_spec

    def to_dict(self) -> dict:
        return asdict(self)


def _lm(name, d, h, layers, ff, seq, batch, vocab=256):
    return ModelConfig(
        name=name, kind="lm", d_model=d, n_heads=h, n_layers=layers,
        d_ff=ff, seq=seq, batch=batch, vocab=vocab,
    )


def _cls(name, d, h, layers, ff, seq, batch, patch_dim, classes=10):
    return ModelConfig(
        name=name, kind="cls", d_model=d, n_heads=h, n_layers=layers,
        d_ff=ff, seq=seq, batch=batch, vocab=classes, patch_dim=patch_dim,
    )


# The artifact family compiled by `make artifacts`. Names encode batch size
# because batch geometry is baked into the HLO. The e2e examples use the
# tiny/small/medium LM family (BERT-style encoder on a byte corpus) and the
# cls family (ViT-style encoder on synthetic patch images), mirroring the
# paper's two workloads at CPU-feasible scale (see DESIGN.md §1).
REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _lm("tiny-lm-b4", d=64, h=4, layers=4, ff=256, seq=32, batch=4),
        _lm("tiny-lm-b8", d=64, h=4, layers=4, ff=256, seq=32, batch=8),
        _lm("small-lm-b8", d=128, h=4, layers=6, ff=512, seq=64, batch=8),
        _lm("medium-lm-b8", d=256, h=8, layers=8, ff=1024, seq=64, batch=8),
        _lm("large-lm-b8", d=512, h=8, layers=12, ff=2048, seq=64, batch=8),
        _cls("tiny-cls-b8", d=64, h=4, layers=4, ff=256, seq=16, batch=8, patch_dim=48),
        _cls("small-cls-b8", d=128, h=4, layers=6, ff=512, seq=16, batch=8, patch_dim=48),
    ]
}

# Subset compiled by default (`make artifacts`); `--all` compiles everything.
DEFAULT_SET = [
    "tiny-lm-b4",
    "tiny-lm-b8",
    "small-lm-b8",
    "medium-lm-b8",
    "tiny-cls-b8",
    "small-cls-b8",
]


def get(name: str) -> ModelConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown config {name!r}; known: {sorted(REGISTRY)}")
