"""L2: the transformer model as *shard functions* in JAX.

A Hydra shard = a contiguous group of layers. The natural cut points of an
encoder transformer are [embed][block]*n[head] (paper §2.1 "model shards"),
so we expose exactly those three shard kinds, each as a pure function over
flat parameter tuples plus data, in both forward and backward form. Every
function here is AOT-lowered by aot.py into its own HLO artifact; all blocks
of a config share one artifact because parameters are runtime arguments.

Backward convention (paper §4.6): only shard-boundary activations are
checkpointed by the coordinator; each *_bwd recomputes its interior. A bwd
shard unit therefore takes (params, saved_input, cotangent) and returns
(d_input, d_params...).

Parameter layout is flat, ordered, and mirrored in param_specs() which
aot.py serialises into manifest.json so the Rust side can allocate and
initialise parameters without Python.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernels
from .configs import ModelConfig

# ---------------------------------------------------------------------------
# Parameter specifications (order matters — it is the ABI with Rust)
# ---------------------------------------------------------------------------


def embed_param_spec(cfg: ModelConfig) -> list[dict]:
    if cfg.kind == "lm":
        return [
            dict(name="tok_emb", shape=[cfg.vocab, cfg.d_model],
                 init=dict(kind="normal", std=0.02)),
            dict(name="pos_emb", shape=[cfg.seq, cfg.d_model],
                 init=dict(kind="normal", std=0.02)),
        ]
    return [
        dict(name="w_patch", shape=[cfg.patch_dim, cfg.d_model],
             init=dict(kind="normal", std=0.02)),
        dict(name="b_patch", shape=[cfg.d_model], init=dict(kind="zeros")),
        dict(name="pos_emb", shape=[cfg.seq, cfg.d_model],
             init=dict(kind="normal", std=0.02)),
    ]


def block_param_spec(cfg: ModelConfig) -> list[dict]:
    d, ff = cfg.d_model, cfg.d_ff
    n = dict(kind="normal", std=0.02)
    z = dict(kind="zeros")
    o = dict(kind="ones")
    return [
        dict(name="ln1_g", shape=[d], init=o),
        dict(name="ln1_b", shape=[d], init=z),
        dict(name="wq", shape=[d, d], init=n),
        dict(name="bq", shape=[d], init=z),
        dict(name="wk", shape=[d, d], init=n),
        dict(name="bk", shape=[d], init=z),
        dict(name="wv", shape=[d, d], init=n),
        dict(name="bv", shape=[d], init=z),
        dict(name="wo", shape=[d, d], init=n),
        dict(name="bo", shape=[d], init=z),
        dict(name="ln2_g", shape=[d], init=o),
        dict(name="ln2_b", shape=[d], init=z),
        dict(name="w1", shape=[d, ff], init=n),
        dict(name="b1", shape=[ff], init=z),
        dict(name="w2", shape=[ff, d], init=n),
        dict(name="b2", shape=[d], init=z),
    ]


def head_param_spec(cfg: ModelConfig) -> list[dict]:
    return [
        dict(name="lnf_g", shape=[cfg.d_model], init=dict(kind="ones")),
        dict(name="lnf_b", shape=[cfg.d_model], init=dict(kind="zeros")),
        dict(name="w_out", shape=[cfg.d_model, cfg.vocab],
             init=dict(kind="normal", std=0.02)),
        dict(name="b_out", shape=[cfg.vocab], init=dict(kind="zeros")),
    ]


def param_specs(cfg: ModelConfig) -> dict[str, list[dict]]:
    return {
        "embed": embed_param_spec(cfg),
        "block": block_param_spec(cfg),
        "head": head_param_spec(cfg),
    }


# ---------------------------------------------------------------------------
# Forward shard functions
# ---------------------------------------------------------------------------


def _ops(use_pallas: bool):
    """Select (layernorm, attention, ffn) implementations.

    Forward shards lower with the Pallas kernels (the L1 hot path lives in
    the fwd HLO). Backward shards recompute their interior with the pure-jnp
    references: gradients are identical up to kernel==ref tolerance (enforced
    by pytest) and the bwd HLO stays free of interpret-mode while-loop
    emulation — an L2 optimization recorded in EXPERIMENTS.md §Perf.
    """
    if use_pallas:
        return kernels.ln, kernels.attention, kernels.ffn
    return (kernels.ref.layernorm_ref, kernels.ref.attention_ref,
            kernels.ref.ffn_ref)


def embed_fwd(cfg: ModelConfig, params: tuple, data) -> jnp.ndarray:
    """LM: data = i32 tokens (batch, seq). CLS: data = f32 patches
    (batch, seq, patch_dim). Returns hidden states (batch, seq, d)."""
    if cfg.kind == "lm":
        tok_emb, pos_emb = params
        return tok_emb[data] + pos_emb[None, :, :]
    w_patch, b_patch, pos_emb = params
    return data @ w_patch + b_patch + pos_emb[None, :, :]


def _split_heads(x, n_heads):
    b, s, d = x.shape
    hd = d // n_heads
    # (b, s, h, hd) -> (b, h, s, hd) -> (b*h, s, hd)
    return x.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3).reshape(
        b * n_heads, s, hd)


def _merge_heads(x, batch, n_heads):
    bh, s, hd = x.shape
    return x.reshape(batch, n_heads, s, hd).transpose(0, 2, 1, 3).reshape(
        batch, s, n_heads * hd)


def block_fwd(cfg: ModelConfig, params: tuple, x: jnp.ndarray,
              use_pallas: bool = True) -> jnp.ndarray:
    """Pre-LN encoder block: x + Attn(LN(x)); then + FFN(LN(.))."""
    ln, attention, ffn = _ops(use_pallas)
    (ln1_g, ln1_b, wq, bq, wk, bk, wv, bv, wo, bo,
     ln2_g, ln2_b, w1, b1, w2, b2) = params
    b, s, d = x.shape

    h = ln(x.reshape(b * s, d), ln1_g, ln1_b).reshape(b, s, d)
    q = _split_heads(h @ wq + bq, cfg.n_heads)
    k = _split_heads(h @ wk + bk, cfg.n_heads)
    v = _split_heads(h @ wv + bv, cfg.n_heads)
    a = _merge_heads(attention(q, k, v), b, cfg.n_heads)
    x = x + a @ wo + bo

    h2 = ln(x.reshape(b * s, d), ln2_g, ln2_b)
    f = ffn(h2, w1, b1, w2, b2).reshape(b, s, d)
    return x + f


def head_fwd(cfg: ModelConfig, params: tuple, x: jnp.ndarray,
             targets, use_pallas: bool = True) -> jnp.ndarray:
    """Final LN + projection + mean cross-entropy loss (scalar).

    LM: targets i32 (batch, seq), loss over every position.
    CLS: targets i32 (batch,), loss over mean-pooled representation.
    """
    ln, _, _ = _ops(use_pallas)
    lnf_g, lnf_b, w_out, b_out = params
    b, s, d = x.shape
    h = ln(x.reshape(b * s, d), lnf_g, lnf_b).reshape(b, s, d)
    if cfg.kind == "cls":
        h = jnp.mean(h, axis=1)  # (b, d)
        logits = h @ w_out + b_out  # (b, classes)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(
            logp, targets[:, None], axis=-1))
    logits = h @ w_out + b_out  # (b, s, vocab)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(
        logp, targets[..., None], axis=-1))


# ---------------------------------------------------------------------------
# Backward shard functions (recompute-inside; see module docstring)
# ---------------------------------------------------------------------------


def embed_bwd(cfg: ModelConfig, params: tuple, data, d_h):
    """Returns d_params (no d_input: embeddings are the first shard)."""
    _, vjp = jax.vjp(lambda p: embed_fwd(cfg, p, data), params)
    (d_params,) = vjp(d_h)
    return d_params


def block_bwd(cfg: ModelConfig, params: tuple, x, d_y):
    """Returns (d_x, d_params)."""
    _, vjp = jax.vjp(
        lambda p, xx: block_fwd(cfg, p, xx, use_pallas=False), params, x)
    d_params, d_x = vjp(d_y)
    return d_x, d_params


def head_bwd(cfg: ModelConfig, params: tuple, x, targets):
    """Returns (loss, d_x, d_params). The head's cotangent is 1.0 (loss)."""
    loss, vjp = jax.vjp(
        lambda p, xx: head_fwd(cfg, p, xx, targets, use_pallas=False),
        params, x)
    d_params, d_x = vjp(jnp.ones_like(loss))
    return loss, d_x, d_params


# ---------------------------------------------------------------------------
# Monolithic reference (test-only): whole model fwd, for composition checks
# ---------------------------------------------------------------------------


def full_fwd(cfg: ModelConfig, embed_params, block_params_list, head_params,
             data, targets):
    h = embed_fwd(cfg, embed_params, data)
    for bp in block_params_list:
        h = block_fwd(cfg, bp, h)
    return head_fwd(cfg, head_params, h, targets)


def init_params(cfg: ModelConfig, key) -> tuple:
    """Test-only JAX-side init (Rust has its own seeded init per manifest)."""
    def mk(spec, k):
        shape = tuple(spec["shape"])
        kind = spec["init"]["kind"]
        if kind == "normal":
            return jax.random.normal(k, shape, jnp.float32) * spec["init"]["std"]
        if kind == "zeros":
            return jnp.zeros(shape, jnp.float32)
        return jnp.ones(shape, jnp.float32)

    specs = param_specs(cfg)
    keys = jax.random.split(key, 3)
    embed = tuple(mk(s, k) for s, k in zip(
        specs["embed"], jax.random.split(keys[0], len(specs["embed"]))))
    blocks = []
    bkeys = jax.random.split(keys[1], cfg.n_layers)
    for bk in bkeys:
        blocks.append(tuple(mk(s, k) for s, k in zip(
            specs["block"], jax.random.split(bk, len(specs["block"])))))
    head = tuple(mk(s, k) for s, k in zip(
        specs["head"], jax.random.split(keys[2], len(specs["head"]))))
    return embed, blocks, head
