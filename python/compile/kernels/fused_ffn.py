"""Fused transformer FFN (matmul -> GELU -> matmul) as a Pallas kernel.

The (R, d_ff) intermediate activation — 4x the residual width — never leaves
the kernel: each row tile computes GELU(x@w1+b1)@w2+b2 with the intermediate
held in VMEM. On GPU this is the classic fused-epilogue trick; on TPU the
BlockSpec row tiling is the analogue (DESIGN.md §2).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile cap; adapts downward to divide the row count. 128 rows amortise
# the weight residency across 4x more output per grid step than 32
# (EXPERIMENTS.md §Perf).
DEFAULT_BLOCK_R = 128


def fit_block(extent: int, cap: int) -> int:
    """Largest power-of-two block <= cap that divides extent (>=1)."""
    b = min(cap, extent)
    while b > 1 and extent % b:
        b //= 2
    return max(b, 1)


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]  # (block_r, d_model)
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = jax.nn.gelu(h + b1_ref[...], approximate=True)
    o = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (o + b2_ref[...]).astype(o_ref.dtype)


def fused_ffn(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
              w2: jnp.ndarray, b2: jnp.ndarray,
              *, block_r: int = DEFAULT_BLOCK_R) -> jnp.ndarray:
    """x: (R, d_model) -> (R, d_model). Matches kernels.ref.ffn_ref."""
    r, d = x.shape
    d_ff = w1.shape[1]
    block_r = fit_block(r, block_r)
    if r % block_r:
        raise ValueError(f"rows {r} must be divisible by block_r {block_r}")

    return pl.pallas_call(
        _ffn_kernel,
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff,), lambda i: (0,)),
            pl.BlockSpec((d_ff, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


def vmem_footprint_bytes(d_model: int, d_ff: int,
                         block_r: int = DEFAULT_BLOCK_R,
                         bytes_per_el: int = 4) -> int:
    """VMEM working set per program: x tile + both weights + intermediate."""
    x_tile = block_r * d_model
    weights = d_model * d_ff + d_ff * d_model + d_ff + d_model
    inter = block_r * d_ff
    out = block_r * d_model
    return (x_tile + weights + inter + out) * bytes_per_el
