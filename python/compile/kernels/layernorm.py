"""Row-tiled LayerNorm as a Pallas kernel: mean/var/normalise in one pass
over a VMEM-resident row tile (no separate reduction kernels)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 256


def fit_block(extent: int, cap: int) -> int:
    """Largest power-of-two block <= cap that divides extent (>=1)."""
    b = min(cap, extent)
    while b > 1 and extent % b:
        b //= 2
    return max(b, 1)
EPS = 1e-5


def _ln_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]  # (block_r, d)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + EPS)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
              *, block_r: int = DEFAULT_BLOCK_R) -> jnp.ndarray:
    """x: (R, d); gamma, beta: (d,). Matches kernels.ref.layernorm_ref."""
    r, d = x.shape
    block_r = fit_block(r, block_r)
    if r % block_r:
        raise ValueError(f"rows {r} must be divisible by block_r {block_r}")

    return pl.pallas_call(
        _ln_kernel,
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=True,
    )(x, gamma, beta)
