"""Flash-attention as a Pallas kernel (L1 hot spot, forward pass).

TPU adaptation of the paper's GPU hot path (DESIGN.md §2): instead of CUDA
threadblocks staging tiles through shared memory, the BlockSpecs express the
HBM->VMEM schedule and the inner loop performs online-softmax accumulation
over K/V tiles so the (S x S) score matrix never materialises. The inner
`q_tile @ k_tile.T` contraction is shaped for the MXU (tile sizes are
multiples of 8/16; f32 under interpret, bf16-ready layout).

Runs under interpret=True only — the CPU PJRT client cannot execute Mosaic
custom-calls. Real-TPU efficiency is estimated from the VMEM footprint in
`vmem_footprint_bytes` (reported by aot.py into the manifest and DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile-size caps. Tiles adapt downward to divide the sequence
# (fit_block); 32 keeps the second-minor dim MXU-friendly while halving the
# grid count vs 16 — a ~1.9x interpret-mode fwd win recorded in
# EXPERIMENTS.md §Perf, and on real TPU fewer/larger MXU issues per tile.
DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32


def fit_block(extent: int, cap: int) -> int:
    """Largest power-of-two block <= cap that divides extent (>=1)."""
    b = min(cap, extent)
    while b > 1 and extent % b:
        b //= 2
    return max(b, 1)

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq: int,
                 scale: float):
    """One (batch*head, q-tile) program: online softmax over K/V tiles."""
    q = q_ref[0]  # (block_q, D) — resident in VMEM for the whole program
    block_q, d = q.shape

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[0, pl.dslice(j * block_k, block_k), :]  # (block_k, D)
        v = v_ref[0, pl.dslice(j * block_k, block_k), :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, seq // block_k, body, (acc0, m0, l0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K) -> jnp.ndarray:
    """Bidirectional attention over (B, S, D) with B = batch*heads.

    Matches kernels.ref.attention_ref numerically (tested to ~1e-5).
    """
    b, s, d = q.shape
    block_q = fit_block(s, block_q)
    block_k = fit_block(s, block_k)
    if s % block_q or s % block_k:
        raise ValueError(f"seq {s} must be divisible by tiles ({block_q},{block_k})")
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _attn_kernel, block_k=block_k, seq=s, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, s // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), q.dtype),
        interpret=True,
    )(q, k, v)


def vmem_footprint_bytes(seq: int, head_dim: int,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         bytes_per_el: int = 4) -> int:
    """Estimated VMEM working set of one program instance.

    q tile + full K + full V + accumulator/softmax state + output tile.
    Used for the real-TPU feasibility estimate in the manifest (must stay
    well under ~16 MiB VMEM).
    """
    block_q = fit_block(seq, block_q)
    q_tile = block_q * head_dim
    kv = 2 * seq * head_dim
    acc = block_q * head_dim + 2 * block_q
    out = block_q * head_dim
    return (q_tile + kv + acc + out) * bytes_per_el
