"""L1 Pallas kernels for the transformer hot spots, with custom-VJP wiring.

Forward passes run the Pallas kernels (interpret=True); backward passes are
jax autodiff of the pure-jnp references in ref.py. Because pytest enforces
kernel == reference to tight tolerances, the resulting gradients are the
gradients of the executed computation. This also keeps the *_bwd shard HLOs
free of the interpret-mode while-loops, which matters for CPU-PJRT runtime
cost (see DESIGN.md §8 L2 notes).
"""

import jax

from . import ref
from .flash_attention import flash_attention
from .fused_ffn import fused_ffn
from .layernorm import layernorm


def _make_custom_vjp(pallas_fn, ref_fn):
    @jax.custom_vjp
    def op(*args):
        return pallas_fn(*args)

    def fwd(*args):
        return pallas_fn(*args), args

    def bwd(args, g):
        _, vjp = jax.vjp(ref_fn, *args)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


#: Differentiable attention: Pallas forward, reference-autodiff backward.
attention = _make_custom_vjp(flash_attention, ref.attention_ref)

#: Differentiable fused FFN.
ffn = _make_custom_vjp(fused_ffn, ref.ffn_ref)

#: Differentiable LayerNorm.
ln = _make_custom_vjp(layernorm, ref.layernorm_ref)

__all__ = [
    "attention", "ffn", "ln",
    "flash_attention", "fused_ffn", "layernorm", "ref",
]
