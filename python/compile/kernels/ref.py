"""Pure-jnp oracles for every Pallas kernel.

These are the single source of truth for numerics: the Pallas kernels must
match them (pytest/hypothesis enforce allclose), and the backward passes of
the L2 shard functions are defined as jax.vjp of *these* references (see
kernels/__init__.py custom_vjp wiring), so gradients are exactly jax
autodiff of the reference semantics.
"""

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Bidirectional scaled dot-product attention.

    q, k, v: (B, S, D) where B = batch * heads, D = head_dim.
    Returns (B, S, D).
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def ffn_ref(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
            w2: jnp.ndarray, b2: jnp.ndarray) -> jnp.ndarray:
    """Feed-forward: GELU(x @ w1 + b1) @ w2 + b2.

    x: (R, d_model); w1: (d_model, d_ff); w2: (d_ff, d_model).
    """
    h = jax.nn.gelu(x @ w1 + b1, approximate=True)
    return h @ w2 + b2


def layernorm_ref(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                  eps: float = 1e-5) -> jnp.ndarray:
    """Row-wise layer normalisation. x: (R, d); gamma, beta: (d,)."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * gamma + beta
