"""L2 correctness: shard-wise execution composes to the monolithic model.

The Rust coordinator chains embed_fwd -> block_fwd* -> head_fwd and then
head_bwd -> block_bwd* -> embed_bwd, passing only boundary activations.
These tests prove that chain equals whole-model forward + jax.grad.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import REGISTRY, ModelConfig, get

TINY = get("tiny-lm-b4")
TINY_CLS = get("tiny-cls-b8")


def _data(cfg: ModelConfig, key):
    kd, kt = jax.random.split(key)
    if cfg.kind == "lm":
        data = jax.random.randint(kd, (cfg.batch, cfg.seq), 0, cfg.vocab)
        targets = jax.random.randint(kt, (cfg.batch, cfg.seq), 0, cfg.vocab)
    else:
        data = jax.random.normal(kd, (cfg.batch, cfg.seq, cfg.patch_dim))
        targets = jax.random.randint(kt, (cfg.batch,), 0, cfg.vocab)
    return data, targets


@pytest.mark.parametrize("cfg", [TINY, TINY_CLS], ids=lambda c: c.name)
def test_shard_forward_composition_matches_full(cfg):
    embed, blocks, head = model.init_params(cfg, jax.random.PRNGKey(0))
    data, targets = _data(cfg, jax.random.PRNGKey(1))

    h = model.embed_fwd(cfg, embed, data)
    for bp in blocks:
        h = model.block_fwd(cfg, bp, h)
    loss_sharded = model.head_fwd(cfg, head, h, targets)

    loss_full = model.full_fwd(cfg, embed, blocks, head, data, targets)
    np.testing.assert_allclose(loss_sharded, loss_full, atol=1e-6, rtol=1e-6)
    assert float(loss_full) > 0.0


@pytest.mark.parametrize("cfg", [TINY, TINY_CLS], ids=lambda c: c.name)
def test_shard_backward_chain_matches_autodiff(cfg):
    """Full backward via shard chain == jax.grad of the monolith."""
    embed, blocks, head = model.init_params(cfg, jax.random.PRNGKey(2))
    data, targets = _data(cfg, jax.random.PRNGKey(3))

    # --- sharded path: checkpoint boundary activations, recompute inside
    acts = [model.embed_fwd(cfg, embed, data)]
    for bp in blocks:
        acts.append(model.block_fwd(cfg, bp, acts[-1]))

    loss, d_x, d_head = model.head_bwd(cfg, head, acts[-1], targets)
    d_blocks = []
    for i in reversed(range(len(blocks))):
        d_x, d_bp = model.block_bwd(cfg, blocks[i], acts[i], d_x)
        d_blocks.append(d_bp)
    d_blocks.reverse()
    d_embed = model.embed_bwd(cfg, embed, data, d_x)

    # --- monolithic autodiff (reference ops for an apples-to-apples graph)
    def full_loss(e, bs, hd):
        h = model.embed_fwd(cfg, e, data)
        for bp in bs:
            h = model.block_fwd(cfg, bp, h, use_pallas=False)
        return model.head_fwd(cfg, hd, h, targets, use_pallas=False)

    loss_ref, grads = jax.value_and_grad(full_loss, argnums=(0, 1, 2))(
        embed, blocks, head)
    g_embed, g_blocks, g_head = grads

    np.testing.assert_allclose(loss, loss_ref, atol=1e-5, rtol=1e-5)
    for a, b in zip(d_embed, g_embed):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-3)
    for a, b in zip(d_head, g_head):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-3)
    for dbp, gbp in zip(d_blocks, g_blocks):
        for a, b in zip(dbp, gbp):
            np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-3)


def test_pallas_and_ref_forward_agree_on_full_model():
    cfg = TINY
    embed, blocks, head = model.init_params(cfg, jax.random.PRNGKey(4))
    data, targets = _data(cfg, jax.random.PRNGKey(5))

    h_p = model.embed_fwd(cfg, embed, data)
    h_r = h_p
    for bp in blocks:
        h_p = model.block_fwd(cfg, bp, h_p, use_pallas=True)
        h_r = model.block_fwd(cfg, bp, h_r, use_pallas=False)
    np.testing.assert_allclose(h_p, h_r, atol=1e-4, rtol=1e-4)


def test_sgd_step_reduces_loss():
    """A few SGD steps on the shard chain reduce the loss (sanity for the
    Rust optimizer's semantics, which mirror this exact update)."""
    cfg = TINY
    embed, blocks, head = model.init_params(cfg, jax.random.PRNGKey(6))
    data, targets = _data(cfg, jax.random.PRNGKey(7))
    lr = 0.05

    def step(embed, blocks, head):
        acts = [model.embed_fwd(cfg, embed, data)]
        for bp in blocks:
            acts.append(model.block_fwd(cfg, bp, acts[-1], use_pallas=False))
        loss, d_x, d_head = model.head_bwd(cfg, head, acts[-1], targets)
        new_blocks = []
        for i in reversed(range(len(blocks))):
            d_x, d_bp = model.block_bwd(cfg, blocks[i], acts[i], d_x)
            new_blocks.append(tuple(
                p - lr * g for p, g in zip(blocks[i], d_bp)))
        new_blocks.reverse()
        d_embed = model.embed_bwd(cfg, embed, data, d_x)
        new_embed = tuple(p - lr * g for p, g in zip(embed, d_embed))
        new_head = tuple(p - lr * g for p, g in zip(head, d_head))
        return float(loss), new_embed, new_blocks, new_head

    losses = []
    for _ in range(4):
        loss, embed, blocks, head = step(embed, blocks, head)
        losses.append(loss)
    assert losses[-1] < losses[0], losses


def test_param_specs_cover_all_shard_kinds():
    for cfg in REGISTRY.values():
        specs = model.param_specs(cfg)
        assert set(specs) == {"embed", "block", "head"}
        assert len(specs["block"]) == cfg.n_param_arrays_block
        for group in specs.values():
            for p in group:
                assert p["init"]["kind"] in ("normal", "zeros", "ones")
                assert all(s > 0 for s in p["shape"])


def test_embed_bwd_scatter_semantics():
    """Token-embedding grads accumulate across repeated tokens."""
    cfg = TINY
    embed, _, _ = model.init_params(cfg, jax.random.PRNGKey(8))
    data = jnp.zeros((cfg.batch, cfg.seq), jnp.int32)  # all token 0
    d_h = jnp.ones((cfg.batch, cfg.seq, cfg.d_model))
    d_tok, d_pos = model.embed_bwd(cfg, embed, data, d_h)
    # every position hit token 0: grad row 0 = batch*seq, rows >0 = 0
    np.testing.assert_allclose(
        d_tok[0], float(cfg.batch * cfg.seq), atol=1e-5)
    np.testing.assert_allclose(d_tok[1:], 0.0, atol=1e-7)
    np.testing.assert_allclose(d_pos, float(cfg.batch), atol=1e-5)
