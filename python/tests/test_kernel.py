"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

This is the CORE correctness signal for the compiled artifacts: the fwd HLOs
embed the Pallas lowering, the bwd HLOs embed autodiff of the references, so
kernel == reference is what makes the two layers consistent.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.flash_attention import flash_attention, vmem_footprint_bytes
from compile.kernels.fused_ffn import fused_ffn
from compile.kernels.layernorm import layernorm

ATOL = 2e-5
RTOL = 2e-5


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def _keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,d", [
    (1, 16, 8), (4, 32, 16), (8, 32, 16), (2, 64, 32), (16, 16, 4),
])
def test_attention_matches_ref(b, s, d):
    kq, kk, kv = _keys(3)
    q, k, v = _rand(kq, b, s, d), _rand(kk, b, s, d), _rand(kv, b, s, d)
    np.testing.assert_allclose(
        flash_attention(q, k, v), ref.attention_ref(q, k, v),
        atol=ATOL, rtol=RTOL)


def test_attention_scale_invariance_of_softmax_shift():
    # Online softmax must be numerically stable for large logits.
    kq, kk, kv = _keys(3, seed=7)
    q = _rand(kq, 2, 16, 8) * 30.0
    k = _rand(kk, 2, 16, 8) * 30.0
    v = _rand(kv, 2, 16, 8)
    out = flash_attention(q, k, v)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v),
                               atol=1e-4, rtol=1e-4)


def test_attention_identity_value_recovery():
    # With one-hot V rows, output rows are convex combinations: rows sum to 1.
    kq, kk = _keys(2, seed=3)
    b, s, d = 2, 16, 16
    q, k = _rand(kq, b, s, d), _rand(kk, b, s, d)
    v = jnp.tile(jnp.eye(s, d)[None], (b, 1, 1))
    out = flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out).sum(-1), 1.0, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    s=st.sampled_from([8, 16, 32, 48, 64]),
    d=st.sampled_from([4, 8, 16, 24, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_hypothesis_sweep(b, s, d, seed):
    kq, kk, kv = _keys(3, seed=seed)
    q, k, v = _rand(kq, b, s, d), _rand(kk, b, s, d), _rand(kv, b, s, d)
    np.testing.assert_allclose(
        flash_attention(q, k, v), ref.attention_ref(q, k, v),
        atol=5e-5, rtol=5e-5)


def test_attention_adapts_tiles_to_awkward_seq():
    # fit_block shrinks the tile until it divides the sequence, so
    # non-power-of-two lengths still run and still match the oracle
    kq, kk, kv = _keys(3, seed=5)
    for s in [24, 40, 23]:
        q, k, v = _rand(kq, 2, s, 8), _rand(kk, 2, s, 8), _rand(kv, 2, s, 8)
        np.testing.assert_allclose(
            flash_attention(q, k, v), ref.attention_ref(q, k, v),
            atol=5e-5, rtol=5e-5)


def test_attention_vmem_under_tpu_budget():
    # Paper-scale geometry must fit the ~16 MiB VMEM class (DESIGN.md §2).
    assert vmem_footprint_bytes(seq=2048, head_dim=64) < 16 * 2**20


# ---------------------------------------------------------------------------
# fused ffn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,d,ff", [
    (32, 16, 64), (64, 64, 256), (256, 64, 256), (128, 32, 96),
])
def test_ffn_matches_ref(r, d, ff):
    kx, k1, k2 = _keys(3, seed=1)
    x = _rand(kx, r, d)
    w1, b1 = _rand(k1, d, ff) * 0.1, jnp.zeros(ff)
    w2, b2 = _rand(k2, ff, d) * 0.1, jnp.full((d,), 0.5)
    np.testing.assert_allclose(
        fused_ffn(x, w1, b1, w2, b2), ref.ffn_ref(x, w1, b1, w2, b2),
        atol=ATOL, rtol=RTOL)


@settings(max_examples=15, deadline=None)
@given(
    r=st.sampled_from([8, 16, 32, 64, 96]),
    d=st.sampled_from([8, 16, 32, 64]),
    ff=st.sampled_from([16, 64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ffn_hypothesis_sweep(r, d, ff, seed):
    kx, k1, k2, kb = _keys(4, seed=seed)
    x = _rand(kx, r, d)
    w1, b1 = _rand(k1, d, ff) * 0.2, _rand(kb, ff) * 0.1
    w2, b2 = _rand(k2, ff, d) * 0.2, jnp.zeros(d)
    np.testing.assert_allclose(
        fused_ffn(x, w1, b1, w2, b2), ref.ffn_ref(x, w1, b1, w2, b2),
        atol=5e-5, rtol=5e-5)


def test_ffn_zero_weights_yield_bias():
    x = _rand(_keys(1)[0], 32, 16)
    w1, b1 = jnp.zeros((16, 32)), jnp.zeros(32)
    w2, b2 = jnp.zeros((32, 16)), jnp.full((16,), 3.0)
    np.testing.assert_allclose(fused_ffn(x, w1, b1, w2, b2), 3.0, atol=1e-6)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,d", [(16, 8), (64, 64), (256, 64), (128, 256)])
def test_layernorm_matches_ref(r, d):
    kx, kg, kb = _keys(3, seed=2)
    x = _rand(kx, r, d) * 3.0
    g, b = 1.0 + _rand(kg, d) * 0.1, _rand(kb, d) * 0.1
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm_ref(x, g, b), atol=ATOL, rtol=RTOL)


def test_layernorm_output_statistics():
    x = _rand(_keys(1, seed=9)[0], 64, 128) * 5 + 2
    out = np.asarray(layernorm(x, jnp.ones(128), jnp.zeros(128)))
    np.testing.assert_allclose(out.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.std(-1), 1.0, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    r=st.sampled_from([8, 16, 64, 128]),
    d=st.sampled_from([4, 8, 32, 64, 128]),
    scale=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_hypothesis_sweep(r, d, scale, seed):
    kx, kg, kb = _keys(3, seed=seed)
    x = _rand(kx, r, d) * scale
    g, b = _rand(kg, d), _rand(kb, d)
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm_ref(x, g, b), atol=5e-5, rtol=5e-4)


# ---------------------------------------------------------------------------
# custom-vjp wrappers: gradients == autodiff of reference
# ---------------------------------------------------------------------------

def test_attention_grad_matches_ref_grad():
    kq, kk, kv = _keys(3, seed=11)
    q, k, v = _rand(kq, 2, 16, 8), _rand(kk, 2, 16, 8), _rand(kv, 2, 16, 8)

    def loss_kernel(q, k, v):
        return jnp.sum(kernels.attention(q, k, v) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_ffn_grad_matches_ref_grad():
    kx, k1, k2 = _keys(3, seed=12)
    x = _rand(kx, 16, 8)
    w1, b1 = _rand(k1, 8, 32) * 0.3, jnp.zeros(32)
    w2, b2 = _rand(k2, 32, 8) * 0.3, jnp.zeros(8)

    gk = jax.grad(lambda *a: jnp.sum(kernels.ffn(*a)), argnums=(0, 1, 3))(
        x, w1, b1, w2, b2)
    gr = jax.grad(lambda *a: jnp.sum(ref.ffn_ref(*a)), argnums=(0, 1, 3))(
        x, w1, b1, w2, b2)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_ln_grad_matches_ref_grad():
    kx, kg = _keys(2, seed=13)
    x, g = _rand(kx, 16, 32), 1.0 + _rand(kg, 32) * 0.2
    b = jnp.zeros(32)
    gk = jax.grad(lambda *a: jnp.sum(jnp.sin(kernels.ln(*a))),
                  argnums=(0, 1, 2))(x, g, b)
    gr = jax.grad(lambda *a: jnp.sum(jnp.sin(ref.layernorm_ref(*a))),
                  argnums=(0, 1, 2))(x, g, b)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, atol=1e-4, rtol=1e-4)
