"""AOT pipeline tests: lowering determinism, manifest shape agreement, and
HLO-text invariants the Rust loader depends on."""

import json
import os

import jax
import pytest

from compile import aot, model
from compile.configs import get

TINY = get("tiny-lm-b4")


@pytest.fixture(scope="module")
def compiled(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entry = aot.compile_config(TINY, str(out))
    return out, entry


def test_all_entry_points_emitted(compiled):
    out, entry = compiled
    assert set(entry["executables"]) == {
        "embed_fwd", "embed_bwd", "block_fwd", "block_fwd_ref", "block_bwd",
        "head_fwd", "head_bwd",
    }
    for exe in entry["executables"].values():
        path = os.path.join(out, exe["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert text.startswith("HloModule"), text[:40]
        assert "ENTRY" in text


def test_manifest_io_matches_param_specs(compiled):
    _, entry = compiled
    specs = model.param_specs(TINY)
    exes = entry["executables"]

    # block_fwd: 16 params + x -> y
    bf = exes["block_fwd"]
    assert len(bf["inputs"]) == len(specs["block"]) + 1
    assert [i["name"] for i in bf["inputs"][:-1]] == [
        p["name"] for p in specs["block"]]
    assert bf["outputs"][0]["shape"] == [TINY.batch, TINY.seq, TINY.d_model]

    # block_bwd: outputs d_x + one grad per param, shapes match params
    bb = exes["block_bwd"]
    assert len(bb["outputs"]) == 1 + len(specs["block"])
    for g, p in zip(bb["outputs"][1:], specs["block"]):
        assert g["shape"] == p["shape"], (g, p)

    # head_bwd: loss scalar + d_x + head grads
    hb = exes["head_bwd"]
    assert hb["outputs"][0]["shape"] == []
    assert hb["outputs"][1]["shape"] == [TINY.batch, TINY.seq, TINY.d_model]

    # embed_fwd data input is i32 tokens for lm
    ef = exes["embed_fwd"]
    assert ef["inputs"][-1]["dtype"] == "i32"


def test_lowering_is_deterministic(compiled, tmp_path):
    _, entry = compiled
    entry2 = aot.compile_config(TINY, str(tmp_path))
    for name in entry["executables"]:
        assert (entry["executables"][name]["sha256"]
                == entry2["executables"][name]["sha256"]), name


def test_fwd_hlo_contains_pallas_bwd_does_not(compiled):
    """Forward shards embed the interpret-mode Pallas lowering (while-loops);
    backward and recompute shards must stay clean XLA (DESIGN.md §8 L2)."""
    out, entry = compiled
    fwd = open(os.path.join(out, entry["executables"]["block_fwd"]["file"])).read()
    bwd = open(os.path.join(out, entry["executables"]["block_bwd"]["file"])).read()
    ref = open(os.path.join(out, entry["executables"]["block_fwd_ref"]["file"])).read()
    assert "while" in fwd  # interpret-mode pallas emits while loops
    assert "while" not in bwd
    assert "while" not in ref


def test_block_fwd_ref_matches_pallas_fwd_io(compiled):
    """The recompute executable is ABI-identical to block_fwd."""
    _, entry = compiled
    a = entry["executables"]["block_fwd"]
    b = entry["executables"]["block_fwd_ref"]
    assert a["inputs"] == b["inputs"]
    assert a["outputs"] == b["outputs"]


def test_kernel_vmem_estimates_present(compiled):
    _, entry = compiled
    vm = entry["kernel_vmem_bytes"]
    assert vm["flash_attention"] > 0
    assert vm["fused_ffn"] > 0
    # must fit the 16 MiB VMEM class at compiled geometry
    assert vm["flash_attention"] < 16 * 2**20
    assert vm["fused_ffn"] < 16 * 2**20


def test_manifest_json_round_trips(compiled, tmp_path):
    _, entry = compiled
    p = tmp_path / "m.json"
    p.write_text(json.dumps({"version": 1, "configs": {TINY.name: entry}}))
    loaded = json.loads(p.read_text())
    assert loaded["configs"][TINY.name]["config"]["d_model"] == TINY.d_model
