//! Baseline execution paradigms (§2.2, §5): from-scratch schedule
//! calculators for the systems Hydra is compared against in Figures 8–10.
//!
//! Each paradigm is an analytical schedule generator over the *same*
//! partitioned ModelTasks and device pool the SHARP engine uses, per the
//! substitution table in DESIGN.md §1: Fig 8 compares execution paradigms,
//! which are fully determined by their schedules over shard units.

use crate::coordinator::sharp::TransferModel;
use crate::coordinator::task::ModelTask;
use crate::error::{HydraError, Result};

/// NVLink-class device-to-device link (the paper's testbed interconnect).
pub fn nvlink() -> TransferModel {
    TransferModel { bandwidth_bytes_per_sec: 50.0e9, latency_secs: 5e-6 }
}

/// Outcome of running a workload under one paradigm.
#[derive(Debug, Clone)]
pub struct ParadigmReport {
    pub name: &'static str,
    pub makespan: f64,
    pub utilization: f64,
}

fn model_compute_secs(t: &ModelTask) -> f64 {
    // remaining_time at construction == total compute
    t.remaining_time()
}

fn total_compute(tasks: &[ModelTask]) -> f64 {
    tasks.iter().map(model_compute_secs).sum()
}

fn unit_sequence_cost(t: &ModelTask) -> Vec<f64> {
    (0..t.total_units())
        .map(|j| {
            let u = t.geometry.unit_at(t.id, j);
            t.shard(u.shard).cost(u.phase)
        })
        .collect()
}

/// Devices needed to hold one model entirely resident (classic MP layout).
fn devices_needed(t: &ModelTask, device_mem: u64) -> usize {
    let shards = &t.shards;
    // first-fit round-robin: shard i -> device i mod g; find min g where
    // every device's share fits
    'outer: for g in 1..=shards.len() {
        let mut loads = vec![0u64; g];
        for (i, s) in shards.iter().enumerate() {
            loads[i % g] += s.param_bytes;
        }
        for l in &loads {
            if *l > device_mem {
                continue 'outer;
            }
        }
        return g;
    }
    usize::MAX
}

/// 1) Strict model parallelism (PyTorch Distributed / DeepSpeed MP):
/// every model's shards are spread across the devices and stay resident;
/// models run one after another; sequential shard dependencies keep exactly
/// one device busy, plus an activation hop between consecutive shards.
pub fn model_parallel(
    tasks: &[ModelTask],
    n_devices: usize,
    device_mem: u64,
    link: TransferModel,
) -> Result<ParadigmReport> {
    let mut makespan = 0.0;
    for t in tasks {
        let need = devices_needed(t, device_mem);
        if need > n_devices {
            return Err(HydraError::DeviceOom {
                device: 0,
                needed: t.total_param_bytes(),
                free: device_mem * n_devices as u64,
            });
        }
        // all units sequential; a cross-shard boundary moves one activation
        // over the device link
        makespan += model_compute_secs(t);
        let hops_per_mb = 2.0 * t.shards.len().saturating_sub(1) as f64;
        let mbs = t.total_units() as f64 / (2.0 * t.shards.len() as f64);
        let hop_bytes = t.shards.iter().map(|s| s.activation_bytes).max().unwrap_or(0);
        makespan += hops_per_mb * mbs * link.secs(hop_bytes);
    }
    Ok(ParadigmReport {
        name: "model-parallel",
        makespan,
        utilization: total_compute(tasks) / (n_devices as f64 * makespan),
    })
}

/// 2) MP + task-parallel hybrid (DeepSpeed MP with concurrent instances):
/// the device pool is split into G = P / devices_per_model groups; each
/// group runs strict MP; models are assigned to groups by LPT.
pub fn mp_task_hybrid(
    tasks: &[ModelTask],
    n_devices: usize,
    device_mem: u64,
    link: TransferModel,
) -> Result<ParadigmReport> {
    let per_model = tasks
        .iter()
        .map(|t| devices_needed(t, device_mem))
        .max()
        .unwrap_or(1);
    if per_model > n_devices {
        return Err(HydraError::DeviceOom {
            device: 0,
            needed: 0,
            free: 0,
        });
    }
    let groups = (n_devices / per_model).max(1);
    // LPT assignment of serial model times to groups
    let mut serial: Vec<f64> = tasks
        .iter()
        .map(|t| {
            let mp = model_parallel(std::slice::from_ref(t), per_model, device_mem, link)?;
            Ok(mp.makespan)
        })
        .collect::<Result<_>>()?;
    serial.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut loads = vec![0.0f64; groups];
    for s in serial {
        let i = (0..groups)
            .min_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap())
            .unwrap();
        loads[i] += s;
    }
    let makespan = loads.iter().cloned().fold(0.0, f64::max);
    Ok(ParadigmReport {
        name: "mp+task",
        makespan,
        utilization: total_compute(tasks) / (n_devices as f64 * makespan),
    })
}

/// 3) MP + data-parallel hybrid (ZeRO/DeepSpeed-style): one model at a time;
/// R = P / devices_per_model replicas consume the epoch's mini-batches in
/// parallel, paying a gradient all-reduce per step.
pub fn mp_data_hybrid(
    tasks: &[ModelTask],
    n_devices: usize,
    device_mem: u64,
    link: TransferModel,
) -> Result<ParadigmReport> {
    let mut makespan = 0.0;
    for t in tasks {
        let need = devices_needed(t, device_mem);
        if need > n_devices {
            return Err(HydraError::DeviceOom { device: 0, needed: 0, free: 0 });
        }
        let replicas = (n_devices / need).max(1) as f64;
        let serial = model_parallel(std::slice::from_ref(t), need, device_mem, link)?;
        // ring all-reduce of gradients once per step: 2 * params bytes
        let mbs = t.total_units() as f64 / (2.0 * t.shards.len() as f64);
        let allreduce = if replicas > 1.0 {
            2.0 * t.total_param_bytes() as f64
                / nlink_bw(link)
                * (replicas - 1.0)
                / replicas
        } else {
            0.0
        };
        makespan += serial.makespan / replicas + mbs / replicas * allreduce;
    }
    Ok(ParadigmReport {
        name: "mp+data",
        makespan,
        utilization: total_compute(tasks) / (n_devices as f64 * makespan),
    })
}

fn nlink_bw(link: TransferModel) -> f64 {
    link.bandwidth_bytes_per_sec
}

/// 4) Synchronous pipeline parallelism (GPipe): partition count and
/// microbatch count equal the GPU count (the paper's §5 configuration);
/// models run one after another; each mini-batch pays the (S-1)-slot fill
/// and drain bubbles of Figure 3.
pub fn pipeline(
    tasks: &[ModelTask],
    n_devices: usize,
    _device_mem: u64,
    _link: TransferModel,
) -> Result<ParadigmReport> {
    let s = n_devices as f64; // stages
    let m = n_devices as f64; // microbatches
    let mut makespan = 0.0;
    for t in tasks {
        let units = unit_sequence_cost(t);
        let per_mb: f64 = units
            .iter()
            .take(2 * t.shards.len())
            .sum();
        let mbs = t.total_units() as f64 / (2.0 * t.shards.len() as f64);
        // uniform stage split: stage time per microbatch = per_mb / (S * M);
        // synchronous fwd+bwd schedule fills and drains twice per minibatch
        let t_mb = (m + s - 1.0) * per_mb / (s * m);
        makespan += mbs * t_mb;
    }
    Ok(ParadigmReport {
        name: "pipeline",
        makespan,
        utilization: total_compute(tasks) / (n_devices as f64 * makespan),
    })
}

/// 5) Pure task parallelism (Cerebro/Ray-style): whole model per device.
/// Errors with OOM when the model (params + optimizer + full backprop
/// activation footprint, no checkpointing) exceeds device memory — the
/// paper's "we cannot even benchmark against them" case.
pub fn task_parallel(
    tasks: &[ModelTask],
    n_devices: usize,
    device_mem: u64,
    full_activation_bytes: &[u64],
) -> Result<ParadigmReport> {
    for (t, &act) in tasks.iter().zip(full_activation_bytes) {
        let resident = t.total_param_bytes() + act;
        if resident > device_mem {
            return Err(HydraError::DeviceOom {
                device: 0,
                needed: resident,
                free: device_mem,
            });
        }
    }
    // LPT over devices
    let mut serial: Vec<f64> = tasks.iter().map(model_compute_secs).collect();
    serial.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut loads = vec![0.0f64; n_devices];
    for s in serial {
        let i = (0..n_devices)
            .min_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap())
            .unwrap();
        loads[i] += s;
    }
    let makespan = loads.iter().cloned().fold(0.0, f64::max);
    Ok(ParadigmReport {
        name: "task-parallel",
        makespan,
        utilization: total_compute(tasks) / (n_devices as f64 * makespan),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::ShardDesc;

    const GIB: u64 = 1 << 30;

    fn mk_tasks(n: usize, shards: usize, cost: f64) -> Vec<ModelTask> {
        (0..n)
            .map(|i| {
                let sd: Vec<ShardDesc> = (0..shards)
                    .map(|_| ShardDesc {
                        param_bytes: GIB,
                        fwd_transfer_bytes: GIB / 3,
                        bwd_transfer_bytes: GIB / 3,
                        activation_bytes: 4 << 20,
                        fwd_cost: cost,
                        bwd_cost: 2.0 * cost,
                        n_layers: 1,
                    })
                    .collect();
                ModelTask::new(i, format!("m{i}"), "sim", sd, 2, 1, 1e-3)
            })
            .collect()
    }

    #[test]
    fn model_parallel_utilization_is_one_over_p() {
        let tasks = mk_tasks(4, 4, 1.0);
        let r = model_parallel(&tasks, 8, 2 * GIB, TransferModel::zero_cost()).unwrap();
        // sequential everything: makespan = total work
        let total: f64 = tasks.iter().map(|t| t.remaining_time()).sum();
        assert!((r.makespan - total).abs() < 1e-9);
        assert!((r.utilization - 1.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn mp_task_splits_into_groups() {
        // each model needs 2 devices (4 shards x 1GiB, 2GiB devices)
        let tasks = mk_tasks(4, 4, 1.0);
        let mp = model_parallel(&tasks, 8, 2 * GIB, TransferModel::zero_cost()).unwrap();
        let ht = mp_task_hybrid(&tasks, 8, 2 * GIB, TransferModel::zero_cost()).unwrap();
        // 4 groups of 2 -> 4 models concurrently: ~4x faster than MP
        assert!(
            (mp.makespan / ht.makespan - 4.0).abs() < 0.2,
            "mp {} ht {}",
            mp.makespan,
            ht.makespan
        );
    }

    #[test]
    fn pipeline_beats_mp_but_has_bubbles() {
        let tasks = mk_tasks(4, 8, 1.0);
        let mp = model_parallel(&tasks, 8, 8 * GIB, TransferModel::zero_cost()).unwrap();
        let pp = pipeline(&tasks, 8, 8 * GIB, TransferModel::zero_cost()).unwrap();
        let speedup = mp.makespan / pp.makespan;
        // GPipe with S=M=8: speedup = P * M/(M+S-1) = 8 * 8/15 ≈ 4.27
        assert!((speedup - 4.27).abs() < 0.3, "speedup {speedup}");
        assert!(pp.utilization > 0.4 && pp.utilization < 0.65, "{}", pp.utilization);
    }

    #[test]
    fn task_parallel_ooms_on_large_models() {
        let tasks = mk_tasks(2, 4, 1.0); // 4 GiB params
        let acts = vec![GIB; 2];
        let err = task_parallel(&tasks, 8, 2 * GIB, &acts);
        assert!(matches!(err, Err(HydraError::DeviceOom { .. })));
    }

    #[test]
    fn task_parallel_lpt_when_models_fit() {
        let tasks = mk_tasks(4, 1, 1.0); // 1 GiB models on 4 GiB devices
        let acts = vec![0u64; 4];
        let r = task_parallel(&tasks, 2, 4 * GIB, &acts).unwrap();
        // 4 models x 6s serial on 2 devices -> 12s
        assert!((r.makespan - 12.0).abs() < 1e-9, "{}", r.makespan);
        assert!((r.utilization - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mp_data_speedup_bounded_by_replicas() {
        let tasks = mk_tasks(2, 4, 1.0);
        let mp = model_parallel(&tasks, 8, 2 * GIB, TransferModel::zero_cost()).unwrap();
        let dp = mp_data_hybrid(&tasks, 8, 2 * GIB, nvlink()).unwrap();
        let speedup = mp.makespan / dp.makespan;
        assert!(speedup > 2.0 && speedup <= 4.0 + 1e-9, "{speedup}");
    }

    #[test]
    fn infeasible_mp_is_oom() {
        // 4 shards of 1 GiB on 2 devices of 1 GiB: needs 4 devices
        let tasks = mk_tasks(1, 4, 1.0);
        assert!(matches!(
            model_parallel(&tasks, 2, GIB, TransferModel::zero_cost()),
            Err(HydraError::DeviceOom { .. })
        ));
    }
}
