//! The one front door to Hydra: a typed [`Session`] builder that unifies
//! the simulated and real execution backends, typed scheduler policies, and
//! streaming observation of a run.
//!
//! Everything the crate can do — paper-scale simulation, real PJRT
//! training, online multi-tenant streams over heterogeneous pools,
//! elasticity/fault injection — is expressed as one pipeline:
//!
//! ```text
//! Session::builder(cluster)        // hardware: Cluster (uniform or mixed)
//!     .backend(Backend::..)        // Sim { noise, seed } | Real { manifest } | Custom(..)
//!     .policy(Policy::..)          // typed scheduler enum (FromStr for CLIs)
//!     .options(EngineOptions::..)  // SHARP knobs
//!     .nvme(TierSpec::..)          // optional NVMe backing tier below DRAM
//!     .build()?                    // validates the cluster
//!     .submit(spec)? -> JobHandle  // pre-partitioned ModelTask or RealModelSpec
//!     .run()? / .run_with(&mut impl EngineObserver)?
//! ```
//!
//! [`JobHandle`]s subsume the raw `JobEvent::Submit`/`Cancel` wiring:
//! [`Session::submit_at`] schedules a mid-run submission, bringing online
//! job streams to the real backend too, [`Session::cancel_at`] schedules a
//! tenant cancellation, and [`SessionReport::job`] looks up the per-job
//! outcome after the run. The deprecated
//! [`crate::coordinator::ModelOrchestrator`] delegates here.

use std::fmt;

use crate::coordinator::durability::replay::DurableTap;
use crate::coordinator::durability::wal::WalRecord;
use crate::coordinator::durability::{run_durable, DurabilityOptions, RunSpec, WalWriter};
use crate::coordinator::memory::{MemoryOptions, TierSpec};
use crate::coordinator::observer::EngineObserver;
use crate::coordinator::partitioner::PartitionPolicy;
use crate::coordinator::sharp::{
    ClusterEvent, EngineOptions, JobEvent, JobStat, QueueKind, RunReport,
    ShardSection, SharpEngine, ShardedEngine,
};
use crate::coordinator::task::ModelTask;
use crate::coordinator::Cluster;
use crate::error::{HydraError, Result};
use crate::exec::real::{MedianRule, RealBackend, RealModelSpec};
use crate::exec::{ExecutionBackend, SimBackend};

pub use crate::coordinator::sched::Policy;

/// Which execution substrate a [`Session`] drives. The engine's scheduling,
/// spilling and buffering decisions are identical across backends — only
/// where unit durations come from differs.
pub enum Backend {
    /// Discrete-event cost model ([`SimBackend`]): unit duration = the
    /// `ShardDesc` estimate, optionally perturbed by `noise` (0.0 =
    /// deterministic) from a stream seeded with `seed`. Takes
    /// pre-partitioned [`ModelTask`] submissions.
    Sim {
        /// Relative noise amplitude (0.05 = ±5% per unit).
        noise: f64,
        /// Seed of the backend's noise stream.
        seed: u64,
    },
    /// Real PJRT execution ([`RealBackend`]): pilot runs + Algorithm-1
    /// partitioning against the cluster's smallest device, then every shard
    /// unit executes its AOT HLO. Takes [`RealModelSpec`] submissions
    /// naming configs in the artifact manifest at `manifest`.
    Real {
        /// Directory of the artifact manifest (`artifacts/` by default).
        manifest: String,
    },
    /// A caller-provided backend (scripted tests, custom cost models).
    /// Takes pre-partitioned [`ModelTask`] submissions like `Sim`.
    Custom(Box<dyn ExecutionBackend>),
}

impl Backend {
    /// The deterministic simulation backend (no noise, seed 0) — what the
    /// figure/bench paths use.
    pub fn sim() -> Backend {
        Backend::Sim { noise: 0.0, seed: 0 }
    }
}

impl fmt::Debug for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Backend::Sim { noise, seed } => {
                write!(f, "Sim {{ noise: {noise}, seed: {seed} }}")
            }
            Backend::Real { manifest } => write!(f, "Real {{ manifest: {manifest:?} }}"),
            Backend::Custom(_) => f.write_str("Custom(..)"),
        }
    }
}

/// One job submission: either a pre-partitioned task (sim/custom backends)
/// or a manifest-config spec the real backend pilots and partitions itself.
/// [`Session::submit`] accepts both via `Into<JobSpec>`.
#[derive(Debug, Clone)]
pub enum JobSpec {
    /// A pre-partitioned model task (see [`crate::sim::build_tasks`] and
    /// friends for paper-scale builders). Its `id` is reassigned by the
    /// session; its arrival time is honoured.
    Task(ModelTask),
    /// A real-backend training/inference spec naming an artifact config.
    Model(RealModelSpec),
}

impl From<ModelTask> for JobSpec {
    fn from(t: ModelTask) -> JobSpec {
        JobSpec::Task(t)
    }
}

impl From<RealModelSpec> for JobSpec {
    fn from(s: RealModelSpec) -> JobSpec {
        JobSpec::Model(s)
    }
}

impl JobSpec {
    fn name(&self) -> &str {
        match self {
            JobSpec::Task(t) => &t.name,
            JobSpec::Model(s) => &s.name,
        }
    }
}

/// Handle to a submitted job: cancel it ([`Session::cancel_at`]) and look
/// up its outcome after the run ([`SessionReport::job`],
/// [`SessionReport::losses_for`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobHandle(usize);

impl JobHandle {
    /// Submission index within the session (not necessarily the engine's
    /// model id — mid-run submissions are renumbered into arrival order).
    pub fn index(self) -> usize {
        self.0
    }
}

/// Builder for a [`Session`]; start with [`Session::builder`].
#[derive(Debug)]
pub struct SessionBuilder {
    cluster: Cluster,
    backend: Backend,
    policy: Policy,
    options: EngineOptions,
    memory: Option<MemoryOptions>,
    partition_policy: PartitionPolicy,
    early_stop_median_after: Option<u32>,
    durability: Option<DurabilityOptions>,
}

impl SessionBuilder {
    /// Select the execution backend (default: deterministic sim).
    pub fn backend(mut self, backend: Backend) -> SessionBuilder {
        self.backend = backend;
        self
    }

    /// Select the scheduling policy (default: [`Policy::ShardedLrtf`]).
    pub fn policy(mut self, policy: Policy) -> SessionBuilder {
        self.policy = policy;
        self
    }

    /// Set the SHARP engine options (mode, double-buffering, transfer
    /// model, event-queue discipline, ...).
    pub fn options(mut self, options: EngineOptions) -> SessionBuilder {
        self.options = options;
        self
    }

    /// Set the prefetch-pipeline depth: how many upcoming units the
    /// scheduler pre-claims per device (§4.6 generalized). 1 — the
    /// default — is the paper's classic double buffer; with an NVMe
    /// backing tier, higher depths overlap the NVMe->DRAM and DRAM->HBM
    /// legs of different slots so multi-hop DRAM-miss chains hide behind
    /// more than one compute span. The prefetch zone size is unchanged;
    /// k is additionally bounded by what fits the zone. Call after
    /// [`SessionBuilder::options`] (which replaces the whole options
    /// struct).
    pub fn prefetch_depth(mut self, depth: usize) -> SessionBuilder {
        self.options.prefetch_depth = depth;
        self
    }

    /// Select the event-queue discipline (default: [`QueueKind::Heap`]).
    /// All disciplines pop the identical `(time, seq)` order;
    /// [`QueueKind::Calendar`] is tuned for storm workloads with heavy
    /// same-timestamp churn. Call after [`SessionBuilder::options`]
    /// (which replaces the whole options struct).
    pub fn queue(mut self, queue: QueueKind) -> SessionBuilder {
        self.options.queue = queue;
        self
    }

    /// Partition the cluster into `n` independent coordinator shards
    /// (ROADMAP item 1): jobs are routed to shards by a stable hash of the
    /// job id through bounded mailboxes, each shard runs its own event
    /// loop over its own device slice / DRAM split / prefetch pipelines,
    /// and the merged report (plus [`SessionReport::shard_sections`])
    /// comes back. `n = 1` — the default — is the single global engine;
    /// sharding requires the sim/custom backends. Call after
    /// [`SessionBuilder::options`] (which replaces the whole options
    /// struct).
    pub fn shards(mut self, n: usize) -> SessionBuilder {
        self.options.shards = n;
        self
    }

    /// Run the shard engines on one scoped OS thread per shard instead of
    /// the sequential shard loop ([`EngineOptions::threads`]). The merged
    /// report and observer stream are byte-identical to sequential
    /// execution; only wall-clock changes. Requires a backend that can fork
    /// an independent per-shard copy (the noiseless sim backend can; noisy
    /// and real backends cannot) — refused with a config error at run time
    /// otherwise. No effect at `shards == 1`. Call after
    /// [`SessionBuilder::options`] (which replaces the whole options
    /// struct).
    pub fn threads(mut self, threads: bool) -> SessionBuilder {
        self.options.threads = threads;
        self
    }

    /// Enable admission-time work stealing between shards
    /// ([`EngineOptions::stealing`]): deep admission queues rebalance into
    /// shallow ones through a capacity-checked steal handshake before any
    /// shard starts, and every migration is recorded in
    /// [`RunReport::stolen`]. Off by default so the hash-routed placement
    /// stays byte-identical. No effect at `shards == 1`. Call after
    /// [`SessionBuilder::options`] (which replaces the whole options
    /// struct).
    pub fn stealing(mut self, stealing: bool) -> SessionBuilder {
        self.options.stealing = stealing;
        self
    }

    /// Override the host-memory hierarchy (DRAM size + optional NVMe
    /// backing tier). The default derives DRAM from the cluster
    /// (`Cluster::dram_bytes`) with no NVMe tier — the legacy two-tier
    /// setup.
    pub fn memory(mut self, memory: MemoryOptions) -> SessionBuilder {
        self.memory = Some(memory);
        self
    }

    /// Add an NVMe backing tier below the cluster's DRAM, so model sets
    /// whose aggregate parameters exceed DRAM still run (DRAM becomes an
    /// evicting cache; see [`crate::coordinator::memory`]).
    pub fn nvme(mut self, tier: TierSpec) -> SessionBuilder {
        let dram = self
            .memory
            .map(|m| m.dram_bytes)
            .unwrap_or(self.cluster.dram_bytes);
        self.memory = Some(MemoryOptions::with_nvme(dram, tier));
        self
    }

    /// Set the Algorithm-1 partitioning knobs (real backend only; sim
    /// submissions arrive pre-partitioned).
    pub fn partition_policy(mut self, policy: PartitionPolicy) -> SessionBuilder {
        self.partition_policy = policy;
        self
    }

    /// Enable AutoML-style median early stopping after `min_epochs`
    /// (real backend, §4.7.2).
    pub fn early_stop_median_after(mut self, min_epochs: u32) -> SessionBuilder {
        self.early_stop_median_after = Some(min_epochs);
        self
    }

    /// Make the run durable: write an event WAL (and, with
    /// [`DurabilityOptions::snapshot_every`], periodic engine-state
    /// snapshots) so the run can be replayed byte-identically or recovered
    /// after a crash via [`crate::coordinator::durability::recover`].
    /// Requires the sim or custom backend — the real backend's measured
    /// wallclock is not replayable.
    pub fn durability(mut self, durability: DurabilityOptions) -> SessionBuilder {
        self.durability = Some(durability);
        self
    }

    /// Validate the cluster and produce the [`Session`].
    pub fn build(self) -> Result<Session> {
        self.cluster.validate()?;
        // more shards than devices would round-robin empty device slices
        // into deviceless shard engines — jobs routed there could never run
        if self.options.shards > self.cluster.devices.len() {
            return Err(HydraError::Config(format!(
                "{} shards over {} devices (each shard needs at least one device)",
                self.options.shards,
                self.cluster.devices.len()
            )));
        }
        let memory = self
            .memory
            .unwrap_or(MemoryOptions::dram_only(self.cluster.dram_bytes));
        Ok(Session {
            cluster: self.cluster,
            backend: self.backend,
            policy: self.policy,
            options: self.options,
            memory,
            partition_policy: self.partition_policy,
            early_stop_median_after: self.early_stop_median_after,
            durability: self.durability,
            jobs: Vec::new(),
            cancels: Vec::new(),
            cluster_events: Vec::new(),
        })
    }
}

struct Job {
    spec: JobSpec,
    /// `None` = known at construction (its own arrival time still gates
    /// eligibility); `Some(t)` = submitted to the engine mid-run at `t`.
    submit_at: Option<f64>,
}

/// A configured run: submit jobs, then [`Session::run`] (or
/// [`Session::run_with`] to stream engine events through an observer).
///
/// ```
/// use hydra::coordinator::task::{ModelTask, ShardDesc};
/// use hydra::coordinator::Cluster;
/// use hydra::session::{Backend, Policy, Session};
///
/// # fn main() -> hydra::Result<()> {
/// let shard = ShardDesc {
///     param_bytes: 1 << 20,
///     fwd_transfer_bytes: 1 << 20,
///     bwd_transfer_bytes: 1 << 20,
///     activation_bytes: 1 << 10,
///     fwd_cost: 1.0,
///     bwd_cost: 2.0,
///     n_layers: 1,
/// };
/// let mut session = Session::builder(Cluster::uniform(2, 1 << 30, 8 << 30))
///     .backend(Backend::Sim { noise: 0.0, seed: 0 })
///     .policy(Policy::ShardedLrtf)
///     .build()?;
/// let job = session.submit(ModelTask::new(0, "demo", "sim", vec![shard], 2, 1, 1e-3))?;
/// let report = session.run()?;
/// // 1 shard x 2 mini-batches x (fwd + bwd) = 4 units
/// assert_eq!(report.job(job).unwrap().units_executed, 4);
/// assert!(report.run.makespan > 0.0);
/// # Ok(())
/// # }
/// ```
pub struct Session {
    cluster: Cluster,
    backend: Backend,
    policy: Policy,
    options: EngineOptions,
    memory: MemoryOptions,
    partition_policy: PartitionPolicy,
    early_stop_median_after: Option<u32>,
    durability: Option<DurabilityOptions>,
    jobs: Vec<Job>,
    /// (job index, virtual time) cancellations.
    cancels: Vec<(usize, f64)>,
    cluster_events: Vec<ClusterEvent>,
}

impl Session {
    /// The cluster this session schedules over.
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Swap the execution backend, returning the previous one. Crate-only:
    /// the selection driver uses it to wrap the configured backend with
    /// trial bookkeeping ([`crate::selection`]).
    pub(crate) fn replace_backend(&mut self, backend: Backend) -> Backend {
        std::mem::replace(&mut self.backend, backend)
    }

    /// The configured engine options (crate-only: the selection driver
    /// sizes trial shards against the session's real buffer zone).
    pub(crate) fn engine_options(&self) -> &EngineOptions {
        &self.options
    }

    /// Run a whole hyperparameter search on this session: every trial of
    /// `search` is submitted via [`Session::submit_at`], per-epoch losses
    /// stream through a [`crate::selection::TrialMonitor`], and
    /// successive-halving searchers prune rung losers mid-run so freed
    /// HBM/DRAM/NVMe immediately benefits the surviving trials.
    ///
    /// The session must be fresh (no jobs submitted) and drive a sim or
    /// custom backend — trial loss curves are synthetic
    /// ([`crate::selection::SynthLoss`]).
    pub fn run_search(
        self,
        search: &crate::selection::Search,
    ) -> Result<crate::selection::SearchReport> {
        crate::selection::driver::drive_search(self, search)
    }

    /// Start building a session over `cluster`.
    pub fn builder(cluster: Cluster) -> SessionBuilder {
        SessionBuilder {
            cluster,
            backend: Backend::sim(),
            policy: Policy::default(),
            options: EngineOptions::default(),
            memory: None,
            partition_policy: PartitionPolicy::default(),
            early_stop_median_after: None,
            durability: None,
        }
    }

    /// Submit a job known up front. Its arrival time (if any) still gates
    /// when it becomes eligible — this is the batch *and* the
    /// arrivals-known-in-advance online setting.
    pub fn submit(&mut self, spec: impl Into<JobSpec>) -> Result<JobHandle> {
        self.push_job(spec.into(), None)
    }

    /// Submit a job the engine first learns about at virtual `time` — a
    /// tenant showing up mid-run. Equivalent to the engine-level
    /// `JobEvent::Submit`, with ids managed for you.
    pub fn submit_at(&mut self, spec: impl Into<JobSpec>, time: f64) -> Result<JobHandle> {
        if !time.is_finite() || time < 0.0 {
            return Err(HydraError::Config(format!("bad submission time {time}")));
        }
        self.push_job(spec.into(), Some(time))
    }

    fn push_job(&mut self, spec: JobSpec, submit_at: Option<f64>) -> Result<JobHandle> {
        match (&self.backend, &spec) {
            (Backend::Real { .. }, JobSpec::Task(_)) => {
                return Err(HydraError::Config(format!(
                    "job {:?}: the real backend takes RealModelSpec submissions \
                     (pre-partitioned ModelTasks carry no artifact config)",
                    spec.name()
                )));
            }
            (Backend::Sim { .. } | Backend::Custom(_), JobSpec::Model(_)) => {
                return Err(HydraError::Config(format!(
                    "job {:?}: a RealModelSpec needs Backend::Real {{ manifest }}; \
                     sim/custom backends take pre-partitioned ModelTasks",
                    spec.name()
                )));
            }
            _ => {}
        }
        let handle = JobHandle(self.jobs.len());
        self.jobs.push(Job { spec, submit_at });
        Ok(handle)
    }

    /// Schedule a tenant cancellation of `job` at virtual `time`.
    /// Unit-granular and idempotent: an in-flight unit completes, the rest
    /// drop. Cancelling an already-finished job is a defined no-op — the
    /// request is still recorded in the report
    /// ([`crate::coordinator::sharp::JobStat::cancel_requested`]) while
    /// `cancelled` stays false; double cancels keep the earliest time.
    pub fn cancel_at(&mut self, job: JobHandle, time: f64) -> Result<()> {
        if !time.is_finite() || time < 0.0 {
            return Err(HydraError::Config(format!("bad cancellation time {time}")));
        }
        if job.0 >= self.jobs.len() {
            return Err(HydraError::Config(format!(
                "cancel of unknown job handle {} (this session has {} jobs — \
                 handle from another session?)",
                job.0,
                self.jobs.len()
            )));
        }
        self.cancels.push((job.0, time));
        Ok(())
    }

    /// Inject cluster elasticity / fault events (§4.7's dynamic setting).
    pub fn cluster_events(&mut self, events: Vec<ClusterEvent>) {
        self.cluster_events.extend(events);
    }

    /// Number of submitted jobs.
    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Run to completion. Per-interval trace recording follows
    /// [`EngineOptions::record_intervals`] (on by default — disable for
    /// very long simulations).
    pub fn run(self) -> Result<SessionReport> {
        self.run_inner(None)
    }

    /// Run to completion, streaming every engine event (decisions, spills,
    /// retired units, job arrivals/finishes, intervals) through `obs` as
    /// they happen in virtual time. Trace recording into the report still
    /// follows [`EngineOptions::record_intervals`]; the observer is fed
    /// either way.
    pub fn run_with<O: EngineObserver>(self, obs: &mut O) -> Result<SessionReport> {
        self.run_inner(Some(obs))
    }

    fn run_inner(self, obs: Option<&mut dyn EngineObserver>) -> Result<SessionReport> {
        let Session {
            cluster,
            backend,
            policy,
            options,
            memory,
            partition_policy,
            early_stop_median_after,
            durability,
            jobs,
            cancels,
            cluster_events,
        } = self;
        // cluster already validated at SessionBuilder::build
        if jobs.is_empty() {
            return Err(HydraError::Config("no jobs submitted".into()));
        }
        if options.shards == 0 {
            return Err(HydraError::Config("shards must be >= 1".into()));
        }
        if options.shards > 1 && matches!(backend, Backend::Real { .. }) {
            return Err(HydraError::Config(
                "shards > 1 requires the sim/custom backend (the real PJRT \
                 backend drives one global coordinator)"
                    .into(),
            ));
        }
        if durability.is_some() && matches!(backend, Backend::Real { .. }) {
            return Err(HydraError::Config(
                "durability requires the sim/custom backend (the real \
                 backend's measured wallclock is not replayable)"
                    .into(),
            ));
        }
        // a NaN time would poison the event queue's (time, seq) total
        // order — the same boundary check submit_at/cancel_at make
        for ev in &cluster_events {
            let time = match ev {
                ClusterEvent::Arrive { time, .. } | ClusterEvent::Fail { time, .. } => *time,
            };
            if !time.is_finite() || time < 0.0 {
                return Err(HydraError::Config(format!(
                    "bad cluster-event time {time}"
                )));
            }
        }

        // Engine model ids: construction jobs first in submission order,
        // then mid-run submissions in (time, submission order) — the
        // engine's ids-follow-submission-order contract.
        let n = jobs.len();
        let submit_times: Vec<Option<f64>> = jobs.iter().map(|j| j.submit_at).collect();
        let mut order: Vec<usize> = (0..n).filter(|&j| submit_times[j].is_none()).collect();
        let n_construction = order.len();
        let mut deferred: Vec<usize> = (0..n).filter(|&j| submit_times[j].is_some()).collect();
        deferred.sort_by(|&a, &b| {
            submit_times[a]
                .unwrap()
                .total_cmp(&submit_times[b].unwrap())
                .then(a.cmp(&b))
        });
        order.extend(&deferred);
        let mut model_of_job = vec![0usize; n];
        for (m, &j) in order.iter().enumerate() {
            model_of_job[j] = m;
        }
        for &(j, time) in &cancels {
            if let Some(st) = submit_times[j] {
                if time < st {
                    return Err(HydraError::Config(format!(
                        "job {:?}: cancellation at {time} precedes its mid-run \
                         submission at {st}",
                        jobs[j].spec.name()
                    )));
                }
            }
        }
        let cancel_events: Vec<JobEvent> = cancels
            .iter()
            .map(|&(j, time)| JobEvent::Cancel { time, model: model_of_job[j] })
            .collect();
        let mut specs: Vec<Option<JobSpec>> = jobs.into_iter().map(|j| Some(j.spec)).collect();

        match backend {
            Backend::Real { manifest } => {
                // Build *all* specs (construction + mid-run) in engine-id
                // order so backend states align with model ids; split the
                // built tasks into construction tasks and Submit events.
                let mut ordered: Vec<RealModelSpec> = Vec::with_capacity(n);
                for &j in &order {
                    match specs[j].take() {
                        Some(JobSpec::Model(s)) => ordered.push(s),
                        _ => unreachable!("validated at submit"),
                    }
                }
                let (mut real, mut tasks) = RealBackend::build(
                    &manifest,
                    &ordered,
                    cluster.min_device_mem(),
                    partition_policy,
                )?;
                if let Some(min_epochs) = early_stop_median_after {
                    real.early_stop = Some(MedianRule { min_epochs });
                }
                let mut job_events: Vec<JobEvent> = tasks
                    .split_off(n_construction)
                    .into_iter()
                    .zip(&deferred)
                    .map(|(task, &j)| JobEvent::Submit {
                        time: submit_times[j].unwrap(),
                        task,
                    })
                    .collect();
                job_events.extend(cancel_events);
                let run = drive(
                    &mut real,
                    tasks,
                    &cluster,
                    memory,
                    policy,
                    options,
                    cluster_events,
                    job_events,
                    obs,
                )?;
                let losses = (0..n).map(|m| real.loss_log(m).to_vec()).collect();
                Ok(SessionReport {
                    run,
                    losses,
                    model_of_job,
                    shard_sections: Vec::new(),
                })
            }
            sim_or_custom => {
                let mut tasks: Vec<ModelTask> = Vec::with_capacity(n_construction);
                let mut job_events: Vec<JobEvent> = Vec::with_capacity(n - n_construction);
                for (m, &j) in order.iter().enumerate() {
                    let mut task = match specs[j].take() {
                        Some(JobSpec::Task(t)) => t,
                        _ => unreachable!("validated at submit"),
                    };
                    task.id = m;
                    match submit_times[j] {
                        None => tasks.push(task),
                        Some(time) => job_events.push(JobEvent::Submit { time, task }),
                    }
                }
                job_events.extend(cancel_events);
                let (run, shard_sections) = match (sim_or_custom, durability) {
                    // The fully durable path: the complete run recipe
                    // becomes the WAL's genesis record, every event is
                    // logged, snapshots interleave with the event loop.
                    (Backend::Sim { noise, seed }, Some(dur)) => {
                        let spec = RunSpec {
                            tasks,
                            devices: cluster.devices.clone(),
                            memory,
                            policy,
                            options,
                            cluster_events,
                            job_events,
                            noise,
                            backend_seed: seed,
                        };
                        run_durable(&spec, &dur, obs)?
                    }
                    (Backend::Sim { noise, seed }, None) => drive_any(
                        &mut SimBackend::new(noise, seed),
                        tasks,
                        &cluster,
                        memory,
                        policy,
                        options,
                        cluster_events,
                        job_events,
                        obs,
                    )?,
                    // Custom backends can't be serialized into a genesis,
                    // so durability degrades to record-only append mode:
                    // events land in the WAL after whatever genesis its
                    // creator wrote (e.g. a search's spec JSON).
                    (Backend::Custom(mut custom), Some(dur)) => {
                        let mut tap = DurableTap {
                            wal: WalWriter::append_to(&dur.wal)?,
                            rec: None,
                            user: obs,
                        };
                        let (run, sections) = drive_any(
                            &mut *custom,
                            tasks,
                            &cluster,
                            memory,
                            policy,
                            options,
                            cluster_events,
                            job_events,
                            Some(&mut tap),
                        )?;
                        tap.wal.append(&WalRecord::RunEnd { makespan: run.makespan });
                        tap.wal.finish()?;
                        (run, sections)
                    }
                    (Backend::Custom(mut custom), None) => drive_any(
                        &mut *custom,
                        tasks,
                        &cluster,
                        memory,
                        policy,
                        options,
                        cluster_events,
                        job_events,
                        obs,
                    )?,
                    (Backend::Real { .. }, _) => unreachable!("handled above"),
                };
                Ok(SessionReport {
                    run,
                    losses: Vec::new(),
                    model_of_job,
                    shard_sections,
                })
            }
        }
    }
}

/// Construct the engine over `cluster` and run it; the engine's
/// `run_observed` owns the `record_intervals` trace wiring.
#[allow(clippy::too_many_arguments)]
fn drive(
    backend: &mut dyn ExecutionBackend,
    tasks: Vec<ModelTask>,
    cluster: &Cluster,
    memory: MemoryOptions,
    policy: Policy,
    options: EngineOptions,
    cluster_events: Vec<ClusterEvent>,
    job_events: Vec<JobEvent>,
    obs: Option<&mut dyn EngineObserver>,
) -> Result<RunReport> {
    let mut engine = SharpEngine::with_devices(
        tasks,
        &cluster.devices,
        memory,
        policy.build(),
        backend,
        options,
    )?
    .with_cluster_events(cluster_events)
    .with_job_events(job_events);
    engine.run_observed(obs)
}

/// Dispatch between the single global engine (`shards == 1`, via [`drive`])
/// and the sharded multi-coordinator engine (`shards > 1`); the sharded
/// path additionally returns the per-shard sections.
#[allow(clippy::too_many_arguments)]
fn drive_any(
    backend: &mut dyn ExecutionBackend,
    tasks: Vec<ModelTask>,
    cluster: &Cluster,
    memory: MemoryOptions,
    policy: Policy,
    options: EngineOptions,
    cluster_events: Vec<ClusterEvent>,
    job_events: Vec<JobEvent>,
    obs: Option<&mut dyn EngineObserver>,
) -> Result<(RunReport, Vec<ShardSection>)> {
    if options.shards > 1 {
        let report = ShardedEngine::with_devices(
            tasks,
            &cluster.devices,
            memory,
            policy,
            backend,
            options,
        )?
        .with_cluster_events(cluster_events)
        .with_job_events(job_events)
        .run_observed(obs)?;
        Ok((report.merged, report.sections))
    } else {
        let run = drive(
            backend,
            tasks,
            cluster,
            memory,
            policy,
            options,
            cluster_events,
            job_events,
            obs,
        )?;
        Ok((run, Vec::new()))
    }
}

/// Everything a caller can inspect after [`Session::run`].
#[derive(Debug, Clone)]
pub struct SessionReport {
    /// Engine-level schedule report (makespan, utilization, per-job stats,
    /// trace when interval recording is on).
    pub run: RunReport,
    /// Per-model loss logs in engine-id order (real backend; empty for
    /// sim/custom runs). Prefer [`SessionReport::losses_for`].
    pub losses: Vec<Vec<(u64, f32)>>,
    /// Per-shard report sections when the run was sharded
    /// ([`SessionBuilder::shards`] with n > 1); empty for single-engine
    /// runs. `run` holds the merged cluster totals either way.
    pub shard_sections: Vec<ShardSection>,
    /// Engine model id per submission index.
    model_of_job: Vec<usize>,
}

impl SessionReport {
    /// Engine model id a handle resolved to (mid-run submissions are
    /// renumbered into arrival order).
    pub fn model_of(&self, job: JobHandle) -> Option<usize> {
        self.model_of_job.get(job.0).copied()
    }

    /// Per-job outcome: arrival, finish, latency, cancellation, units.
    pub fn job(&self, job: JobHandle) -> Option<&JobStat> {
        self.model_of(job).and_then(|m| self.run.jobs.get(m))
    }

    /// The job's loss log (real backend runs).
    pub fn losses_for(&self, job: JobHandle) -> Option<&[(u64, f32)]> {
        self.model_of(job)
            .and_then(|m| self.losses.get(m))
            .map(Vec::as_slice)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sharp::TransferModel;
    use crate::coordinator::task::ShardDesc;

    fn task(name: &str, mbs: u32, cost: f64) -> ModelTask {
        let sd = vec![ShardDesc {
            param_bytes: 1 << 20,
            fwd_transfer_bytes: 1 << 20,
            bwd_transfer_bytes: 1 << 20,
            activation_bytes: 1 << 10,
            fwd_cost: cost,
            bwd_cost: 2.0 * cost,
            n_layers: 1,
        }];
        // session reassigns ids; 999 proves that
        ModelTask::new(999, name, "sim", sd, mbs, 1, 1e-3)
    }

    fn zero_transfer() -> EngineOptions {
        EngineOptions { transfer: TransferModel::zero_cost(), ..Default::default() }
    }

    #[test]
    fn empty_cluster_is_rejected_at_build() {
        let err = Session::builder(Cluster::heterogeneous(vec![], 1 << 30))
            .build()
            .unwrap_err();
        assert!(matches!(err, HydraError::Config(_)), "{err:?}");
    }

    #[test]
    fn no_jobs_is_a_config_error() {
        let s = Session::builder(Cluster::uniform(1, 1 << 30, 4 << 30))
            .build()
            .unwrap();
        assert!(s.run().is_err());
    }

    #[test]
    fn submit_reassigns_ids_and_handles_look_up_jobs() {
        let mut s = Session::builder(Cluster::uniform(1, 1 << 30, 4 << 30))
            .options(zero_transfer())
            .build()
            .unwrap();
        let a = s.submit(task("a", 1, 1.0)).unwrap();
        let b = s.submit(task("b", 2, 1.0)).unwrap();
        assert_eq!(s.n_jobs(), 2);
        let r = s.run().unwrap();
        assert_eq!(r.model_of(a), Some(0));
        assert_eq!(r.job(a).unwrap().name, "a");
        assert_eq!(r.job(b).unwrap().units_executed, 4);
    }

    #[test]
    fn real_spec_on_sim_backend_is_rejected() {
        use crate::train::optimizer::OptKind;
        let mut s = Session::builder(Cluster::uniform(1, 1 << 30, 4 << 30))
            .build()
            .unwrap();
        let err = s
            .submit(RealModelSpec {
                name: "x".into(),
                config: "tiny-lm-b8".into(),
                lr: 0.01,
                opt: OptKind::Sgd,
                epochs: 1,
                minibatches_per_epoch: 1,
                seed: 0,
                inference: false,
                arrival: 0.0,
                tenant: 0,
                weight: 1.0,
                deadline: None,
            })
            .unwrap_err();
        assert!(matches!(err, HydraError::Config(_)), "{err:?}");
    }

    #[test]
    fn task_on_real_backend_is_rejected() {
        let mut s = Session::builder(Cluster::uniform(1, 1 << 30, 4 << 30))
            .backend(Backend::Real { manifest: "artifacts".into() })
            .build()
            .unwrap();
        assert!(s.submit(task("t", 1, 1.0)).is_err());
    }

    #[test]
    fn submit_at_and_cancel_at_wire_job_events() {
        let mut s = Session::builder(Cluster::uniform(1, 1 << 30, 4 << 30))
            .options(zero_transfer())
            .build()
            .unwrap();
        let a = s.submit(task("a", 2, 1.0)).unwrap(); // 6s of work
        let late = s.submit_at(task("late", 1, 1.0), 2.0).unwrap(); // 3s
        s.cancel_at(a, 100.0).unwrap(); // after completion: no-op
        assert!(s.cancel_at(a, f64::NAN).is_err());
        assert!(s.cancel_at(a, -1.0).is_err());
        // a handle from a different (larger) session is rejected, not a panic
        assert!(s.cancel_at(JobHandle(99), 1.0).is_err());
        let r = s.run().unwrap();
        assert_eq!(r.model_of(late), Some(1));
        let lj = r.job(late).unwrap();
        assert_eq!(lj.arrival, 2.0);
        assert!((lj.finished - 9.0).abs() < 1e-9, "{lj:?}");
        assert!(!r.job(a).unwrap().cancelled);
    }

    #[test]
    fn mid_run_submissions_renumber_into_arrival_order() {
        let mut s = Session::builder(Cluster::uniform(2, 1 << 30, 4 << 30))
            .options(zero_transfer())
            .build()
            .unwrap();
        let _base = s.submit(task("base", 2, 1.0)).unwrap();
        // submitted out of time order: handles keep call order, engine ids
        // follow submission-time order
        let second = s.submit_at(task("second", 1, 1.0), 5.0).unwrap();
        let first = s.submit_at(task("first", 1, 1.0), 1.0).unwrap();
        let r = s.run().unwrap();
        assert_eq!(r.model_of(first), Some(1));
        assert_eq!(r.model_of(second), Some(2));
        assert_eq!(r.job(first).unwrap().name, "first");
        assert_eq!(r.job(second).unwrap().name, "second");
    }

    #[test]
    fn cancel_before_mid_run_submission_is_rejected() {
        let mut s = Session::builder(Cluster::uniform(1, 1 << 30, 4 << 30))
            .options(zero_transfer())
            .build()
            .unwrap();
        let _a = s.submit(task("a", 1, 1.0)).unwrap();
        let late = s.submit_at(task("late", 1, 1.0), 5.0).unwrap();
        s.cancel_at(late, 1.0).unwrap();
        assert!(s.run().is_err());
    }

    #[test]
    fn nvme_tier_runs_model_sets_that_exceed_dram() {
        // three 1 MiB-param models over 2 MiB of DRAM: rejected without an
        // NVMe tier, completes (with NVMe traffic) when one is configured
        let mk = |nvme: Option<TierSpec>| {
            let mut b = Session::builder(Cluster::uniform(1, 1 << 30, 2 << 20))
                .options(zero_transfer());
            if let Some(t) = nvme {
                b = b.nvme(t);
            }
            let mut s = b.build().unwrap();
            for i in 0..3 {
                s.submit(task(&format!("m{i}"), 1, 1.0)).unwrap();
            }
            s.run()
        };
        let err = mk(None).unwrap_err();
        assert!(matches!(err, HydraError::Exec(_)), "{err:?}");
        assert!(format!("{err}").contains("NVMe"), "{err}");
        let r = mk(Some(TierSpec::nvme(1 << 30))).unwrap();
        // 3 models x 1 shard x 1 mini-batch x (fwd + bwd)
        assert_eq!(r.run.units_executed, 6);
        assert!(r.run.nvme_promoted_bytes > 0, "{:?}", r.run.nvme_promoted_bytes);
    }

    #[test]
    fn prefetch_depth_threads_through_and_zero_is_rejected() {
        let mk = |depth: usize| {
            let mut s = Session::builder(Cluster::uniform(1, 1 << 30, 4 << 30))
                .options(zero_transfer())
                .prefetch_depth(depth)
                .build()
                .unwrap();
            s.submit(task("a", 2, 1.0)).unwrap();
            s.submit(task("b", 1, 1.0)).unwrap();
            s.run()
        };
        let r = mk(3).unwrap();
        assert_eq!(r.run.units_executed, 6);
        // depth 0 is meaningless and rejected at engine construction
        let err = mk(0).unwrap_err();
        assert!(matches!(err, HydraError::Config(_)), "{err:?}");
        assert!(format!("{err}").contains("prefetch_depth"), "{err}");
    }

    #[test]
    fn queue_kind_threads_through_and_reports_are_identical() {
        let mk = |queue: QueueKind| {
            let mut s = Session::builder(Cluster::uniform(2, 1 << 30, 4 << 30))
                .options(zero_transfer())
                .queue(queue)
                .build()
                .unwrap();
            s.submit(task("a", 2, 1.0)).unwrap();
            s.submit(task("b", 3, 1.0)).unwrap();
            s.run().unwrap()
        };
        let heap = mk(QueueKind::Heap);
        let scan = mk(QueueKind::LinearScan);
        let cal = mk(QueueKind::Calendar);
        assert_eq!(format!("{:?}", heap.run), format!("{:?}", scan.run));
        assert_eq!(format!("{:?}", heap.run), format!("{:?}", cal.run));
    }

    #[test]
    fn custom_backend_drives_execution() {
        struct Fixed;
        impl ExecutionBackend for Fixed {
            fn execute_unit(
                &mut self,
                _task: &ModelTask,
                _unit: &crate::coordinator::unit::ShardUnit,
            ) -> Result<f64> {
                Ok(0.5)
            }
        }
        let mut s = Session::builder(Cluster::uniform(1, 1 << 30, 4 << 30))
            .backend(Backend::Custom(Box::new(Fixed)))
            .options(zero_transfer())
            .build()
            .unwrap();
        s.submit(task("c", 2, 1.0)).unwrap();
        let r = s.run().unwrap();
        // 4 units x 0.5s each, ignoring the ShardDesc costs
        assert!((r.run.makespan - 2.0).abs() < 1e-9, "{}", r.run.makespan);
    }

    #[test]
    fn run_with_streams_events_and_respects_record_intervals() {
        #[derive(Default)]
        struct Counting {
            arrived: usize,
            finished: usize,
            retired: usize,
            decisions: usize,
            intervals: usize,
        }
        impl EngineObserver for Counting {
            fn on_job_arrived(&mut self, _m: usize, _n: &str, _t: f64) {
                self.arrived += 1;
            }
            fn on_job_finished(&mut self, _m: usize, _t: f64, _c: bool) {
                self.finished += 1;
            }
            fn on_unit_retired(
                &mut self,
                _d: usize,
                _u: &crate::coordinator::unit::ShardUnit,
                _t: f64,
            ) {
                self.retired += 1;
            }
            fn on_decision(&mut self, _d: usize, _m: usize, _p: bool, _t: f64) {
                self.decisions += 1;
            }
            fn on_interval(&mut self, _iv: &crate::coordinator::metrics::Interval) {
                self.intervals += 1;
            }
        }
        let mk = |record: bool| {
            let mut s = Session::builder(Cluster::uniform(2, 1 << 30, 4 << 30))
                .options(EngineOptions { record_intervals: record, ..zero_transfer() })
                .build()
                .unwrap();
            s.submit(task("a", 2, 1.0)).unwrap();
            s.submit(task("b", 1, 1.0)).unwrap();
            let mut c = Counting::default();
            let r = s.run_with(&mut c).unwrap();
            (r, c)
        };
        let (r_on, c_on) = mk(true);
        assert_eq!(c_on.arrived, 2);
        assert_eq!(c_on.finished, 2);
        assert_eq!(c_on.retired, 6);
        assert!(c_on.decisions >= 6);
        assert_eq!(c_on.intervals, r_on.run.trace.intervals.len());
        let (r_off, c_off) = mk(false);
        // observer still sees every interval; the report trace stays empty
        assert_eq!(c_off.intervals, c_on.intervals);
        assert!(r_off.run.trace.intervals.is_empty());
        assert_eq!(r_off.run.makespan, r_on.run.makespan);
    }
}
