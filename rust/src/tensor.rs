//! Host-side tensors: the coordinator's representation of parameters,
//! activations, gradients and data batches while they live in DRAM
//! (the paper's "spilled" tier). Conversion to/from `xla::Literal` happens
//! only at device promotion time (runtime::literal).

use crate::util::rng::Rng;

/// Element type of a host tensor. Only the two types the model ABI uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        4
    }

    pub fn parse(s: &str) -> Result<DType, String> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(format!("unsupported dtype {other:?}")),
        }
    }
}

/// A dense host tensor (row-major). Scalars have an empty shape.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn zeros(shape: &[usize], dtype: DType) -> HostTensor {
        let n: usize = shape.iter().product();
        let data = match dtype {
            DType::F32 => TensorData::F32(vec![0.0; n]),
            DType::I32 => TensorData::I32(vec![0; n]),
        };
        HostTensor { shape: shape.to_vec(), data }
    }

    pub fn ones(shape: &[usize]) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor { shape: shape.to_vec(), data: TensorData::F32(vec![1.0; n]) }
    }

    pub fn normal(shape: &[usize], std: f32, rng: &mut Rng) -> HostTensor {
        let n: usize = shape.iter().product();
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, std);
        HostTensor { shape: shape.to_vec(), data: TensorData::F32(v) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.element_count() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    /// First element as f32 (for scalar losses).
    pub fn scalar_value(&self) -> f32 {
        self.as_f32()[0]
    }

    /// L2 norm (diagnostics / gradient clipping).
    pub fn l2_norm(&self) -> f32 {
        match &self.data {
            TensorData::F32(v) => v.iter().map(|x| x * x).sum::<f32>().sqrt(),
            TensorData::I32(v) => (v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() as f32).sqrt(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_and_bytes() {
        let t = HostTensor::zeros(&[2, 3], DType::F32);
        assert_eq!(t.element_count(), 6);
        assert_eq!(t.size_bytes(), 24);
        assert!(t.as_f32().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scalar_has_empty_shape() {
        let t = HostTensor::scalar_f32(3.5);
        assert!(t.shape.is_empty());
        assert_eq!(t.element_count(), 1);
        assert_eq!(t.scalar_value(), 3.5);
    }

    #[test]
    fn normal_respects_std() {
        let mut rng = Rng::new(1);
        let t = HostTensor::normal(&[10_000], 0.02, &mut rng);
        let v = t.as_f32();
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        let var: f32 = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / v.len() as f32;
        assert!(mean.abs() < 0.001, "{mean}");
        assert!((var.sqrt() - 0.02).abs() < 0.002, "{}", var.sqrt());
    }

    #[test]
    #[should_panic]
    fn from_f32_checks_length() {
        HostTensor::from_f32(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn dtype_accessors_guard() {
        let t = HostTensor::from_i32(&[2], vec![1, 2]);
        assert_eq!(t.dtype(), DType::I32);
        assert_eq!(t.as_i32(), &[1, 2]);
    }

    #[test]
    fn l2_norm_matches_hand_value() {
        let t = HostTensor::from_f32(&[2], vec![3.0, 4.0]);
        assert!((t.l2_norm() - 5.0).abs() < 1e-6);
    }
}
