//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §4 experiment index). Each function returns a printable
//! report plus CSV rows; the `hydra figure <id>` subcommand and the bench
//! harness both route through here.

use std::time::Duration;

use crate::baselines;
use crate::coordinator::memory::TierSpec;
use crate::coordinator::sched::bnb;
use crate::coordinator::sharp::{EngineOptions, ParallelMode, RunReport, TransferModel};
use crate::coordinator::task::{ModelTask, ShardDesc};
use crate::coordinator::Cluster;
use crate::error::Result;
use crate::selection::{Algo, Search, SearchSpace};
use crate::session::{Backend, Policy, Session};
use crate::sim::{bert_grid, build_tasks, uniform_grid, vit_grid, GpuSpec};
use crate::util::rng::Rng;

/// A rendered figure/table: human-readable rows + CSV for plotting.
#[derive(Debug, Clone)]
pub struct FigureOutput {
    pub id: &'static str,
    pub title: String,
    pub lines: Vec<String>,
    pub csv: String,
}

impl FigureOutput {
    pub fn print(&self) {
        println!("=== {}: {} ===", self.id, self.title);
        for l in &self.lines {
            println!("{l}");
        }
        println!();
    }

    pub fn write_csv(&self, dir: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{}.csv", self.id);
        std::fs::write(path, &self.csv)?;
        Ok(())
    }
}

const DRAM: u64 = 500 << 30; // paper machine: 500 GB DRAM

/// Paper-scale partition/buffer policy: the prefetch zone must hold a full
/// shard's transferable weights for double-buffering to engage (the paper's
/// 5% claim assumes activation-dominated shards; at 1B-params/11GB the
/// weights are the dominant term, so we protect 30%). The partitioner and
/// the engine share the same fraction.
const PAPER_BUFFER_FRAC: f64 = 0.30;

fn paper_policy() -> crate::coordinator::partitioner::PartitionPolicy {
    crate::coordinator::partitioner::PartitionPolicy {
        buffer_frac: PAPER_BUFFER_FRAC,
        ..Default::default()
    }
}

/// Drive a pre-built task set through a simulated [`Session`] — the single
/// engine-construction path every figure/table uses.
fn sim_run(
    tasks: Vec<ModelTask>,
    cluster: Cluster,
    policy: Policy,
    options: EngineOptions,
) -> Result<RunReport> {
    sim_run_tiered(tasks, cluster, policy, options, None)
}

/// [`sim_run`] with an optional NVMe backing tier below the cluster's DRAM
/// (the `ext_hierarchy` sweep and the Table 3 hierarchy arm use it).
fn sim_run_tiered(
    tasks: Vec<ModelTask>,
    cluster: Cluster,
    policy: Policy,
    options: EngineOptions,
    nvme: Option<TierSpec>,
) -> Result<RunReport> {
    let mut builder = Session::builder(cluster)
        .backend(Backend::sim())
        .policy(policy)
        .options(options);
    if let Some(tier) = nvme {
        builder = builder.nvme(tier);
    }
    let mut session = builder.build()?;
    for t in tasks {
        session.submit(t)?;
    }
    Ok(session.run()?.run)
}

/// Run the Hydra engine on a task set with the simulated backend at the
/// paper's buffer/transfer settings. A thin [`Session`] wrapper.
pub fn run_hydra(
    tasks: Vec<ModelTask>,
    n_devices: usize,
    device_mem: u64,
    mode: ParallelMode,
    double_buffer: bool,
    policy: Policy,
) -> Result<RunReport> {
    let opts = EngineOptions {
        mode,
        double_buffer,
        buffer_frac: PAPER_BUFFER_FRAC,
        transfer: TransferModel::pcie_gen3(),
        record_intervals: false,
        ..Default::default()
    };
    sim_run(tasks, Cluster::uniform(n_devices, device_mem, DRAM), policy, opts)
}

fn hours(secs: f64) -> String {
    format!("{:7.2}h", secs / 3600.0)
}

// ---------------------------------------------------------------------------
// Figure 7 — scheduler comparison (normalised makespans)
// ---------------------------------------------------------------------------

/// Build a Fig-7 style abstract instance as single-shard ModelTasks.
fn fig7_tasks(hetero: bool, n_models: usize, seed: u64) -> Vec<ModelTask> {
    let mut rng = Rng::new(seed);
    (0..n_models)
        .map(|i| {
            // homogeneous: 2h per-model runtime over 2000 units;
            // heterogeneous: 0.5-4h over 100-10000 units (paper §4.7.3)
            let (total_secs, units) = if hetero {
                (rng.range_f64(0.5, 4.0) * 3600.0, rng.range_u64(100, 10_000))
            } else {
                (2.0 * 3600.0, 2000)
            };
            let units = (units / 2).max(1); // fwd+bwd pairs
            let per_unit = total_secs / (2 * units) as f64;
            let sd = vec![ShardDesc {
                param_bytes: 1 << 30,
                fwd_transfer_bytes: 0,
                bwd_transfer_bytes: 0,
                activation_bytes: 1 << 20,
                fwd_cost: per_unit,
                bwd_cost: per_unit,
                n_layers: 1,
            }];
            ModelTask::new(i, format!("m{i}"), "fig7", sd, units as u32, 1, 1e-3)
        })
        .collect()
}

fn tasks_to_problem(tasks: &[ModelTask], devices: usize) -> bnb::Problem {
    bnb::Problem {
        units: tasks
            .iter()
            .map(|t| {
                (0..t.total_units())
                    .map(|j| {
                        let u = t.geometry.unit_at(t.id, j);
                        t.shard(u.shard).cost(u.phase)
                    })
                    .collect()
            })
            .collect(),
        devices,
    }
}

/// Figure 7: Sharded-LRTF vs Random vs MILP(BnB, time-budgeted) across
/// homogeneous and heterogeneous settings. Makespans normalised to the BnB
/// incumbent (like the paper, the "optimal" may not have converged — the
/// solver warm-starts from FIFO and keeps its best incumbent).
pub fn fig7(bnb_budget: Duration) -> Result<FigureOutput> {
    let mut lines = vec![format!(
        "{:<14} {:>7} {:>7} {:>9} {:>9} {:>9}",
        "setting", "models", "devices", "lrtf", "random", "milp"
    )];
    let mut csv = String::from("setting,models,devices,lrtf,random,milp\n");
    for &hetero in &[false, true] {
        for &(n_models, devices) in &[(4usize, 4usize), (8, 8), (16, 8)] {
            let mk = |policy: Policy, seed: u64| -> Result<f64> {
                let tasks = fig7_tasks(hetero, n_models, 7);
                let opts = EngineOptions {
                    transfer: TransferModel::zero_cost(),
                    double_buffer: false,
                    record_intervals: false,
                    seed,
                    ..Default::default()
                };
                let cluster = Cluster::uniform(devices, 16 << 30, DRAM);
                Ok(sim_run(tasks, cluster, policy, opts)?.makespan)
            };
            let lrtf = mk(Policy::ShardedLrtf, 0)?;
            // random: average of 3 seeded runs (paper: 3 runs, mean)
            let random =
                (mk(Policy::Random, 1)? + mk(Policy::Random, 2)? + mk(Policy::Random, 3)?) / 3.0;
            let fifo = mk(Policy::Fifo, 0)?;
            let tasks = fig7_tasks(hetero, n_models, 7);
            let problem = tasks_to_problem(&tasks, devices);
            let milp = bnb::solve(&problem, bnb_budget, Some(fifo)).makespan;
            let base = milp.min(lrtf).min(random);
            let setting = if hetero { "heterogeneous" } else { "homogeneous" };
            lines.push(format!(
                "{:<14} {:>7} {:>7} {:>9.3} {:>9.3} {:>9.3}",
                setting,
                n_models,
                devices,
                lrtf / base,
                random / base,
                milp / base
            ));
            csv.push_str(&format!(
                "{setting},{n_models},{devices},{},{},{}\n",
                lrtf / base,
                random / base,
                milp / base
            ));
        }
    }
    lines.push("(normalised to best-known schedule; paper Fig 7 expects lrtf ≈ 1.0,".into());
    lines.push(" random ≥ lrtf, milp sometimes > lrtf due to solver timeout)".into());
    Ok(FigureOutput {
        id: "fig7",
        title: "Scheduling algorithm comparison (normalised makespan)".into(),
        lines,
        csv,
    })
}

// ---------------------------------------------------------------------------
// Figure 8 — end-to-end workloads
// ---------------------------------------------------------------------------

fn fig8_workload(kind: &str) -> Vec<crate::sim::WorkloadModel> {
    match kind {
        "bert" => bert_grid(6),
        _ => vit_grid(3),
    }
}

/// One paradigm row: (name, makespan, utilization); Hydra last.
pub fn fig8_rows(kind: &str) -> Result<Vec<(String, f64, f64)>> {
    let gpu = GpuSpec::rtx2080ti();
    let workload = fig8_workload(kind);
    let tasks = build_tasks(&workload, &gpu, paper_policy())?;
    let link = baselines::nvlink();
    let n = 8;

    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    let mp = baselines::model_parallel(&tasks, n, gpu.mem_bytes, link)?;
    rows.push(("model-parallel".into(), mp.makespan, mp.utilization));
    let mpt = baselines::mp_task_hybrid(&tasks, n, gpu.mem_bytes, link)?;
    rows.push(("mp+task".into(), mpt.makespan, mpt.utilization));
    let mpd = baselines::mp_data_hybrid(&tasks, n, gpu.mem_bytes, link)?;
    rows.push(("mp+data".into(), mpd.makespan, mpd.utilization));
    let pp = baselines::pipeline(&tasks, n, gpu.mem_bytes, link)?;
    rows.push(("pipeline(gpipe)".into(), pp.makespan, pp.utilization));

    // task parallelism: expected OOM at these scales (paper: "cannot even
    // benchmark")
    let acts: Vec<u64> = workload
        .iter()
        .map(|w| {
            (w.model.batch * w.model.seq * w.model.d_model * 4) as u64
                * w.model.n_layers as u64
        })
        .collect();
    match baselines::task_parallel(&tasks, n, gpu.mem_bytes, &acts) {
        Ok(tp) => rows.push(("task-parallel".into(), tp.makespan, tp.utilization)),
        Err(_) => rows.push(("task-parallel".into(), f64::NAN, f64::NAN)),
    }

    let hydra = run_hydra(
        build_tasks(&workload, &gpu, paper_policy())?,
        n,
        gpu.mem_bytes,
        ParallelMode::Sharp,
        true,
        Policy::ShardedLrtf,
    )?;
    rows.push(("hydra".into(), hydra.makespan, hydra.utilization));
    Ok(rows)
}

/// Figure 8: runtime speedups vs PyTorch-Distributed-style MP + utilization
/// for the two Table 2 workloads.
pub fn fig8() -> Result<FigureOutput> {
    let mut lines = vec![format!(
        "{:<10} {:<16} {:>10} {:>9} {:>7}",
        "workload", "system", "runtime", "speedup", "util"
    )];
    let mut csv = String::from("workload,system,runtime_h,speedup,utilization\n");
    for kind in ["bert", "vit"] {
        let rows = fig8_rows(kind)?;
        let mp = rows[0].1;
        for (name, makespan, util) in &rows {
            if makespan.is_nan() {
                lines.push(format!(
                    "{:<10} {:<16} {:>10} {:>9} {:>7}",
                    kind, name, "OOM", "-", "-"
                ));
                csv.push_str(&format!("{kind},{name},OOM,,\n"));
            } else {
                lines.push(format!(
                    "{:<10} {:<16} {:>10} {:>8.2}x {:>6.1}%",
                    kind,
                    name,
                    hours(*makespan),
                    mp / makespan,
                    100.0 * util
                ));
                csv.push_str(&format!(
                    "{kind},{name},{},{},{}\n",
                    makespan / 3600.0,
                    mp / makespan,
                    util
                ));
            }
        }
    }
    lines.push("(paper Fig 8: hydra ≈ 7.5x over MP, pipeline ≈ 4x, hybrids between,".into());
    lines.push(" task-parallel OOM, hydra utilization > 80%)".into());
    Ok(FigureOutput {
        id: "fig8",
        title: "End-to-end workloads: speedup over model parallelism & GPU utilization"
            .into(),
        lines,
        csv,
    })
}

// ---------------------------------------------------------------------------
// Figure 9A/9B — drill-down sweeps
// ---------------------------------------------------------------------------

/// Serial reference: all models one after another with no idle parallelism.
fn serial_reference(tasks: &[ModelTask]) -> f64 {
    tasks.iter().map(|t| t.remaining_time()).sum()
}

/// Figure 9A: vary the number of models (1..16) at 8 GPUs, 250M params.
pub fn fig9a() -> Result<FigureOutput> {
    let gpu = GpuSpec::rtx2080ti();
    let mut lines = vec![format!(
        "{:<8} {:>9} {:>9} {:>7}",
        "models", "runtime", "speedup", "util"
    )];
    let mut csv = String::from("models,runtime_h,speedup,utilization\n");
    for n in [1usize, 2, 4, 8, 12, 16] {
        let grid = uniform_grid(n, 250_000_000, 8, 1, 24);
        let tasks = build_tasks(&grid, &gpu, paper_policy())?;
        let serial = serial_reference(&tasks);
        let r = run_hydra(
            tasks,
            8,
            gpu.mem_bytes,
            ParallelMode::Sharp,
            true,
            Policy::ShardedLrtf,
        )?;
        let speedup = serial / r.makespan;
        lines.push(format!(
            "{:<8} {:>9} {:>8.2}x {:>6.1}%",
            n,
            hours(r.makespan),
            speedup,
            100.0 * r.utilization
        ));
        csv.push_str(&format!(
            "{n},{},{speedup},{}\n",
            r.makespan / 3600.0,
            r.utilization
        ));
    }
    lines.push("(paper Fig 9A: speedup ≈ min(#models, 8), flattening at 8)".into());
    Ok(FigureOutput {
        id: "fig9a",
        title: "Impact of number of models (8 GPUs, 250M params each)".into(),
        lines,
        csv,
    })
}

/// Figure 9B: vary the number of GPUs (1..8) with 4 models of 250M params.
pub fn fig9b() -> Result<FigureOutput> {
    let gpu = GpuSpec::rtx2080ti();
    let mut lines = vec![format!(
        "{:<8} {:>9} {:>9} {:>7}",
        "gpus", "runtime", "speedup", "util"
    )];
    let mut csv = String::from("gpus,runtime_h,speedup,utilization\n");
    let grid = uniform_grid(4, 250_000_000, 8, 1, 24);
    let base_tasks = build_tasks(&grid, &gpu, paper_policy())?;
    let serial = serial_reference(&base_tasks);
    for d in 1..=8usize {
        let tasks = build_tasks(&grid, &gpu, paper_policy())?;
        let r = run_hydra(
            tasks,
            d,
            gpu.mem_bytes,
            ParallelMode::Sharp,
            true,
            Policy::ShardedLrtf,
        )?;
        let speedup = serial / r.makespan;
        lines.push(format!(
            "{:<8} {:>9} {:>8.2}x {:>6.1}%",
            d,
            hours(r.makespan),
            speedup,
            100.0 * r.utilization
        ));
        csv.push_str(&format!(
            "{d},{},{speedup},{}\n",
            r.makespan / 3600.0,
            r.utilization
        ));
    }
    lines.push("(paper Fig 9B: near-linear up to #models=4 GPUs, flat beyond)".into());
    Ok(FigureOutput {
        id: "fig9b",
        title: "Impact of number of GPUs (4 models, 250M params each)".into(),
        lines,
        csv,
    })
}

// ---------------------------------------------------------------------------
// Figure 10 — impact of model scale
// ---------------------------------------------------------------------------

/// Figure 10: paradigm runtimes normalised to model parallelism, across
/// model scales (12 models, 8 GPUs).
pub fn fig10() -> Result<FigureOutput> {
    let gpu = GpuSpec::rtx2080ti();
    let link = baselines::nvlink();
    let mut lines = vec![format!(
        "{:<8} {:<16} {:>10} {:>11}",
        "scale", "system", "runtime", "norm-to-MP"
    )];
    let mut csv = String::from("scale,system,runtime_h,normalized\n");
    for (params, tag) in [
        (500_000_000u64, "0.5B"),
        (1_000_000_000, "1B"),
        (2_000_000_000, "2B"),
    ] {
        let grid = uniform_grid(12, params, 8, 1, 12);
        let tasks = build_tasks(&grid, &gpu, paper_policy())?;
        let mp = baselines::model_parallel(&tasks, 8, gpu.mem_bytes, link)?;
        let pp = baselines::pipeline(&tasks, 8, gpu.mem_bytes, link)?;
        let hy = run_hydra(
            build_tasks(&grid, &gpu, paper_policy())?,
            8,
            gpu.mem_bytes,
            ParallelMode::Sharp,
            true,
            Policy::ShardedLrtf,
        )?;
        for (name, t) in [
            ("model-parallel", mp.makespan),
            ("pipeline(gpipe)", pp.makespan),
            ("hydra", hy.makespan),
        ] {
            lines.push(format!(
                "{:<8} {:<16} {:>10} {:>11.3}",
                tag,
                name,
                hours(t),
                t / mp.makespan
            ));
            csv.push_str(&format!(
                "{tag},{name},{},{}\n",
                t / 3600.0,
                t / mp.makespan
            ));
        }
    }
    lines.push("(paper Fig 10: hydra's advantage holds steady across scales)".into());
    Ok(FigureOutput {
        id: "fig10",
        title: "Impact of model scale (12 models, 8 GPUs, normalised to MP)".into(),
        lines,
        csv,
    })
}

// ---------------------------------------------------------------------------
// Table 3 — ablation
// ---------------------------------------------------------------------------

/// Table 3: disable the two key optimizations one by one
/// (16 transformer models, 8 devices; spilling always on).
pub fn table3() -> Result<FigureOutput> {
    let gpu = GpuSpec::rtx2080ti();
    let grid = uniform_grid(16, 1_000_000_000, 8, 1, 6);
    let mk = |mode, db, full_state| -> Result<f64> {
        let opts = EngineOptions {
            mode,
            double_buffer: db,
            buffer_frac: PAPER_BUFFER_FRAC,
            transfer: TransferModel::pcie_gen3(),
            record_intervals: false,
            full_state_transfers: full_state,
            ..Default::default()
        };
        let tasks = build_tasks(&grid, &gpu, paper_policy())?;
        let cluster = Cluster::uniform(8, gpu.mem_bytes, DRAM);
        Ok(sim_run(tasks, cluster, Policy::ShardedLrtf, opts)?.makespan)
    };
    let full = mk(ParallelMode::Sharp, true, false)?;
    let no_db = mk(ParallelMode::Sharp, false, false)?;
    let spill_only = mk(ParallelMode::Sequential, false, false)?;
    // paper-fidelity rows: full shard state (w+g+opt) moves on every spill,
    // as in the paper's GPU-side-optimizer design
    let no_db_full_state = mk(ParallelMode::Sharp, false, true)?;
    let spill_only_full_state = mk(ParallelMode::Sequential, false, true)?;
    // hierarchy arm (beyond the paper): same workload with DRAM provisioned
    // at 75% of the aggregate parameter state over an NVMe backing tier —
    // a configuration the two-tier engine rejects outright
    let nvme_backed = {
        let tasks = build_tasks(&grid, &gpu, paper_policy())?;
        let total: u64 = tasks.iter().map(|t| t.total_param_bytes()).sum();
        let opts = EngineOptions {
            buffer_frac: PAPER_BUFFER_FRAC,
            transfer: TransferModel::pcie_gen3(),
            record_intervals: false,
            ..Default::default()
        };
        let cluster = Cluster::uniform(8, gpu.mem_bytes, (total as f64 * 0.75) as u64);
        sim_run_tiered(
            tasks,
            cluster,
            Policy::ShardedLrtf,
            opts,
            Some(TierSpec::nvme(2 * total)),
        )?
        .makespan
    };

    let mut lines = vec![format!(
        "{:<42} {:>10} {:>10}",
        "optimization level", "runtime", "vs hydra"
    )];
    let mut csv = String::from("level,runtime_h,relative\n");
    for (name, t) in [
        ("hydra without SHARP or double-buffering", spill_only),
        ("hydra without double-buffering", no_db),
        ("hydra (full)", full),
        ("(paper design) full-state spill, no SHARP/DB", spill_only_full_state),
        ("(paper design) full-state spill, no DB", no_db_full_state),
        ("(ext) hydra + NVMe tier (DRAM at 75% of params)", nvme_backed),
    ] {
        lines.push(format!(
            "{:<42} {:>10} {:>9.2}X",
            name,
            hours(t),
            t / full
        ));
        csv.push_str(&format!("{name},{},{}\n", t / 3600.0, t / full));
    }
    lines.push("(paper Table 3: 13.05X / 2.3X / 1X)".into());
    Ok(FigureOutput {
        id: "table3",
        title: "Ablation: SHARP and double-buffering (16 models, 8 GPUs)".into(),
        lines,
        csv,
    })
}

// ---------------------------------------------------------------------------
// Table 2 + Figure 6 — workload definitions & illustrative schedule
// ---------------------------------------------------------------------------

pub fn table2() -> Result<FigureOutput> {
    let mut lines = vec![format!(
        "{:<10} {:<22} {:>9} {:>7} {:>7} {:>7}",
        "dataset", "model", "params", "batch", "epochs", "mbs"
    )];
    let mut csv = String::from("dataset,model,params,batch,epochs,minibatches\n");
    for w in bert_grid(6) {
        lines.push(format!(
            "{:<10} {:<22} {:>8.2}M {:>7} {:>7} {:>7}",
            "wikitext2",
            w.name,
            w.model.total_params() as f64 / 1e6,
            w.model.batch,
            w.epochs,
            w.minibatches_per_epoch
        ));
        csv.push_str(&format!(
            "wikitext2,{},{},{},{},{}\n",
            w.name,
            w.model.total_params(),
            w.model.batch,
            w.epochs,
            w.minibatches_per_epoch
        ));
    }
    for w in vit_grid(3) {
        lines.push(format!(
            "{:<10} {:<22} {:>8.2}M {:>7} {:>7} {:>7}",
            "cifar10",
            w.name,
            w.model.total_params() as f64 / 1e6,
            w.model.batch,
            w.epochs,
            w.minibatches_per_epoch
        ));
        csv.push_str(&format!(
            "cifar10,{},{},{},{},{}\n",
            w.name,
            w.model.total_params(),
            w.model.batch,
            w.epochs,
            w.minibatches_per_epoch
        ));
    }
    Ok(FigureOutput {
        id: "table2",
        title: "End-to-end workload definitions (Table 2)".into(),
        lines,
        csv,
    })
}

/// Figure 6: illustrative SHARP schedule (3 models x 2 shards) as an ASCII
/// Gantt, with the task-/model-parallel makespans for contrast.
pub fn fig6() -> Result<FigureOutput> {
    let mk_tasks = || -> Vec<ModelTask> {
        (0..3)
            .map(|i| {
                let sd = vec![
                    ShardDesc {
                        param_bytes: 4 << 30,
                        fwd_transfer_bytes: 2 << 30,
                        bwd_transfer_bytes: 2 << 30,
                        activation_bytes: 8 << 20,
                        fwd_cost: 1.0,
                        bwd_cost: 2.0,
                        n_layers: 1,
                    };
                    2
                ];
                ModelTask::new(i, format!("m{i}"), "fig6", sd, 2, 1, 1e-3)
            })
            .collect()
    };
    let opts = EngineOptions {
        transfer: TransferModel::pcie_gen3(),
        ..Default::default()
    };
    let r = sim_run(
        mk_tasks(),
        Cluster::uniform(2, 11 << 30, DRAM),
        Policy::ShardedLrtf,
        opts,
    )?;

    let mp = baselines::model_parallel(
        &mk_tasks(),
        2,
        11 << 30,
        baselines::nvlink(),
    )?;
    let mut lines = Vec::new();
    lines.push("SHARP schedule (2 devices, 3 models x 2 shards, A/B/C = models):".into());
    lines.extend(r.trace.gantt(72).lines().map(String::from));
    lines.push(format!(
        "SHARP makespan {:.1}s vs model-parallel {:.1}s ({:.2}x)",
        r.makespan,
        mp.makespan,
        mp.makespan / r.makespan
    ));
    let csv = format!(
        "system,makespan\nsharp,{}\nmodel-parallel,{}\n",
        r.makespan, mp.makespan
    );
    Ok(FigureOutput {
        id: "fig6",
        title: "Illustrative SHARP schedule vs model parallelism (Fig 6)".into(),
        lines,
        csv,
    })
}

// ---------------------------------------------------------------------------
// Extension ablations (beyond the paper; DESIGN.md §4 "ablation benches for
// the design choices")
// ---------------------------------------------------------------------------

/// ext-sched: all scheduling policies at paper scale on a heterogeneous
/// workload (where policy choice matters most, §4.7.2).
pub fn ext_sched() -> Result<FigureOutput> {
    let gpu = GpuSpec::rtx2080ti();
    // heterogeneous: mixed scales like the ViT grid
    let grid = crate::sim::vit_grid(3);
    let mut lines = vec![format!("{:<16} {:>10} {:>9} {:>7}", "scheduler", "runtime", "vs lrtf", "util")];
    let mut csv = String::from("scheduler,runtime_h,vs_lrtf,utilization\n");
    let mut base = None;
    for policy in Policy::ALL {
        let tasks = build_tasks(&grid, &gpu, paper_policy())?;
        let r = run_hydra(tasks, 8, gpu.mem_bytes, ParallelMode::Sharp, true, policy)?;
        let b = *base.get_or_insert(r.makespan);
        lines.push(format!(
            "{:<16} {:>10} {:>9.3} {:>6.1}%",
            policy,
            hours(r.makespan),
            r.makespan / b,
            100.0 * r.utilization
        ));
        csv.push_str(&format!(
            "{policy},{},{},{}\n",
            r.makespan / 3600.0,
            r.makespan / b,
            r.utilization
        ));
    }
    lines.push("(design ablation: LRTF-family ahead of FIFO/SRTF/random on".into());
    lines.push(" heterogeneous mixes; affinity tie-break exploits §4.6 caching)".into());
    Ok(FigureOutput {
        id: "ext_sched",
        title: "Extension ablation: scheduling policies at paper scale".into(),
        lines,
        csv,
    })
}

/// ext-buffer: double-buffer zone size sweep — the §4.6 "5% is enough"
/// claim holds only when shards are activation-dominated; at 1B-params the
/// zone must hold a shard's transferable weights to engage.
pub fn ext_buffer() -> Result<FigureOutput> {
    let gpu = GpuSpec::rtx2080ti();
    let grid = uniform_grid(12, 1_000_000_000, 8, 1, 6);
    let mut lines = vec![format!(
        "{:<12} {:>10} {:>9} {:>10} {:>10}",
        "zone frac", "runtime", "util", "stalls(h)", "transfers(h)"
    )];
    let mut csv = String::from("buffer_frac,runtime_h,utilization,stall_h,transfer_h\n");
    for frac in [0.05, 0.10, 0.20, 0.30, 0.40] {
        let policy = crate::coordinator::partitioner::PartitionPolicy {
            buffer_frac: frac,
            ..Default::default()
        };
        let tasks = build_tasks(&grid, &gpu, policy)?;
        let opts = EngineOptions {
            buffer_frac: frac,
            transfer: TransferModel::pcie_gen3(),
            record_intervals: false,
            ..Default::default()
        };
        let r = sim_run(
            tasks,
            Cluster::uniform(8, gpu.mem_bytes, DRAM),
            Policy::ShardedLrtf,
            opts,
        )?;
        lines.push(format!(
            "{:<12} {:>10} {:>8.1}% {:>10.3} {:>10.3}",
            format!("{:.0}%", frac * 100.0),
            hours(r.makespan),
            100.0 * r.utilization,
            r.stall_secs / 3600.0,
            r.transfer_secs / 3600.0
        ));
        csv.push_str(&format!(
            "{frac},{},{},{},{}\n",
            r.makespan / 3600.0,
            r.utilization,
            r.stall_secs / 3600.0,
            r.transfer_secs / 3600.0
        ));
    }
    lines.push("(small zones cannot stage 1B-scale shards: prefetch disengages and".into());
    lines.push(" transfers serialise — quantifying the limit of the paper's 5% rule)".into());
    Ok(FigureOutput {
        id: "ext_buffer",
        title: "Extension ablation: double-buffer zone size at 1B scale".into(),
        lines,
        csv,
    })
}

/// ext-online: the production-serving scenario beyond the paper — a Poisson
/// stream of mixed BERT/ViT tenant jobs arriving online over a mixed
/// A4000/A6000 pool, scheduled by the event-heap engine. Reports per-job
/// latency (finish - arrival), the metric a serving deployment cares about,
/// alongside the engine's utilization.
pub fn ext_online() -> Result<FigureOutput> {
    let pool = crate::sim::mixed_pool(4, 4);
    let stream = crate::sim::poisson_mixed_tenants(12, 6.0, 7, 3);
    let (tasks, specs) = crate::sim::build_tasks_pool(&stream, &pool, paper_policy())?;
    let opts = EngineOptions {
        buffer_frac: PAPER_BUFFER_FRAC,
        record_intervals: false,
        ..Default::default()
    };
    let r = sim_run(
        tasks,
        Cluster::heterogeneous(specs, DRAM),
        Policy::ShardedLrtf,
        opts,
    )?;

    let mut lines = vec![format!(
        "{:<26} {:>10} {:>10} {:>10} {:>7}",
        "job", "arrival", "finish", "latency", "units"
    )];
    let mut csv = String::from("job,arrival_h,finish_h,latency_h,units\n");
    let mut total_latency = 0.0;
    for j in &r.jobs {
        lines.push(format!(
            "{:<26} {:>9.2}h {:>9.2}h {:>9.2}h {:>7}",
            j.name,
            j.arrival / 3600.0,
            j.finished / 3600.0,
            j.latency() / 3600.0,
            j.units_executed
        ));
        csv.push_str(&format!(
            "{},{},{},{},{}\n",
            j.name,
            j.arrival / 3600.0,
            j.finished / 3600.0,
            j.latency() / 3600.0,
            j.units_executed
        ));
        total_latency += j.latency();
    }
    lines.push(format!(
        "mean latency {:.2}h | makespan {:.2}h | utilization {:.1}%",
        total_latency / r.jobs.len().max(1) as f64 / 3600.0,
        r.makespan / 3600.0,
        100.0 * r.utilization
    ));
    lines.push("(online extension: jobs arrive Poisson(6/h) on 4x A4000 + 4x A6000;".into());
    lines.push(" speeds/links per class, shards bounded by the smallest device)".into());
    Ok(FigureOutput {
        id: "ext_online",
        title: "Extension: online multi-tenant serving on a heterogeneous pool".into(),
        lines,
        csv,
    })
}

/// ext-hierarchy: DRAM-pressure sweep over the tiered memory hierarchy —
/// 12 x 1B models whose aggregate parameter state (weights + gradients +
/// optimizer) is run against DRAM capacities from 0.3x to 1.5x of that
/// footprint, with and without an NVMe backing tier. Without NVMe,
/// under-provisioned DRAM rejects the workload outright (the paper's hard
/// "fits in DRAM" precondition); with NVMe the same workloads complete,
/// trading throughput for NVMe traffic.
pub fn ext_hierarchy() -> Result<FigureOutput> {
    // small-memory devices keep shards small relative to DRAM, so the
    // pinned working set (resident + staged shard per device) fits even at
    // the tightest ratio
    let gpu = GpuSpec { mem_bytes: 6 << 30, ..GpuSpec::rtx2080ti() };
    let devices = 4usize;
    let grid = uniform_grid(12, 1_000_000_000, 8, 1, 2);
    let probe = build_tasks(&grid, &gpu, paper_policy())?;
    let total: u64 = probe.iter().map(|t| t.total_param_bytes()).sum();
    let max_shard = probe
        .iter()
        .flat_map(|t| &t.shards)
        .map(|sh| sh.param_bytes)
        .max()
        .unwrap_or(0);
    // DRAM floor: every device pins a resident + a staged shard and one
    // more fetch must still fit without thrashing
    let floor = (2 * devices as u64 + 1) * max_shard;
    let opts = || EngineOptions {
        buffer_frac: PAPER_BUFFER_FRAC,
        transfer: TransferModel::pcie_gen3(),
        record_intervals: false,
        ..Default::default()
    };
    let mut lines = vec![format!(
        "{:<7} {:>9} {:<10} {:>10} {:>10} {:>11} {:>11}",
        "ratio", "dram", "tier", "runtime", "units/h", "nvme-read", "nvme-write"
    )];
    let mut csv = String::from(
        "dram_ratio,dram_gib,tier,runtime_h,throughput_units_per_h,\
         nvme_read_gib,nvme_write_gib\n",
    );
    for ratio in [0.3, 0.5, 0.75, 1.0, 1.5] {
        let dram = ((total as f64 * ratio) as u64).max(floor);
        let dram_gib = dram >> 30;
        for with_nvme in [true, false] {
            let tasks = build_tasks(&grid, &gpu, paper_policy())?;
            let nvme = with_nvme.then(|| TierSpec::nvme(2 * total));
            let tier = if with_nvme { "nvme" } else { "dram-only" };
            let cluster = Cluster::uniform(devices, gpu.mem_bytes, dram);
            match sim_run_tiered(tasks, cluster, Policy::ShardedLrtf, opts(), nvme) {
                Ok(r) => {
                    let tput = r.units_executed as f64 / (r.makespan / 3600.0);
                    lines.push(format!(
                        "{:<7} {:>8}G {:<10} {:>10} {:>10.0} {:>10.1}G {:>10.1}G",
                        format!("{ratio:.2}x"),
                        dram_gib,
                        tier,
                        hours(r.makespan),
                        tput,
                        r.nvme_promoted_bytes as f64 / (1u64 << 30) as f64,
                        r.nvme_demoted_bytes as f64 / (1u64 << 30) as f64,
                    ));
                    csv.push_str(&format!(
                        "{ratio},{dram_gib},{tier},{},{tput},{},{}\n",
                        r.makespan / 3600.0,
                        r.nvme_promoted_bytes as f64 / (1u64 << 30) as f64,
                        r.nvme_demoted_bytes as f64 / (1u64 << 30) as f64,
                    ));
                }
                // only the expected two-tier rejection becomes a "reject"
                // row; any other failure (ledger OOM, engine bug) propagates
                Err(e) if !with_nvme && format!("{e}").contains("DRAM exhausted") => {
                    lines.push(format!(
                        "{:<7} {:>8}G {:<10} {:>10} {:>10} {:>11} {:>11}",
                        format!("{ratio:.2}x"),
                        dram_gib,
                        tier,
                        "reject",
                        "-",
                        "-",
                        "-",
                    ));
                    csv.push_str(&format!("{ratio},{dram_gib},{tier},reject,,,\n"));
                }
                Err(e) => return Err(e),
            }
        }
    }
    lines.push("(the paper's two-tier engine rejects DRAM < params outright; the".into());
    lines.push(" NVMe-backed hierarchy completes them, paying staged NVMe traffic)".into());
    Ok(FigureOutput {
        id: "ext_hierarchy",
        title: "Extension: DRAM-pressure sweep over the HBM/DRAM/NVMe hierarchy"
            .into(),
        lines,
        csv,
    })
}

/// ext-selection: ASHA-vs-grid model selection makespan across pool sizes
/// — the workload Hydra exists for (§1). The 27-trial lr x depth x batch
/// space (the acceptance workload of `hydra search`) runs on A4000 pools
/// of 2/4/8 devices under both algorithms; ASHA (eta=3, rungs at 1 and 3
/// of 9 epochs) keeps 9 then 3 survivors, so both its makespan and its
/// simulated GPU-hours must land strictly below the full grid's on every
/// pool size (asserted by figures_smoke).
pub fn ext_selection() -> Result<FigureOutput> {
    let a4000 = GpuSpec::a4000();
    let space = SearchSpace::parse("lr=1e-4..1e-2:log,layers=12,24,48,batch=4,8,16")?;
    let mk_search = |algo: Algo| {
        let mut s = Search::new(space.clone());
        s.algo = algo;
        s.epochs = 9;
        s.minibatches_per_epoch = 2;
        s.seed = 7;
        s.reference = a4000;
        s
    };
    let mut lines = vec![format!(
        "{:<6} {:<6} {:>7} {:>10} {:>9} {:>8} {:>12}",
        "pool", "algo", "trials", "makespan", "gpu-h", "saved", "best"
    )];
    let mut csv =
        String::from("pool,algo,trials,makespan_h,gpu_h,saved_pct,best_loss\n");
    for pool in [2usize, 4, 8] {
        for algo in [
            Algo::Grid,
            Algo::Asha { trials: None, eta: 3, min_epochs: 1 },
        ] {
            let opts = EngineOptions {
                buffer_frac: PAPER_BUFFER_FRAC,
                transfer: a4000.transfer_model(),
                record_intervals: false,
                ..Default::default()
            };
            let session = Session::builder(Cluster::uniform(pool, a4000.mem_bytes, DRAM))
                .backend(Backend::sim())
                .policy(Policy::ShardedLrtf)
                .options(opts)
                .build()?;
            let r = session.run_search(&mk_search(algo))?;
            let saved_pct =
                100.0 * (r.full_secs - r.spent_secs) / r.full_secs.max(1e-12);
            let best = r
                .best_trial()
                .and_then(|t| t.final_loss())
                .unwrap_or(f64::NAN);
            lines.push(format!(
                "{:<6} {:<6} {:>7} {:>10} {:>9.2} {:>7.1}% {:>12.4}",
                pool,
                r.algo,
                r.trials.len(),
                hours(r.run.makespan),
                r.spent_secs / 3600.0,
                saved_pct,
                best
            ));
            csv.push_str(&format!(
                "{pool},{},{},{},{},{saved_pct},{best}\n",
                r.algo,
                r.trials.len(),
                r.run.makespan / 3600.0,
                r.spent_secs / 3600.0,
            ));
        }
    }
    lines.push("(ASHA shares the grid's 27-trial cohort; rungs at 1 and 3 of 9 epochs".into());
    lines.push(" keep 9 then 3 survivors — pruning must beat the grid on every pool)".into());
    Ok(FigureOutput {
        id: "ext_selection",
        title: "Extension: ASHA vs full-grid model selection across pool sizes".into(),
        lines,
        csv,
    })
}

/// ext-prefetch: depth sweep of the scheduler-aware prefetch pipeline —
/// k ∈ {1, 2, 4} pre-claimed slots per device, crossed with DRAM pressure
/// (0.75x and 1.5x of the aggregate parameter state) and an NVMe backing
/// tier on/off. At depth 1 the pipeline is the paper's classic double
/// buffer; under NVMe pressure a promote is a NVMe->DRAM->HBM *chain*
/// that one compute span cannot hide, so deeper pipelines — whose slots
/// overlap the NVMe and PCIe legs of *different* prefetches — must show
/// strictly lower stall seconds than depth 1 (asserted by figures_smoke).
/// Without pressure (or without the NVMe tier) depth is nearly free and
/// nearly useless: single-hop transfers already hide behind one span.
pub fn ext_prefetch() -> Result<FigureOutput> {
    const MIB: u64 = 1 << 20;
    let n_models = 16usize;
    let devices = 2usize;
    let shard = 256 * MIB;
    let mk_tasks = || -> Vec<ModelTask> {
        (0..n_models)
            .map(|i| {
                let sd = vec![ShardDesc {
                    param_bytes: shard,
                    fwd_transfer_bytes: shard,
                    bwd_transfer_bytes: shard,
                    activation_bytes: MIB,
                    fwd_cost: 0.03,
                    bwd_cost: 0.06,
                    n_layers: 1,
                }];
                ModelTask::new(i, format!("m{i}"), "ext_prefetch", sd, 3, 1, 1e-3)
            })
            .collect()
    };
    let total = n_models as u64 * shard;
    let mut lines = vec![format!(
        "{:<6} {:<7} {:<10} {:>10} {:>10} {:>10} {:>11}",
        "depth", "dram", "tier", "runtime", "stalls(s)", "wait(s)", "nvme-read"
    )];
    let mut csv = String::from(
        "depth,dram_ratio,tier,makespan_h,stall_s,wait_s,nvme_read_gib,units\n",
    );
    for ratio in [0.75f64, 1.5] {
        let dram = (total as f64 * ratio) as u64;
        for with_nvme in [true, false] {
            let tier = if with_nvme { "nvme" } else { "dram-only" };
            let nvme = with_nvme.then(|| TierSpec::nvme(4 * total));
            for depth in [1usize, 2, 4] {
                let opts = EngineOptions {
                    buffer_frac: PAPER_BUFFER_FRAC,
                    prefetch_depth: depth,
                    transfer: TransferModel::pcie_gen3(),
                    record_intervals: false,
                    ..Default::default()
                };
                let cluster = Cluster::uniform(devices, 4 << 30, dram);
                match sim_run_tiered(mk_tasks(), cluster, Policy::ShardedLrtf, opts, nvme)
                {
                    Ok(r) => {
                        lines.push(format!(
                            "{:<6} {:<7} {:<10} {:>10} {:>10.2} {:>10.2} {:>10.1}G",
                            depth,
                            format!("{ratio:.2}x"),
                            tier,
                            hours(r.makespan),
                            r.stall_secs,
                            r.prefetch_wait_secs,
                            r.nvme_promoted_bytes as f64 / (1u64 << 30) as f64,
                        ));
                        csv.push_str(&format!(
                            "{depth},{ratio},{tier},{},{},{},{},{}\n",
                            r.makespan / 3600.0,
                            r.stall_secs,
                            r.prefetch_wait_secs,
                            r.nvme_promoted_bytes as f64 / (1u64 << 30) as f64,
                            r.units_executed,
                        ));
                    }
                    // only the expected two-tier rejection becomes a
                    // "reject" row; anything else is a real failure
                    Err(e)
                        if !with_nvme
                            && format!("{e}").contains("DRAM exhausted") =>
                    {
                        lines.push(format!(
                            "{:<6} {:<7} {:<10} {:>10} {:>10} {:>10} {:>11}",
                            depth,
                            format!("{ratio:.2}x"),
                            tier,
                            "reject",
                            "-",
                            "-",
                            "-",
                        ));
                        csv.push_str(&format!("{depth},{ratio},{tier},reject,,,,\n"));
                    }
                    Err(e) => return Err(e),
                }
            }
        }
    }
    lines.push("(depth 1 = the paper's double buffer. Under NVMe pressure the".into());
    lines.push(" promote chain is NVMe->DRAM->HBM; depth >= 2 overlaps the legs of".into());
    lines.push(" different slots and strictly cuts stall seconds. Queueing on the".into());
    lines.push(" serialized staging links is the wait(s) column.)".into());
    Ok(FigureOutput {
        id: "ext_prefetch",
        title: "Extension: prefetch-pipeline depth sweep (k x DRAM pressure x NVMe)"
            .into(),
        lines,
        csv,
    })
}

// ---------------------------------------------------------------------------
// ext-sharding: shard-count scale sweep
// ---------------------------------------------------------------------------

/// ext-sharding: scale sweep of the sharded multi-coordinator engine — one
/// large synthetic pool (64 single-shard models) over clusters that grow
/// with the shard count (4 devices and 64 GiB of DRAM per shard), k ∈
/// {1, 2, 4, 8}. Each shard runs its own event loop over its stable-hash
/// slice of the pool, so the bottleneck shard shrinks as k grows: the
/// merged makespan must be monotone non-increasing from 1 to 8 shards, and
/// the k=1 sharded row must equal the unsharded `legacy` arm exactly —
/// both asserted by figures_smoke, the figure-level restatement of the
/// differential suite's byte-identity obligation.
pub fn ext_sharding() -> Result<FigureOutput> {
    use crate::coordinator::sharp::{DeviceSpec, ShardedEngine, SharpEngine};
    use crate::exec::SimBackend;

    const MIB: u64 = 1 << 20;
    const GIB: u64 = 1 << 30;
    let n_models = 64usize;
    let per_shard_devices = 4usize;
    let mk_tasks = || -> Vec<ModelTask> {
        (0..n_models)
            .map(|i| {
                let sd = vec![ShardDesc {
                    param_bytes: 8 * MIB,
                    fwd_transfer_bytes: 8 * MIB,
                    bwd_transfer_bytes: 8 * MIB,
                    activation_bytes: MIB,
                    fwd_cost: 0.4,
                    bwd_cost: 0.8,
                    n_layers: 1,
                }];
                ModelTask::new(i, format!("m{i}"), "ext_sharding", sd, 4, 1, 1e-3)
            })
            .collect()
    };
    let opts = |shards: usize| EngineOptions {
        transfer: TransferModel::zero_cost(),
        record_intervals: false,
        shards,
        ..Default::default()
    };
    fn push_row(
        lines: &mut Vec<String>,
        csv: &mut String,
        arm: &str,
        shards: usize,
        devices: usize,
        models: usize,
        r: &RunReport,
    ) {
        lines.push(format!(
            "{:<8} {:<7} {:<8} {:>10} {:>6.2} {:>7}",
            arm,
            shards,
            devices,
            hours(r.makespan),
            r.utilization,
            r.units_executed
        ));
        csv.push_str(&format!(
            "{arm},{shards},{devices},{models},{},{},{}\n",
            r.makespan / 3600.0,
            r.utilization,
            r.units_executed
        ));
    }
    let mut lines = vec![format!(
        "{:<8} {:<7} {:<8} {:>10} {:>6} {:>7}",
        "arm", "shards", "devices", "makespan", "util", "units"
    )];
    let mut csv =
        String::from("arm,shards,devices,models,makespan_h,utilization,units\n");

    // unsharded reference: the legacy single engine on the k=1 cluster
    let specs = vec![DeviceSpec::uniform(GIB); per_shard_devices];
    let mut backend = SimBackend::deterministic();
    let legacy = SharpEngine::with_devices(
        mk_tasks(),
        &specs,
        64 * GIB,
        Policy::ShardedLrtf.build(),
        &mut backend,
        opts(1),
    )?
    .run()?;
    push_row(
        &mut lines,
        &mut csv,
        "legacy",
        1,
        per_shard_devices,
        n_models,
        &legacy,
    );

    for k in [1usize, 2, 4, 8] {
        let devices = per_shard_devices * k;
        let specs = vec![DeviceSpec::uniform(GIB); devices];
        let mut backend = SimBackend::deterministic();
        let report = ShardedEngine::with_devices(
            mk_tasks(),
            &specs,
            64 * GIB * k as u64,
            Policy::ShardedLrtf,
            &mut backend,
            opts(k),
        )?
        .run()?;
        push_row(
            &mut lines,
            &mut csv,
            "sharded",
            k,
            devices,
            n_models,
            &report.merged,
        );
    }
    lines.push("(each shard owns 4 devices and an equal DRAM slice; jobs route by".into());
    lines.push(" stable hash, so the bottleneck shard shrinks as the shard count".into());
    lines.push(" grows. The k=1 sharded row must equal the legacy row exactly.)".into());
    Ok(FigureOutput {
        id: "ext_sharding",
        title: "Extension: sharded multi-coordinator scale sweep (1/2/4/8 shards)"
            .into(),
        lines,
        csv,
    })
}

// ---------------------------------------------------------------------------
// ext-durability: WAL overhead and recovery-time sweep
// ---------------------------------------------------------------------------

/// ext-durability: cost/benefit sweep of the durability subsystem. One
/// fixed 12-model workload runs four ways — no WAL (baseline), WAL only,
/// and WAL + snapshots at two cadences — measuring the wallclock overhead
/// of event logging, the WAL's on-disk size, and then the wallclock to
/// `recover()` each WAL. Snapshots bound the re-execution suffix (events
/// after the last snapshot), so recovery time falls as the cadence
/// tightens while the run-time overhead stays flat; every recovered
/// report must be Debug-byte-identical to the baseline.
pub fn ext_durability() -> Result<FigureOutput> {
    use crate::coordinator::durability::{
        read_snapshot, recover, scan_wal, snapshot_path, DurabilityOptions,
        Recovered, WalRecord,
    };
    use std::time::Instant;

    let gpu = GpuSpec::rtx2080ti();
    let grid = uniform_grid(12, 250_000_000, 8, 1, 4);
    let run_arm = |dur: Option<DurabilityOptions>| -> Result<(RunReport, f64)> {
        let tasks = build_tasks(&grid, &gpu, paper_policy())?;
        let opts = EngineOptions {
            buffer_frac: 0.30,
            record_intervals: false,
            transfer: TransferModel::pcie_gen3(),
            ..Default::default()
        };
        let mut builder = Session::builder(Cluster::uniform(8, gpu.mem_bytes, DRAM))
            .backend(Backend::sim())
            .policy(Policy::ShardedLrtf)
            .options(opts);
        if let Some(d) = dur {
            builder = builder.durability(d);
        }
        let mut session = builder.build()?;
        for t in tasks {
            session.submit(t)?;
        }
        let started = Instant::now();
        let r = session.run()?.run;
        Ok((r, started.elapsed().as_secs_f64() * 1e3))
    };

    let mut lines = vec![format!(
        "{:<14} {:>8} {:>9} {:>9} {:>8} {:>11} {:>10}",
        "arm", "run(ms)", "overhead", "wal(KiB)", "records", "suffix(evs)", "recov(ms)"
    )];
    let mut csv = String::from(
        "arm,snapshot_every,run_ms,overhead,wal_bytes,records,suffix_events,recover_ms,identical\n",
    );

    let (baseline, base_ms) = run_arm(None)?;
    let base_dbg = format!("{baseline:?}");
    lines.push(format!(
        "{:<14} {:>8.1} {:>9} {:>9} {:>8} {:>11} {:>10}",
        "baseline", base_ms, "1.00x", "-", "-", "-", "-"
    ));
    csv.push_str(&format!("baseline,,{base_ms},1.0,,,,,\n"));

    for every in [0u64, 4096, 512] {
        let wal = std::env::temp_dir().join(format!(
            "hydra-ext-durability-{}-{every}.wal",
            std::process::id()
        ));
        let arm = if every == 0 {
            "wal".to_string()
        } else {
            format!("wal+snap@{every}")
        };
        let (r, run_ms) =
            run_arm(Some(DurabilityOptions::new(&wal).snapshot_every(every)))?;
        let wal_bytes = std::fs::metadata(&wal)?.len();
        let scanned = scan_wal(&wal)?;
        // re-execution suffix: events after the last snapshot mark (all of
        // them when snapshots are off)
        let suffix = scanned.records.len()
            - scanned
                .records
                .iter()
                .rposition(|rec| matches!(rec, WalRecord::SnapshotMark { .. }))
                .map_or(0, |i| i + 1);
        let snap = read_snapshot(&snapshot_path(&wal))?;
        let started = Instant::now();
        let recovered = match recover(&wal)? {
            Recovered::Run(rep) => rep,
            Recovered::Search(_) => unreachable!("run genesis"),
        };
        let recover_ms = started.elapsed().as_secs_f64() * 1e3;
        let identical =
            format!("{r:?}") == base_dbg && format!("{recovered:?}") == base_dbg;
        lines.push(format!(
            "{:<14} {:>8.1} {:>8.2}x {:>9.1} {:>8} {:>11} {:>10.1}{}",
            arm,
            run_ms,
            run_ms / base_ms,
            wal_bytes as f64 / 1024.0,
            scanned.records.len(),
            suffix,
            recover_ms,
            if identical { "" } else { "  MISMATCH" }
        ));
        csv.push_str(&format!(
            "{arm},{every},{run_ms},{},{wal_bytes},{},{suffix},{recover_ms},{identical}\n",
            run_ms / base_ms,
            scanned.records.len(),
        ));
        if every > 0 && snap.is_none() {
            lines.push(format!("  (no snapshot taken at cadence {every})"));
        }
        let _ = std::fs::remove_file(&wal);
        let _ = std::fs::remove_file(snapshot_path(&wal));
    }
    lines.push("(the WAL logs every engine event with a CRC frame; snapshots".into());
    lines.push(" bound recovery to the post-snapshot suffix, so recover(ms) falls".into());
    lines.push(" with cadence while run overhead stays flat. identical = recovered".into());
    lines.push(" report is byte-identical to the undisturbed baseline.)".into());
    Ok(FigureOutput {
        id: "ext_durability",
        title: "Extension: durability — WAL overhead and recovery-time sweep"
            .into(),
        lines,
        csv,
    })
}

/// ext-fairness: one hot tenant (weight 10) flooding 12 jobs at t=0 against
/// three background tenants (weight 1) with one job each, under FIFO vs
/// weighted fair queueing. FIFO serves the flood in submission order and
/// starves the background; WFQ bounds the hot tenant to its weighted share
/// (10/13 of GPU-seconds while every tenant is backlogged) and the
/// background tenants' latency SLOs recover. The SLO deadline is calibrated
/// between the two policies' background latencies so attainment separates
/// them cleanly.
pub fn ext_fairness() -> Result<FigureOutput> {
    use crate::coordinator::metrics::IntervalKind;

    const HOT_JOBS: usize = 12;
    const BG_TENANTS: usize = 3;
    const HOT_WEIGHT: f64 = 10.0;
    let n_jobs = HOT_JOBS + BG_TENANTS;
    let devices = 4usize;
    let gpu = GpuSpec::rtx2080ti();

    // identical jobs so GPU-second shares compare directly; the hot tenant
    // owns the first 12 ids (submission order = FIFO order)
    let mut grid = uniform_grid(n_jobs, 300_000_000, 8, 1, 4);
    for (i, w) in grid.iter_mut().enumerate() {
        if i < HOT_JOBS {
            w.tenant = 0;
            w.weight = HOT_WEIGHT;
        } else {
            w.tenant = 1 + (i - HOT_JOBS);
            w.weight = 1.0;
        }
        w.name = format!("t{}-job{i}", w.tenant);
    }
    let tenant_of: Vec<usize> = grid.iter().map(|w| w.tenant).collect();

    let run = |policy: Policy, ws: &[crate::sim::WorkloadModel]| -> Result<RunReport> {
        let tasks = build_tasks(ws, &gpu, paper_policy())?;
        let opts = EngineOptions {
            buffer_frac: PAPER_BUFFER_FRAC,
            transfer: TransferModel::pcie_gen3(),
            record_intervals: true,
            ..Default::default()
        };
        sim_run(tasks, Cluster::uniform(devices, gpu.mem_bytes, DRAM), policy, opts)
    };

    // the hot tenant's GPU-second share over the window where every tenant
    // still has queued work (ends when the first tenant drains)
    let hot_share = |r: &RunReport| -> f64 {
        let mut last = vec![0.0f64; 1 + BG_TENANTS];
        for (m, j) in r.jobs.iter().enumerate() {
            if j.finished.is_finite() {
                last[tenant_of[m]] = last[tenant_of[m]].max(j.finished);
            }
        }
        let t_end = last.iter().copied().fold(f64::INFINITY, f64::min);
        let (mut hot, mut total) = (0.0, 0.0);
        for iv in &r.trace.intervals {
            if iv.kind != IntervalKind::Compute {
                continue;
            }
            let end = iv.end.min(t_end);
            if end <= iv.start {
                continue;
            }
            total += end - iv.start;
            if tenant_of[iv.model] == 0 {
                hot += end - iv.start;
            }
        }
        if total > 0.0 {
            hot / total
        } else {
            0.0
        }
    };
    let bg_latencies = |r: &RunReport| -> Vec<f64> {
        r.jobs
            .iter()
            .enumerate()
            .filter(|(m, _)| tenant_of[*m] != 0)
            .map(|(_, j)| j.latency())
            .collect()
    };

    // calibration pass (no SLO): pick a deadline between WFQ's worst and
    // FIFO's best background latency
    let cal_wfq = run(Policy::WeightedFair, &grid)?;
    let cal_fifo = run(Policy::Fifo, &grid)?;
    let wfq_worst = bg_latencies(&cal_wfq).into_iter().fold(0.0, f64::max);
    let fifo_best =
        bg_latencies(&cal_fifo).into_iter().fold(f64::INFINITY, f64::min);
    let deadline = 0.5 * (wfq_worst + fifo_best);

    let mut slo_grid = grid.clone();
    for w in &mut slo_grid {
        w.deadline = Some(deadline);
    }

    let mut lines = vec![format!(
        "SLO deadline {:.2}h (calibrated between the policies' background latencies)",
        deadline / 3600.0
    )];
    let mut csv =
        String::from("policy,hot_share_window,bg_slo_attainment,makespan_h\n");
    for policy in [Policy::Fifo, Policy::WeightedFair] {
        let r = run(policy, &slo_grid)?;
        let share = hot_share(&r);
        let (mut bg_slo_jobs, mut bg_slo_met) = (0usize, 0usize);
        lines.push(format!(
            "{:<14} hot share {:5.1}% (target {:5.1}%) | makespan {}",
            policy.name(),
            100.0 * share,
            100.0 * HOT_WEIGHT / (HOT_WEIGHT + BG_TENANTS as f64),
            hours(r.makespan),
        ));
        lines.push(format!(
            "  {:<8} {:>6} {:>12} {:>8} {:>6} {:>8}",
            "tenant", "jobs", "gpu-secs", "units", "shed", "slo"
        ));
        for t in &r.tenants {
            if t.tenant != 0 {
                bg_slo_jobs += t.slo_jobs;
                bg_slo_met += t.slo_met;
            }
            lines.push(format!(
                "  {:<8} {:>6} {:>12.1} {:>8} {:>6} {:>7.0}%",
                t.tenant,
                t.jobs,
                t.gpu_secs,
                t.units,
                t.shed,
                100.0 * t.slo_attainment().unwrap_or(0.0),
            ));
        }
        let bg_att = bg_slo_met as f64 / bg_slo_jobs.max(1) as f64;
        csv.push_str(&format!(
            "{},{share},{bg_att},{}\n",
            policy.name(),
            r.makespan / 3600.0
        ));
    }
    lines.push(
        "(1 hot tenant floods 12 jobs at t=0; 3 background tenants submit \
         1 job each."
            .into(),
    );
    lines.push(
        " Shares are measured while every tenant is backlogged; FIFO gives \
         the flood"
            .into(),
    );
    lines.push(" everything, WFQ holds it to weight/total = 10/13.)".into());
    Ok(FigureOutput {
        id: "ext_fairness",
        title: "Extension: weighted fairness under a hot-tenant flood".into(),
        lines,
        csv,
    })
}

/// All figure generators by id.
pub fn by_id(id: &str, bnb_budget: Duration) -> Option<Result<FigureOutput>> {
    match id {
        "fig6" => Some(fig6()),
        "fig7" => Some(fig7(bnb_budget)),
        "fig8" => Some(fig8()),
        "fig9a" => Some(fig9a()),
        "fig9b" => Some(fig9b()),
        "fig10" => Some(fig10()),
        "table2" => Some(table2()),
        "table3" => Some(table3()),
        "ext_sched" => Some(ext_sched()),
        "ext_buffer" => Some(ext_buffer()),
        "ext_online" => Some(ext_online()),
        "ext_hierarchy" => Some(ext_hierarchy()),
        "ext_selection" => Some(ext_selection()),
        "ext_prefetch" => Some(ext_prefetch()),
        "ext_sharding" => Some(ext_sharding()),
        "ext_durability" => Some(ext_durability()),
        "ext_fairness" => Some(ext_fairness()),
        _ => None,
    }
}

/// Every figure/table id, in presentation order.
pub const ALL_IDS: [&str; 17] = [
    "table2", "fig6", "fig7", "fig8", "fig9a", "fig9b", "fig10", "table3",
    "ext_sched", "ext_buffer", "ext_online", "ext_hierarchy", "ext_selection",
    "ext_prefetch", "ext_sharding", "ext_durability", "ext_fairness",
];
