//! Real execution backend: shard units run the AOT-compiled HLO artifacts on
//! the PJRT CPU client, parameters live in host memory (the DRAM tier of the
//! spilling design), and optimizer steps apply per-shard as backward units
//! retire. Unit durations reported to the engine are measured wallclock, so
//! the virtual-time schedule reflects real compute.
//!
//! Backward recompute: only shard-boundary activations are checkpointed
//! (paper §4.6); a bwd unit first re-runs the shard's interior forwards from
//! the checkpoint, then walks the layers in reverse applying *_bwd HLOs.

use std::time::Instant;

use crate::coordinator::partitioner::{partition, LayerDesc, Partition, PartitionPolicy};
use crate::coordinator::task::ModelTask;
use crate::coordinator::unit::{Phase, ShardUnit};
use crate::error::{HydraError, Result};
use crate::exec::ExecutionBackend;
use crate::runtime::{ConfigArtifacts, Manifest, ModelKind, RuntimeClient};
use crate::tensor::{DType, HostTensor};
use crate::train::data::DataGen;
use crate::train::optimizer::{OptKind, OptSlot, Optimizer};
use crate::util::rng::Rng;

/// User-facing training spec for one model (Figure 4's ModelTask fields).
#[derive(Debug, Clone)]
pub struct RealModelSpec {
    /// Tenant-facing task name.
    pub name: String,
    /// Artifact config (manifest entry) this model executes.
    pub config: String,
    /// Learning rate (runtime-side; never baked into HLO).
    pub lr: f32,
    /// Optimizer kind (SGD / momentum / Adam).
    pub opt: OptKind,
    /// Training epochs.
    pub epochs: u32,
    /// Mini-batches per epoch.
    pub minibatches_per_epoch: u32,
    /// Seed for parameter init and the data stream.
    pub seed: u64,
    /// Forward-only inference task (paper §6). Losses are still logged per
    /// batch (they are the model's NLL on the eval stream) but no gradients
    /// or optimizer steps happen.
    pub inference: bool,
    /// Virtual arrival time of the job (0.0 = present from the start). The
    /// engine keeps the job out of the eligible set until this time passes
    /// — the online multi-tenant setting.
    pub arrival: f64,
    /// Owning tenant (0 = default tenant). Drives weighted-fair scheduling,
    /// per-tenant report sections and admission control.
    pub tenant: usize,
    /// Fair-share weight under the `weighted-fair` scheduler (must be
    /// finite and > 0; 1.0 = equal share).
    pub weight: f64,
    /// Optional latency SLO: the job meets its deadline iff
    /// `finish - arrival <= deadline`. Attainment lands in the report's
    /// per-tenant section.
    pub deadline: Option<f64>,
}

/// A model layer at shard granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LayerKind {
    Embed,
    Block,
    Head,
}

impl LayerKind {
    fn exe(self, phase: Phase) -> &'static str {
        match (self, phase) {
            (LayerKind::Embed, Phase::Fwd) => "embed_fwd",
            (LayerKind::Embed, Phase::Bwd) => "embed_bwd",
            (LayerKind::Block, Phase::Fwd) => "block_fwd",
            (LayerKind::Block, Phase::Bwd) => "block_bwd",
            (LayerKind::Head, Phase::Fwd) => "head_fwd",
            (LayerKind::Head, Phase::Bwd) => "head_bwd",
        }
    }
}

/// Mutable training state of one model instance.
struct ModelState {
    spec: RealModelSpec,
    cfg: ConfigArtifacts,
    layers: Vec<LayerKind>,
    /// Parameters per layer, in manifest spec order (the HLO ABI).
    params: Vec<Vec<HostTensor>>,
    opt: Optimizer,
    slots: Vec<Vec<OptSlot>>,
    /// Layer ranges per shard: shard i covers layers[ranges[i].0..ranges[i].1].
    ranges: Vec<(usize, usize)>,
    /// Checkpointed input activation per shard boundary (index = layer).
    boundary: Vec<Option<HostTensor>>,
    /// Cotangent flowing down between bwd shard units.
    cot: Option<HostTensor>,
    datagen: DataGen,
    /// Loss per minibatch (step, loss), appended by head_fwd.
    pub losses: Vec<(u64, f32)>,
    step: u64,
}

impl ModelState {
    fn minibatch_data(&self, epoch: u32, minibatch: u32) -> (HostTensor, HostTensor) {
        self.datagen.minibatch(&self.cfg.config, epoch, minibatch)
    }

    fn layer_params(&self, layer: usize) -> Vec<&HostTensor> {
        self.params[layer].iter().collect()
    }
}

/// Measured pilot-run statistics for one artifact config (Algorithm 1's
/// "record runtime statistics for later use by our Scheduler").
#[derive(Debug, Clone, Copy)]
pub struct PilotStats {
    pub embed_fwd: f64,
    pub embed_bwd: f64,
    pub block_fwd: f64,
    pub block_bwd: f64,
    pub head_fwd: f64,
    pub head_bwd: f64,
}

/// Median early-stopping rule (§4.7.2 / Vizier-style): after `min_epochs`,
/// a model whose epoch-mean loss is worse than the median of all models'
/// means at the same epoch is stopped.
#[derive(Debug, Clone, Copy)]
pub struct MedianRule {
    pub min_epochs: u32,
}

/// The real backend: owns the runtime client and all model states.
pub struct RealBackend {
    client: RuntimeClient,
    states: Vec<ModelState>,
    /// Optional AutoML-style early stopping across the model set.
    pub early_stop: Option<MedianRule>,
}

impl RealBackend {
    /// Build states + ModelTasks: pilot-runs each distinct config, estimates
    /// layer memory footprints, partitions against the smallest device, and
    /// initialises parameters (seeded).
    pub fn build(
        manifest_dir: &str,
        specs: &[RealModelSpec],
        min_device_mem: u64,
        policy: PartitionPolicy,
    ) -> Result<(RealBackend, Vec<ModelTask>)> {
        let manifest = Manifest::load(manifest_dir)?;
        let mut client = RuntimeClient::new(manifest)?;

        let mut states = Vec::new();
        let mut tasks = Vec::new();
        let mut pilot_cache: std::collections::BTreeMap<String, PilotStats> =
            Default::default();

        for (id, spec) in specs.iter().enumerate() {
            let cfg = client.config(&spec.config)?.clone();
            let pilot = match pilot_cache.get(&spec.config) {
                Some(p) => *p,
                None => {
                    let p = pilot_run(&mut client, &cfg)?;
                    pilot_cache.insert(spec.config.clone(), p);
                    p
                }
            };

            let layers = layer_list(&cfg);
            let layer_descs = layer_descs(&cfg, &layers, &pilot, spec.opt);
            let part: Partition = partition(&layer_descs, min_device_mem, policy)?;
            let ranges = ranges_from_cuts(&part.cuts);

            let task = if spec.inference {
                ModelTask::new_inference(
                    id,
                    spec.name.clone(),
                    spec.config.clone(),
                    part.shards.clone(),
                    spec.minibatches_per_epoch,
                )
            } else {
                ModelTask::new(
                    id,
                    spec.name.clone(),
                    spec.config.clone(),
                    part.shards.clone(),
                    spec.minibatches_per_epoch,
                    spec.epochs,
                    spec.lr,
                )
            }
            .with_arrival(spec.arrival)
            .with_tenant(spec.tenant, spec.weight);
            let task = match spec.deadline {
                Some(d) => task.with_deadline(d),
                None => task,
            };

            let mut rng = Rng::new(spec.seed);
            let params: Vec<Vec<HostTensor>> = layers
                .iter()
                .enumerate()
                .map(|(li, _)| init_layer_params(&cfg, kind_str(layers[li]), &mut rng))
                .collect();
            let slots = params
                .iter()
                .map(|ps| ps.iter().map(|_| OptSlot::default()).collect())
                .collect();

            let n_layers = layers.len();
            states.push(ModelState {
                spec: spec.clone(),
                cfg,
                layers,
                params,
                opt: Optimizer::new(spec.opt, spec.lr),
                slots,
                ranges,
                boundary: vec![None; n_layers + 1],
                cot: None,
                datagen: DataGen::new(spec.seed ^ 0xDA7A),
                losses: Vec::new(),
                step: 0,
            });
            tasks.push(task);
        }

        // Warm the executable cache so compilation never lands mid-schedule.
        for spec in specs {
            client.preload_config(&spec.config)?;
        }
        Ok((RealBackend { client, states, early_stop: None }, tasks))
    }

    pub fn loss_log(&self, model: usize) -> &[(u64, f32)] {
        &self.states[model].losses
    }

    pub fn model_params(&self, model: usize) -> &[Vec<HostTensor>] {
        &self.states[model].params
    }

    pub fn steps_completed(&self, model: usize) -> u64 {
        self.states[model].step
    }

    /// Forward one layer; returns its output (head returns loss: logged).
    /// `recompute` selects the reference-ops forward for interior recompute
    /// inside bwd units (same numerics, no interpret-mode loops — §Perf L2).
    fn run_layer_fwd(
        &mut self,
        model: usize,
        layer: usize,
        input: &HostTensor,
        unit: &ShardUnit,
        recompute: bool,
    ) -> Result<Option<HostTensor>> {
        let kind = self.states[model].layers[layer];
        let entry = if recompute && kind == LayerKind::Block {
            "block_fwd_ref"
        } else {
            kind.exe(Phase::Fwd)
        };
        let exe = self
            .client
            .load(&self.states[model].spec.config, entry)?;
        match kind {
            LayerKind::Embed | LayerKind::Block => {
                let st = &self.states[model];
                let mut args = st.layer_params(layer);
                args.push(input);
                let out = exe.run(&args)?;
                Ok(Some(out.into_iter().next().unwrap()))
            }
            LayerKind::Head => {
                let (_, targets) =
                    self.states[model].minibatch_data(unit.epoch, unit.minibatch);
                let st = &self.states[model];
                let mut args = st.layer_params(layer);
                args.push(input);
                args.push(&targets);
                let out = exe.run(&args)?;
                let loss = out[0].scalar_value();
                let step = self.states[model].step;
                self.states[model].losses.push((step, loss));
                if self.states[model].spec.inference {
                    // forward-only: the batch is complete here
                    let st = &mut self.states[model];
                    st.boundary.iter_mut().for_each(|b| *b = None);
                    st.step += 1;
                }
                Ok(None)
            }
        }
    }

    /// Backward one layer: returns d_input (None for embed) and applies the
    /// optimizer to the layer's parameters.
    fn run_layer_bwd(
        &mut self,
        model: usize,
        layer: usize,
        input: &HostTensor,
        cot: Option<&HostTensor>,
        unit: &ShardUnit,
    ) -> Result<Option<HostTensor>> {
        let kind = self.states[model].layers[layer];
        let exe = self
            .client
            .load(&self.states[model].spec.config, kind.exe(Phase::Bwd))?;
        let (d_input, grads): (Option<HostTensor>, Vec<HostTensor>) = match kind {
            LayerKind::Head => {
                let (_, targets) =
                    self.states[model].minibatch_data(unit.epoch, unit.minibatch);
                let st = &self.states[model];
                let mut args = st.layer_params(layer);
                args.push(input);
                args.push(&targets);
                let mut out = exe.run(&args)?;
                // outputs: [loss, d_x, grads...]
                let grads = out.split_off(2);
                let d_x = out.pop().unwrap();
                (Some(d_x), grads)
            }
            LayerKind::Block => {
                let cot = cot.ok_or_else(|| {
                    HydraError::Exec("block bwd without cotangent".into())
                })?;
                let st = &self.states[model];
                let mut args = st.layer_params(layer);
                args.push(input);
                args.push(cot);
                let mut out = exe.run(&args)?;
                // outputs: [d_x, grads...]
                let grads = out.split_off(1);
                let d_x = out.pop().unwrap();
                (Some(d_x), grads)
            }
            LayerKind::Embed => {
                let cot = cot.ok_or_else(|| {
                    HydraError::Exec("embed bwd without cotangent".into())
                })?;
                let (data, _) =
                    self.states[model].minibatch_data(unit.epoch, unit.minibatch);
                let st = &self.states[model];
                let mut args = st.layer_params(layer);
                args.push(&data);
                args.push(cot);
                let out = exe.run(&args)?;
                (None, out)
            }
        };
        // optimizer step on this layer
        let st = &mut self.states[model];
        debug_assert_eq!(grads.len(), st.params[layer].len());
        for (i, g) in grads.iter().enumerate() {
            let mut slot = std::mem::take(&mut st.slots[layer][i]);
            st.opt.step(&mut st.params[layer][i], g, &mut slot);
            st.slots[layer][i] = slot;
        }
        Ok(d_input)
    }

    fn exec_fwd_unit(&mut self, model: usize, unit: &ShardUnit) -> Result<()> {
        let (a, b) = self.states[model].ranges[unit.shard as usize];
        let mut x: HostTensor = if a == 0 {
            let (data, _) = self.states[model].minibatch_data(unit.epoch, unit.minibatch);
            data
        } else {
            self.states[model].boundary[a]
                .clone()
                .ok_or_else(|| HydraError::Exec(format!(
                    "model {model}: missing boundary activation at layer {a}")))?
        };
        for layer in a..b {
            match self.run_layer_fwd(model, layer, &x, unit, false)? {
                Some(out) => x = out,
                None => return Ok(()), // head: minibatch forward complete
            }
        }
        self.states[model].boundary[b] = Some(x);
        Ok(())
    }

    fn exec_bwd_unit(&mut self, model: usize, unit: &ShardUnit) -> Result<()> {
        let (a, b) = self.states[model].ranges[unit.shard as usize];
        // 1. recompute interior inputs from the boundary checkpoint
        let mut xs: Vec<HostTensor> = Vec::with_capacity(b - a);
        let mut x: HostTensor = if a == 0 {
            self.states[model].minibatch_data(unit.epoch, unit.minibatch).0
        } else {
            self.states[model].boundary[a]
                .clone()
                .ok_or_else(|| HydraError::Exec(format!(
                    "model {model}: missing boundary activation at layer {a}")))?
        };
        for layer in a..b {
            xs.push(x.clone());
            if layer + 1 < b {
                x = self
                    .run_layer_fwd(model, layer, &x, unit, true)?
                    .ok_or_else(|| HydraError::Exec("head mid-shard".into()))?;
            }
        }
        // 2. reverse sweep
        let mut cot = self.states[model].cot.take();
        for (idx, layer) in (a..b).enumerate().rev() {
            cot = self.run_layer_bwd(model, layer, &xs[idx], cot.as_ref(), unit)?;
        }
        if a == 0 {
            // minibatch complete: clear checkpoints, bump step
            let st = &mut self.states[model];
            st.boundary.iter_mut().for_each(|bnd| *bnd = None);
            st.cot = None;
            st.step += 1;
        } else {
            self.states[model].cot = cot;
            self.states[model].boundary[b] = None; // consumed
        }
        Ok(())
    }
}

impl RealBackend {
    /// Mean loss of `model` during `epoch` (None if not fully recorded).
    fn epoch_mean_loss(&self, model: usize, epoch: u32) -> Option<f32> {
        let st = &self.states[model];
        let mbs = st.spec.minibatches_per_epoch as usize;
        let lo = epoch as usize * mbs;
        let hi = lo + mbs;
        if st.losses.len() < hi {
            return None;
        }
        Some(st.losses[lo..hi].iter().map(|&(_, l)| l).sum::<f32>() / mbs as f32)
    }
}

impl ExecutionBackend for RealBackend {
    fn execute_unit(&mut self, task: &ModelTask, unit: &ShardUnit) -> Result<f64> {
        let t0 = Instant::now();
        match unit.phase {
            Phase::Fwd => self.exec_fwd_unit(task.id, unit)?,
            Phase::Bwd => self.exec_bwd_unit(task.id, unit)?,
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    fn should_early_stop(&mut self, task: &ModelTask, epoch: u32) -> bool {
        let Some(rule) = self.early_stop else { return false };
        if epoch + 1 < rule.min_epochs {
            return false;
        }
        let Some(mine) = self.epoch_mean_loss(task.id, epoch) else {
            return false;
        };
        // median over every model that has completed this epoch
        let mut peers: Vec<f32> = (0..self.states.len())
            .filter_map(|m| self.epoch_mean_loss(m, epoch))
            .collect();
        if peers.len() < 2 {
            return false;
        }
        peers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = peers[peers.len() / 2];
        mine > median
    }
}

// ---------------------------------------------------------------------------
// construction helpers
// ---------------------------------------------------------------------------

fn kind_str(k: LayerKind) -> &'static str {
    match k {
        LayerKind::Embed => "embed",
        LayerKind::Block => "block",
        LayerKind::Head => "head",
    }
}

fn layer_list(cfg: &ConfigArtifacts) -> Vec<LayerKind> {
    let mut layers = vec![LayerKind::Embed];
    layers.extend(std::iter::repeat(LayerKind::Block).take(cfg.config.n_layers));
    layers.push(LayerKind::Head);
    layers
}

/// Initialise one layer's parameters per the manifest init specs.
pub fn init_layer_params(
    cfg: &ConfigArtifacts,
    kind: &str,
    rng: &mut Rng,
) -> Vec<HostTensor> {
    cfg.param_specs(kind)
        .iter()
        .map(|p| match p.init {
            crate::runtime::InitSpec::Normal { std } => {
                HostTensor::normal(&p.shape, std, rng)
            }
            crate::runtime::InitSpec::Zeros => HostTensor::zeros(&p.shape, DType::F32),
            crate::runtime::InitSpec::Ones => HostTensor::ones(&p.shape),
        })
        .collect()
}

/// Estimated memory footprints + measured costs per layer.
fn layer_descs(
    cfg: &ConfigArtifacts,
    layers: &[LayerKind],
    pilot: &PilotStats,
    opt: OptKind,
) -> Vec<LayerDesc> {
    let c = &cfg.config;
    let opt_factor = 1 + opt.state_factor();
    let act = (c.batch * c.seq * c.d_model * 4) as u64;
    let wbytes = |kind: &str| -> u64 {
        cfg.param_specs(kind).iter().map(|p| p.size_bytes()).sum::<u64>()
    };
    let pbytes = |kind: &str| -> u64 { wbytes(kind) * opt_factor };
    // workspace: intra-layer activations. Block: qkv + attn + ffn hidden;
    // head: logits dominate; embed: negligible beyond output.
    let block_ws = (c.batch * c.seq * (3 * c.d_model + c.d_ff) * 4) as u64;
    let head_ws = (c.batch * c.seq * c.vocab * 4) as u64;
    layers
        .iter()
        .map(|k| match k {
            LayerKind::Embed => LayerDesc {
                param_bytes: pbytes("embed"),
                weight_bytes: wbytes("embed"),
                workspace_bytes: act,
                activation_bytes: act,
                fwd_cost: pilot.embed_fwd,
                bwd_cost: pilot.embed_bwd,
            },
            LayerKind::Block => LayerDesc {
                param_bytes: pbytes("block"),
                weight_bytes: wbytes("block"),
                workspace_bytes: block_ws,
                activation_bytes: act,
                fwd_cost: pilot.block_fwd,
                bwd_cost: pilot.block_bwd,
            },
            LayerKind::Head => LayerDesc {
                param_bytes: pbytes("head"),
                weight_bytes: wbytes("head"),
                workspace_bytes: head_ws,
                activation_bytes: act,
                fwd_cost: pilot.head_fwd,
                bwd_cost: pilot.head_bwd,
            },
        })
        .collect()
}

fn ranges_from_cuts(cuts: &[usize]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::with_capacity(cuts.len());
    let mut start = 0;
    for &end in cuts {
        ranges.push((start, end));
        start = end;
    }
    ranges
}

/// Algorithm 1's pilot pass: run each entry point once with synthetic
/// inputs, recording wallclock. Compilation happens here too, so the pilot
/// also serves as the warm-up.
fn pilot_run(client: &mut RuntimeClient, cfg: &ConfigArtifacts) -> Result<PilotStats> {
    let c = cfg.config.clone();
    let mut rng = Rng::new(0x9107);
    let name = c.name.clone();

    let embed_p = init_layer_params(cfg, "embed", &mut rng);
    let block_p = init_layer_params(cfg, "block", &mut rng);
    let head_p = init_layer_params(cfg, "head", &mut rng);

    let data = match c.kind {
        ModelKind::Lm => HostTensor::from_i32(
            &[c.batch, c.seq],
            (0..c.batch * c.seq).map(|i| (i % c.vocab) as i32).collect(),
        ),
        ModelKind::Cls => {
            HostTensor::normal(&[c.batch, c.seq, c.patch_dim], 1.0, &mut rng)
        }
    };
    let targets = match c.kind {
        ModelKind::Lm => HostTensor::from_i32(
            &[c.batch, c.seq],
            (0..c.batch * c.seq).map(|i| ((i * 3) % c.vocab) as i32).collect(),
        ),
        ModelKind::Cls => HostTensor::from_i32(
            &[c.batch],
            (0..c.batch).map(|i| (i % c.vocab) as i32).collect(),
        ),
    };

    let timed = |client: &mut RuntimeClient, entry: &str, args: &[&HostTensor]| -> Result<(Vec<HostTensor>, f64)> {
        let exe = client.load(&name, entry)?;
        // first call includes one-time buffer warmup; measure second call
        let _ = exe.run(args)?;
        let (out, d) = exe.run_timed(args)?;
        Ok((out, d.as_secs_f64()))
    };

    let mut args: Vec<&HostTensor> = embed_p.iter().collect();
    args.push(&data);
    let (h_out, embed_fwd) = timed(client, "embed_fwd", &args)?;
    let h = h_out.into_iter().next().unwrap();

    let mut args: Vec<&HostTensor> = embed_p.iter().collect();
    args.push(&data);
    args.push(&h);
    let (_, embed_bwd) = timed(client, "embed_bwd", &args)?;

    let mut args: Vec<&HostTensor> = block_p.iter().collect();
    args.push(&h);
    let (y_out, block_fwd) = timed(client, "block_fwd", &args)?;
    let y = y_out.into_iter().next().unwrap();

    let mut args: Vec<&HostTensor> = block_p.iter().collect();
    args.push(&h);
    args.push(&y);
    let (_, block_bwd) = timed(client, "block_bwd", &args)?;

    let mut args: Vec<&HostTensor> = head_p.iter().collect();
    args.push(&y);
    args.push(&targets);
    let (_, head_fwd) = timed(client, "head_fwd", &args)?;

    let mut args: Vec<&HostTensor> = head_p.iter().collect();
    args.push(&y);
    args.push(&targets);
    let (_, head_bwd) = timed(client, "head_bwd", &args)?;

    Ok(PilotStats { embed_fwd, embed_bwd, block_fwd, block_bwd, head_fwd, head_bwd })
}
