//! Execution backends for the SHARP engine.
//!
//! The engine's scheduling, spilling and buffering logic is backend-agnostic
//! (DESIGN.md §1): `SimBackend` advances virtual time by a calibrated cost
//! model (paper-scale figure reproduction); `RealBackend` (exec::real)
//! executes the AOT HLO artifacts on the PJRT CPU client and reports
//! measured wallclock, while actually updating model parameters.

pub mod real;

use crate::coordinator::task::ModelTask;
use crate::coordinator::unit::ShardUnit;
use crate::error::Result;
use crate::util::rng::Rng;

/// A backend executes shard units and observes retirements.
pub trait ExecutionBackend {
    /// Execute one shard unit; returns its compute duration in (virtual)
    /// seconds. For the sim backend this is the cost model; for the real
    /// backend it is measured wallclock of the PJRT execution.
    fn execute_unit(&mut self, task: &ModelTask, unit: &ShardUnit) -> Result<f64>;

    /// Called after the engine retires a unit (loss logging, optimizer
    /// hooks). Default: no-op.
    fn on_unit_retired(&mut self, _task: &ModelTask, _unit: &ShardUnit) {}

    /// Consulted at each epoch boundary (§4.7.2: convergence-based stopping
    /// and AutoML early stopping). Returning true drops the model's
    /// remaining units. Default: never stop.
    fn should_early_stop(&mut self, _task: &ModelTask, _epoch: u32) -> bool {
        false
    }

    /// The backend's PRNG state, if it has one the durability subsystem can
    /// snapshot ([`SimBackend`] does; the real backend's wallclock is not
    /// replayable and returns `None`, which restricts snapshots to sim
    /// runs). Default: `None`.
    fn sim_rng_state(&self) -> Option<[u64; 4]> {
        None
    }

    /// Fork an independent copy of this backend for one shard of a
    /// threaded sharded run (`EngineOptions::threads`). The contract: every
    /// fork must return exactly what the original would have returned for
    /// that shard's units in the sequential shard loop — otherwise the
    /// threaded merge cannot be byte-identical to sequential execution.
    ///
    /// Default: `None`, meaning the backend has cross-shard state threads
    /// would corrupt and the sharded engine must refuse `threads: true`.
    /// [`SimBackend`] forks only when `noise == 0.0`: the noiseless cost
    /// model never draws from its RNG, so copies are trivially equivalent,
    /// while a noisy backend consumes one global RNG stream in shard order
    /// that per-shard copies could not replicate.
    fn fork_for_shard(&self) -> Option<Box<dyn ExecutionBackend + Send>> {
        None
    }
}

/// Cost-model backend: unit duration = ShardDesc estimate, optionally
/// perturbed by multiplicative noise to model runtime variance.
pub struct SimBackend {
    /// Relative noise amplitude (0.0 = deterministic; 0.05 = ±5%).
    pub noise: f64,
    rng: Rng,
}

impl SimBackend {
    pub fn new(noise: f64, seed: u64) -> SimBackend {
        SimBackend { noise, rng: Rng::new(seed) }
    }

    pub fn deterministic() -> SimBackend {
        SimBackend::new(0.0, 0)
    }

    /// The noise stream's raw PRNG state — captured by durability
    /// snapshots so a resumed run draws the exact same perturbations.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Rebuild the backend mid-stream from a snapshot
    /// ([`SimBackend::rng_state`]).
    pub fn from_state(noise: f64, state: [u64; 4]) -> SimBackend {
        SimBackend { noise, rng: Rng::from_state(state) }
    }
}

impl ExecutionBackend for SimBackend {
    fn execute_unit(&mut self, task: &ModelTask, unit: &ShardUnit) -> Result<f64> {
        let base = task.shard(unit.shard).cost(unit.phase);
        if self.noise == 0.0 {
            Ok(base)
        } else {
            let f = 1.0 + self.noise * (2.0 * self.rng.uniform() - 1.0);
            Ok(base * f.max(0.01))
        }
    }

    fn sim_rng_state(&self) -> Option<[u64; 4]> {
        Some(self.rng_state())
    }

    fn fork_for_shard(&self) -> Option<Box<dyn ExecutionBackend + Send>> {
        // noise == 0.0 never touches the RNG, so a fresh copy is
        // byte-equivalent to the shared sequential backend; a noisy stream
        // is consumed in shard order and cannot be split across threads
        if self.noise == 0.0 {
            Some(Box::new(SimBackend::deterministic()))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{ModelTask, ShardDesc};

    fn task() -> ModelTask {
        ModelTask::new(
            0,
            "t",
            "cfg",
            vec![ShardDesc {
                param_bytes: 1,
                fwd_transfer_bytes: 1,
                bwd_transfer_bytes: 1,
                activation_bytes: 1,
                fwd_cost: 2.0,
                bwd_cost: 4.0,
                n_layers: 1,
            }],
            1,
            1,
            0.1,
        )
    }

    #[test]
    fn deterministic_returns_cost_model() {
        let mut b = SimBackend::deterministic();
        let t = task();
        let fwd = t.geometry.unit_at(0, 0);
        let bwd = t.geometry.unit_at(0, 1);
        assert_eq!(b.execute_unit(&t, &fwd).unwrap(), 2.0);
        assert_eq!(b.execute_unit(&t, &bwd).unwrap(), 4.0);
    }

    #[test]
    fn noise_stays_within_band_and_is_seeded() {
        let t = task();
        let fwd = t.geometry.unit_at(0, 0);
        let mut b1 = SimBackend::new(0.1, 7);
        let mut b2 = SimBackend::new(0.1, 7);
        for _ in 0..100 {
            let d1 = b1.execute_unit(&t, &fwd).unwrap();
            let d2 = b2.execute_unit(&t, &fwd).unwrap();
            assert_eq!(d1, d2);
            assert!(d1 >= 2.0 * 0.9 - 1e-9 && d1 <= 2.0 * 1.1 + 1e-9, "{d1}");
        }
    }
}
