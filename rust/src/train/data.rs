//! Synthetic training data (DESIGN.md §1: WikiText-2 / CIFAR-10 stand-ins).
//!
//! - LM: a byte-level corpus with *learnable* bigram structure — a seeded
//!   Markov chain over a small alphabet embedded in white noise. The masked
//!   targets are next-byte predictions, so loss demonstrably drops below
//!   ln(vocab) within a few hundred steps (the e2e validation signal).
//! - CLS: patch "images" drawn from per-class prototype vectors + noise, so
//!   a linear-separable signal exists for the ViT-style classifier.
//!
//! Generation is a pure function of (seed, epoch, minibatch): forward and
//! backward units of the same mini-batch regenerate identical batches, so
//! the backend never has to keep raw data resident (mirrors the paper's
//! "data loading function" contract).

use crate::runtime::{ModelConfig, ModelKind};
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

/// A deterministic mini-batch generator for one model.
#[derive(Debug, Clone)]
pub struct DataGen {
    pub seed: u64,
}

impl DataGen {
    pub fn new(seed: u64) -> DataGen {
        DataGen { seed }
    }

    fn batch_rng(&self, epoch: u32, minibatch: u32) -> Rng {
        Rng::new(
            self.seed ^ ((epoch as u64) << 32) ^ ((minibatch as u64) << 1) ^ 0xDA7A,
        )
    }

    /// Produce (data, targets) for one mini-batch of `cfg`.
    pub fn minibatch(&self, cfg: &ModelConfig, epoch: u32, minibatch: u32) -> (HostTensor, HostTensor) {
        match cfg.kind {
            ModelKind::Lm => self.lm_batch(cfg, epoch, minibatch),
            ModelKind::Cls => self.cls_batch(cfg, epoch, minibatch),
        }
    }

    /// Byte-LM: sequences from a 2-state Markov source over a 16-byte
    /// alphabet; target = next byte (last position wraps to first).
    fn lm_batch(&self, cfg: &ModelConfig, epoch: u32, minibatch: u32) -> (HostTensor, HostTensor) {
        let mut rng = self.batch_rng(epoch, minibatch);
        let alphabet = 16.min(cfg.vocab as u64);
        let b = cfg.batch;
        let s = cfg.seq;
        let mut tokens = vec![0i32; b * s];
        for row in 0..b {
            let mut cur = rng.below(alphabet) as i32;
            for col in 0..s {
                tokens[row * s + col] = cur;
                // bigram structure: mostly deterministic successor + noise
                cur = if rng.uniform() < 0.85 {
                    (cur * 7 + 3) % alphabet as i32
                } else {
                    rng.below(alphabet) as i32
                };
            }
        }
        let mut targets = vec![0i32; b * s];
        for row in 0..b {
            for col in 0..s {
                targets[row * s + col] = if col + 1 < s {
                    tokens[row * s + col + 1]
                } else {
                    tokens[row * s]
                };
            }
        }
        (
            HostTensor::from_i32(&[b, s], tokens),
            HostTensor::from_i32(&[b, s], targets),
        )
    }

    /// CLS: each class has a prototype patch sequence; samples are
    /// prototype + N(0, 0.5) noise.
    fn cls_batch(&self, cfg: &ModelConfig, epoch: u32, minibatch: u32) -> (HostTensor, HostTensor) {
        let mut rng = self.batch_rng(epoch, minibatch);
        let classes = cfg.vocab;
        let b = cfg.batch;
        let n = cfg.seq * cfg.patch_dim;
        let mut data = vec![0.0f32; b * n];
        let mut labels = vec![0i32; b];
        for row in 0..b {
            let class = rng.below(classes as u64) as usize;
            labels[row] = class as i32;
            // prototype: deterministic per (class, position)
            let mut proto = Rng::new(0xC1A55 ^ class as u64);
            for i in 0..n {
                let p = proto.normal() as f32;
                data[row * n + i] = p + 0.5 * rng.normal() as f32;
            }
        }
        (
            HostTensor::from_f32(&[b, cfg.seq, cfg.patch_dim], data),
            HostTensor::from_i32(&[b], labels),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ModelConfig, ModelKind};

    fn lm_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            kind: ModelKind::Lm,
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            d_ff: 128,
            seq: 32,
            batch: 4,
            vocab: 256,
            patch_dim: 0,
        }
    }

    fn cls_cfg() -> ModelConfig {
        ModelConfig {
            name: "c".into(),
            kind: ModelKind::Cls,
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            d_ff: 128,
            seq: 16,
            batch: 8,
            vocab: 10,
            patch_dim: 48,
        }
    }

    #[test]
    fn lm_batches_are_deterministic_per_key() {
        let g = DataGen::new(7);
        let (d1, t1) = g.minibatch(&lm_cfg(), 0, 3);
        let (d2, t2) = g.minibatch(&lm_cfg(), 0, 3);
        assert_eq!(d1, d2);
        assert_eq!(t1, t2);
        let (d3, _) = g.minibatch(&lm_cfg(), 0, 4);
        assert_ne!(d1, d3);
    }

    #[test]
    fn lm_tokens_in_alphabet_targets_shifted() {
        let g = DataGen::new(1);
        let cfg = lm_cfg();
        let (d, t) = g.minibatch(&cfg, 0, 0);
        assert_eq!(d.shape, vec![4, 32]);
        assert!(d.as_i32().iter().all(|&x| (0..16).contains(&x)));
        // target[i] == token[i+1]
        let tok = d.as_i32();
        let tgt = t.as_i32();
        for row in 0..4 {
            for col in 0..31 {
                assert_eq!(tgt[row * 32 + col], tok[row * 32 + col + 1]);
            }
        }
    }

    #[test]
    fn lm_has_bigram_structure() {
        // successor (c*7+3)%16 should dominate
        let g = DataGen::new(2);
        let (d, _) = g.minibatch(&lm_cfg(), 0, 0);
        let tok = d.as_i32();
        let mut hits = 0;
        let mut total = 0;
        for row in 0..4 {
            for col in 0..31 {
                let c = tok[row * 32 + col];
                let n = tok[row * 32 + col + 1];
                total += 1;
                if n == (c * 7 + 3) % 16 {
                    hits += 1;
                }
            }
        }
        assert!(hits as f64 / total as f64 > 0.7, "{hits}/{total}");
    }

    #[test]
    fn cls_labels_and_shapes() {
        let g = DataGen::new(3);
        let cfg = cls_cfg();
        let (d, l) = g.minibatch(&cfg, 1, 2);
        assert_eq!(d.shape, vec![8, 16, 48]);
        assert_eq!(l.shape, vec![8]);
        assert!(l.as_i32().iter().all(|&x| (0..10).contains(&x)));
    }

    #[test]
    fn cls_same_class_samples_correlate() {
        let g = DataGen::new(4);
        let cfg = cls_cfg();
        // gather many samples, average per class, check prototype distance
        let mut per_class: Vec<Vec<f32>> = vec![vec![]; 10];
        for mb in 0..20 {
            let (d, l) = g.minibatch(&cfg, 0, mb);
            let n = cfg.seq * cfg.patch_dim;
            for row in 0..cfg.batch {
                let c = l.as_i32()[row] as usize;
                if per_class[c].is_empty() {
                    per_class[c] = d.as_f32()[row * n..(row + 1) * n].to_vec();
                } else {
                    let other = &d.as_f32()[row * n..(row + 1) * n];
                    let dot: f32 = per_class[c]
                        .iter()
                        .zip(other)
                        .map(|(a, b)| a * b)
                        .sum();
                    // same-class samples share the prototype -> positive corr
                    assert!(dot > 0.0, "class {c} dot {dot}");
                }
            }
        }
    }
}
