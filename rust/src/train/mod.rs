//! Training substrate: synthetic data generation and host-side optimizers.

pub mod data;
pub mod optimizer;
