//! Optimizers over host-owned f32 parameter buffers.
//!
//! Updates run in Rust on the DRAM-resident ("spilled") parameter copies
//! right after a shard's backward unit retires — the per-shard analogue of
//! ZeRO-Offload's CPU optimizer step (§7), and bitwise deterministic.

use crate::tensor::HostTensor;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptKind {
    Sgd,
    Momentum { beta: f32 },
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

impl OptKind {
    pub fn parse(s: &str) -> Result<OptKind, String> {
        match s {
            "sgd" => Ok(OptKind::Sgd),
            "momentum" => Ok(OptKind::Momentum { beta: 0.9 }),
            "adam" => Ok(OptKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }),
            other => Err(format!("unknown optimizer {other:?}")),
        }
    }

    /// Bytes of optimizer state per parameter byte (for the memory model:
    /// spilled shard bytes = params * (1 + state_factor)).
    pub fn state_factor(&self) -> u64 {
        match self {
            OptKind::Sgd => 0,
            OptKind::Momentum { .. } => 1,
            OptKind::Adam { .. } => 2,
        }
    }
}

/// Per-parameter-array optimizer state.
#[derive(Debug, Clone, Default)]
pub struct OptSlot {
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

/// One optimizer instance (shared hyperparameters, per-array slots).
#[derive(Debug, Clone)]
pub struct Optimizer {
    pub kind: OptKind,
    pub lr: f32,
    /// Optional global gradient-norm clip (0 = off).
    pub clip: f32,
}

impl Optimizer {
    pub fn new(kind: OptKind, lr: f32) -> Optimizer {
        Optimizer { kind, lr, clip: 0.0 }
    }

    /// Apply one update step to `param` given `grad`; `slot` holds state.
    pub fn step(&self, param: &mut HostTensor, grad: &HostTensor, slot: &mut OptSlot) {
        let g = grad.as_f32();
        let p = param.as_f32_mut();
        assert_eq!(p.len(), g.len(), "param/grad shape mismatch");

        let scale = if self.clip > 0.0 {
            let norm = g.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > self.clip {
                self.clip / norm
            } else {
                1.0
            }
        } else {
            1.0
        };

        match self.kind {
            OptKind::Sgd => {
                for (pi, gi) in p.iter_mut().zip(g) {
                    *pi -= self.lr * gi * scale;
                }
            }
            OptKind::Momentum { beta } => {
                if slot.m.len() != g.len() {
                    slot.m = vec![0.0; g.len()];
                }
                for ((pi, gi), mi) in p.iter_mut().zip(g).zip(slot.m.iter_mut()) {
                    *mi = beta * *mi + gi * scale;
                    *pi -= self.lr * *mi;
                }
            }
            OptKind::Adam { beta1, beta2, eps } => {
                if slot.m.len() != g.len() {
                    slot.m = vec![0.0; g.len()];
                    slot.v = vec![0.0; g.len()];
                }
                slot.t += 1;
                let bc1 = 1.0 - beta1.powi(slot.t as i32);
                let bc2 = 1.0 - beta2.powi(slot.t as i32);
                for (((pi, gi), mi), vi) in
                    p.iter_mut().zip(g).zip(slot.m.iter_mut()).zip(slot.v.iter_mut())
                {
                    let gs = gi * scale;
                    *mi = beta1 * *mi + (1.0 - beta1) * gs;
                    *vi = beta2 * *vi + (1.0 - beta2) * gs * gs;
                    let mhat = *mi / bc1;
                    let vhat = *vi / bc2;
                    *pi -= self.lr * mhat / (vhat.sqrt() + eps);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32]) -> HostTensor {
        HostTensor::from_f32(&[v.len()], v.to_vec())
    }

    #[test]
    fn sgd_step_matches_hand_math() {
        let opt = Optimizer::new(OptKind::Sgd, 0.1);
        let mut p = t(&[1.0, 2.0]);
        let g = t(&[10.0, -5.0]);
        opt.step(&mut p, &g, &mut OptSlot::default());
        assert_eq!(p.as_f32(), &[0.0, 2.5]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let opt = Optimizer::new(OptKind::Momentum { beta: 0.5 }, 1.0);
        let mut p = t(&[0.0]);
        let g = t(&[1.0]);
        let mut s = OptSlot::default();
        opt.step(&mut p, &g, &mut s); // v=1, p=-1
        opt.step(&mut p, &g, &mut s); // v=1.5, p=-2.5
        assert!((p.as_f32()[0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // bias correction makes |Δp| ≈ lr on step 1 regardless of grad scale
        let opt = Optimizer::new(
            OptKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            0.01,
        );
        for g0 in [0.001f32, 1.0, 100.0] {
            let mut p = t(&[0.0]);
            let g = t(&[g0]);
            opt.step(&mut p, &g, &mut OptSlot::default());
            assert!((p.as_f32()[0].abs() - 0.01).abs() < 1e-4, "{}", p.as_f32()[0]);
        }
    }

    #[test]
    fn clipping_caps_effective_gradient() {
        let mut opt = Optimizer::new(OptKind::Sgd, 1.0);
        opt.clip = 1.0;
        let mut p = t(&[0.0, 0.0]);
        let g = t(&[30.0, 40.0]); // norm 50 -> scaled to 1
        opt.step(&mut p, &g, &mut OptSlot::default());
        let v = p.as_f32();
        let norm = (v[0] * v[0] + v[1] * v[1]).sqrt();
        assert!((norm - 1.0).abs() < 1e-5, "{norm}");
    }

    #[test]
    fn quadratic_converges_under_all_optimizers() {
        // minimise f(p) = (p-3)^2, grad = 2(p-3)
        for kind in [
            OptKind::Sgd,
            OptKind::Momentum { beta: 0.9 },
            OptKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        ] {
            let lr = match kind {
                OptKind::Adam { .. } => 0.3,
                _ => 0.05,
            };
            let opt = Optimizer::new(kind, lr);
            let mut p = t(&[0.0]);
            let mut slot = OptSlot::default();
            for _ in 0..200 {
                let g = t(&[2.0 * (p.as_f32()[0] - 3.0)]);
                opt.step(&mut p, &g, &mut slot);
            }
            assert!((p.as_f32()[0] - 3.0).abs() < 0.05, "{kind:?}: {}", p.as_f32()[0]);
        }
    }

    #[test]
    fn state_factor_reflects_buffers() {
        assert_eq!(OptKind::Sgd.state_factor(), 0);
        assert_eq!(OptKind::Momentum { beta: 0.9 }.state_factor(), 1);
        assert_eq!(
            OptKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }.state_factor(),
            2
        );
    }
}
