//! Analytical cost model for paper-scale experiments (DESIGN.md §1).
//!
//! The paper's testbed is 8x RTX 2080Ti training 250M–2B-parameter
//! transformers; this module produces per-layer `LayerDesc`s (FLOPs ->
//! seconds via an efficiency-derated throughput, bytes from shapes) so the
//! *same partitioner, engine, and schedulers* that drive real training also
//! regenerate the paper's figures at full scale.

use crate::coordinator::partitioner::LayerDesc;
use crate::coordinator::sharp::{DeviceSpec, TransferModel};

/// A GPU class for the simulator: memory, compute, and host link.
///
/// Heterogeneous pools mix classes; [`GpuSpec::device_spec`] converts a
/// class into the engine-facing [`DeviceSpec`] relative to the reference
/// class the unit costs were calibrated on.
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    /// Device memory capacity in bytes.
    pub mem_bytes: u64,
    /// Peak dense f32 throughput.
    pub peak_flops: f64,
    /// Achievable fraction of peak for transformer training kernels.
    pub efficiency: f64,
    /// Host (PCIe) link bandwidth for spill traffic, bytes per second.
    pub pcie_bytes_per_sec: f64,
}

impl GpuSpec {
    /// NVIDIA RTX 2080Ti (11 GB, ~13.4 TFLOPS fp32, PCIe gen3), the
    /// paper's device.
    pub fn rtx2080ti() -> GpuSpec {
        GpuSpec {
            mem_bytes: 11 * (1 << 30),
            peak_flops: 13.4e12,
            // fp32 PyTorch transformer training on Turing: ~15% of peak
            efficiency: 0.15,
            pcie_bytes_per_sec: 12.0e9,
        }
    }

    /// NVIDIA RTX A4000-class card (16 GB, ~19.2 TFLOPS fp32, PCIe gen4).
    pub fn a4000() -> GpuSpec {
        GpuSpec {
            mem_bytes: 16 * (1 << 30),
            peak_flops: 19.2e12,
            efficiency: 0.15,
            pcie_bytes_per_sec: 24.0e9,
        }
    }

    /// NVIDIA RTX A6000-class card (48 GB, ~38.7 TFLOPS fp32, PCIe gen4).
    pub fn a6000() -> GpuSpec {
        GpuSpec {
            mem_bytes: 48 * (1 << 30),
            peak_flops: 38.7e12,
            efficiency: 0.15,
            pcie_bytes_per_sec: 24.0e9,
        }
    }

    /// Look a class up by name (CLI / config surface).
    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name {
            "rtx2080ti" | "2080ti" => Some(GpuSpec::rtx2080ti()),
            "a4000" => Some(GpuSpec::a4000()),
            "a6000" => Some(GpuSpec::a6000()),
            _ => None,
        }
    }

    /// Sustained training throughput.
    pub fn effective_flops(&self) -> f64 {
        self.peak_flops * self.efficiency
    }

    /// Host-link transfer model for this class.
    pub fn transfer_model(&self) -> TransferModel {
        TransferModel {
            bandwidth_bytes_per_sec: self.pcie_bytes_per_sec,
            latency_secs: 20e-6,
        }
    }

    /// Engine-facing device spec, with speed expressed relative to
    /// `reference` (the class the `ShardDesc` costs were computed for).
    pub fn device_spec(&self, reference: &GpuSpec) -> DeviceSpec {
        DeviceSpec {
            mem_bytes: self.mem_bytes,
            speed: self.effective_flops() / reference.effective_flops(),
            link: Some(self.transfer_model()),
        }
    }
}

/// The calibration reference of a pool: its slowest class by sustained
/// FLOPs, so every relative [`DeviceSpec::speed`] comes out >= 1.0. `None`
/// for an empty pool. Shared by [`crate::sim::build_tasks_pool`] and the
/// config layer so CLI-spec runs and simulated runs always agree on
/// speeds.
pub fn pool_reference(pool: &[GpuSpec]) -> Option<GpuSpec> {
    pool.iter().copied().reduce(|r, g| {
        if g.effective_flops() < r.effective_flops() {
            g
        } else {
            r
        }
    })
}

/// A paper-scale transformer description (BERT-Large* / ViT* of Table 2).
#[derive(Debug, Clone, Copy)]
pub struct PaperModel {
    /// Hidden width.
    pub d_model: usize,
    /// Encoder block count.
    pub n_layers: usize,
    /// Feed-forward width.
    pub d_ff: usize,
    /// Sequence length (ViT: patch count).
    pub seq: usize,
    /// Mini-batch size.
    pub batch: usize,
    /// Vocabulary (ViT: class count).
    pub vocab: usize,
    /// Optimizer state bytes per parameter byte (momentum = 1).
    pub opt_factor: u64,
}

impl PaperModel {
    /// BERT-Large-style encoder scaled to ~`target_params` parameters
    /// (Table 2: 1B with seq 128, vocab 30k; batch from the grid).
    pub fn bert_like(target_params: u64, batch: usize) -> PaperModel {
        let d = 2048usize;
        let vocab = 30_522usize;
        let per_layer = 12 * d * d; // qkvo (4d^2) + ffn (8d^2) with ff=4d
        let embed = vocab * d;
        let n_layers =
            (((target_params as usize).saturating_sub(embed)) / per_layer).max(1);
        PaperModel {
            d_model: d,
            n_layers,
            d_ff: 4 * d,
            seq: 128,
            batch,
            vocab,
            // gradient buffer + momentum alongside weights (paper's training
            // residency; what makes 1B "larger than GPU memory" on 11 GB)
            opt_factor: 2,
        }
    }

    /// BERT-style encoder with an explicit depth — model-selection spaces
    /// sweep `layers` directly ([`crate::selection::SearchSpace`]), where
    /// [`PaperModel::bert_like`] solves depth from a parameter target.
    /// Same width/sequence/vocab as the Table 2 grid.
    pub fn bert_depth(n_layers: usize, batch: usize) -> PaperModel {
        let d = 2048usize;
        PaperModel {
            d_model: d,
            n_layers: n_layers.max(1),
            d_ff: 4 * d,
            seq: 128,
            batch: batch.max(1),
            vocab: 30_522,
            opt_factor: 2,
        }
    }

    /// ViT-style encoder scaled to ~`target_params` (Table 2: 300M–2B,
    /// CIFAR-10: small patch grid, 10 classes).
    pub fn vit_like(target_params: u64, batch: usize) -> PaperModel {
        let d = 1664usize;
        let per_layer = 12 * d * d;
        let n_layers = ((target_params as usize) / per_layer).max(1);
        PaperModel {
            d_model: d,
            n_layers,
            d_ff: 4 * d,
            seq: 64,
            batch,
            vocab: 10,
            opt_factor: 2,
        }
    }

    /// Tokens processed per mini-batch.
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq
    }

    /// Parameters of one encoder block.
    pub fn block_params(&self) -> u64 {
        (4 * self.d_model * self.d_model
            + 2 * self.d_model * self.d_ff
            + 9 * self.d_model
            + self.d_ff) as u64
    }

    /// Parameters of the embedding (token + positional) layer.
    pub fn embed_params(&self) -> u64 {
        (self.vocab * self.d_model + self.seq * self.d_model) as u64
    }

    /// Parameters of the output head.
    pub fn head_params(&self) -> u64 {
        (self.d_model * self.vocab + self.vocab + 2 * self.d_model) as u64
    }

    /// Total model parameters.
    pub fn total_params(&self) -> u64 {
        self.embed_params()
            + self.n_layers as u64 * self.block_params()
            + self.head_params()
    }

    /// Forward FLOPs of one encoder block on one mini-batch:
    /// 2 * params * tokens (GEMMs) + attention score/context matmuls.
    pub fn block_fwd_flops(&self) -> f64 {
        let tokens = self.tokens_per_batch() as f64;
        let gemm = 2.0 * self.block_params() as f64 * tokens;
        let attn = 4.0 * tokens * self.seq as f64 * self.d_model as f64;
        gemm + attn
    }

    /// Forward FLOPs of the embedding layer on one mini-batch.
    pub fn embed_fwd_flops(&self) -> f64 {
        // lookup + positional add: bandwidth-bound; charge 10 flops/token/dim
        10.0 * self.tokens_per_batch() as f64 * self.d_model as f64
    }

    /// Forward FLOPs of the output head on one mini-batch.
    pub fn head_fwd_flops(&self) -> f64 {
        2.0 * self.tokens_per_batch() as f64
            * self.d_model as f64
            * self.vocab as f64
    }

    /// Per-layer descriptors for the partitioner (same path as real models).
    pub fn layer_descs(&self, gpu: &GpuSpec) -> Vec<LayerDesc> {
        let flops = gpu.effective_flops();
        let act = (self.batch * self.seq * self.d_model * 4) as u64;
        let bwd_factor = 2.0;
        let block_ws =
            (self.batch * self.seq * (3 * self.d_model + self.d_ff) * 4) as u64;
        let head_ws = (self.batch * self.seq * self.vocab * 4) as u64;

        let mut layers = Vec::with_capacity(self.n_layers + 2);
        layers.push(LayerDesc {
            param_bytes: self.embed_params() * 4 * (1 + self.opt_factor),
            weight_bytes: self.embed_params() * 4,
            workspace_bytes: act,
            activation_bytes: act,
            fwd_cost: self.embed_fwd_flops() / flops,
            bwd_cost: bwd_factor * self.embed_fwd_flops() / flops,
        });
        for _ in 0..self.n_layers {
            layers.push(LayerDesc {
                param_bytes: self.block_params() * 4 * (1 + self.opt_factor),
                weight_bytes: self.block_params() * 4,
                workspace_bytes: block_ws,
                activation_bytes: act,
                fwd_cost: self.block_fwd_flops() / flops,
                bwd_cost: bwd_factor * self.block_fwd_flops() / flops,
            });
        }
        layers.push(LayerDesc {
            param_bytes: self.head_params() * 4 * (1 + self.opt_factor),
            weight_bytes: self.head_params() * 4,
            workspace_bytes: head_ws,
            activation_bytes: act,
            fwd_cost: self.head_fwd_flops() / flops,
            bwd_cost: bwd_factor * self.head_fwd_flops() / flops,
        });
        layers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::partitioner::{partition, PartitionPolicy};

    #[test]
    fn bert_1b_hits_parameter_target() {
        let m = PaperModel::bert_like(1_000_000_000, 8);
        let p = m.total_params();
        assert!(
            (0.8e9..1.2e9).contains(&(p as f64)),
            "params {p}"
        );
    }

    #[test]
    fn bert_depth_scales_linearly_in_layers() {
        let shallow = PaperModel::bert_depth(12, 8);
        let deep = PaperModel::bert_depth(48, 8);
        assert_eq!(shallow.n_layers, 12);
        assert_eq!(deep.n_layers, 48);
        let extra = deep.total_params() - shallow.total_params();
        assert_eq!(extra, 36 * deep.block_params());
        // degenerate inputs clamp instead of panicking
        assert_eq!(PaperModel::bert_depth(0, 0).n_layers, 1);
        assert_eq!(PaperModel::bert_depth(0, 0).batch, 1);
    }

    #[test]
    fn vit_scales_span_the_table2_range() {
        for target in [300e6 as u64, 600e6 as u64, 2_000_000_000] {
            let m = PaperModel::vit_like(target, 512);
            let p = m.total_params() as f64;
            assert!(
                (0.7 * target as f64..1.3 * target as f64).contains(&p),
                "target {target} got {p}"
            );
        }
    }

    #[test]
    fn one_b_model_does_not_fit_one_2080ti() {
        // the paper's premise: 1B params (+momentum) > 11 GB
        let m = PaperModel::bert_like(1_000_000_000, 8);
        let gpu = GpuSpec::rtx2080ti();
        let bytes = m.total_params() * 4 * (1 + m.opt_factor);
        assert!(bytes > gpu.mem_bytes, "{bytes} <= {}", gpu.mem_bytes);
    }

    #[test]
    fn partitioner_splits_1b_model_into_multiple_shards() {
        let m = PaperModel::bert_like(1_000_000_000, 8);
        let gpu = GpuSpec::rtx2080ti();
        let p = partition(&m.layer_descs(&gpu), gpu.mem_bytes, PartitionPolicy::default())
            .unwrap();
        assert!(p.shards.len() >= 2, "{} shards", p.shards.len());
        // every shard individually respects the memory bound
        for s in &p.shards {
            assert!(s.param_bytes < gpu.mem_bytes);
        }
    }

    #[test]
    fn block_fwd_time_is_plausible_milliseconds() {
        // 1B model, batch 8, seq 128: block fwd should be O(10ms) on 2080Ti
        let m = PaperModel::bert_like(1_000_000_000, 8);
        let gpu = GpuSpec::rtx2080ti();
        let t = m.block_fwd_flops() / gpu.effective_flops();
        assert!(t > 1e-3 && t < 0.5, "block fwd {t}s");
    }

    #[test]
    fn gpu_classes_resolve_by_name_and_scale() {
        let r = GpuSpec::by_name("rtx2080ti").unwrap();
        let a4 = GpuSpec::by_name("a4000").unwrap();
        let a6 = GpuSpec::by_name("a6000").unwrap();
        assert!(GpuSpec::by_name("h100").is_none());
        assert!(a6.mem_bytes > a4.mem_bytes && a4.mem_bytes > r.mem_bytes);
        // device spec relative to the 2080Ti reference
        let spec = a6.device_spec(&r);
        assert!(spec.speed > 2.0 && spec.speed < 4.0, "{}", spec.speed);
        assert_eq!(spec.mem_bytes, a6.mem_bytes);
        let link = spec.link.unwrap();
        assert!(link.bandwidth_bytes_per_sec > r.pcie_bytes_per_sec);
        // the reference maps to itself at speed 1.0
        assert!((r.device_spec(&r).speed - 1.0).abs() < 1e-12);
    }

    #[test]
    fn flops_scale_linearly_with_batch() {
        let a = PaperModel::bert_like(1_000_000_000, 8);
        let b = PaperModel::bert_like(1_000_000_000, 16);
        let ratio = b.block_fwd_flops() / a.block_fwd_flops();
        assert!((ratio - 2.0).abs() < 1e-9);
    }
}
