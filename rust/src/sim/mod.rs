//! Paper-scale simulation substrate: GPU/transformer cost models and
//! Table 2 workload builders. The SHARP engine itself is backend-agnostic
//! (coordinator::sharp); this module only supplies the numbers.

pub mod cost;
pub mod workload;

pub use cost::{GpuSpec, PaperModel};
pub use workload::{bert_grid, build_tasks, uniform_grid, vit_grid, WorkloadModel};
