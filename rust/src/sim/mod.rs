//! Paper-scale simulation substrate: GPU/transformer cost models and
//! workload builders — the Table 2 batch grids plus online multi-tenant
//! streams and heterogeneous GPU pools. The SHARP engine itself is
//! backend-agnostic (coordinator::sharp); this module only supplies the
//! numbers.

pub mod cost;
pub mod workload;

pub use cost::{pool_reference, GpuSpec, PaperModel};
pub use workload::{
    assign_tenants, bert_grid, build_tasks, build_tasks_pool,
    bursty_mixed_tenants, diurnal_mixed_tenants, mixed_pool, parse_pool,
    poisson_mixed_tenants, uniform_grid, vit_grid, WorkloadModel,
};
