//! Workload builders: Table 2 grids for the paper's batch experiments, plus
//! online multi-tenant streams (Poisson arrivals, mixed BERT/ViT tenants)
//! and heterogeneous GPU pools for the production-serving scenarios.

use crate::coordinator::partitioner::{partition, PartitionPolicy};
use crate::coordinator::sharp::DeviceSpec;
use crate::coordinator::task::ModelTask;
use crate::error::{HydraError, Result};
use crate::sim::cost::{GpuSpec, PaperModel};
use crate::util::rng::Rng;

/// One workload entry prior to partitioning.
#[derive(Debug, Clone)]
pub struct WorkloadModel {
    /// Tenant-facing job name.
    pub name: String,
    /// Transformer description (size, batch, sequence length).
    pub model: PaperModel,
    /// Training epochs.
    pub epochs: u32,
    /// Mini-batches per epoch.
    pub minibatches_per_epoch: u32,
    /// Virtual arrival time in seconds (0.0 = batch workload).
    pub arrival: f64,
    /// Owning tenant (0 = default tenant).
    pub tenant: usize,
    /// Fair-share weight under the weighted-fair scheduler (1.0 = equal).
    pub weight: f64,
    /// Optional latency SLO in virtual seconds after arrival.
    pub deadline: Option<f64>,
}

/// Table 2 row 1: BERT-Large* hyperparameter grid — batch {8,16,32} x
/// lr {1e-3..1e-6} = 12 models, 1B params each, 4 epochs (WikiText-2).
///
/// `minibatches_per_epoch` is scaled down from the real corpus so that
/// simulated makespans stay tractable; schedules are unit-count invariant
/// beyond a few hundred units per model (verified in benches).
pub fn bert_grid(minibatches_per_epoch: u32) -> Vec<WorkloadModel> {
    let mut out = Vec::new();
    for &batch in &[8usize, 16, 32] {
        for &lr_exp in &[3, 4, 5, 6] {
            out.push(WorkloadModel {
                name: format!("bert-1b-b{batch}-lr1e-{lr_exp}"),
                model: PaperModel::bert_like(1_000_000_000, batch),
                epochs: 4,
                // same tokens per epoch regardless of batch size
                minibatches_per_epoch: (minibatches_per_epoch * 8 / batch as u32)
                    .max(1),
                arrival: 0.0,
                tenant: 0,
                weight: 1.0,
                deadline: None,
            });
        }
    }
    out
}

/// Table 2 row 2: ViT* architecture grid — sizes {0.3,0.6,0.8,1,1.5,2}B x
/// batch {512,1024} = 12 models, 5 epochs (CIFAR-10).
pub fn vit_grid(minibatches_per_epoch: u32) -> Vec<WorkloadModel> {
    let sizes: [(u64, &str); 6] = [
        (300_000_000, "300m"),
        (600_000_000, "600m"),
        (800_000_000, "800m"),
        (1_000_000_000, "1b"),
        (1_500_000_000, "1.5b"),
        (2_000_000_000, "2b"),
    ];
    let mut out = Vec::new();
    for (params, tag) in sizes {
        for &batch in &[512usize, 1024] {
            out.push(WorkloadModel {
                name: format!("vit-{tag}-b{batch}"),
                model: PaperModel::vit_like(params, batch),
                epochs: 5,
                minibatches_per_epoch: (minibatches_per_epoch * 512
                    / batch as u32)
                    .max(1),
                arrival: 0.0,
                tenant: 0,
                weight: 1.0,
                deadline: None,
            });
        }
    }
    out
}

/// Uniform grid for the drill-down studies (§5.2): `n` transformer models
/// of `params` parameters each.
pub fn uniform_grid(
    n: usize,
    params: u64,
    batch: usize,
    epochs: u32,
    minibatches_per_epoch: u32,
) -> Vec<WorkloadModel> {
    (0..n)
        .map(|i| WorkloadModel {
            name: format!("uniform-{i}"),
            model: PaperModel::bert_like(params, batch),
            epochs,
            minibatches_per_epoch,
            arrival: 0.0,
            tenant: 0,
            weight: 1.0,
            deadline: None,
        })
        .collect()
}

/// Online multi-tenant stream: `n` jobs with exponential inter-arrival
/// times (a Poisson process at `rate_per_hour`), alternating BERT-style
/// language-model tenants and ViT-style vision tenants with per-tenant
/// size/batch variety. Deterministic for a given `seed`.
pub fn poisson_mixed_tenants(
    n: usize,
    rate_per_hour: f64,
    seed: u64,
    minibatches_per_epoch: u32,
) -> Vec<WorkloadModel> {
    assert!(rate_per_hour > 0.0, "rate must be positive");
    let mut rng = Rng::new(seed);
    let mean_gap_secs = 3600.0 / rate_per_hour;
    let mut t = 0.0f64;
    let mut out = Vec::new();
    for i in 0..n {
        t += exp_sample(&mut rng, mean_gap_secs);
        out.push(mixed_job(i, t, &mut rng, minibatches_per_epoch));
    }
    out
}

/// Inverse-CDF exponential sample; `uniform() < 1.0` keeps ln finite.
fn exp_sample(rng: &mut Rng, mean: f64) -> f64 {
    -(1.0 - rng.uniform()).ln() * mean
}

/// One job of the mixed BERT/ViT stream arriving at `t` (even indexes are
/// BERT-style language models, odd indexes ViT-style vision models).
fn mixed_job(
    i: usize,
    t: f64,
    rng: &mut Rng,
    minibatches_per_epoch: u32,
) -> WorkloadModel {
    if i % 2 == 0 {
        let batch = [8usize, 16, 32][rng.below(3) as usize];
        let params = [600_000_000u64, 1_000_000_000][rng.below(2) as usize];
        WorkloadModel {
            name: format!("tenant{i}-bert-{}m-b{batch}", params / 1_000_000),
            model: PaperModel::bert_like(params, batch),
            epochs: 1,
            minibatches_per_epoch,
            arrival: t,
            tenant: 0,
            weight: 1.0,
            deadline: None,
        }
    } else {
        let batch = [512usize, 1024][rng.below(2) as usize];
        let params =
            [300_000_000u64, 800_000_000, 1_500_000_000][rng.below(3) as usize];
        WorkloadModel {
            name: format!("tenant{i}-vit-{}m-b{batch}", params / 1_000_000),
            model: PaperModel::vit_like(params, batch),
            epochs: 1,
            minibatches_per_epoch,
            arrival: t,
            tenant: 0,
            weight: 1.0,
            deadline: None,
        }
    }
}

/// Diurnal variant of [`poisson_mixed_tenants`]: the arrival rate follows a
/// 24-hour sinusoid around `mean_rate_per_hour` (peak ~1.8x the mean at
/// virtual 6h, trough ~0.2x at 18h), the day/night load cycle of a shared
/// training cluster. Each inter-arrival gap is an exponential sample at the
/// instantaneous rate. Deterministic for a given `seed`.
pub fn diurnal_mixed_tenants(
    n: usize,
    mean_rate_per_hour: f64,
    seed: u64,
    minibatches_per_epoch: u32,
) -> Vec<WorkloadModel> {
    assert!(mean_rate_per_hour > 0.0, "rate must be positive");
    const DAY_SECS: f64 = 86_400.0;
    const AMPLITUDE: f64 = 0.8;
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::new();
    for i in 0..n {
        let phase = (2.0 * std::f64::consts::PI * t / DAY_SECS).sin();
        let rate = (mean_rate_per_hour * (1.0 + AMPLITUDE * phase)).max(1e-3);
        t += exp_sample(&mut rng, 3600.0 / rate);
        out.push(mixed_job(i, t, &mut rng, minibatches_per_epoch));
    }
    out
}

/// Bursty variant of [`poisson_mixed_tenants`]: a two-state Markov-modulated
/// Poisson process. The stream alternates between a quiet state (Poisson at
/// `rate_per_hour`, mean sojourn 30 virtual minutes) and a burst state
/// (Poisson at `burst_factor * rate_per_hour`, mean sojourn 5 minutes), with
/// exponentially distributed sojourns. Memorylessness lets the gap be
/// resampled at each state flip without biasing the process. Deterministic
/// for a given `seed`.
pub fn bursty_mixed_tenants(
    n: usize,
    rate_per_hour: f64,
    burst_factor: f64,
    seed: u64,
    minibatches_per_epoch: u32,
) -> Vec<WorkloadModel> {
    assert!(rate_per_hour > 0.0, "rate must be positive");
    assert!(burst_factor >= 1.0, "burst_factor must be >= 1");
    const QUIET_SOJOURN_SECS: f64 = 1800.0;
    const BURST_SOJOURN_SECS: f64 = 300.0;
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut burst = false;
    let mut state_end = exp_sample(&mut rng, QUIET_SOJOURN_SECS);
    let mut out = Vec::new();
    for i in 0..n {
        loop {
            let rate = if burst { rate_per_hour * burst_factor } else { rate_per_hour };
            let gap = exp_sample(&mut rng, 3600.0 / rate);
            if t + gap <= state_end {
                t += gap;
                break;
            }
            t = state_end;
            burst = !burst;
            let mean = if burst { BURST_SOJOURN_SECS } else { QUIET_SOJOURN_SECS };
            state_end = t + exp_sample(&mut rng, mean);
        }
        out.push(mixed_job(i, t, &mut rng, minibatches_per_epoch));
    }
    out
}

/// Assign tenant metadata round-robin over a weight vector: job `i` belongs
/// to tenant `i % weights.len()` with that tenant's weight, and optionally a
/// uniform latency SLO. This is what the `hydra simulate --online
/// --tenants/--tenant-weights/--slo` flags apply to a generated stream.
pub fn assign_tenants(
    workload: &mut [WorkloadModel],
    weights: &[f64],
    deadline: Option<f64>,
) {
    assert!(!weights.is_empty(), "need at least one tenant weight");
    for (i, w) in workload.iter_mut().enumerate() {
        w.tenant = i % weights.len();
        w.weight = weights[w.tenant];
        w.deadline = deadline;
    }
}

/// A mixed GPU pool: `n_a4000` A4000-class and `n_a6000` A6000-class cards.
pub fn mixed_pool(n_a4000: usize, n_a6000: usize) -> Vec<GpuSpec> {
    let mut pool = vec![GpuSpec::a4000(); n_a4000];
    pool.extend(vec![GpuSpec::a6000(); n_a6000]);
    pool
}

/// Parse a pool description like `"a4000:4,a6000:2"` (class names from
/// [`GpuSpec::by_name`]; a bare class name means one card) into GPU specs.
/// Shared by the `hydra simulate --online --pool ...` CLI flag and the
/// workload-spec `"pool"` key.
pub fn parse_pool(s: &str) -> Result<Vec<GpuSpec>> {
    let mut pool = Vec::new();
    for part in s.split(',') {
        let (class, count) = match part.split_once(':') {
            Some((c, n)) => {
                let n: usize = n.parse().map_err(|_| {
                    HydraError::Config(format!("bad device count in {part:?}"))
                })?;
                (c, n)
            }
            None => (part, 1),
        };
        let gpu = GpuSpec::by_name(class).ok_or_else(|| {
            HydraError::Config(format!("unknown GPU class {class:?} in pool"))
        })?;
        pool.extend(std::iter::repeat(gpu).take(count));
    }
    if pool.is_empty() {
        return Err(HydraError::Config(format!("empty pool {s:?}")));
    }
    Ok(pool)
}

/// Partition every workload model for `gpu` and build ModelTasks
/// (homogeneous pool; arrivals are threaded through).
pub fn build_tasks(
    workload: &[WorkloadModel],
    gpu: &GpuSpec,
    policy: PartitionPolicy,
) -> Result<Vec<ModelTask>> {
    workload
        .iter()
        .enumerate()
        .map(|(id, w)| {
            let layers = w.model.layer_descs(gpu);
            let part = partition(&layers, gpu.mem_bytes, policy)?;
            let task = ModelTask::new(
                id,
                w.name.clone(),
                "paper-sim",
                part.shards,
                w.minibatches_per_epoch,
                w.epochs,
                1e-3,
            )
            .with_arrival(w.arrival)
            .with_tenant(w.tenant, w.weight);
            Ok(match w.deadline {
                Some(d) => task.with_deadline(d),
                None => task,
            })
        })
        .collect()
}

/// Build tasks for a heterogeneous `pool`: unit costs are calibrated
/// against the *slowest* class (so every [`DeviceSpec::speed`] >= 1.0) and
/// shards are partitioned for the *smallest* memory in the pool (the §4.3
/// "smallest-memory GPU" contract, which keeps every shard placeable on
/// every device). Returns the tasks plus the engine-facing device specs,
/// ready for [`crate::coordinator::sharp::SharpEngine::with_devices`].
pub fn build_tasks_pool(
    workload: &[WorkloadModel],
    pool: &[GpuSpec],
    policy: PartitionPolicy,
) -> Result<(Vec<ModelTask>, Vec<DeviceSpec>)> {
    let reference = crate::sim::cost::pool_reference(pool)
        .ok_or_else(|| HydraError::Config("empty GPU pool".into()))?;
    let min_mem = pool.iter().map(|g| g.mem_bytes).min().expect("non-empty pool");
    // cost-calibrate on the slowest class, partition for the smallest memory
    let probe = GpuSpec { mem_bytes: min_mem, ..reference };
    let tasks = workload
        .iter()
        .enumerate()
        .map(|(id, w)| {
            let layers = w.model.layer_descs(&probe);
            let part = partition(&layers, min_mem, policy)?;
            let task = ModelTask::new(
                id,
                w.name.clone(),
                "paper-sim",
                part.shards,
                w.minibatches_per_epoch,
                w.epochs,
                1e-3,
            )
            .with_arrival(w.arrival)
            .with_tenant(w.tenant, w.weight);
            Ok(match w.deadline {
                Some(d) => task.with_deadline(d),
                None => task,
            })
        })
        .collect::<Result<Vec<ModelTask>>>()?;
    let specs = pool.iter().map(|g| g.device_spec(&reference)).collect();
    Ok((tasks, specs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_grid_has_12_models_all_1b() {
        let g = bert_grid(8);
        assert_eq!(g.len(), 12);
        for w in &g {
            let p = w.model.total_params() as f64;
            assert!((0.8e9..1.2e9).contains(&p), "{}: {p}", w.name);
            assert_eq!(w.epochs, 4);
            assert_eq!(w.arrival, 0.0);
        }
        // token budget equalised: batch 32 gets 1/4 the minibatches of batch 8
        assert_eq!(g[0].minibatches_per_epoch, 8); // batch 8
        assert_eq!(g[11].minibatches_per_epoch, 2); // batch 32
    }

    #[test]
    fn vit_grid_spans_sizes() {
        let g = vit_grid(4);
        assert_eq!(g.len(), 12);
        let smallest = g[0].model.total_params();
        let largest = g[10].model.total_params();
        assert!(largest > 5 * smallest);
    }

    #[test]
    fn build_tasks_partitions_against_gpu() {
        let gpu = GpuSpec::rtx2080ti();
        let tasks =
            build_tasks(&uniform_grid(3, 1_000_000_000, 8, 1, 2), &gpu, Default::default())
                .unwrap();
        assert_eq!(tasks.len(), 3);
        for t in &tasks {
            assert!(t.shards.len() >= 2, "{} shards", t.shards.len());
            assert!(t.total_units() > 0);
        }
    }

    #[test]
    fn poisson_arrivals_are_increasing_and_seeded() {
        let a = poisson_mixed_tenants(10, 6.0, 3, 4);
        let b = poisson_mixed_tenants(10, 6.0, 3, 4);
        assert_eq!(a.len(), 10);
        let mut last = 0.0;
        for w in &a {
            assert!(w.arrival > last, "{} <= {last}", w.arrival);
            last = w.arrival;
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.name, y.name);
        }
        // mean inter-arrival roughly 10 minutes at 6 jobs/hour
        let mean = a.last().unwrap().arrival / 10.0;
        assert!(mean > 60.0 && mean < 6000.0, "{mean}");
        // tenants alternate modality
        assert!(a[0].name.contains("bert") && a[1].name.contains("vit"));
    }

    #[test]
    fn diurnal_arrivals_are_increasing_seeded_and_rate_modulated() {
        let a = diurnal_mixed_tenants(40, 60.0, 7, 2);
        let b = diurnal_mixed_tenants(40, 60.0, 7, 2);
        assert_eq!(a.len(), 40);
        let mut last = 0.0;
        for (x, y) in a.iter().zip(&b) {
            assert!(x.arrival > last, "{} <= {last}", x.arrival);
            last = x.arrival;
            assert_eq!(x.arrival, y.arrival);
        }
        // a different seed gives a different stream
        let c = diurnal_mixed_tenants(40, 60.0, 8, 2);
        assert!(a.iter().zip(&c).any(|(x, y)| x.arrival != y.arrival));
    }

    #[test]
    fn bursty_arrivals_are_increasing_and_burstier_than_poisson() {
        let n = 400;
        let mmpp = bursty_mixed_tenants(n, 60.0, 20.0, 5, 2);
        let poisson = poisson_mixed_tenants(n, 60.0, 5, 2);
        let mut last = 0.0;
        for w in &mmpp {
            assert!(w.arrival > last, "{} <= {last}", w.arrival);
            last = w.arrival;
        }
        // squared coefficient of variation of inter-arrival gaps: ~1 for a
        // Poisson process, strictly larger for a 20x burst MMPP
        let scv = |ws: &[WorkloadModel]| {
            let gaps: Vec<f64> = ws
                .windows(2)
                .map(|p| p[1].arrival - p[0].arrival)
                .collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
                / gaps.len() as f64;
            var / (mean * mean)
        };
        assert!(
            scv(&mmpp) > 1.5 * scv(&poisson),
            "mmpp scv {} vs poisson scv {}",
            scv(&mmpp),
            scv(&poisson)
        );
    }

    #[test]
    fn assign_tenants_round_robins_weights_and_slo() {
        let mut ws = uniform_grid(5, 1_000_000, 8, 1, 1);
        assign_tenants(&mut ws, &[10.0, 1.0], Some(120.0));
        assert_eq!(ws[0].tenant, 0);
        assert_eq!(ws[0].weight, 10.0);
        assert_eq!(ws[1].tenant, 1);
        assert_eq!(ws[1].weight, 1.0);
        assert_eq!(ws[4].tenant, 0);
        assert!(ws.iter().all(|w| w.deadline == Some(120.0)));
        // the metadata flows through task building
        let gpu = GpuSpec::rtx2080ti();
        let tasks = build_tasks(&ws, &gpu, Default::default()).unwrap();
        assert_eq!(tasks[1].tenant(), 1);
        assert_eq!(tasks[0].weight(), 10.0);
        assert_eq!(tasks[2].deadline(), Some(120.0));
        assert!(tasks[0].has_tenant_meta());
    }

    #[test]
    fn pool_build_partitions_for_smallest_and_speeds_relative_to_slowest() {
        let pool = mixed_pool(1, 1);
        let grid = uniform_grid(2, 1_000_000_000, 8, 1, 2);
        let (tasks, specs) = build_tasks_pool(&grid, &pool, Default::default()).unwrap();
        assert_eq!(tasks.len(), 2);
        assert_eq!(specs.len(), 2);
        // A4000 is the slowest class -> speed 1.0; A6000 strictly faster
        assert!((specs[0].speed - 1.0).abs() < 1e-12, "{}", specs[0].speed);
        assert!(specs[1].speed > 1.0);
        // every shard fits the smallest (16 GB) device
        let min_mem = pool.iter().map(|g| g.mem_bytes).min().unwrap();
        for t in &tasks {
            for s in &t.shards {
                assert!(s.param_bytes < min_mem);
            }
        }
    }

    #[test]
    fn empty_pool_is_config_error() {
        let grid = uniform_grid(1, 1_000_000, 8, 1, 1);
        assert!(build_tasks_pool(&grid, &[], Default::default()).is_err());
    }

    #[test]
    fn parse_pool_expands_classes_and_counts() {
        let p = parse_pool("a4000:2,a6000").unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(p[0].mem_bytes, GpuSpec::a4000().mem_bytes);
        assert_eq!(p[2].mem_bytes, GpuSpec::a6000().mem_bytes);
    }

    #[test]
    fn parse_pool_rejects_bad_inputs() {
        assert!(parse_pool("h100:2").is_err()); // unknown class
        assert!(parse_pool("a4000:x").is_err()); // bad count
        assert!(parse_pool("a4000:0").is_err()); // expands to nothing
        assert!(parse_pool("").is_err());
    }
}
