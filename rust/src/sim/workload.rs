//! Table 2 workload builders: turn paper-scale model grids into ModelTask
//! sets (partitioned for the target GPU) ready for the SHARP engine or any
//! baseline paradigm.

use crate::coordinator::partitioner::{partition, PartitionPolicy};
use crate::coordinator::task::ModelTask;
use crate::error::Result;
use crate::sim::cost::{GpuSpec, PaperModel};

/// One workload entry prior to partitioning.
#[derive(Debug, Clone)]
pub struct WorkloadModel {
    pub name: String,
    pub model: PaperModel,
    pub epochs: u32,
    pub minibatches_per_epoch: u32,
}

/// Table 2 row 1: BERT-Large* hyperparameter grid — batch {8,16,32} x
/// lr {1e-3..1e-6} = 12 models, 1B params each, 4 epochs (WikiText-2).
///
/// `minibatches_per_epoch` is scaled down from the real corpus so that
/// simulated makespans stay tractable; schedules are unit-count invariant
/// beyond a few hundred units per model (verified in benches).
pub fn bert_grid(minibatches_per_epoch: u32) -> Vec<WorkloadModel> {
    let mut out = Vec::new();
    for &batch in &[8usize, 16, 32] {
        for &lr_exp in &[3, 4, 5, 6] {
            out.push(WorkloadModel {
                name: format!("bert-1b-b{batch}-lr1e-{lr_exp}"),
                model: PaperModel::bert_like(1_000_000_000, batch),
                epochs: 4,
                // same tokens per epoch regardless of batch size
                minibatches_per_epoch: (minibatches_per_epoch * 8 / batch as u32)
                    .max(1),
            });
        }
    }
    out
}

/// Table 2 row 2: ViT* architecture grid — sizes {0.3,0.6,0.8,1,1.5,2}B x
/// batch {512,1024} = 12 models, 5 epochs (CIFAR-10).
pub fn vit_grid(minibatches_per_epoch: u32) -> Vec<WorkloadModel> {
    let sizes: [(u64, &str); 6] = [
        (300_000_000, "300m"),
        (600_000_000, "600m"),
        (800_000_000, "800m"),
        (1_000_000_000, "1b"),
        (1_500_000_000, "1.5b"),
        (2_000_000_000, "2b"),
    ];
    let mut out = Vec::new();
    for (params, tag) in sizes {
        for &batch in &[512usize, 1024] {
            out.push(WorkloadModel {
                name: format!("vit-{tag}-b{batch}"),
                model: PaperModel::vit_like(params, batch),
                epochs: 5,
                minibatches_per_epoch: (minibatches_per_epoch * 512
                    / batch as u32)
                    .max(1),
            });
        }
    }
    out
}

/// Uniform grid for the drill-down studies (§5.2): `n` transformer models
/// of `params` parameters each.
pub fn uniform_grid(
    n: usize,
    params: u64,
    batch: usize,
    epochs: u32,
    minibatches_per_epoch: u32,
) -> Vec<WorkloadModel> {
    (0..n)
        .map(|i| WorkloadModel {
            name: format!("uniform-{i}"),
            model: PaperModel::bert_like(params, batch),
            epochs,
            minibatches_per_epoch,
        })
        .collect()
}

/// Partition every workload model for `gpu` and build ModelTasks.
pub fn build_tasks(
    workload: &[WorkloadModel],
    gpu: &GpuSpec,
    policy: PartitionPolicy,
) -> Result<Vec<ModelTask>> {
    workload
        .iter()
        .enumerate()
        .map(|(id, w)| {
            let layers = w.model.layer_descs(gpu);
            let part = partition(&layers, gpu.mem_bytes, policy)?;
            Ok(ModelTask::new(
                id,
                w.name.clone(),
                "paper-sim",
                part.shards,
                w.minibatches_per_epoch,
                w.epochs,
                1e-3,
            ))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_grid_has_12_models_all_1b() {
        let g = bert_grid(8);
        assert_eq!(g.len(), 12);
        for w in &g {
            let p = w.model.total_params() as f64;
            assert!((0.8e9..1.2e9).contains(&p), "{}: {p}", w.name);
            assert_eq!(w.epochs, 4);
        }
        // token budget equalised: batch 32 gets 1/4 the minibatches of batch 8
        assert_eq!(g[0].minibatches_per_epoch, 8); // batch 8
        assert_eq!(g[11].minibatches_per_epoch, 2); // batch 32
    }

    #[test]
    fn vit_grid_spans_sizes() {
        let g = vit_grid(4);
        assert_eq!(g.len(), 12);
        let smallest = g[0].model.total_params();
        let largest = g[10].model.total_params();
        assert!(largest > 5 * smallest);
    }

    #[test]
    fn build_tasks_partitions_against_gpu() {
        let gpu = GpuSpec::rtx2080ti();
        let tasks =
            build_tasks(&uniform_grid(3, 1_000_000_000, 8, 1, 2), &gpu, Default::default())
                .unwrap();
        assert_eq!(tasks.len(), 3);
        for t in &tasks {
            assert!(t.shards.len() >= 2, "{} shards", t.shards.len());
            assert!(t.total_units() > 0);
        }
    }
}
