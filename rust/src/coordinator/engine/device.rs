//! Device state and lifecycle: the per-accelerator spec, the engine's
//! runtime `DeviceState`, and the elasticity events (arrive / fail-stop)
//! that change pool membership mid-run (§4.7's dynamic setting).

use crate::coordinator::memory::DeviceLedger;
use crate::error::{HydraError, Result};
use crate::util::codec::{ByteReader, ByteWriter};

use super::core::{EngineOptions, SharpEngine};
use super::events::Event;
use super::prefetch::PrefetchPipeline;
use super::TransferModel;

/// Static description of one accelerator in a (possibly heterogeneous) pool.
///
/// The memory ledger, prefetch-zone sizing, transfer accounting and unit
/// durations are all derived per device from this spec, so mixed pools
/// (e.g. A4000s next to A6000s) schedule correctly: bigger devices get
/// bigger prefetch zones, faster devices retire units sooner, and every
/// transfer is charged against the device's own host link.
#[derive(Debug, Clone, Copy)]
pub struct DeviceSpec {
    /// Usable device memory in bytes (the ledger capacity).
    pub mem_bytes: u64,
    /// Compute speed relative to the reference GPU that calibrated the
    /// `ShardDesc` unit costs (1.0 = the reference itself, 2.0 = twice as
    /// fast). Unit durations are divided by this factor.
    pub speed: f64,
    /// Host-link override for this device; `None` uses
    /// [`EngineOptions::transfer`].
    pub link: Option<TransferModel>,
}

impl DeviceSpec {
    /// A reference-speed device with the engine-wide default link.
    pub fn uniform(mem_bytes: u64) -> DeviceSpec {
        DeviceSpec { mem_bytes, speed: 1.0, link: None }
    }

    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u64(self.mem_bytes);
        w.put_f64(self.speed);
        match &self.link {
            None => w.put_bool(false),
            Some(l) => {
                w.put_bool(true);
                l.encode(w);
            }
        }
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<DeviceSpec> {
        Ok(DeviceSpec {
            mem_bytes: r.get_u64()?,
            speed: r.get_f64()?,
            link: if r.get_bool()? { Some(TransferModel::decode(r)?) } else { None },
        })
    }
}

/// A fault-injection / elasticity event (§4.7's dynamic setting).
#[derive(Debug, Clone, Copy)]
pub enum ClusterEvent {
    /// Device joins at `time` with the given memory capacity (reference
    /// speed; use [`SharpEngine::with_devices`] for heterogeneous pools
    /// known up front).
    Arrive {
        /// Virtual time the device joins.
        time: f64,
        /// Memory capacity of the joining device.
        mem_bytes: u64,
    },
    /// Device `device` is lost at `time` (takes effect when its in-flight
    /// unit retires; the unit itself completes — fail-stop between units).
    Fail {
        /// Virtual time of the loss.
        time: f64,
        /// Index of the failing device.
        device: usize,
    },
}

impl ClusterEvent {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        match self {
            ClusterEvent::Arrive { time, mem_bytes } => {
                w.put_u8(0);
                w.put_f64(*time);
                w.put_u64(*mem_bytes);
            }
            ClusterEvent::Fail { time, device } => {
                w.put_u8(1);
                w.put_f64(*time);
                w.put_usize(*device);
            }
        }
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<ClusterEvent> {
        Ok(match r.get_u8()? {
            0 => ClusterEvent::Arrive { time: r.get_f64()?, mem_bytes: r.get_u64()? },
            1 => ClusterEvent::Fail { time: r.get_f64()?, device: r.get_usize()? },
            t => {
                return Err(HydraError::WalCorrupt(format!(
                    "unknown cluster-event tag {t}"
                )))
            }
        })
    }
}

/// Runtime state of one device in the engine.
#[derive(Debug)]
pub(crate) struct DeviceState {
    pub(crate) spec: DeviceSpec,
    pub(crate) ledger: DeviceLedger,
    /// Depth-k prefetch ring: pre-claimed units + staged transfers.
    pub(crate) pipeline: PrefetchPipeline,
    /// (model, shard) whose parameters are resident from the previous unit.
    pub(crate) resident: Option<(usize, u32)>,
    pub(crate) alive: bool,
    /// Set while a unit is in flight.
    pub(crate) busy: bool,
    pub(crate) fail_pending: bool,
    /// Bytes that flow back to DRAM when the resident shard is evicted.
    pub(crate) last_demote_bytes: u64,
}

impl DeviceState {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        self.spec.encode(w);
        self.ledger.encode(w);
        self.pipeline.encode(w);
        match self.resident {
            None => w.put_bool(false),
            Some((m, sh)) => {
                w.put_bool(true);
                w.put_usize(m);
                w.put_u32(sh);
            }
        }
        w.put_bool(self.alive);
        w.put_bool(self.busy);
        w.put_bool(self.fail_pending);
        w.put_u64(self.last_demote_bytes);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<DeviceState> {
        let spec = DeviceSpec::decode(r)?;
        let ledger = DeviceLedger::decode(r)?;
        let pipeline = PrefetchPipeline::decode(r)?;
        let resident = if r.get_bool()? {
            Some((r.get_usize()?, r.get_u32()?))
        } else {
            None
        };
        Ok(DeviceState {
            spec,
            ledger,
            pipeline,
            resident,
            alive: r.get_bool()?,
            busy: r.get_bool()?,
            fail_pending: r.get_bool()?,
            last_demote_bytes: r.get_u64()?,
        })
    }
}

impl<'a> SharpEngine<'a> {
    pub(crate) fn mk_device(
        id: usize,
        spec: DeviceSpec,
        options: &EngineOptions,
    ) -> Result<DeviceState> {
        if !spec.speed.is_finite() || spec.speed <= 0.0 {
            return Err(HydraError::Config(format!(
                "device {id}: speed {} must be finite and positive",
                spec.speed
            )));
        }
        let mut ledger = DeviceLedger::new(id, spec.mem_bytes);
        let zone = (spec.mem_bytes as f64 * options.buffer_frac) as u64;
        let pipeline = PrefetchPipeline::new(
            options.double_buffer,
            zone,
            options.prefetch_depth,
            &mut ledger,
        )?;
        Ok(DeviceState {
            spec,
            ledger,
            pipeline,
            resident: None,
            alive: true,
            busy: false,
            fail_pending: false,
            last_demote_bytes: 0,
        })
    }

    /// The effective host link of `device`.
    pub(crate) fn link(&self, device: usize) -> TransferModel {
        self.devices[device].spec.link.unwrap_or(self.options.transfer)
    }

    pub(crate) fn on_cluster_event(&mut self, i: usize, now: f64) -> Result<()> {
        match self.cluster_events[i] {
            ClusterEvent::Arrive { mem_bytes, .. } => {
                let id = self.devices.len();
                self.devices
                    .push(Self::mk_device(id, DeviceSpec::uniform(mem_bytes), &self.options)?);
                self.free_devices += 1;
                self.trace.set_device_window(id, now, f64::INFINITY);
                self.queue.push(now, Event::DeviceFree { device: id });
            }
            ClusterEvent::Fail { device, .. } => {
                if device < self.devices.len() && self.devices[device].alive {
                    if self.devices[device].busy {
                        // fail-stop between units: take effect on retire
                        self.devices[device].fail_pending = true;
                    } else {
                        self.kill_device(device, now);
                    }
                }
            }
        }
        Ok(())
    }

    /// Remove `device` from the pool: every pre-claimed slot returns to
    /// its model's queue (releasing its staged DRAM pin), the resident
    /// shard unpins, and the device's trace window closes.
    ///
    /// Only ever called for a non-busy device — a mid-compute loss defers
    /// through `fail_pending` and lands here from `on_unit_retire`, after
    /// the retire already returned the device to the free count. That is
    /// why the unconditional `free_devices -= 1` below is safe; the
    /// debug-build invariant check re-verifies it after every event.
    pub(crate) fn kill_device(&mut self, device: usize, now: f64) {
        debug_assert!(!self.devices[device].busy, "kill of a busy device");
        let slots = self.devices[device].pipeline.clear();
        for slot in &slots {
            if let Some(st) = slot.staged {
                self.memory.release_device_copy(st.model, st.shard);
            }
        }
        if let Some((m, sh)) = self.devices[device].resident.take() {
            self.memory.release_device_copy(m, sh);
        }
        self.devices[device].alive = false;
        self.parked.remove(device);
        self.free_devices -= 1;
        for slot in slots {
            // return each pre-claimed unit to its model's queue; the
            // models may now be runnable elsewhere
            self.tasks[slot.unit.model].unclaim(&slot.unit);
            self.ready.insert(slot.unit.model);
            self.wake_one(now);
        }
        let start = self.trace.device_windows.get(&device).map(|w| w.0).unwrap_or(0.0);
        self.trace.set_device_window(device, start, now);
    }

    /// Debug-build engine invariants, asserted after every same-timestamp
    /// event batch: `free_devices` equals the count of alive non-busy
    /// devices, every parked device is alive and idle, and no pipeline's
    /// staged set exceeds its zone.
    #[cfg(debug_assertions)]
    pub(crate) fn assert_engine_invariants(&self) {
        let free = self.devices.iter().filter(|d| d.alive && !d.busy).count();
        assert_eq!(
            free, self.free_devices,
            "free_devices drift: counter {} vs actual {free}",
            self.free_devices
        );
        for d in self.parked.iter() {
            assert!(
                self.devices[d].alive && !self.devices[d].busy,
                "parked device {d} is dead or busy"
            );
        }
        for (i, d) in self.devices.iter().enumerate() {
            assert!(
                d.pipeline.staged_bytes() <= d.pipeline.zone_bytes,
                "device {i}: staged bytes exceed the prefetch zone"
            );
            assert!(
                d.pipeline.len() <= d.pipeline.depth(),
                "device {i}: pipeline holds more slots than its depth"
            );
        }
    }
}
