//! SHARP — Shard Alternator Parallelism (§4.4): the event-driven engine
//! that blends the shard-unit queues of many models over a pool of devices.
//!
//! The engine runs in *virtual time*: every decision (eligibility, memory
//! promotion/demotion, prefetch staging, stalls) is identical whether the
//! execution backend is the discrete-event cost model (`SimBackend`) or
//! the real PJRT runtime (`RealBackend`, which reports measured wallclock
//! as the unit duration). That is what lets one engine both *reproduce the
//! paper's figures* at 8-GPU scale and *actually train* models on this
//! machine (DESIGN.md §1).
//!
//! Beyond the paper's batch setting, the engine is **online and
//! multi-tenant**: jobs carry arrival times
//! ([`crate::coordinator::task::ModelTask::with_arrival`]), can be
//! submitted and cancelled while the engine runs ([`JobEvent`]), and
//! devices may be **heterogeneous** ([`DeviceSpec`]: per-device memory,
//! relative compute speed, and host-link bandwidth). Per-job latency
//! statistics come back in [`RunReport::jobs`].
//!
//! Host memory is a tiered [`crate::coordinator::memory::MemoryHierarchy`]:
//! with an NVMe backing tier configured
//! ([`crate::coordinator::memory::MemoryOptions`]), model sets larger than
//! DRAM still run — DRAM acts as an evicting cache, DRAM misses stage
//! NVMe->DRAM->HBM (overlapped with compute by the prefetch pipeline when
//! staged, synchronous
//! [`crate::coordinator::metrics::IntervalKind::NvmeTransfer`] intervals
//! otherwise), and per-tier traffic lands in
//! [`RunReport::nvme_promoted_bytes`] / [`RunReport::nvme_demoted_bytes`].
//! Without an NVMe tier the engine is bit-for-bit the legacy two-tier
//! system.
//!
//! §4.6's double buffer is generalized to a **depth-k prefetch pipeline**
//! ([`PrefetchPipeline`], [`EngineOptions::prefetch_depth`]): each
//! device's protected zone holds a small ring of staged slots, the
//! scheduler pre-claims up to k upcoming units, and the NVMe->DRAM and
//! DRAM->HBM legs of different slots overlap with at most one in-flight
//! transfer per link (queueing surfaced as
//! [`RunReport::prefetch_wait_secs`]). Depth 1 is the paper's classic
//! double buffer, decision for decision.
//!
//! The dispatch hot path is incremental: a binary-heap event queue
//! (O(log n) push/pop), a ready-set of eligible models, a parked-set of
//! idle devices, and engine-owned scratch snapshot buffers (no per-decision
//! allocation). Every engine event additionally streams through an
//! [`crate::coordinator::observer::EngineObserver`]
//! ([`SharpEngine::run_with`]): trace bookkeeping is just one observer
//! impl, and live progress/gantt streaming for online runs is another.
//! [`QueueKind::LinearScan`] keeps the O(n) event-selection discipline
//! available as a reference implementation — the two produce identical
//! schedules (property- and equivalence-tested in rust/tests) because both
//! pop events in (time, submission-order) order.
//!
//! Module family (one file per concern; `coordinator::sharp` re-exports
//! this surface for compatibility):
//!
//! | module | owns |
//! |---|---|
//! | [`events`] | [`QueueKind`], the event kinds, the (time, seq) queue |
//! | [`device`] | [`DeviceSpec`], device runtime state, [`ClusterEvent`] arrive/fail lifecycle, engine invariants |
//! | [`jobs`]   | [`JobEvent`] submit/cancel, arrival gating, finish bookkeeping, [`JobStat`] |
//! | [`prefetch`] | the depth-k [`PrefetchPipeline`] (zone, slots, staging-link clocks) |
//! | [`core`](self::core) | [`SharpEngine`] construction, the run loop, unit dispatch, [`RunReport`] |
//! | [`routing`] | [`ShardId`], the stable job->shard hash, the bounded [`ShardMailbox`] and its [`ShardBusy`] backpressure signal |
//! | [`sharded`] | [`ShardedEngine`]: N independent shard engines, report merge, [`ShardedReport`] |
//!
//! Invariants enforced here (property-tested in rust/tests, and — for the
//! free/parked/zone accounting — asserted after every event in debug
//! builds):
//!   1. sequential order of a model's shard units (MILP constraint (a)),
//!   2. device isolation — one unit per device at a time (b, c),
//!   3. model isolation — one in-flight or pre-claimed unit per model,
//!   4. ledgers never exceed device capacity; staged sets never exceed
//!      the prefetch zone,
//!   5. every unit executes exactly once (unless its job is cancelled),
//!   6. no unit of a job starts before the job's arrival time.

pub mod core;
pub mod device;
pub mod events;
pub mod jobs;
pub mod prefetch;
pub mod routing;
pub mod sharded;

pub use self::core::{EngineOptions, ParallelMode, RunReport, SharpEngine, TenantStat};
pub use self::device::{ClusterEvent, DeviceSpec};
pub use self::events::QueueKind;
pub use self::jobs::{Admission, JobEvent, JobStat};
pub use self::prefetch::{PrefetchPipeline, PrefetchSlot, StagedShard};
pub use self::routing::{Route, ShardBusy, ShardId, ShardMailbox, StolenJob};
pub use self::sharded::{
    ShardOutcome, ShardSection, ShardedEngine, ShardedReport,
};

pub use crate::coordinator::memory::TransferModel;
