//! Job→shard routing and bounded shard mailboxes for the sharded engine.
//!
//! Routing is a pure function of the *global* job id: a splitmix64 stable
//! hash picks the home shard, so the assignment is deterministic, stable
//! under submission reordering, and independent of everything else in the
//! run (property-tested in rust/tests/sharded_engine.rs). One override
//! exists: a job whose largest shard cannot fit the routed shard's smallest
//! device is re-routed to the shard with the roomiest device (capacity-aware
//! override for oversized jobs), deterministically tie-broken by shard id.
//!
//! Admission into a shard goes through a bounded [`ShardMailbox`]:
//! `try_push` either accepts the job or returns it with a typed
//! [`ShardBusy`] signal instead of growing an unbounded queue — the
//! backpressure idiom of the multi-tenant serving literature (PAPERS.md,
//! 2111.14247). The caller decides how to resolve the pressure (the
//! [`super::sharded::ShardedEngine`] drains the mailbox into the shard's
//! accepted list and retries, so every backpressured submit eventually
//! lands).

use std::collections::VecDeque;
use std::fmt;

/// Identifier of one shard engine inside a [`super::sharded::ShardedEngine`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct ShardId(pub usize);

impl fmt::Display for ShardId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard {}", self.0)
    }
}

/// splitmix64 finalizer: a cheap, well-mixed stable hash of a job id.
/// Stable across runs and platforms by construction (pure integer math).
pub fn stable_hash(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Home shard of `job` among `n_shards` shards: `stable_hash(job) % n`.
///
/// Deterministic and independent of submission order — two runs that
/// contain the same job ids route identically no matter how the jobs were
/// interleaved.
pub fn route(job: usize, n_shards: usize) -> ShardId {
    assert!(n_shards >= 1, "route called with zero shards");
    ShardId((stable_hash(job as u64) % n_shards as u64) as usize)
}

/// A routing decision: the chosen shard, and whether the capacity-aware
/// override moved the job away from its hash-routed home.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Route {
    pub shard: ShardId,
    pub overridden: bool,
}

/// Route `job`, overriding the hash choice when the job's largest shard
/// (`largest_shard_bytes`) exceeds the routed shard's device capacity.
///
/// `device_caps[s]` is the smallest device memory of shard `s` (the
/// binding constraint: every shard of a model must fit every device it may
/// be placed on). An oversized job is re-routed to the shard with the
/// largest capacity; ties break to the lowest shard id so the override is
/// as deterministic as the hash. If no shard fits, the roomiest shard
/// still wins and the shard engine reports the placement failure itself.
pub fn route_capacity_aware(job: usize, largest_shard_bytes: u64, device_caps: &[u64]) -> Route {
    let home = route(job, device_caps.len());
    if largest_shard_bytes <= device_caps[home.0] {
        return Route { shard: home, overridden: false };
    }
    let roomiest = device_caps
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
        .map(|(s, _)| s)
        .unwrap_or(home.0);
    Route { shard: ShardId(roomiest), overridden: roomiest != home.0 }
}

/// One job migration planned by the work stealer: `job` (global id) left
/// `from`'s admission queue for `to`'s. Recorded in
/// `RunReport::stolen` so a stealing run documents exactly how it diverged
/// from the hash-routed baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StolenJob {
    /// Global job id that migrated.
    pub job: usize,
    /// Victim shard the job was routed to.
    pub from: ShardId,
    /// Thief shard that executed it.
    pub to: ShardId,
}

/// The capacity-checked steal handshake: the thief may take a job only
/// when (a) the victim's admission queue is deeper by at least two — moving
/// a job across a difference of one merely swaps the imbalance — and
/// (b) the job's largest shard fits the thief's smallest device
/// (`footprint <= thief_cap`), the same binding constraint
/// [`route_capacity_aware`] enforces at admission.
pub fn steal_allowed(
    footprint: u64,
    thief_cap: u64,
    victim_depth: usize,
    thief_depth: usize,
) -> bool {
    victim_depth >= thief_depth + 2 && footprint <= thief_cap
}

/// Greedy admission-time steal planner: repeatedly move one job from the
/// deepest admission queue to the shallowest until the pool is balanced
/// (depth difference < 2) or the deepest queue holds nothing the thief can
/// fit. Jobs are stolen from the *back* of the victim's queue (most
/// recently admitted first) so the victim's imminent work keeps its
/// hash-routed home. Only not-yet-started jobs are in these queues, so no
/// in-flight unit ever migrates.
///
/// `queues[s]` holds global job ids accepted to shard `s`,
/// `footprints[gid]` the job's largest shard in bytes, `caps[s]` the
/// smallest device memory of shard `s`. Ties (equal depth) break to the
/// lowest shard id on both sides, so the plan is fully deterministic.
pub fn plan_steals(
    queues: &mut [Vec<usize>],
    footprints: &[u64],
    caps: &[u64],
) -> Vec<StolenJob> {
    let mut stolen = Vec::new();
    let n = queues.len();
    if n < 2 {
        return stolen;
    }
    loop {
        let thief = (0..n).min_by_key(|&s| (queues[s].len(), s)).unwrap();
        let victim = (0..n).max_by_key(|&s| (queues[s].len(), n - s)).unwrap();
        let (vd, td) = (queues[victim].len(), queues[thief].len());
        let movable = queues[victim]
            .iter()
            .rposition(|&gid| steal_allowed(footprints[gid], caps[thief], vd, td));
        match movable {
            Some(i) => {
                let gid = queues[victim].remove(i);
                queues[thief].push(gid);
                stolen.push(StolenJob {
                    job: gid,
                    from: ShardId(victim),
                    to: ShardId(thief),
                });
            }
            // balanced, or the deepest queue has nothing the emptiest
            // shard can hold — either way the plan is done
            None => break,
        }
    }
    stolen
}

/// Typed backpressure signal: the mailbox of `shard` is full (at
/// `capacity` queued jobs) and rejected the submit instead of growing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardBusy {
    pub shard: ShardId,
    pub capacity: usize,
}

impl fmt::Display for ShardBusy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} mailbox is full ({} queued jobs); drain it before resubmitting",
            self.shard, self.capacity
        )
    }
}

/// Bounded FIFO admission queue in front of one shard engine.
///
/// `try_push` never grows past `capacity`: a full mailbox hands the item
/// back together with a [`ShardBusy`] signal. The bound is the whole
/// point — backpressure is surfaced to the submitter as a typed value
/// rather than absorbed into an unbounded queue.
#[derive(Debug, Clone)]
pub struct ShardMailbox<T> {
    shard: ShardId,
    capacity: usize,
    queue: VecDeque<T>,
}

impl<T> ShardMailbox<T> {
    /// A mailbox for `shard` holding at most `capacity` (>= 1) items.
    pub fn new(shard: ShardId, capacity: usize) -> ShardMailbox<T> {
        ShardMailbox {
            shard,
            capacity: capacity.max(1),
            queue: VecDeque::with_capacity(capacity.max(1)),
        }
    }

    /// Accept `item`, or hand it back with a [`ShardBusy`] when full.
    pub fn try_push(&mut self, item: T) -> Result<(), (T, ShardBusy)> {
        if self.queue.len() >= self.capacity {
            return Err((item, ShardBusy { shard: self.shard, capacity: self.capacity }));
        }
        self.queue.push_back(item);
        Ok(())
    }

    /// Pop the oldest queued item (FIFO).
    pub fn pop(&mut self) -> Option<T> {
        self.queue.pop_front()
    }

    /// Drain every queued item in FIFO order.
    pub fn drain(&mut self) -> impl Iterator<Item = T> + '_ {
        self.queue.drain(..)
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn shard(&self) -> ShardId {
        self.shard
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_hash_is_stable() {
        // pinned values: the routing contract is "stable across runs and
        // platforms", so the hash itself must never drift
        assert_eq!(stable_hash(0), 16294208416658607535);
        assert_eq!(stable_hash(1), 10451216379200822465);
        assert_eq!(stable_hash(0), stable_hash(0));
    }

    #[test]
    fn route_is_deterministic_and_in_range() {
        for n in 1..9 {
            for job in 0..256 {
                let a = route(job, n);
                let b = route(job, n);
                assert_eq!(a, b);
                assert!(a.0 < n);
            }
        }
        // n=1 routes everything to shard 0
        for job in 0..64 {
            assert_eq!(route(job, 1), ShardId(0));
        }
    }

    #[test]
    fn capacity_override_moves_only_oversized_jobs() {
        let caps = [1 << 30, 4 << 30, 2 << 30, 1 << 30];
        for job in 0..64 {
            // small job: always the hash home
            let r = route_capacity_aware(job, 1 << 20, &caps);
            assert_eq!(r.shard, route(job, caps.len()));
            assert!(!r.overridden);
            // oversized for every shard but 1: lands on the roomiest
            let r = route_capacity_aware(job, 3 << 30, &caps);
            assert_eq!(r.shard, ShardId(1));
            assert_eq!(r.overridden, route(job, caps.len()) != ShardId(1));
        }
    }

    #[test]
    fn capacity_override_ties_break_to_lowest_shard() {
        // nothing fits: the roomiest wins, ties to the lowest id
        let caps = [2 << 30, 2 << 30, 1 << 30];
        let r = route_capacity_aware(7, 8 << 30, &caps);
        assert_eq!(r.shard, ShardId(0));
    }

    #[test]
    fn mailbox_bounds_and_backpressures() {
        let mut mb: ShardMailbox<usize> = ShardMailbox::new(ShardId(2), 2);
        assert!(mb.try_push(10).is_ok());
        assert!(mb.try_push(11).is_ok());
        let (item, busy) = mb.try_push(12).unwrap_err();
        assert_eq!(item, 12);
        assert_eq!(busy.shard, ShardId(2));
        assert_eq!(busy.capacity, 2);
        assert!(busy.to_string().contains("shard 2"));
        assert_eq!(mb.len(), 2);
        // FIFO drain frees the bound; the rejected item lands on retry
        assert_eq!(mb.pop(), Some(10));
        assert!(mb.try_push(item).is_ok());
        let drained: Vec<usize> = mb.drain().collect();
        assert_eq!(drained, vec![11, 12]);
        assert!(mb.is_empty());
    }

    #[test]
    fn steal_handshake_requires_room_and_imbalance() {
        // fits and imbalanced: allowed
        assert!(steal_allowed(1 << 20, 1 << 30, 5, 1));
        // depth difference of one merely swaps the imbalance: refused
        assert!(!steal_allowed(1 << 20, 1 << 30, 2, 1));
        assert!(!steal_allowed(1 << 20, 1 << 30, 1, 1));
        // job too large for the thief's smallest device: refused
        assert!(!steal_allowed(2 << 30, 1 << 30, 5, 1));
    }

    #[test]
    fn plan_steals_balances_and_conserves_jobs() {
        let footprints = vec![1u64; 8];
        let caps = [10, 10, 10];
        let mut queues = vec![vec![0, 1, 2, 3, 4, 5], vec![6, 7], vec![]];
        let stolen = plan_steals(&mut queues, &footprints, &caps);
        // balanced within 1 and no job lost or duplicated
        let mut all: Vec<usize> = queues.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
        let depths: Vec<usize> = queues.iter().map(Vec::len).collect();
        assert!(depths.iter().max().unwrap() - depths.iter().min().unwrap() < 2);
        // stolen records match what actually moved, back of queue first
        assert!(!stolen.is_empty());
        for s in &stolen {
            assert_ne!(s.from, s.to);
            assert!(queues[s.to.0].contains(&s.job));
        }
        assert_eq!(stolen[0].from, ShardId(0));
        assert_eq!(stolen[0].job, 5);
    }

    #[test]
    fn plan_steals_respects_thief_capacity() {
        // shard 1 is empty but too small for any of shard 0's jobs
        let footprints = vec![100u64; 4];
        let caps = [200, 50];
        let mut queues = vec![vec![0, 1, 2, 3], vec![]];
        let stolen = plan_steals(&mut queues, &footprints, &caps);
        assert!(stolen.is_empty());
        assert_eq!(queues[0], vec![0, 1, 2, 3]);
    }

    #[test]
    fn plan_steals_is_deterministic_and_single_shard_is_noop() {
        let footprints = vec![1u64; 6];
        let caps = [10, 10];
        let mut a = vec![vec![0, 1, 2, 3, 4, 5], vec![]];
        let mut b = a.clone();
        let sa = plan_steals(&mut a, &footprints, &caps);
        let sb = plan_steals(&mut b, &footprints, &caps);
        assert_eq!(sa, sb);
        assert_eq!(a, b);
        let mut one = vec![vec![0, 1, 2]];
        assert!(plan_steals(&mut one, &footprints, &caps[..1]).is_empty());
    }

    #[test]
    fn mailbox_capacity_floor_is_one() {
        let mut mb: ShardMailbox<u8> = ShardMailbox::new(ShardId(0), 0);
        assert_eq!(mb.capacity(), 1);
        assert!(mb.try_push(1).is_ok());
        assert!(mb.try_push(2).is_err());
    }
}
