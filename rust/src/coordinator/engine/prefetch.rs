//! Depth-k scheduler-aware prefetch pipeline — §4.6 generalized.
//!
//! The paper's double-buffering stages exactly *one* shard one unit ahead:
//! while a unit computes, the next scheduled unit's parameters are
//! prefetched into a protected zone, hiding DRAM->device latency. With the
//! NVMe backing tier a DRAM miss turns that single hop into a
//! NVMe->DRAM->HBM *chain*, and one compute span rarely hides the whole
//! chain. [`PrefetchPipeline`] therefore generalizes the single slot to a
//! small ring of up to `depth` staged slots per device (zone bytes
//! unchanged — k is bounded by what fits): the scheduler pre-claims up to
//! k upcoming units, the NVMe->DRAM and DRAM->HBM legs of *different*
//! slots overlap as a two-stage pipeline, and each leg admits at most one
//! in-flight transfer per link — a later slot's leg queues behind the
//! earlier slot's, and that queueing delay is modeled and surfaced as
//! `RunReport::prefetch_wait_secs`.
//!
//! With `depth == 1` the pipeline is the classic double buffer, decision
//! for decision and second for second: one slot, both links idle whenever
//! a transfer starts, zero queueing delay — which is what the depth-1
//! report-equivalence suite in `rust/tests/prefetch_pipeline.rs` pins.
//!
//! Scope of the link discipline: the serialized clocks govern *staged*
//! transfers only. Synchronous fallback transfers (an unstaged slot's
//! promote, activation hops, no-DB write-backs) are charged immediately,
//! exactly like the classic §4.6 model always charged them — making them
//! queue on the staging clocks would change depth-1 timing and break the
//! byte-for-byte equivalence with the pre-pipeline engine.
//!
//! The timing math beyond the links lives in the engine
//! ([`super::core`]); this module owns the zone lifecycle, slot/zone
//! accounting and the per-link clocks, so it can be unit-tested in
//! isolation and disabled wholesale for Table 3's ablation.

use std::collections::VecDeque;

use crate::coordinator::memory::{DeviceLedger, MemTier, Residency};
use crate::coordinator::sched::PickContext;
use crate::coordinator::unit::ShardUnit;
use crate::error::Result;
use crate::util::codec::{ByteReader, ByteWriter};

use super::core::SharpEngine;

/// A shard parked in the buffer zone mid-prefetch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StagedShard {
    /// Model the staged shard belongs to.
    pub model: usize,
    /// Shard index within the model.
    pub shard: u32,
    /// Bytes occupying the zone while staged.
    pub bytes: u64,
    /// Virtual time the NVMe->DRAM leg completes (== the staging time when
    /// the fetch was a DRAM hit). Kept so revoking a slot can rewind the
    /// link clocks to the remaining in-flight transfers.
    pub nvme_done: f64,
    /// Virtual time when the prefetch transfer finishes (both legs done).
    pub ready_at: f64,
}

impl StagedShard {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_usize(self.model);
        w.put_u32(self.shard);
        w.put_u64(self.bytes);
        w.put_f64(self.nvme_done);
        w.put_f64(self.ready_at);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<StagedShard> {
        Ok(StagedShard {
            model: r.get_usize()?,
            shard: r.get_u32()?,
            bytes: r.get_u64()?,
            nvme_done: r.get_f64()?,
            ready_at: r.get_f64()?,
        })
    }
}

/// One pre-claimed unit in the pipeline: the unit itself plus its staged
/// transfer, if the zone had room and DRAM admitted the fetch (`None`
/// falls back to a synchronous transfer at start time).
#[derive(Debug, Clone, Copy)]
pub struct PrefetchSlot {
    /// The claimed shard unit.
    pub unit: ShardUnit,
    /// Its staged transfer, when one was issued.
    pub staged: Option<StagedShard>,
}

impl PrefetchSlot {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        self.unit.encode(w);
        w.put_bool(self.staged.is_some());
        if let Some(st) = &self.staged {
            st.encode(w);
        }
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<PrefetchSlot> {
        let unit = ShardUnit::decode(r)?;
        let staged = if r.get_bool()? { Some(StagedShard::decode(r)?) } else { None };
        Ok(PrefetchSlot { unit, staged })
    }
}

/// Per-device prefetch state: a ring of up to `depth` pre-claimed slots
/// sharing one protected zone, plus the two staging-link clocks
/// (NVMe->DRAM and DRAM->HBM) that serialize overlapping transfers.
///
/// The zone is sized from the owning device's own capacity (a fraction of
/// [`DeviceLedger::capacity`]), so in heterogeneous pools bigger devices
/// stage bigger prefetches.
#[derive(Debug, Clone)]
pub struct PrefetchPipeline {
    /// Whether prefetching is active (Table 3 ablation disables it).
    pub enabled: bool,
    /// Bytes reserved in the device ledger for the loading zone.
    pub zone_bytes: u64,
    /// Maximum number of pre-claimed slots (`EngineOptions::prefetch_depth`).
    depth: usize,
    /// Pre-claimed slots in claim order; the front is consumed next.
    slots: VecDeque<PrefetchSlot>,
    /// Sum of staged slot bytes currently occupying the zone.
    staged_bytes: u64,
    /// Virtual time the NVMe->DRAM staging link frees up.
    nvme_busy_until: f64,
    /// Virtual time the DRAM->HBM staging link frees up.
    link_busy_until: f64,
}

impl PrefetchPipeline {
    /// Reserve the zone in the ledger (done once at startup, mirroring the
    /// partitioner's §4.6 "protect a buffer space during partitioning").
    pub fn new(
        enabled: bool,
        zone_bytes: u64,
        depth: usize,
        ledger: &mut DeviceLedger,
    ) -> Result<PrefetchPipeline> {
        if enabled {
            ledger.alloc(Residency::BufferZone, zone_bytes)?;
        }
        Ok(PrefetchPipeline {
            enabled,
            zone_bytes,
            depth: depth.max(1),
            slots: VecDeque::new(),
            staged_bytes: 0,
            nvme_busy_until: 0.0,
            link_busy_until: 0.0,
        })
    }

    /// Configured slot count (k).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Pre-claimed slots currently held.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether no unit is pre-claimed.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Whether every slot is claimed (the fill loop's stop condition).
    pub fn is_full(&self) -> bool {
        self.slots.len() >= self.depth
    }

    /// Bytes of the zone currently occupied by staged transfers.
    pub fn staged_bytes(&self) -> u64 {
        self.staged_bytes
    }

    /// The pre-claimed slots, front (next to run) first.
    pub fn slots(&self) -> impl Iterator<Item = &PrefetchSlot> {
        self.slots.iter()
    }

    /// Whether a `bytes`-sized staging still fits the zone next to the
    /// already-staged set. A shard larger than the remaining zone (or a
    /// disabled pipeline) is refused — in release builds too, so callers
    /// fall back to a synchronous transfer instead of silently
    /// overcommitting the zone.
    pub fn can_stage(&self, bytes: u64) -> bool {
        self.enabled && self.staged_bytes.saturating_add(bytes) <= self.zone_bytes
    }

    /// Claim `unit` without staging a transfer (zone full, or DRAM too
    /// contended to fetch): its promotion happens synchronously at start.
    pub fn push_unstaged(&mut self, unit: ShardUnit) {
        debug_assert!(!self.is_full(), "push into a full pipeline");
        self.slots.push_back(PrefetchSlot { unit, staged: None });
    }

    /// Claim `unit` and stage its transfer: the NVMe leg (`nvme_secs`,
    /// 0.0 on a DRAM hit) queues on the NVMe link, then the DRAM->HBM leg
    /// (`link_secs`) queues on the device link — at most one in-flight
    /// transfer per link, so a later slot's legs wait for the earlier
    /// slot's. Returns the total queueing delay this staging incurred
    /// (always 0.0 at depth 1: a lone slot never finds a busy link).
    pub fn stage(
        &mut self,
        unit: ShardUnit,
        bytes: u64,
        now: f64,
        nvme_secs: f64,
        link_secs: f64,
    ) -> f64 {
        debug_assert!(!self.is_full(), "stage into a full pipeline");
        debug_assert!(self.can_stage(bytes), "staging past the zone");
        let mut wait = 0.0;
        let nvme_done = if nvme_secs > 0.0 {
            let start = now.max(self.nvme_busy_until);
            wait += start - now;
            self.nvme_busy_until = start + nvme_secs;
            self.nvme_busy_until
        } else {
            now
        };
        let ready_at = if link_secs > 0.0 {
            let start = nvme_done.max(self.link_busy_until);
            wait += start - nvme_done;
            self.link_busy_until = start + link_secs;
            self.link_busy_until
        } else {
            nvme_done
        };
        self.staged_bytes += bytes;
        self.slots.push_back(PrefetchSlot {
            unit,
            staged: Some(StagedShard {
                model: unit.model,
                shard: unit.shard,
                bytes,
                nvme_done,
                ready_at,
            }),
        });
        wait
    }

    /// Consume the front slot (the device is about to run it). Its staged
    /// bytes leave the zone; the caller inherits the staged DRAM pin as
    /// the device-resident pin.
    pub fn pop_front(&mut self) -> Option<PrefetchSlot> {
        let slot = self.slots.pop_front()?;
        if let Some(st) = slot.staged {
            self.staged_bytes -= st.bytes;
        }
        Some(slot)
    }

    /// Revoke the slot claimed for `model` (tenant cancellation), if this
    /// pipeline holds one. The caller must unclaim the unit and release
    /// the staged DRAM pin. The revoked slot's transfer is abandoned, so
    /// the link clocks rewind to the remaining in-flight transfers —
    /// otherwise later stagings would queue behind a phantom transfer
    /// (breaking the depth-1 "a lone slot never waits" guarantee under
    /// online cancellation churn).
    pub fn remove_model(&mut self, model: usize) -> Option<PrefetchSlot> {
        let i = self.slots.iter().position(|s| s.unit.model == model)?;
        let slot = self.slots.remove(i)?;
        if let Some(st) = slot.staged {
            self.staged_bytes -= st.bytes;
        }
        // legs are issued in slot order, so each clock is the last
        // remaining staged slot's leg end (0 = idle since startup)
        self.nvme_busy_until = 0.0;
        self.link_busy_until = 0.0;
        for s in &self.slots {
            if let Some(st) = s.staged {
                self.nvme_busy_until = self.nvme_busy_until.max(st.nvme_done);
                self.link_busy_until = self.link_busy_until.max(st.ready_at);
            }
        }
        Some(slot)
    }

    /// Serialize the full pipeline state — slots in claim order, zone
    /// accounting, both link clocks — for durability snapshots. The zone's
    /// ledger reservation is re-created by the ledger's own snapshot, so
    /// decode never touches a [`DeviceLedger`].
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_bool(self.enabled);
        w.put_u64(self.zone_bytes);
        w.put_usize(self.depth);
        w.put_usize(self.slots.len());
        for s in &self.slots {
            s.encode(w);
        }
        w.put_u64(self.staged_bytes);
        w.put_f64(self.nvme_busy_until);
        w.put_f64(self.link_busy_until);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<PrefetchPipeline> {
        let enabled = r.get_bool()?;
        let zone_bytes = r.get_u64()?;
        let depth = r.get_usize()?;
        // each slot: ShardUnit (8+8+4+4+4+1) + staged flag
        let n = r.get_count(30)?;
        let mut slots = VecDeque::with_capacity(n);
        for _ in 0..n {
            slots.push_back(PrefetchSlot::decode(r)?);
        }
        Ok(PrefetchPipeline {
            enabled,
            zone_bytes,
            depth: depth.max(1),
            slots,
            staged_bytes: r.get_u64()?,
            nvme_busy_until: r.get_f64()?,
            link_busy_until: r.get_f64()?,
        })
    }

    /// Drop every slot and reset the link clocks (device loss). Returns
    /// the revoked slots so the caller can unclaim units and release pins.
    pub fn clear(&mut self) -> Vec<PrefetchSlot> {
        self.staged_bytes = 0;
        self.nvme_busy_until = 0.0;
        self.link_busy_until = 0.0;
        self.slots.drain(..).collect()
    }
}

impl<'a> SharpEngine<'a> {
    /// While `device` computes, pre-claim up to `prefetch_depth` upcoming
    /// units for it and start their staged transfers into the buffer zone
    /// (§4.6: "the Scheduler is actually picking shard units for
    /// double-buffering", generalized to a depth-k ring).
    pub(crate) fn try_fill_prefetch(
        &mut self,
        device: usize,
        now: f64,
        obs: &mut dyn crate::coordinator::observer::EngineObserver,
    ) {
        if self.devices[device].fail_pending {
            return;
        }
        // Don't steal an eligible model from a device that could run it
        // *right now* — prefetching is only a win when every device is busy
        // (claiming for the buffer would otherwise serialise work that task
        // parallelism would run immediately).
        if self.free_devices > 0 {
            return;
        }
        // Cursor refill: snapshot the eligible set and device residency
        // ONCE and walk the snapshot, removing each picked model in place.
        // A depth-k refill used to rebuild both buffers for every slot
        // (O(k * |eligible|) rescans); nothing in the loop body invalidates
        // either snapshot — the picked model leaves `ready` (and leaves the
        // cursor), residency only changes at unit start/retire, and no
        // events fire mid-loop — so one snapshot serves the whole ring and
        // the picks (and their order) match the rebuild-per-slot version.
        let mut eligible = self.take_eligible();
        let resident = self.take_resident(device);
        while !self.devices[device].pipeline.is_full() && !eligible.is_empty() {
            let ctx = PickContext {
                now,
                device,
                speed: self.devices[device].spec.speed,
                resident: Some(&resident),
                tenant_gpu_secs: Some(&self.tenant_gpu_secs),
            };
            let Some(i) = self.scheduler.pick(&eligible, ctx, &mut self.rng) else {
                break;
            };
            let id = eligible[i].id;
            // order-preserving removal keeps the remaining snapshot exactly
            // what a fresh rebuild from the ready-set would produce
            eligible.remove(i);
            self.ready.remove(id);
            obs.on_decision(device, id, true, now);
            let unit = self.tasks[id].claim_front();
            let bytes = if self.options.full_state_transfers {
                self.tasks[id].shard(unit.shard).param_bytes
            } else {
                self.tasks[id].shard(unit.shard).transfer_bytes(unit.phase)
            };
            // Only stage what fits next to the already-staged set;
            // otherwise the unit is claimed unstaged and falls back to a
            // synchronous transfer at start time.
            if self.devices[device].pipeline.can_stage(bytes) {
                // multi-hop staging: pull the shard NVMe->DRAM (pinning it)
                // and queue the NVMe leg ahead of the DRAM->HBM leg, so
                // compute hides the whole DRAM-miss path exactly like §4.6
                // hides PCIe. If DRAM is too contended to fetch now, claim
                // without staging — start_unit retries synchronously once
                // the demote has freed a slot.
                if let Ok(fetch) = self.memory.fetch_to_dram(id, unit.shard) {
                    if fetch.fetched_bytes > 0 {
                        obs.on_spill(
                            device,
                            fetch.fetched_bytes,
                            fetch.evicted_bytes,
                            MemTier::Nvme,
                            now,
                        );
                    }
                    let link_secs = self.link(device).secs(bytes);
                    let wait = self.devices[device].pipeline.stage(
                        unit,
                        bytes,
                        now,
                        fetch.secs,
                        link_secs,
                    );
                    self.agg_wait += wait;
                    continue;
                }
            }
            self.devices[device].pipeline.push_unstaged(unit);
            // an unstaged claim overlaps nothing: claiming further ahead
            // would only hoard eligible models, so stop filling here
            break;
        }
        self.put_eligible(eligible);
        self.put_resident(resident);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::unit::UnitGeometry;

    fn ledger() -> DeviceLedger {
        DeviceLedger::new(0, 1_000)
    }

    fn unit(model: usize) -> ShardUnit {
        UnitGeometry::new(1, 1, 1).unit_at(model, 0)
    }

    #[test]
    fn zone_reserved_in_ledger() {
        let mut l = ledger();
        let _p = PrefetchPipeline::new(true, 50, 1, &mut l).unwrap();
        assert_eq!(l.used(), 50);
        assert!(l.contains(&Residency::BufferZone));
    }

    #[test]
    fn disabled_pipeline_reserves_nothing_and_refuses_staging() {
        let mut l = ledger();
        let p = PrefetchPipeline::new(false, 50, 1, &mut l).unwrap();
        assert_eq!(l.used(), 0);
        assert!(!p.can_stage(10));
    }

    #[test]
    fn transfer_hidden_behind_compute_has_zero_stall() {
        let mut l = ledger();
        let mut p = PrefetchPipeline::new(true, 100, 1, &mut l).unwrap();
        // prefetch starts at t=0, takes 2s; unit starts at t=5 (compute hid it)
        let wait = p.stage(unit(3), 80, 0.0, 0.0, 2.0);
        assert_eq!(wait, 0.0);
        let slot = p.pop_front().unwrap();
        let st = slot.staged.unwrap();
        assert!((st.ready_at - 2.0).abs() < 1e-12);
        assert_eq!((st.ready_at - 5.0f64).max(0.0), 0.0); // no stall at t=5
        assert!(p.is_empty());
        assert_eq!(p.staged_bytes(), 0);
    }

    #[test]
    fn slow_transfer_produces_partial_stall() {
        let mut l = ledger();
        let mut p = PrefetchPipeline::new(true, 100, 1, &mut l).unwrap();
        p.stage(unit(3), 80, 0.0, 0.0, 7.0);
        let st = p.pop_front().unwrap().staged.unwrap();
        // consumed at t=5: 2s of the 7s transfer remain
        assert!(((st.ready_at - 5.0f64).max(0.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn oversized_shard_is_refused_not_overcommitted() {
        let mut l = ledger();
        let p = PrefetchPipeline::new(true, 100, 1, &mut l).unwrap();
        // larger than the zone: refused in release builds too
        assert!(!p.can_stage(200));
        assert!(p.can_stage(100));
    }

    #[test]
    fn zone_accounts_the_staged_set_not_just_one_slot() {
        let mut l = ledger();
        let mut p = PrefetchPipeline::new(true, 100, 4, &mut l).unwrap();
        assert!(p.can_stage(60));
        p.stage(unit(0), 60, 0.0, 0.0, 1.0);
        // a second 60-byte staging no longer fits next to the first
        assert!(!p.can_stage(60));
        assert!(p.can_stage(40));
        p.stage(unit(1), 40, 0.0, 0.0, 1.0);
        assert_eq!(p.staged_bytes(), 100);
        assert!(!p.can_stage(1));
        // consuming the front frees its bytes
        p.pop_front().unwrap();
        assert_eq!(p.staged_bytes(), 40);
        assert!(p.can_stage(60));
    }

    #[test]
    fn nvme_and_link_legs_of_different_slots_overlap_as_a_pipeline() {
        let mut l = ledger();
        let mut p = PrefetchPipeline::new(true, 100, 2, &mut l).unwrap();
        // slot A: NVMe leg 4s then link leg 1s -> ready at 5
        let wait_a = p.stage(unit(0), 10, 0.0, 4.0, 1.0);
        assert_eq!(wait_a, 0.0);
        // slot B staged at the same instant: its NVMe leg queues behind
        // A's (starts at 4), its link leg behind A's link leg (free at 5,
        // B's NVMe done at 8 -> starts at 8) -> ready at 9, waited 4s on
        // the NVMe link
        let wait_b = p.stage(unit(1), 10, 0.0, 4.0, 1.0);
        assert!((wait_b - 4.0).abs() < 1e-12, "{wait_b}");
        let a = p.pop_front().unwrap().staged.unwrap();
        let b = p.pop_front().unwrap().staged.unwrap();
        assert!((a.ready_at - 5.0).abs() < 1e-12);
        assert!((b.ready_at - 9.0).abs() < 1e-12);
    }

    #[test]
    fn link_leg_queues_behind_previous_link_leg() {
        let mut l = ledger();
        let mut p = PrefetchPipeline::new(true, 100, 2, &mut l).unwrap();
        // A: pure-PCIe staging (DRAM hit), 3s -> ready 3
        let wait_a = p.stage(unit(0), 10, 0.0, 0.0, 3.0);
        // B: DRAM hit too; its link leg waits for A's -> ready 6, waited 3
        let wait_b = p.stage(unit(1), 10, 0.0, 0.0, 3.0);
        assert_eq!(wait_a, 0.0);
        assert!((wait_b - 3.0).abs() < 1e-12);
        assert!((p.pop_front().unwrap().staged.unwrap().ready_at - 3.0).abs() < 1e-12);
        assert!((p.pop_front().unwrap().staged.unwrap().ready_at - 6.0).abs() < 1e-12);
    }

    #[test]
    fn remove_model_revokes_a_middle_slot() {
        let mut l = ledger();
        let mut p = PrefetchPipeline::new(true, 100, 3, &mut l).unwrap();
        p.stage(unit(0), 20, 0.0, 0.0, 1.0);
        p.stage(unit(1), 20, 0.0, 0.0, 1.0);
        p.push_unstaged(unit(2));
        assert_eq!(p.len(), 3);
        let revoked = p.remove_model(1).unwrap();
        assert_eq!(revoked.unit.model, 1);
        assert!(revoked.staged.is_some());
        assert_eq!(p.len(), 2);
        assert_eq!(p.staged_bytes(), 20);
        assert!(p.remove_model(1).is_none());
        // remaining order preserved: 0 then 2
        assert_eq!(p.pop_front().unwrap().unit.model, 0);
        assert_eq!(p.pop_front().unwrap().unit.model, 2);
    }

    #[test]
    fn remove_model_rewinds_the_link_clocks_past_the_abandoned_transfer() {
        let mut l = ledger();
        let mut p = PrefetchPipeline::new(true, 100, 1, &mut l).unwrap();
        // stage a slow transfer (NVMe 5s + link 1s -> busy until 6), then
        // revoke it: the clocks must rewind, so the next staging at t=2.5
        // neither queues nor inherits the phantom transfer's ready time
        p.stage(unit(0), 10, 0.0, 5.0, 1.0);
        assert!(p.remove_model(0).is_some());
        let wait = p.stage(unit(1), 10, 2.5, 1.0, 1.0);
        assert_eq!(wait, 0.0, "staging queued behind an abandoned transfer");
        let st = p.pop_front().unwrap().staged.unwrap();
        assert!((st.ready_at - 4.5).abs() < 1e-12, "{}", st.ready_at);
    }

    #[test]
    fn remove_model_keeps_the_clocks_of_the_surviving_slots() {
        let mut l = ledger();
        let mut p = PrefetchPipeline::new(true, 100, 3, &mut l).unwrap();
        // A: nvme [0,4] link [4,5]; B: nvme [4,8] link [8,9]
        p.stage(unit(0), 10, 0.0, 4.0, 1.0);
        p.stage(unit(1), 10, 0.0, 4.0, 1.0);
        // revoking B rewinds to A's legs: a new slot staged at t=0 queues
        // its NVMe leg behind A only (starts at 4, not 8)
        assert!(p.remove_model(1).is_some());
        let wait = p.stage(unit(2), 10, 0.0, 4.0, 1.0);
        assert!((wait - 4.0).abs() < 1e-12, "{wait}");
        p.pop_front().unwrap();
        let st = p.pop_front().unwrap().staged.unwrap();
        assert!((st.ready_at - 9.0).abs() < 1e-12, "{}", st.ready_at);
    }

    #[test]
    fn clear_drops_every_slot_and_resets_the_link_clocks() {
        let mut l = ledger();
        let mut p = PrefetchPipeline::new(true, 100, 2, &mut l).unwrap();
        p.stage(unit(0), 20, 0.0, 4.0, 1.0);
        p.push_unstaged(unit(1));
        let dropped = p.clear();
        assert_eq!(dropped.len(), 2);
        assert!(p.is_empty());
        assert_eq!(p.staged_bytes(), 0);
        // clocks reset: a fresh staging sees idle links again
        let wait = p.stage(unit(2), 20, 0.0, 4.0, 1.0);
        assert_eq!(wait, 0.0);
        assert!((p.pop_front().unwrap().staged.unwrap().ready_at - 5.0).abs() < 1e-12);
    }

    #[test]
    fn codec_round_trips_a_busy_pipeline() {
        let mut l = ledger();
        let mut p = PrefetchPipeline::new(true, 100, 3, &mut l).unwrap();
        p.stage(unit(0), 20, 0.0, 4.0, 1.0);
        p.push_unstaged(unit(1));
        let mut w = ByteWriter::new();
        p.encode(&mut w);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        let back = PrefetchPipeline::decode(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(format!("{p:?}"), format!("{back:?}"));
        assert_eq!(back.staged_bytes(), 20);
        assert_eq!(back.len(), 2);
    }

    #[test]
    fn depth_one_never_queues() {
        let mut l = ledger();
        let mut p = PrefetchPipeline::new(true, 100, 1, &mut l).unwrap();
        // stage/consume cycles where the next stage always happens at or
        // after the previous ready time (the engine guarantees this: the
        // consumer stalls until ready_at before computing again)
        let mut t = 0.0;
        for i in 0..5 {
            let wait = p.stage(unit(i), 50, t, 2.0, 1.0);
            assert_eq!(wait, 0.0, "depth-1 staging must never queue");
            let st = p.pop_front().unwrap().staged.unwrap();
            assert!((st.ready_at - (t + 3.0)).abs() < 1e-12);
            t = st.ready_at + 0.5; // next compute start, past ready
        }
    }
}
