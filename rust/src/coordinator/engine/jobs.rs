//! Job lifecycle: online submissions and cancellations, arrival gating,
//! finish bookkeeping, and the per-job outcome statistics
//! ([`JobStat`]) the multi-tenant setting reports.

use crate::coordinator::observer::EngineObserver;
use crate::coordinator::task::{ModelTask, TaskState};
use crate::error::{HydraError, Result};
use crate::util::codec::{ByteReader, ByteWriter};

use super::core::{tenant_slot, SharpEngine};
use super::events::Event;

/// A tenant-facing job-queue event: submissions and cancellations that take
/// effect *while the engine runs* (the online multi-tenant setting).
///
/// Jobs known up front carry their arrival via
/// [`ModelTask::with_arrival`]; `Submit` additionally allows tasks the
/// engine has never seen (e.g. a tenant showing up mid-run), and `Cancel`
/// revokes a job at unit granularity: an in-flight unit completes,
/// everything else is dropped.
#[derive(Debug, Clone)]
pub enum JobEvent {
    /// Submit `task` at `time`. The task's id must equal the number of
    /// tasks the engine will know at that point (construction tasks +
    /// earlier submissions), i.e. ids follow submission order.
    Submit {
        /// Virtual time of the submission.
        time: f64,
        /// The job being submitted.
        task: ModelTask,
    },
    /// Cancel `model` at `time`. Idempotent; cancelling a finished job is a
    /// no-op.
    Cancel {
        /// Virtual time of the cancellation.
        time: f64,
        /// Task id to cancel.
        model: usize,
    },
}

impl JobEvent {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        match self {
            JobEvent::Submit { time, task } => {
                w.put_u8(0);
                w.put_f64(*time);
                task.encode(w);
            }
            JobEvent::Cancel { time, model } => {
                w.put_u8(1);
                w.put_f64(*time);
                w.put_usize(*model);
            }
        }
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<JobEvent> {
        Ok(match r.get_u8()? {
            0 => JobEvent::Submit { time: r.get_f64()?, task: ModelTask::decode(r)? },
            1 => JobEvent::Cancel { time: r.get_f64()?, model: r.get_usize()? },
            t => {
                return Err(HydraError::WalCorrupt(format!(
                    "unknown job-event tag {t}"
                )))
            }
        })
    }
}

/// A typed admission-control rejection, recorded in
/// [`super::core::RunReport::sheds`] — the same make-the-drop-visible idiom
/// as the sharded front door's `ShardBusy`. Carries no model id, so sharded
/// merges concatenate sections without remapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// A submission was shed because its tenant already had `depth`
    /// unfinished jobs queued (the configured
    /// [`super::core::EngineOptions::admission_depth`] bound).
    Shed {
        /// Tenant whose queue was full.
        tenant: usize,
        /// The bound that was hit.
        depth: usize,
    },
}

impl Admission {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        match self {
            Admission::Shed { tenant, depth } => {
                w.put_u8(0);
                w.put_usize(*tenant);
                w.put_usize(*depth);
            }
        }
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<Admission> {
        Ok(match r.get_u8()? {
            0 => Admission::Shed { tenant: r.get_usize()?, depth: r.get_usize()? },
            t => {
                return Err(HydraError::WalCorrupt(format!(
                    "unknown admission tag {t}"
                )))
            }
        })
    }
}

/// Per-job outcome statistics for the online setting.
#[derive(Clone)]
pub struct JobStat {
    /// Task id.
    pub model: usize,
    /// Task name (tenant-facing tag).
    pub name: String,
    /// Arrival (submission) time.
    pub arrival: f64,
    /// Virtual time the job finished (last unit retired, or the moment a
    /// cancellation took effect). `NaN` if the run ended with the job
    /// unfinished (e.g. every device failed).
    pub finished: f64,
    /// Whether the job was cancelled.
    pub cancelled: bool,
    /// Earliest tenant cancel request, if any was issued — recorded even
    /// when the request was a no-op because the job had already finished
    /// (`cancelled` stays false then). This is how
    /// `Session::cancel_at`-after-completion is observable in the report
    /// instead of vanishing silently.
    pub cancel_requested: Option<f64>,
    /// Units this job actually executed.
    pub units_executed: u64,
    /// Whether admission control shed this job at submission: it finished
    /// instantly with zero units and was never scheduled.
    pub shed: bool,
}

/// Hand-rolled to match the pre-tenancy derive output: `shed` is appended
/// only when set, so jobs from runs without admission control print exactly
/// as they always did (part of the Debug-byte-identity compat proof).
impl std::fmt::Debug for JobStat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = f.debug_struct("JobStat");
        s.field("model", &self.model)
            .field("name", &self.name)
            .field("arrival", &self.arrival)
            .field("finished", &self.finished)
            .field("cancelled", &self.cancelled)
            .field("cancel_requested", &self.cancel_requested)
            .field("units_executed", &self.units_executed);
        if self.shed {
            s.field("shed", &self.shed);
        }
        s.finish()
    }
}

impl JobStat {
    /// Job latency (finish - arrival), clamped at 0 so a job cancelled
    /// *before* its arrival reports zero rather than a negative latency;
    /// `NaN` for unfinished jobs.
    pub fn latency(&self) -> f64 {
        let l = self.finished - self.arrival;
        // NaN compares false, so unfinished jobs keep their NaN latency
        if l < 0.0 {
            0.0
        } else {
            l
        }
    }
}

impl<'a> SharpEngine<'a> {
    /// Mark `model` finished at `now` (first transition only) and release
    /// its homed parameters from the hierarchy — online streams with churn
    /// would otherwise exhaust the tiers and reject later submissions.
    /// Releasing twice is a real error (the old pool saturated silently).
    pub(crate) fn finish_job(
        &mut self,
        model: usize,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        if self.finish_times[model].is_nan() {
            self.finish_times[model] = now;
            // the tenant's queue-depth gauge drains here (shed jobs bypass
            // this path entirely: they were never counted in)
            if self.tenant_meta {
                let slot =
                    tenant_slot(&mut self.tenant_outstanding, self.tasks[model].tenant());
                *slot = slot.saturating_sub(1);
            }
            let bytes = Self::shard_bytes(&self.tasks[model]);
            self.memory.unhome_model(model, &bytes)?;
            obs.on_job_finished(model, now, self.job_cancelled[model]);
        }
        Ok(())
    }

    pub(crate) fn on_job_arrive(
        &mut self,
        model: usize,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) {
        self.arrived[model] = true;
        // a job cancelled before its arrival never becomes eligible: no
        // arrival notification after its on_job_finished(cancelled=true)
        if !self.job_cancelled[model] && self.tasks[model].state() == TaskState::Idle {
            obs.on_job_arrived(model, &self.tasks[model].name, now);
            self.ready.insert(model);
            self.wake_one(now);
        }
    }

    pub(crate) fn on_job_submit(
        &mut self,
        idx: usize,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        let Some(task) = self.pending_submissions[idx].take() else {
            return Ok(());
        };
        let id = self.tasks.len();
        if task.id != id {
            return Err(HydraError::Sched(format!(
                "submitted task has id {} but {id} tasks are registered \
                 (ids must follow submission order)",
                task.id
            )));
        }
        // a submission carrying tenant metadata switches tenant accounting
        // on for the rest of the run
        self.tenant_meta |= task.has_tenant_meta();
        // admission control: shed when the tenant's queue sits at its
        // bound. The shed task keeps its dense id (later submissions stay
        // valid) but finishes instantly with zero units — never homed,
        // never eligible, never retiring anything.
        if let Some(depth) = self.options.admission_depth {
            let tenant = task.tenant();
            if self.tenant_outstanding.get(tenant).copied().unwrap_or(0) >= depth {
                obs.on_job_shed(id, &task.name, tenant, depth, now);
                let mut task = task;
                task.early_stop();
                self.tasks.push(task);
                self.job_cancelled.push(false);
                self.cancel_requested.push(f64::NAN);
                self.finish_times.push(now);
                self.arrived.push(false);
                self.sheds.push(Admission::Shed { tenant, depth });
                self.shed_models.insert(id);
                return Ok(());
            }
        }
        self.memory.home_model(task.id, &Self::shard_bytes(&task))?;
        obs.on_job_submitted(task.id, &task.name, now);
        if self.tenant_meta {
            *tenant_slot(&mut self.tenant_outstanding, task.tenant()) += 1;
        }
        self.tasks.push(task);
        self.job_cancelled.push(false);
        self.cancel_requested.push(f64::NAN);
        self.finish_times.push(f64::NAN);
        // a submission may carry its own later arrival time; gate on it
        let arrival = self.tasks[id].arrival();
        if arrival > now {
            self.arrived.push(false);
            self.queue.push(arrival, Event::JobArrive { model: id });
        } else {
            self.arrived.push(true);
            obs.on_job_arrived(id, &self.tasks[id].name, now);
            if self.tasks[id].state() == TaskState::Idle {
                self.ready.insert(id);
                self.wake_one(now);
            }
        }
        Ok(())
    }

    pub(crate) fn on_job_cancel(
        &mut self,
        model: usize,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        if model >= self.tasks.len() {
            return Err(HydraError::Sched(format!(
                "cancel of unknown model {model}"
            )));
        }
        obs.on_job_cancel_requested(model, now);
        // every request is recorded (earliest wins), even the no-op ones
        // against already-finished jobs — the report stays auditable
        if self.cancel_requested[model].is_nan() {
            self.cancel_requested[model] = now;
        }
        if self.job_cancelled[model] || self.tasks[model].state() == TaskState::Done {
            return Ok(()); // idempotent; cancelling a finished job is a no-op
        }
        self.job_cancelled[model] = true;
        match self.tasks[model].state() {
            TaskState::Idle => {
                self.ready.remove(model);
                self.tasks[model].early_stop();
                self.finish_job(model, now, obs)?;
            }
            TaskState::Running => {
                // The claim is either a pre-claimed prefetch slot (revoked
                // immediately, releasing its staged DRAM pin) or a
                // genuinely in-flight unit (completes first; cancellation
                // is unit-granular).
                let mut revoked = false;
                for d in 0..self.devices.len() {
                    if let Some(slot) = self.devices[d].pipeline.remove_model(model) {
                        if let Some(st) = slot.staged {
                            // the staged fetch pinned the shard in DRAM
                            self.memory.release_device_copy(st.model, st.shard);
                        }
                        self.tasks[model].unclaim(&slot.unit);
                        self.tasks[model].early_stop();
                        self.finish_job(model, now, obs)?;
                        revoked = true;
                        break;
                    }
                }
                if !revoked {
                    self.cancel_pending.insert(model);
                }
            }
            TaskState::Done => {}
        }
        Ok(())
    }
}
