//! The engine core: [`SharpEngine`] construction, the virtual-time run
//! loop ([`SharpEngine::run_with`]), unit dispatch (`on_device_free` /
//! `start_unit` / `on_unit_retire`), and the run report.
//!
//! Everything else lives in the sibling modules: the event queue in
//! [`super::events`], device lifecycle in [`super::device`], job lifecycle
//! in [`super::jobs`], and the depth-k prefetch pipeline in
//! [`super::prefetch`].

use crate::coordinator::memory::{
    MemTier, MemoryHierarchy, MemoryOptions, Residency,
};
use crate::coordinator::metrics::{Interval, IntervalKind, Trace};
use crate::coordinator::observer::{EngineObserver, NoopObserver, Tee, TraceRecorder};
use crate::coordinator::sched::{PickContext, Scheduler};
use crate::coordinator::task::{ModelSnapshot, ModelTask, TaskState};
use crate::coordinator::unit::{Phase, ShardUnit};
use crate::error::{HydraError, Result};
use crate::exec::ExecutionBackend;
use crate::util::codec::{ByteReader, ByteWriter};
use crate::util::idset::IdSet;
use crate::util::rng::Rng;

use super::device::{ClusterEvent, DeviceSpec, DeviceState};
use super::events::{Event, EventQueue, QueueKind, QueuedEvent};
use super::jobs::{Admission, JobEvent, JobStat};
use super::prefetch::StagedShard;
use super::routing::StolenJob;
use super::TransferModel;

/// Parallelism mode: SHARP blending vs the spilling-only ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelMode {
    /// Full SHARP: all idle models are eligible on any free device.
    Sharp,
    /// Ablation (Table 3 "without SHARP"): models run one-after-another;
    /// only the lowest-id unfinished (arrived) model is ever eligible, so
    /// sequential shard dependencies leave at most one device busy.
    Sequential,
}

impl ParallelMode {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            ParallelMode::Sharp => 0,
            ParallelMode::Sequential => 1,
        });
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<ParallelMode> {
        Ok(match r.get_u8()? {
            0 => ParallelMode::Sharp,
            1 => ParallelMode::Sequential,
            t => {
                return Err(HydraError::WalCorrupt(format!(
                    "unknown parallel-mode tag {t}"
                )))
            }
        })
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// SHARP blending vs the sequential ablation.
    pub mode: ParallelMode,
    /// Enable §4.6 double-buffered prefetch.
    pub double_buffer: bool,
    /// Fraction of device memory reserved as the prefetch zone (§4.6).
    pub buffer_frac: f64,
    /// Upcoming units the scheduler pre-claims per device — the depth of
    /// the prefetch pipeline. 1 (the default) is the paper's classic
    /// double buffer; higher depths overlap the NVMe->DRAM and DRAM->HBM
    /// legs of different slots so multi-hop DRAM-miss chains hide behind
    /// more than one compute span. The zone size is unchanged: k is
    /// additionally bounded by what fits the zone.
    pub prefetch_depth: usize,
    /// Engine-wide DRAM<->device link (overridable per device via
    /// [`DeviceSpec::link`]).
    pub transfer: TransferModel,
    /// Seed for the engine's RNG stream (Random scheduler etc.).
    pub seed: u64,
    /// Record per-interval trace entries into the report
    /// (`RunReport::trace`). Implemented as an opt-in
    /// [`crate::coordinator::observer::TraceRecorder`] observer, so turning
    /// it off removes the bookkeeping from the hot path entirely (disable
    /// for very long sims to bound memory; scalar aggregates are still
    /// collected).
    pub record_intervals: bool,
    /// Paper-fidelity mode: spilling moves the *full* shard state (weights +
    /// gradients + optimizer state) instead of weights-only. Hydra's default
    /// (false) keeps optimizer state in DRAM with a Rust-side update — the
    /// same design the real backend implements — which shrinks transfer
    /// volume ~3x. Used by the Table 3 ablation to recover the paper's
    /// no-double-buffering penalty.
    pub full_state_transfers: bool,
    /// Event-queue discipline: heap by default, linear scan as the
    /// reference, calendar for heavy same-timestamp churn (arrival
    /// storms). All three pop in identical (time, seq) order.
    pub queue: QueueKind,
    /// Number of independent coordinator shards the cluster is partitioned
    /// into (>= 1). Only the sharded front doors
    /// ([`super::sharded::ShardedEngine`], `Session::builder().shards(n)`)
    /// act on it; a directly-constructed [`SharpEngine`] always runs as the
    /// single global coordinator and ignores this field. 1 (the default) is
    /// the unsharded engine.
    pub shards: usize,
    /// Per-tenant admission bound: a mid-run submission
    /// ([`super::jobs::JobEvent::Submit`]) is shed when its tenant already
    /// has this many unfinished jobs queued. Shed jobs keep their dense task
    /// id but finish immediately with zero units, and each rejection is
    /// recorded as an [`super::jobs::Admission::Shed`] in
    /// [`RunReport::sheds`]. `None` (the default) admits everything.
    /// Construction-time tasks are never shed — they model the accepted
    /// backlog. Under a sharded front door the bound applies per shard.
    pub admission_depth: Option<usize>,
    /// Run the shard engines of a sharded front door on real OS threads
    /// (one scoped thread per shard) instead of the sequential shard loop.
    /// Requires an [`ExecutionBackend`] that can
    /// [`fork`](ExecutionBackend::fork_for_shard) an independent per-shard
    /// copy — the noiseless [`crate::exec::SimBackend`] can, a noisy one
    /// cannot (it threads a single RNG stream through the shards in shard
    /// order, which threads could not replicate). The merged report is
    /// Debug-byte-identical to the sequential shard loop either way; only
    /// wall-clock changes. Ignored at `shards == 1`.
    pub threads: bool,
    /// Admission-time work stealing between shards: after routing and
    /// mailbox drain, jobs migrate from the deepest admission queue to the
    /// shallowest through a capacity-checked steal handshake
    /// ([`super::routing::steal_allowed`]). Off by default so the
    /// hash-routed baseline stays byte-identical; every migration is
    /// recorded in [`RunReport::stolen`]. Only not-yet-started jobs move —
    /// never in-flight units.
    pub stealing: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            mode: ParallelMode::Sharp,
            double_buffer: true,
            buffer_frac: 0.05,
            prefetch_depth: 1,
            transfer: TransferModel::pcie_gen3(),
            seed: 0,
            record_intervals: true,
            full_state_transfers: false,
            queue: QueueKind::Heap,
            shards: 1,
            admission_depth: None,
            threads: false,
            stealing: false,
        }
    }
}

impl EngineOptions {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        self.mode.encode(w);
        w.put_bool(self.double_buffer);
        w.put_f64(self.buffer_frac);
        w.put_usize(self.prefetch_depth);
        self.transfer.encode(w);
        w.put_u64(self.seed);
        w.put_bool(self.record_intervals);
        w.put_bool(self.full_state_transfers);
        self.queue.encode(w);
        w.put_usize(self.shards);
        match self.admission_depth {
            None => w.put_bool(false),
            Some(d) => {
                w.put_bool(true);
                w.put_usize(d);
            }
        }
        // codec is append-only (the WAL genesis embeds it): new fields go
        // strictly after every older one
        w.put_bool(self.threads);
        w.put_bool(self.stealing);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<EngineOptions> {
        Ok(EngineOptions {
            mode: ParallelMode::decode(r)?,
            double_buffer: r.get_bool()?,
            buffer_frac: r.get_f64()?,
            prefetch_depth: r.get_usize()?,
            transfer: TransferModel::decode(r)?,
            seed: r.get_u64()?,
            record_intervals: r.get_bool()?,
            full_state_transfers: r.get_bool()?,
            queue: QueueKind::decode(r)?,
            shards: r.get_usize()?,
            admission_depth: if r.get_bool()? { Some(r.get_usize()?) } else { None },
            threads: r.get_bool()?,
            stealing: r.get_bool()?,
        })
    }
}

/// Per-tenant accounting section of a [`RunReport`], present only when the
/// run carried tenant metadata (any job with a non-default tenant, weight or
/// deadline, or admission control switched on). Sections merge across
/// coordinator shards exactly like the scalar aggregates: counts add, and
/// GPU-seconds fold in shard order so sharded totals conserve bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStat {
    /// Tenant id (dense, `0` is the default tenant).
    pub tenant: usize,
    /// Jobs submitted under this tenant, shed ones included.
    pub jobs: usize,
    /// Accumulated compute seconds across the tenant's units — the WFQ
    /// virtual clock's input.
    pub gpu_secs: f64,
    /// Shard units the tenant's jobs retired.
    pub units: u64,
    /// Jobs rejected by admission control.
    pub shed: u64,
    /// Jobs that carried a deadline.
    pub slo_jobs: usize,
    /// Deadline-carrying jobs that finished (uncancelled, unshed) within
    /// `arrival + deadline`.
    pub slo_met: usize,
}

impl TenantStat {
    /// SLO attainment: fraction of deadline-carrying jobs that met their
    /// deadline; `None` when the tenant set no deadlines.
    pub fn slo_attainment(&self) -> Option<f64> {
        (self.slo_jobs > 0).then(|| self.slo_met as f64 / self.slo_jobs as f64)
    }
}

/// Result summary of an engine run.
#[derive(Clone)]
pub struct RunReport {
    /// Full execution trace (intervals, device windows, makespan).
    pub trace: Trace,
    /// Virtual time the last interval ends.
    pub makespan: f64,
    /// Compute seconds / available device seconds.
    pub utilization: f64,
    /// Total shard-unit compute seconds.
    pub compute_secs: f64,
    /// Total synchronous transfer seconds.
    pub transfer_secs: f64,
    /// Total prefetch stall seconds (devices waiting on an in-flight
    /// staged transfer).
    pub stall_secs: f64,
    /// Total seconds prefetch transfers spent queued behind a busy staging
    /// link (the at-most-one-in-flight-per-link discipline). Always 0 at
    /// `prefetch_depth == 1`; at depth >= 2 it measures how saturated the
    /// staging links are.
    pub prefetch_wait_secs: f64,
    /// Shard units retired.
    pub units_executed: u64,
    /// DRAM->device promotion traffic.
    pub promoted_bytes: u64,
    /// Device->DRAM demotion traffic.
    pub demoted_bytes: u64,
    /// NVMe->DRAM fetch traffic (zero without an NVMe tier).
    pub nvme_promoted_bytes: u64,
    /// DRAM->NVMe eviction write-back traffic.
    pub nvme_demoted_bytes: u64,
    /// Seconds devices spent blocked on synchronous NVMe staging.
    pub nvme_secs: f64,
    /// Name of the scheduling policy used.
    pub scheduler: &'static str,
    /// Per-job arrival/finish/cancellation statistics (online setting;
    /// batch runs have arrival 0.0 everywhere).
    pub jobs: Vec<JobStat>,
    /// Per-tenant accounting, ascending tenant id. Empty unless the run
    /// carried tenant metadata (see [`TenantStat`]).
    pub tenants: Vec<TenantStat>,
    /// Admission-control rejections in submission order. Empty unless
    /// [`EngineOptions::admission_depth`] shed something.
    pub sheds: Vec<Admission>,
    /// Jobs the steal planner migrated between shards, in planning order
    /// (shard-order concatenated when merged). Empty unless
    /// [`EngineOptions::stealing`] moved something; always empty on
    /// per-shard and unsharded reports.
    pub stolen: Vec<StolenJob>,
}

/// Hand-rolled to match the output the derive produced before the
/// multi-tenant fields existed: `tenants`/`sheds` are appended only when
/// non-empty, so reports without tenant metadata stay Debug-byte-identical
/// to pre-tenancy builds (the backward-compat proof the property suite
/// pins).
impl std::fmt::Debug for RunReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = f.debug_struct("RunReport");
        s.field("trace", &self.trace)
            .field("makespan", &self.makespan)
            .field("utilization", &self.utilization)
            .field("compute_secs", &self.compute_secs)
            .field("transfer_secs", &self.transfer_secs)
            .field("stall_secs", &self.stall_secs)
            .field("prefetch_wait_secs", &self.prefetch_wait_secs)
            .field("units_executed", &self.units_executed)
            .field("promoted_bytes", &self.promoted_bytes)
            .field("demoted_bytes", &self.demoted_bytes)
            .field("nvme_promoted_bytes", &self.nvme_promoted_bytes)
            .field("nvme_demoted_bytes", &self.nvme_demoted_bytes)
            .field("nvme_secs", &self.nvme_secs)
            .field("scheduler", &self.scheduler)
            .field("jobs", &self.jobs);
        if !self.tenants.is_empty() {
            s.field("tenants", &self.tenants);
        }
        if !self.sheds.is_empty() {
            s.field("sheds", &self.sheds);
        }
        if !self.stolen.is_empty() {
            s.field("stolen", &self.stolen);
        }
        s.finish()
    }
}

/// The SHARP engine.
pub struct SharpEngine<'a> {
    /// The model tasks (public for post-run inspection in tests/figures).
    pub tasks: Vec<ModelTask>,
    pub(crate) devices: Vec<DeviceState>,
    pub(crate) memory: MemoryHierarchy,
    pub(crate) options: EngineOptions,
    pub(crate) scheduler: Box<dyn Scheduler>,
    pub(crate) backend: &'a mut dyn ExecutionBackend,
    pub(crate) cluster_events: Vec<ClusterEvent>,
    pub(crate) job_events: Vec<JobEvent>,
    // run state
    pub(crate) queue: EventQueue,
    pub(crate) pending_submissions: Vec<Option<ModelTask>>,
    /// Models whose front unit is eligible right now (arrived + idle).
    /// Sorted dense-id slab ([`IdSet`]): ascending iteration matches the
    /// `BTreeSet` it replaced, so snapshots and schedules are unchanged.
    pub(crate) ready: IdSet,
    /// Per-model: has the arrival time passed?
    pub(crate) arrived: Vec<bool>,
    /// Per-model: has a cancellation been issued?
    pub(crate) job_cancelled: Vec<bool>,
    /// Per-model earliest cancel-request time (NaN = never requested);
    /// recorded even for no-op requests against finished jobs.
    pub(crate) cancel_requested: Vec<f64>,
    /// Cancellations waiting for an in-flight unit to retire.
    pub(crate) cancel_pending: IdSet,
    /// Per-model finish time (NaN until finished).
    pub(crate) finish_times: Vec<f64>,
    /// Devices that are alive, idle, and found no work at their last wake.
    pub(crate) parked: IdSet,
    /// Count of alive devices not currently computing.
    pub(crate) free_devices: usize,
    pub(crate) trace: Trace,
    pub(crate) units_executed: u64,
    pub(crate) agg_compute: f64,
    pub(crate) agg_transfer: f64,
    pub(crate) agg_stall: f64,
    pub(crate) agg_nvme: f64,
    /// Prefetch-link queueing seconds (see `RunReport::prefetch_wait_secs`).
    pub(crate) agg_wait: f64,
    pub(crate) rng: Rng,
    /// Scratch snapshot buffer reused across scheduling decisions, so the
    /// dispatch hot path allocates nothing per decision.
    pub(crate) scratch_eligible: Vec<ModelSnapshot>,
    /// Scratch residency buffer reused across `PickContext` builds.
    pub(crate) scratch_resident: Vec<(usize, u32)>,
    // multi-tenant state: dense per-tenant slabs grown on first touch (no
    // tree maps on the hot path), live only when `tenant_meta` is set
    /// Does this run carry tenant metadata at all? Latched at construction
    /// from the initial tasks and admission config, and by any mid-run
    /// submission that brings metadata with it. Off, the tenant slabs stay
    /// untouched and the report's tenant section stays empty.
    pub(crate) tenant_meta: bool,
    /// Accumulated compute seconds per tenant — the WFQ virtual clock.
    pub(crate) tenant_gpu_secs: Vec<f64>,
    /// Shard units retired per tenant.
    pub(crate) tenant_units: Vec<u64>,
    /// Unfinished jobs per tenant (admission's queue-depth gauge).
    pub(crate) tenant_outstanding: Vec<usize>,
    /// Admission rejections in submission order.
    pub(crate) sheds: Vec<Admission>,
    /// Models rejected by admission control (`JobStat::shed`).
    pub(crate) shed_models: IdSet,
}

/// Index into a dense per-tenant slab, growing it (default-filled) on first
/// touch. Tenant ids are small dense integers (bounded by
/// [`crate::coordinator::task::MAX_TENANT_ID`]), so flat `Vec`s replace the
/// tree maps the hot path must avoid.
pub(crate) fn tenant_slot<T: Default + Clone>(v: &mut Vec<T>, tenant: usize) -> &mut T {
    if v.len() <= tenant {
        v.resize(tenant + 1, T::default());
    }
    &mut v[tenant]
}

impl<'a> SharpEngine<'a> {
    /// Build an engine over a homogeneous pool (`device_mem[i]` bytes each,
    /// reference speed, engine-wide link). The seed API; see
    /// [`SharpEngine::with_devices`] for heterogeneous pools. `memory` is
    /// either a bare `dram_bytes: u64` (the legacy two-tier setup) or a
    /// full [`MemoryOptions`] with an NVMe backing tier.
    pub fn new(
        tasks: Vec<ModelTask>,
        device_mem: &[u64],
        memory: impl Into<MemoryOptions>,
        scheduler: Box<dyn Scheduler>,
        backend: &'a mut dyn ExecutionBackend,
        options: EngineOptions,
    ) -> Result<SharpEngine<'a>> {
        let specs: Vec<DeviceSpec> =
            device_mem.iter().map(|&m| DeviceSpec::uniform(m)).collect();
        Self::with_devices(tasks, &specs, memory, scheduler, backend, options)
    }

    /// Build an engine over an explicit (possibly heterogeneous) device
    /// pool. Tasks must be partitioned so every shard fits the smallest
    /// device (the §4.3 "smallest-memory GPU" contract — see
    /// [`crate::sim::build_tasks_pool`]).
    pub fn with_devices(
        tasks: Vec<ModelTask>,
        specs: &[DeviceSpec],
        memory: impl Into<MemoryOptions>,
        scheduler: Box<dyn Scheduler>,
        backend: &'a mut dyn ExecutionBackend,
        options: EngineOptions,
    ) -> Result<SharpEngine<'a>> {
        if specs.is_empty() {
            return Err(HydraError::Config("no devices".into()));
        }
        if options.prefetch_depth == 0 {
            return Err(HydraError::Config(
                "prefetch_depth must be >= 1 (1 = classic double-buffering)".into(),
            ));
        }
        for (m, t) in tasks.iter().enumerate() {
            if t.id != m {
                return Err(HydraError::Config(format!(
                    "task {m} has id {} (ids must be dense and in order)",
                    t.id
                )));
            }
        }
        let mut memory = MemoryHierarchy::new(memory);
        for t in &tasks {
            memory.home_model(t.id, &Self::shard_bytes(t))?;
        }
        let mut devices = Vec::new();
        for (id, &spec) in specs.iter().enumerate() {
            devices.push(Self::mk_device(id, spec, &options)?);
        }
        let rng = Rng::new(options.seed);
        let n_tasks = tasks.len();
        let n_devices = devices.len();
        let tenant_meta = options.admission_depth.is_some()
            || tasks.iter().any(|t| t.has_tenant_meta());
        let mut tenant_outstanding = Vec::new();
        if tenant_meta {
            // construction tasks are pre-admitted backlog: they count
            // against their tenant's queue depth from t = 0
            for t in &tasks {
                *tenant_slot(&mut tenant_outstanding, t.tenant()) += 1;
            }
        }
        Ok(SharpEngine {
            tasks,
            devices,
            memory,
            options: options.clone(),
            scheduler,
            backend,
            cluster_events: Vec::new(),
            job_events: Vec::new(),
            queue: EventQueue::new(options.queue),
            pending_submissions: Vec::new(),
            ready: IdSet::new(),
            arrived: vec![false; n_tasks],
            job_cancelled: vec![false; n_tasks],
            cancel_requested: vec![f64::NAN; n_tasks],
            cancel_pending: IdSet::new(),
            finish_times: vec![f64::NAN; n_tasks],
            parked: IdSet::new(),
            free_devices: n_devices,
            trace: Trace::default(),
            units_executed: 0,
            agg_compute: 0.0,
            agg_transfer: 0.0,
            agg_stall: 0.0,
            agg_nvme: 0.0,
            agg_wait: 0.0,
            rng,
            scratch_eligible: Vec::new(),
            scratch_resident: Vec::new(),
            tenant_meta,
            tenant_gpu_secs: Vec::new(),
            tenant_units: Vec::new(),
            tenant_outstanding,
            sheds: Vec::new(),
            shed_models: IdSet::new(),
        })
    }

    /// Per-shard home-tier footprints of a task (what the hierarchy homes
    /// and unhomes).
    pub(crate) fn shard_bytes(task: &ModelTask) -> Vec<u64> {
        task.shards.iter().map(|s| s.param_bytes).collect()
    }

    /// Register arrival/failure events before `run`.
    pub fn with_cluster_events(mut self, events: Vec<ClusterEvent>) -> Self {
        self.cluster_events = events;
        self
    }

    /// Register online job submissions/cancellations before `run`.
    pub fn with_job_events(mut self, events: Vec<JobEvent>) -> Self {
        self.job_events = events;
        self
    }

    /// Serialize the complete mid-run state for a durability snapshot.
    ///
    /// Everything mutable is captured: tasks (with their private unit
    /// bookkeeping), device states, the memory hierarchy, the pending event
    /// queue, job gating/cancellation vectors, the trace, the scalar
    /// aggregates and the engine RNG stream. Deliberately *not* captured —
    /// restored from the WAL genesis record instead — are `options`,
    /// `cluster_events` (queued events reference them by index), the
    /// scheduler (stateless; rebuilt from the policy) and the backend
    /// (its RNG state rides alongside this payload in the snapshot).
    pub(crate) fn encode_state(&self, w: &mut ByteWriter) {
        w.put_usize(self.tasks.len());
        for t in &self.tasks {
            t.encode(w);
        }
        w.put_usize(self.devices.len());
        for d in &self.devices {
            d.encode(w);
        }
        self.memory.encode(w);
        let (entries, seq) = self.queue.snapshot();
        w.put_usize(entries.len());
        for q in &entries {
            w.put_f64(q.time);
            w.put_u64(q.seq);
            q.ev.encode(w);
        }
        w.put_u64(seq);
        w.put_usize(self.pending_submissions.len());
        for p in &self.pending_submissions {
            match p {
                None => w.put_bool(false),
                Some(t) => {
                    w.put_bool(true);
                    t.encode(w);
                }
            }
        }
        w.put_usize(self.ready.len());
        for m in self.ready.iter() {
            w.put_usize(m);
        }
        w.put_usize(self.arrived.len());
        for &b in &self.arrived {
            w.put_bool(b);
        }
        w.put_usize(self.job_cancelled.len());
        for &b in &self.job_cancelled {
            w.put_bool(b);
        }
        w.put_usize(self.cancel_requested.len());
        for &t in &self.cancel_requested {
            w.put_f64(t);
        }
        w.put_usize(self.cancel_pending.len());
        for m in self.cancel_pending.iter() {
            w.put_usize(m);
        }
        w.put_usize(self.finish_times.len());
        for &t in &self.finish_times {
            w.put_f64(t);
        }
        w.put_usize(self.parked.len());
        for d in self.parked.iter() {
            w.put_usize(d);
        }
        w.put_usize(self.free_devices);
        self.trace.encode(w);
        w.put_u64(self.units_executed);
        w.put_f64(self.agg_compute);
        w.put_f64(self.agg_transfer);
        w.put_f64(self.agg_stall);
        w.put_f64(self.agg_nvme);
        w.put_f64(self.agg_wait);
        for s in self.rng.state() {
            w.put_u64(s);
        }
        // multi-tenant state: only the non-derivable pieces are serialized —
        // the unit/outstanding slabs are rebuilt from the tasks on restore
        w.put_bool(self.tenant_meta);
        w.put_usize(self.tenant_gpu_secs.len());
        for &g in &self.tenant_gpu_secs {
            w.put_f64(g);
        }
        w.put_usize(self.sheds.len());
        for s in &self.sheds {
            s.encode(w);
        }
        w.put_usize(self.shed_models.len());
        for m in self.shed_models.iter() {
            w.put_usize(m);
        }
    }

    /// Overwrite this engine's run state with an [`SharpEngine::encode_state`]
    /// payload. The engine must have been constructed from the same genesis
    /// record (same options, cluster events, scheduler) and must *not* be
    /// primed — a restored engine resumes by stepping directly.
    pub(crate) fn restore_state(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
        let n = r.get_count(32)?;
        let mut tasks = Vec::with_capacity(n);
        for _ in 0..n {
            tasks.push(ModelTask::decode(r)?);
        }
        self.tasks = tasks;
        let n = r.get_count(32)?;
        let mut devices = Vec::with_capacity(n);
        for _ in 0..n {
            devices.push(DeviceState::decode(r)?);
        }
        self.devices = devices;
        self.memory = MemoryHierarchy::decode(r)?;
        let n = r.get_count(17)?;
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            entries.push(QueuedEvent {
                time: r.get_f64()?,
                seq: r.get_u64()?,
                ev: Event::decode(r)?,
            });
        }
        let seq = r.get_u64()?;
        self.queue = EventQueue::from_snapshot(self.options.queue, entries, seq);
        let n = r.get_count(1)?;
        let mut pending = Vec::with_capacity(n);
        for _ in 0..n {
            pending.push(if r.get_bool()? { Some(ModelTask::decode(r)?) } else { None });
        }
        self.pending_submissions = pending;
        let n = r.get_count(8)?;
        self.ready = (0..n).map(|_| r.get_usize()).collect::<Result<_>>()?;
        let n = r.get_count(1)?;
        self.arrived = (0..n).map(|_| r.get_bool()).collect::<Result<_>>()?;
        let n = r.get_count(1)?;
        self.job_cancelled = (0..n).map(|_| r.get_bool()).collect::<Result<_>>()?;
        let n = r.get_count(8)?;
        self.cancel_requested = (0..n).map(|_| r.get_f64()).collect::<Result<_>>()?;
        let n = r.get_count(8)?;
        self.cancel_pending = (0..n).map(|_| r.get_usize()).collect::<Result<_>>()?;
        let n = r.get_count(8)?;
        self.finish_times = (0..n).map(|_| r.get_f64()).collect::<Result<_>>()?;
        let n = r.get_count(8)?;
        self.parked = (0..n).map(|_| r.get_usize()).collect::<Result<_>>()?;
        self.free_devices = r.get_usize()?;
        self.trace = Trace::decode(r)?;
        self.units_executed = r.get_u64()?;
        self.agg_compute = r.get_f64()?;
        self.agg_transfer = r.get_f64()?;
        self.agg_stall = r.get_f64()?;
        self.agg_nvme = r.get_f64()?;
        self.agg_wait = r.get_f64()?;
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = r.get_u64()?;
        }
        self.rng = Rng::from_state(s);
        self.tenant_meta = r.get_bool()?;
        let n = r.get_count(8)?;
        self.tenant_gpu_secs = (0..n).map(|_| r.get_f64()).collect::<Result<_>>()?;
        let n = r.get_count(1)?;
        let mut sheds = Vec::with_capacity(n);
        for _ in 0..n {
            sheds.push(Admission::decode(r)?);
        }
        self.sheds = sheds;
        let n = r.get_count(8)?;
        self.shed_models = (0..n).map(|_| r.get_usize()).collect::<Result<_>>()?;
        // derived per-tenant slabs: rebuilt from the restored tasks so they
        // can never disagree with them
        self.tenant_units.clear();
        self.tenant_outstanding.clear();
        if self.tenant_meta {
            for t in &self.tasks {
                *tenant_slot(&mut self.tenant_units, t.tenant()) += t.completed_units();
                if t.state() != TaskState::Done {
                    *tenant_slot(&mut self.tenant_outstanding, t.tenant()) += 1;
                }
            }
        }
        // a restored engine never primes: its job events already live in the
        // queue / pending-submission list captured above
        self.job_events.clear();
        // scratch buffers are transient per-decision storage; start empty
        self.scratch_eligible.clear();
        self.scratch_resident.clear();
        // cross-field sanity so a corrupt-but-checksummed payload cannot
        // install an inconsistent engine
        let nt = self.tasks.len();
        if self.arrived.len() != nt
            || self.job_cancelled.len() != nt
            || self.cancel_requested.len() != nt
            || self.finish_times.len() != nt
        {
            return Err(HydraError::WalCorrupt(
                "snapshot per-task vectors disagree with the task count".into(),
            ));
        }
        let free = self.devices.iter().filter(|d| d.alive && !d.busy).count();
        if free != self.free_devices {
            return Err(HydraError::WalCorrupt(format!(
                "snapshot free-device counter {} disagrees with device states ({free})",
                self.free_devices
            )));
        }
        Ok(())
    }

    /// Fill and hand out the engine-owned snapshot buffer of eligible
    /// models under the current parallel mode. Built from the
    /// incrementally-maintained ready-set, so the cost is O(|eligible|),
    /// not O(|all tasks|) — and the buffer is reused across decisions, so
    /// the hot path allocates nothing. Return it with
    /// [`SharpEngine::put_eligible`] when done.
    pub(crate) fn take_eligible(&mut self) -> Vec<ModelSnapshot> {
        let mut buf = std::mem::take(&mut self.scratch_eligible);
        buf.clear();
        match self.options.mode {
            ParallelMode::Sharp => {
                for id in self.ready.iter() {
                    if let Some(s) = ModelSnapshot::of(&self.tasks[id]) {
                        buf.push(s);
                    }
                }
            }
            ParallelMode::Sequential => {
                // strictly one model in flight across the whole pool: while
                // any model runs, nothing else is eligible (otherwise a
                // lower-id job arriving mid-unit would put two devices to
                // work and corrupt the no-SHARP ablation)
                if !self.tasks.iter().any(|t| t.state() == TaskState::Running) {
                    // then: the lowest-id unfinished *arrived* model
                    for t in &self.tasks {
                        if t.state() != TaskState::Done && self.arrived[t.id] {
                            buf.extend(ModelSnapshot::of(t));
                            break;
                        }
                    }
                }
            }
        }
        buf
    }

    /// Return the snapshot buffer taken by [`SharpEngine::take_eligible`].
    pub(crate) fn put_eligible(&mut self, buf: Vec<ModelSnapshot>) {
        self.scratch_eligible = buf;
    }

    /// Fill and hand out the engine-owned residency buffer for `device`'s
    /// `PickContext`. Return it with [`SharpEngine::put_resident`].
    pub(crate) fn take_resident(&mut self, device: usize) -> Vec<(usize, u32)> {
        let mut buf = std::mem::take(&mut self.scratch_resident);
        buf.clear();
        buf.extend(self.devices[device].resident);
        buf
    }

    /// Return the residency buffer taken by [`SharpEngine::take_resident`].
    pub(crate) fn put_resident(&mut self, buf: Vec<(usize, u32)>) {
        self.scratch_resident = buf;
    }

    /// Wake one parked device (a model just became eligible). Waking
    /// exactly one is sufficient — at most one model becomes eligible per
    /// event — and with the slab-backed parked set the lowest-id pick is
    /// a front read instead of the seed engine's O(devices) broadcast.
    pub(crate) fn wake_one(&mut self, now: f64) {
        if let Some(d) = self.parked.first() {
            self.parked.remove(d);
            self.queue.push(now, Event::DeviceFree { device: d });
        }
    }

    /// Run to completion; returns the report. Per-interval trace recording
    /// honours [`EngineOptions::record_intervals`] by installing a
    /// [`TraceRecorder`] observer — see [`SharpEngine::run_with`] for the
    /// underlying observer-threaded loop.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_observed(None)
    }

    /// Run with an optional external observer. This is the one place the
    /// [`EngineOptions::record_intervals`] semantics live: when set, a
    /// [`TraceRecorder`] is installed (teed with `obs` if both are present)
    /// and its intervals become `RunReport::trace.intervals`.
    pub fn run_observed(
        &mut self,
        obs: Option<&mut dyn EngineObserver>,
    ) -> Result<RunReport> {
        if !self.options.record_intervals {
            return match obs {
                Some(o) => self.run_with(o),
                None => self.run_with(&mut NoopObserver),
            };
        }
        let mut rec = TraceRecorder::default();
        let mut report = match obs {
            Some(o) => self.run_with(&mut Tee(o, &mut rec))?,
            None => self.run_with(&mut rec)?,
        };
        report.trace.intervals = rec.intervals;
        Ok(report)
    }

    /// Run to completion, streaming every engine event through `obs`.
    ///
    /// The report's `trace.intervals` stays empty on this path — interval
    /// bookkeeping belongs to the observer (pass a [`TraceRecorder`], or use
    /// [`SharpEngine::run`] which wires one from the options). Makespan,
    /// device windows, utilization and the scalar aggregates are always
    /// maintained engine-side.
    pub fn run_with(&mut self, obs: &mut dyn EngineObserver) -> Result<RunReport> {
        self.prime(obs);
        while self.step(obs)? {}
        self.finalize()
    }

    /// Seed the event queue for a fresh run: initial device wakes, cluster
    /// events, construction-task arrivals, and the online job events. Split
    /// out of [`SharpEngine::run_with`] so the durability runner can
    /// interleave snapshots between [`SharpEngine::step`] calls — a resumed
    /// engine restores a mid-run queue instead of priming.
    pub(crate) fn prime(&mut self, obs: &mut dyn EngineObserver) {
        for d in 0..self.devices.len() {
            self.trace.set_device_window(d, 0.0, f64::INFINITY);
            self.queue.push(0.0, Event::DeviceFree { device: d });
        }
        for (i, ev) in self.cluster_events.clone().into_iter().enumerate() {
            let time = match ev {
                ClusterEvent::Arrive { time, .. } | ClusterEvent::Fail { time, .. } => time,
            };
            self.queue.push(time, Event::Cluster(i));
        }
        // Online jobs: construction-time tasks with future arrivals stay out
        // of the ready-set until their arrival event fires.
        self.ready.clear();
        for m in 0..self.tasks.len() {
            let arrival = self.tasks[m].arrival();
            if arrival > 0.0 {
                self.arrived[m] = false;
                self.queue.push(arrival, Event::JobArrive { model: m });
            } else {
                self.arrived[m] = true;
                obs.on_job_arrived(m, &self.tasks[m].name, 0.0);
                if self.tasks[m].state() == TaskState::Idle {
                    self.ready.insert(m);
                }
            }
        }
        let job_events = std::mem::take(&mut self.job_events);
        for ev in job_events {
            match ev {
                JobEvent::Submit { time, task } => {
                    let idx = self.pending_submissions.len();
                    self.pending_submissions.push(Some(task));
                    self.queue.push(time, Event::JobSubmit(idx));
                }
                JobEvent::Cancel { time, model } => {
                    self.queue.push(time, Event::JobCancel { model });
                }
            }
        }
    }

    /// Dispatch the next same-timestamp batch of queued events; `Ok(false)`
    /// when the queue drained. `prime + while step + finalize` is exactly
    /// the old monolithic run loop, event for event: within a batch,
    /// `pop_at` yields precisely the events `pop` would have, in the same
    /// (time, seq) order — including events the batch itself schedules at
    /// the current timestamp (wakes, device-free reposts) — so schedules
    /// and observer callback order are byte-identical to one-event
    /// stepping. What coalescing buys: a burst of N simultaneous
    /// arrivals/retires costs one queue descent + one (debug-only,
    /// side-effect-free) invariant sweep instead of N.
    pub(crate) fn step(&mut self, obs: &mut dyn EngineObserver) -> Result<bool> {
        let Some(mut q) = self.queue.pop() else {
            return Ok(false);
        };
        let now = q.time;
        loop {
            self.dispatch(q, now, obs)?;
            match self.queue.pop_at(now) {
                Some(next) => q = next,
                None => break,
            }
        }
        #[cfg(debug_assertions)]
        self.assert_engine_invariants();
        Ok(true)
    }

    fn dispatch(
        &mut self,
        q: QueuedEvent,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        match q.ev {
            Event::DeviceFree { device } => self.on_device_free(device, now, obs)?,
            Event::UnitRetire { device, unit } => {
                self.on_unit_retire(device, unit, now, obs)?
            }
            Event::Cluster(i) => self.on_cluster_event(i, now)?,
            Event::JobArrive { model } => self.on_job_arrive(model, now, obs),
            Event::JobSubmit(idx) => self.on_job_submit(idx, now, obs)?,
            Event::JobCancel { model } => self.on_job_cancel(model, now, obs)?,
        }
        Ok(())
    }

    /// Check the end-of-run invariant and build the report.
    pub(crate) fn finalize(&mut self) -> Result<RunReport> {
        // Sanity: every task finished (unless devices all died).
        let alive = self.devices.iter().any(|d| d.alive);
        let done = self.tasks.iter().all(|t| t.state() == TaskState::Done);
        if alive && !done {
            return Err(HydraError::Sched(
                "engine drained events with unfinished tasks".into(),
            ));
        }

        self.trace.close_device_windows();
        let device_secs = self.trace.device_seconds();
        let utilization =
            if device_secs > 0.0 { self.agg_compute / device_secs } else { 0.0 };
        let jobs: Vec<JobStat> = self
            .tasks
            .iter()
            .enumerate()
            .map(|(m, t)| JobStat {
                model: m,
                name: t.name.clone(),
                arrival: t.arrival(),
                finished: self.finish_times[m],
                cancelled: self.job_cancelled[m],
                cancel_requested: (!self.cancel_requested[m].is_nan())
                    .then_some(self.cancel_requested[m]),
                units_executed: t.completed_units(),
                shed: self.shed_models.contains(m),
            })
            .collect();
        let tenants = self.tenant_sections();
        Ok(RunReport {
            makespan: self.trace.makespan,
            utilization,
            compute_secs: self.agg_compute,
            transfer_secs: self.agg_transfer,
            stall_secs: self.agg_stall,
            prefetch_wait_secs: self.agg_wait,
            units_executed: self.units_executed,
            promoted_bytes: self.memory.dram_traffic.promoted_bytes,
            demoted_bytes: self.memory.dram_traffic.demoted_bytes,
            nvme_promoted_bytes: self.memory.nvme_traffic.promoted_bytes,
            nvme_demoted_bytes: self.memory.nvme_traffic.demoted_bytes,
            nvme_secs: self.agg_nvme,
            scheduler: self.scheduler.name(),
            jobs,
            tenants,
            sheds: std::mem::take(&mut self.sheds),
            stolen: Vec::new(),
            trace: std::mem::take(&mut self.trace),
        })
    }

    /// Assemble the per-tenant report rows (ascending tenant id). Empty
    /// unless the run carried tenant metadata, which is what keeps
    /// metadata-free reports Debug-identical to pre-tenancy builds.
    fn tenant_sections(&self) -> Vec<TenantStat> {
        fn row(rows: &mut Vec<TenantStat>, tenant: usize) -> &mut TenantStat {
            for t in rows.len()..=tenant {
                rows.push(TenantStat {
                    tenant: t,
                    jobs: 0,
                    gpu_secs: 0.0,
                    units: 0,
                    shed: 0,
                    slo_jobs: 0,
                    slo_met: 0,
                });
            }
            &mut rows[tenant]
        }
        let mut rows: Vec<TenantStat> = Vec::new();
        if !self.tenant_meta {
            return rows;
        }
        for (m, t) in self.tasks.iter().enumerate() {
            let r = row(&mut rows, t.tenant());
            r.jobs += 1;
            if let Some(deadline) = t.deadline() {
                r.slo_jobs += 1;
                let finish = self.finish_times[m];
                // shed and cancelled jobs never meet their SLO — a shed
                // job "finishes" instantly, which must not count
                if finish.is_finite()
                    && !self.job_cancelled[m]
                    && !self.shed_models.contains(m)
                    && finish - t.arrival() <= deadline
                {
                    r.slo_met += 1;
                }
            }
        }
        for (t, &g) in self.tenant_gpu_secs.iter().enumerate() {
            if g != 0.0 {
                row(&mut rows, t).gpu_secs = g;
            }
        }
        for (t, &u) in self.tenant_units.iter().enumerate() {
            if u != 0 {
                row(&mut rows, t).units = u;
            }
        }
        for s in &self.sheds {
            let Admission::Shed { tenant, .. } = s;
            row(&mut rows, *tenant).shed += 1;
        }
        // dense fill leaves all-zero gap rows for unused tenant ids
        rows.retain(|r| r.jobs > 0 || r.shed > 0);
        rows
    }

    /// Attach concrete sizing numbers to the memory hierarchy's "thrashing"
    /// error: the pinned working set this configuration can demand —
    /// `(devices × (prefetch_depth + 1) + 1) × max_shard`, every device
    /// pinning one resident shard plus `prefetch_depth` staged ones, plus
    /// one slot for the fetch in flight — alongside the DRAM actually
    /// configured. Every other error passes through untouched.
    fn enrich_thrashing(&self, e: HydraError) -> HydraError {
        match e {
            HydraError::Exec(msg) if msg.contains("thrashing") => {
                let devices = self.devices.len();
                let k = self.options.prefetch_depth;
                let max_shard = self
                    .tasks
                    .iter()
                    .flat_map(|t| t.shards.iter().map(|s| s.param_bytes))
                    .max()
                    .unwrap_or(0);
                let need = (devices * (k + 1) + 1) as u64 * max_shard;
                HydraError::Exec(format!(
                    "{msg}; the pinned working set can reach \
                     (devices x (prefetch_depth + 1) + 1) x max_shard = \
                     ({devices} x {} + 1) x {max_shard} = {need} bytes \
                     against {} bytes of configured DRAM",
                    k + 1,
                    self.memory.dram_capacity()
                ))
            }
            other => other,
        }
    }

    fn on_device_free(
        &mut self,
        device: usize,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        if !self.devices[device].alive || self.devices[device].busy {
            return Ok(());
        }
        self.parked.remove(device);
        // 1. the front pre-claimed (prefetched) slot takes priority
        let mut staged: Option<StagedShard> = None;
        let unit = if let Some(slot) = self.devices[device].pipeline.pop_front() {
            staged = slot.staged;
            Some(slot.unit)
        } else {
            let eligible = self.take_eligible();
            let resident = self.take_resident(device);
            let ctx = PickContext {
                now,
                device,
                speed: self.devices[device].spec.speed,
                resident: Some(&resident),
                tenant_gpu_secs: Some(&self.tenant_gpu_secs),
            };
            let picked = self
                .scheduler
                .pick(&eligible, ctx, &mut self.rng)
                .map(|i| eligible[i].id);
            self.put_eligible(eligible);
            self.put_resident(resident);
            match picked {
                Some(id) => {
                    self.ready.remove(id);
                    obs.on_decision(device, id, false, now);
                    Some(self.tasks[id].claim_front())
                }
                None => None, // park until a wake-up
            }
        };
        match unit {
            Some(unit) => self.start_unit(device, unit, staged, now, obs),
            None => {
                self.parked.insert(device);
                Ok(())
            }
        }
    }

    /// Promote memory, account transfers/stalls, execute, schedule retire.
    fn start_unit(
        &mut self,
        device: usize,
        unit: ShardUnit,
        staged: Option<StagedShard>,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        let task_shard = self.tasks[unit.model].shard(unit.shard).clone();
        let link = self.link(device);
        let mut t = now;

        // --- parameter promotion -----------------------------------------
        let promote_bytes = if self.options.full_state_transfers {
            task_shard.param_bytes
        } else {
            task_shard.transfer_bytes(unit.phase)
        };
        let cached = self.devices[device].resident == Some((unit.model, unit.shard));
        debug_assert!(
            staged.is_none() || !cached,
            "a staged slot can never be the already-resident shard"
        );
        if !cached {
            // demote whatever was resident (a bwd unit's gradients/updated
            // weights flow back; fwd demotion is a discard of clean weights)
            if let Some((m, s)) = self.devices[device].resident.take() {
                self.devices[device]
                    .ledger
                    .release(&Residency::ShardParams { model: m, shard: s });
                let wb = self.devices[device].last_demote_bytes;
                self.memory.note_demote(wb);
                if wb > 0 {
                    obs.on_spill(device, 0, wb, MemTier::Dram, t);
                }
                if !self.options.double_buffer && wb > 0 {
                    // synchronous write-back (no overlap without DB)
                    let dt = link.secs(wb);
                    self.record(device, t, t + dt, unit, IntervalKind::Transfer, obs);
                    t += dt;
                }
                // write-back landed: the old resident's DRAM slot unpins
                // and becomes an eviction candidate for the fetch below
                self.memory.release_device_copy(m, s);
            }
            // promote: either consume the staged prefetch or transfer now
            let stall = staged.map(|st| {
                debug_assert_eq!((st.model, st.shard), (unit.model, unit.shard));
                (st.ready_at - t).max(0.0)
            });
            // like demotions above, spill events carry the time the
            // transfer starts
            if promote_bytes > 0 {
                obs.on_spill(device, promote_bytes, 0, MemTier::Dram, t);
            }
            let dt = match stall {
                Some(stall) => {
                    // the staged prefetch already fetched (and pinned) the
                    // shard in DRAM; any NVMe leg was folded into its
                    // transfer time, overlapped with compute like §4.6
                    if stall > 0.0 {
                        self.record(device, t, t + stall, unit, IntervalKind::BufferStall, obs);
                    }
                    stall
                }
                None => {
                    // DRAM miss with nothing prefetched: stage the shard up
                    // from NVMe synchronously, charged on the NVMe link
                    let fetch = match self.memory.fetch_to_dram(unit.model, unit.shard) {
                        Ok(f) => f,
                        Err(e) => return Err(self.enrich_thrashing(e)),
                    };
                    if fetch.fetched_bytes > 0 {
                        obs.on_spill(
                            device,
                            fetch.fetched_bytes,
                            fetch.evicted_bytes,
                            MemTier::Nvme,
                            t,
                        );
                    }
                    if fetch.secs > 0.0 {
                        self.record(
                            device,
                            t,
                            t + fetch.secs,
                            unit,
                            IntervalKind::NvmeTransfer,
                            obs,
                        );
                        t += fetch.secs;
                    }
                    let dt = link.secs(promote_bytes);
                    if dt > 0.0 {
                        self.record(device, t, t + dt, unit, IntervalKind::Transfer, obs);
                    }
                    dt
                }
            };
            t += dt;
            self.memory.note_promote(promote_bytes);
            self.devices[device]
                .ledger
                .alloc(
                    Residency::ShardParams { model: unit.model, shard: unit.shard },
                    task_shard.param_bytes,
                )?;
            self.devices[device].resident = Some((unit.model, unit.shard));
        }
        // what flows back to DRAM when this residency is evicted: bwd units
        // produce gradients/updated weights; fwd residency is clean
        self.devices[device].last_demote_bytes = if self.options.full_state_transfers {
            task_shard.param_bytes
        } else {
            match unit.phase {
                Phase::Bwd => task_shard.bwd_transfer_bytes,
                Phase::Fwd => 0,
            }
        };

        // --- boundary activation ------------------------------------------
        // Needed unless this model's previous unit ran on this device and the
        // checkpoint never left (§4.6 bonus). We approximate with: cached
        // shard => activation also local (fwd+bwd pairs share the device).
        let needs_act = unit.shard > 0 || unit.phase == Phase::Bwd;
        if needs_act && !cached {
            let dt = link.secs(task_shard.activation_bytes);
            if dt > 0.0 {
                self.record(device, t, t + dt, unit, IntervalKind::Transfer, obs);
                t += dt;
            }
        }
        self.devices[device]
            .ledger
            .alloc(Residency::Activation { model: unit.model }, 2 * task_shard.activation_bytes)?;

        // --- execute -------------------------------------------------------
        // Unit costs are calibrated on the reference GPU; faster devices in
        // a heterogeneous pool retire the same unit proportionally sooner.
        let dur = self.backend.execute_unit(&self.tasks[unit.model], &unit)?
            / self.devices[device].spec.speed;
        self.devices[device].busy = true;
        self.free_devices -= 1;
        self.record(device, t, t + dur, unit, IntervalKind::Compute, obs);
        let end = t + dur;

        // --- prefetch of the next up-to-k units ----------------------------
        if self.options.double_buffer {
            self.try_fill_prefetch(device, t, obs);
        }

        self.queue.push(end, Event::UnitRetire { device, unit });
        Ok(())
    }

    fn on_unit_retire(
        &mut self,
        device: usize,
        unit: ShardUnit,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        self.units_executed += 1;
        if self.tenant_meta {
            let tenant = self.tasks[unit.model].tenant();
            *tenant_slot(&mut self.tenant_units, tenant) += 1;
        }
        self.devices[device].busy = false;
        self.free_devices += 1;
        self.devices[device]
            .ledger
            .release(&Residency::Activation { model: unit.model });
        self.tasks[unit.model].retire(&unit);
        self.backend.on_unit_retired(&self.tasks[unit.model], &unit);
        obs.on_unit_retired(device, &unit, now);

        // epoch boundary: last unit of the epoch just retired — give the
        // backend its early-stop vote (§4.7.2)
        let epoch_done = self.tasks[unit.model].geometry.closes_epoch(&unit);
        if epoch_done
            && self.tasks[unit.model].state() == TaskState::Idle
            && self.backend.should_early_stop(&self.tasks[unit.model], unit.epoch)
        {
            self.tasks[unit.model].early_stop();
        }

        // a cancellation issued while this unit was in flight lands now
        if self.cancel_pending.remove(unit.model) {
            self.tasks[unit.model].early_stop();
        }
        match self.tasks[unit.model].state() {
            TaskState::Idle => {
                self.ready.insert(unit.model);
            }
            TaskState::Done => {
                self.finish_job(unit.model, now, obs)?;
            }
            TaskState::Running => {}
        }

        if self.devices[device].fail_pending {
            self.kill_device(device, now);
        } else {
            self.queue.push(now, Event::DeviceFree { device });
        }
        // The retired model is idle again: one parked device may now have
        // eligible work.
        if self.tasks[unit.model].state() == TaskState::Idle {
            self.wake_one(now);
        }
        Ok(())
    }

    /// Account an interval: scalar aggregates + makespan stay engine-side
    /// (they feed the report); per-interval bookkeeping is the observer's.
    fn record(
        &mut self,
        device: usize,
        start: f64,
        end: f64,
        unit: ShardUnit,
        kind: IntervalKind,
        obs: &mut dyn EngineObserver,
    ) {
        if end > self.trace.makespan {
            self.trace.makespan = end;
        }
        match kind {
            IntervalKind::Compute => {
                self.agg_compute += end - start;
                // the WFQ virtual clock: tenants are charged on dispatch
                // (the compute interval is recorded when the unit starts)
                if self.tenant_meta {
                    let tenant = self.tasks[unit.model].tenant();
                    *tenant_slot(&mut self.tenant_gpu_secs, tenant) += end - start;
                }
            }
            IntervalKind::Transfer => self.agg_transfer += end - start,
            IntervalKind::BufferStall => self.agg_stall += end - start,
            IntervalKind::NvmeTransfer => self.agg_nvme += end - start,
        }
        obs.on_interval(&Interval {
            device,
            start,
            end,
            model: unit.model,
            shard: unit.shard,
            phase: unit.phase,
            unit_seq: unit.seq_idx,
            kind,
        });
    }
}
