//! The sharded multi-coordinator engine (ROADMAP item 1): N independent
//! [`SharpEngine`] shards over one cluster, one merged [`RunReport`].
//!
//! [`ShardedEngine`] partitions the cluster into `EngineOptions::shards`
//! shard engines. Each shard owns a private slice of everything the single
//! engine owns globally — its own event queue, device pool (global devices
//! round-robin: shard `i` gets devices `i, i+N, i+2N, ...`), an equal split
//! of the DRAM pool and of the NVMe tier's capacity, and its own prefetch
//! pipelines. Jobs are assigned by the deterministic routing of
//! [`super::routing`] (stable hash of the *global* job id, capacity-aware
//! override for oversized jobs) and admitted through bounded per-shard
//! [`super::routing::ShardMailbox`]es: a full mailbox backpressures with a
//! typed [`super::routing::ShardBusy`] instead of growing, and the engine
//! resolves the pressure by draining the mailbox into the shard's accepted
//! list and retrying — every backpressured submit eventually lands, and
//! admission order (hence the schedule) is independent of the mailbox
//! capacity.
//!
//! Shards run their event loops independently, each in its own virtual
//! clock — sequentially in shard order by default, or on one scoped OS
//! thread per shard with [`EngineOptions::threads`] — and their reports
//! merge into one [`ShardedReport`]: per-shard [`ShardSection`]s plus
//! cluster totals. Threaded execution changes wall-clock only: outcomes
//! land in a fixed shard-indexed slot vector, totals fold in shard order
//! exactly as the sequential loop's merge does, and each thread streams
//! its events into a private [`BufferedEvents`] that is replayed through
//! the caller's observer in shard order after all threads join — so the
//! merged report *and* the observer byte stream are identical to
//! sequential execution. Opt-in admission-time work stealing
//! ([`EngineOptions::stealing`]) rebalances deep admission queues into
//! shallow ones through the capacity-checked
//! [`super::routing::steal_allowed`] handshake before any shard starts;
//! only not-yet-started jobs move, and every migration is recorded in
//! [`RunReport::stolen`].
//!
//! **The proof obligation** (rust/tests/sharded_engine.rs): with N=1 the
//! partition, the routing, the id remapping and the merge are all exact
//! identities, so the merged report is Debug-byte-identical to what
//! [`SharpEngine`] produces on the same workload. With N>1 the merged
//! totals (units, compute-seconds, per-tier traffic) are conserved exactly
//! against the sum of the shard sections: sums are accumulated in shard
//! order, makespan is the max over shards, utilization is recomputed as
//! total compute over total device-seconds, and per-job stats / trace
//! intervals are remapped back to global device and job ids.

use crate::coordinator::memory::{MemTier, MemoryOptions, TierSpec};
use crate::coordinator::metrics::{Interval, Trace};
use crate::coordinator::observer::{BufferedEvents, EngineObserver};
use crate::coordinator::sched::Policy;
use crate::coordinator::task::ModelTask;
use crate::coordinator::unit::ShardUnit;
use crate::error::{HydraError, Result};
use crate::exec::ExecutionBackend;

use super::core::{EngineOptions, RunReport, SharpEngine, TenantStat};
use super::device::{ClusterEvent, DeviceSpec};
use super::jobs::{Admission, JobEvent, JobStat};
use super::routing::{self, ShardId, ShardMailbox, StolenJob};

/// Default bound of each shard's admission mailbox. Small enough that
/// routing skew on large pools actually exercises the backpressure path;
/// admission order — and therefore the schedule — does not depend on it.
pub const DEFAULT_MAILBOX_CAPACITY: usize = 64;

/// One shard's slice of a finished sharded run.
#[derive(Debug, Clone)]
pub struct ShardSection {
    /// Which shard this section describes.
    pub shard: ShardId,
    /// Global device ids owned by the shard, in shard-local id order
    /// (initial round-robin slice, then mid-run arrivals in firing order).
    pub devices: Vec<usize>,
    /// Global job ids routed to the shard, in shard-local id order.
    pub jobs: Vec<usize>,
    /// Global job ids the capacity-aware override moved *to* this shard.
    pub overridden: Vec<usize>,
    /// [`super::routing::ShardBusy`] signals this shard's mailbox raised
    /// during admission (each was resolved by a drain-and-retry).
    pub backpressured: usize,
    /// Jobs the steal planner migrated *to* this shard (global ids, in
    /// planning order). Empty unless [`EngineOptions::stealing`] is on; a
    /// stolen job also appears in this shard's `jobs`.
    pub stolen: Vec<StolenJob>,
    /// The shard engine's own report, in shard-local device/job ids.
    pub report: RunReport,
}

/// Merged result of a sharded run: cluster totals plus per-shard sections.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    /// Cluster-level totals with device/job ids remapped to the global
    /// namespace. With N=1 this is byte-identical (Debug) to the report of
    /// the single [`SharpEngine`] on the same workload.
    pub merged: RunReport,
    /// Per-shard sections, in shard order.
    pub sections: Vec<ShardSection>,
}

impl ShardedReport {
    /// Total mailbox backpressure signals across all shards.
    pub fn backpressure_events(&self) -> usize {
        self.sections.iter().map(|s| s.backpressured).sum()
    }
}

/// Outcome of one shard's event loop from [`ShardedEngine::run_isolated`]:
/// shards fail independently, so a thrashing or OOM shard reports its error
/// here while the other shards' reports stand.
#[derive(Debug)]
pub struct ShardOutcome {
    /// Which shard ran.
    pub shard: ShardId,
    /// Global device ids owned by the shard, in shard-local id order.
    pub devices: Vec<usize>,
    /// Global job ids routed to the shard, in shard-local id order.
    pub jobs: Vec<usize>,
    /// Global job ids the capacity-aware override moved to this shard.
    pub overridden: Vec<usize>,
    /// Mailbox backpressure signals raised during admission.
    pub backpressured: usize,
    /// Jobs the steal planner migrated to this shard (see
    /// [`ShardSection::stolen`]).
    pub stolen: Vec<StolenJob>,
    /// The shard's report, or its failure tagged with the shard id.
    pub outcome: Result<RunReport>,
}

/// N independent shard engines over one cluster; see the module docs.
pub struct ShardedEngine<'a> {
    tasks: Vec<ModelTask>,
    specs: Vec<DeviceSpec>,
    memory: MemoryOptions,
    policy: Policy,
    backend: &'a mut dyn ExecutionBackend,
    options: EngineOptions,
    cluster_events: Vec<ClusterEvent>,
    job_events: Vec<JobEvent>,
    mailbox_capacity: usize,
}

impl<'a> ShardedEngine<'a> {
    /// Build a sharded engine over an explicit device pool.
    /// `options.shards` is the shard count N (>= 1, <= number of devices);
    /// task ids must be dense and in order, exactly as for
    /// [`SharpEngine::with_devices`].
    pub fn with_devices(
        tasks: Vec<ModelTask>,
        specs: &[DeviceSpec],
        memory: impl Into<MemoryOptions>,
        policy: Policy,
        backend: &'a mut dyn ExecutionBackend,
        options: EngineOptions,
    ) -> Result<ShardedEngine<'a>> {
        if options.shards == 0 {
            return Err(HydraError::Config("shards must be >= 1".into()));
        }
        if specs.is_empty() {
            return Err(HydraError::Config("no devices".into()));
        }
        if specs.len() < options.shards {
            return Err(HydraError::Config(format!(
                "{} shards over {} devices (each shard needs at least one device)",
                options.shards,
                specs.len()
            )));
        }
        if options.prefetch_depth == 0 {
            return Err(HydraError::Config(
                "prefetch_depth must be >= 1 (1 = classic double-buffering)".into(),
            ));
        }
        for (m, t) in tasks.iter().enumerate() {
            if t.id != m {
                return Err(HydraError::Config(format!(
                    "task {m} has id {} (ids must be dense and in order)",
                    t.id
                )));
            }
        }
        Ok(ShardedEngine {
            tasks,
            specs: specs.to_vec(),
            memory: memory.into(),
            policy,
            backend,
            options,
            cluster_events: Vec::new(),
            job_events: Vec::new(),
            mailbox_capacity: DEFAULT_MAILBOX_CAPACITY,
        })
    }

    /// Register arrival/failure events before `run`. Failures name global
    /// device ids and are delivered to the owning shard; arriving devices
    /// join the shard that currently owns the fewest.
    pub fn with_cluster_events(mut self, events: Vec<ClusterEvent>) -> Self {
        self.cluster_events = events;
        self
    }

    /// Register online submissions/cancellations before `run`. Submitted
    /// task ids continue the global id sequence in (time-sorted) submission
    /// order and cancellations name global job ids — the same contract
    /// [`crate::session::Session`] produces for the single engine.
    pub fn with_job_events(mut self, events: Vec<JobEvent>) -> Self {
        self.job_events = events;
        self
    }

    /// Override the per-shard mailbox bound (admission order is independent
    /// of it; only the backpressure counters move).
    pub fn with_mailbox_capacity(mut self, capacity: usize) -> Self {
        self.mailbox_capacity = capacity.max(1);
        self
    }

    /// Run every shard and merge; equivalent to `run_observed(None)`.
    pub fn run(self) -> Result<ShardedReport> {
        self.run_observed(None)
    }

    /// Run every shard, streaming each shard's events through `obs` with
    /// device/job ids remapped to the global namespace;
    /// [`EngineObserver::on_shard_begin`] brackets each shard's stream.
    /// Returns the merged report, or the first failing shard's error
    /// (tagged with its shard id) — use [`ShardedEngine::run_isolated`] to
    /// keep the surviving shards' reports on partial failure.
    pub fn run_observed(
        self,
        obs: Option<&mut dyn EngineObserver>,
    ) -> Result<ShardedReport> {
        let outcomes = self.run_isolated(obs)?;
        let mut sections = Vec::with_capacity(outcomes.len());
        for o in outcomes {
            match o.outcome {
                Ok(report) => sections.push(ShardSection {
                    shard: o.shard,
                    devices: o.devices,
                    jobs: o.jobs,
                    overridden: o.overridden,
                    backpressured: o.backpressured,
                    stolen: o.stolen,
                    report,
                }),
                Err(e) => return Err(e),
            }
        }
        let merged = merge_sections(&sections);
        Ok(ShardedReport { merged, sections })
    }

    /// Run every shard to completion independently and report per-shard
    /// outcomes: shards fail in isolation, so one shard hitting e.g. the
    /// memory-hierarchy thrashing error does not stop the others from
    /// finishing. Errors come back tagged with the owning shard id.
    /// Returns `Err` only for global configuration problems (malformed
    /// submit ids, unknown cancel/failure targets).
    pub fn run_isolated(
        mut self,
        mut obs: Option<&mut dyn EngineObserver>,
    ) -> Result<Vec<ShardOutcome>> {
        let n = self.options.shards;

        // --- partition devices (round-robin) and memory (equal split) ----
        let mut shard_specs: Vec<Vec<DeviceSpec>> = vec![Vec::new(); n];
        let mut device_maps: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (d, &spec) in self.specs.iter().enumerate() {
            shard_specs[d % n].push(spec);
            device_maps[d % n].push(d);
        }
        let split = |total: u64, i: usize| -> u64 {
            total / n as u64 + u64::from((i as u64) < total % n as u64)
        };
        let memories: Vec<MemoryOptions> = (0..n)
            .map(|i| MemoryOptions {
                dram_bytes: split(self.memory.dram_bytes, i),
                nvme: self.memory.nvme.map(|t| TierSpec {
                    capacity_bytes: split(t.capacity_bytes, i),
                    link: t.link,
                }),
            })
            .collect();

        // --- split job events into submissions and cancellations ---------
        let n_construction = self.tasks.len();
        let mut submits: Vec<(f64, Option<ModelTask>)> = Vec::new();
        let mut cancels: Vec<(f64, usize)> = Vec::new();
        let mut last_submit_time = f64::NEG_INFINITY;
        for ev in std::mem::take(&mut self.job_events) {
            match ev {
                JobEvent::Submit { time, task } => {
                    let expect = n_construction + submits.len();
                    if task.id != expect {
                        return Err(HydraError::Config(format!(
                            "submitted task has id {} but {expect} jobs precede it \
                             (ids must follow submission order)",
                            task.id
                        )));
                    }
                    if time < last_submit_time {
                        return Err(HydraError::Config(
                            "mid-run submissions must be ordered by time (the \
                             ids-follow-submission-order contract)"
                                .into(),
                        ));
                    }
                    last_submit_time = time;
                    submits.push((time, Some(task)));
                }
                JobEvent::Cancel { time, model } => cancels.push((time, model)),
            }
        }
        let n_jobs = n_construction + submits.len();
        for &(_, model) in &cancels {
            if model >= n_jobs {
                return Err(HydraError::Config(format!(
                    "cancellation targets unknown job {model} ({n_jobs} jobs known)"
                )));
            }
        }

        // --- deterministic routing through the bounded mailboxes ---------
        let caps: Vec<u64> = shard_specs
            .iter()
            .map(|s| s.iter().map(|d| d.mem_bytes).min().unwrap_or(0))
            .collect();
        let largest = |t: &ModelTask| {
            t.shards.iter().map(|s| s.param_bytes).max().unwrap_or(0)
        };
        let footprints: Vec<u64> = self
            .tasks
            .iter()
            .map(&largest)
            .chain(submits.iter().map(|(_, t)| largest(t.as_ref().unwrap())))
            .collect();
        let mut mailboxes: Vec<ShardMailbox<usize>> = (0..n)
            .map(|i| ShardMailbox::new(ShardId(i), self.mailbox_capacity))
            .collect();
        let mut accepted: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut overridden: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut backpressured = vec![0usize; n];
        for (gid, &bytes) in footprints.iter().enumerate() {
            let r = routing::route_capacity_aware(gid, bytes, &caps);
            let s = r.shard.0;
            if r.overridden {
                overridden[s].push(gid);
            }
            let mut item = gid;
            loop {
                match mailboxes[s].try_push(item) {
                    Ok(()) => break,
                    Err((back, _busy)) => {
                        // typed backpressure: resolve by draining this
                        // shard's mailbox into its accepted list, then retry
                        // — the submit lands, and FIFO order is preserved
                        backpressured[s] += 1;
                        accepted[s].extend(mailboxes[s].drain());
                        item = back;
                    }
                }
            }
        }
        for (s, mb) in mailboxes.iter_mut().enumerate() {
            accepted[s].extend(mb.drain());
        }

        // --- opt-in admission-time work stealing --------------------------
        // Runs after the mailboxes drain and before local ids are assigned,
        // so everything downstream (locate map, id remapping, observers)
        // sees the post-steal placement. Each shard's queue is then
        // re-sorted to ascending global id — exactly the order hash routing
        // produces — so the per-shard submit streams keep the
        // ids-follow-submission-order contract the shard engines enforce.
        let mut stolen_by_shard: Vec<Vec<StolenJob>> = vec![Vec::new(); n];
        if self.options.stealing {
            for st in routing::plan_steals(&mut accepted, &footprints, &caps) {
                stolen_by_shard[st.to.0].push(st);
            }
            for queue in &mut accepted {
                queue.sort_unstable();
            }
        }

        // global job id -> (shard, shard-local id)
        let mut locate = vec![(0usize, 0usize); n_jobs];
        for (s, ids) in accepted.iter().enumerate() {
            for (local, &gid) in ids.iter().enumerate() {
                locate[gid] = (s, local);
            }
        }

        // --- build per-shard task lists and job events --------------------
        let mut construction_slots: Vec<Option<ModelTask>> =
            std::mem::take(&mut self.tasks).into_iter().map(Some).collect();
        let mut shard_tasks: Vec<Vec<ModelTask>> = vec![Vec::new(); n];
        let mut shard_jobs: Vec<Vec<JobEvent>> = vec![Vec::new(); n];
        for (s, ids) in accepted.iter().enumerate() {
            for (local, &gid) in ids.iter().enumerate() {
                if gid < n_construction {
                    let mut t = construction_slots[gid].take().unwrap();
                    t.id = local;
                    shard_tasks[s].push(t);
                } else {
                    let (time, slot) = &mut submits[gid - n_construction];
                    let mut t = slot.take().unwrap();
                    t.id = local;
                    shard_jobs[s].push(JobEvent::Submit { time: *time, task: t });
                }
            }
        }
        // cancels after submits, mirroring the session's event order
        for (time, model) in cancels {
            let (s, local) = locate[model];
            shard_jobs[s].push(JobEvent::Cancel { time, model: local });
        }

        // --- route cluster events; arrivals extend the device maps --------
        let mut shard_cluster: Vec<Vec<ClusterEvent>> = vec![Vec::new(); n];
        let n_initial = self.specs.len();
        let mut arrivals = 0usize;
        for ev in std::mem::take(&mut self.cluster_events) {
            match ev {
                ClusterEvent::Arrive { time, mem_bytes } => {
                    // join the emptiest shard (deterministic: lowest id wins
                    // ties); the new device's global id continues the global
                    // sequence in event order, its local id the shard's
                    let s = (0..n).min_by_key(|&s| (device_maps[s].len(), s)).unwrap();
                    device_maps[s].push(n_initial + arrivals);
                    arrivals += 1;
                    shard_cluster[s].push(ClusterEvent::Arrive { time, mem_bytes });
                }
                ClusterEvent::Fail { time, device } => {
                    let owner = device_maps.iter().enumerate().find_map(|(s, ids)| {
                        ids.iter().position(|&g| g == device).map(|local| (s, local))
                    });
                    let Some((s, local)) = owner else {
                        return Err(HydraError::Config(format!(
                            "cluster failure targets unknown device {device}"
                        )));
                    };
                    shard_cluster[s].push(ClusterEvent::Fail { time, device: local });
                }
            }
        }

        // --- run each shard's event loop ----------------------------------
        let results: Vec<Result<RunReport>> = if self.options.threads && n > 1 {
            // fork one backend per shard up front, so a backend that cannot
            // give shards independent streams is a clean config error
            // before any thread spawns
            let mut forks = Vec::with_capacity(n);
            for _ in 0..n {
                match self.backend.fork_for_shard() {
                    Some(b) => forks.push(b),
                    None => {
                        return Err(HydraError::Config(
                            "threads requires an execution backend that can \
                             fork an independent per-shard copy (a noiseless \
                             SimBackend can; noisy and real backends thread \
                             one global state through the shards in shard \
                             order, which parallel shard clocks cannot \
                             replicate)"
                                .into(),
                        ))
                    }
                }
            }
            let buffering = obs.is_some();
            // fixed shard-indexed slots: arrival order of thread results
            // can never reorder the merge
            let mut slots: Vec<Option<(Result<RunReport>, BufferedEvents)>> =
                (0..n).map(|_| None).collect();
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(n);
                for (s, mut backend) in forks.into_iter().enumerate() {
                    let tasks = std::mem::take(&mut shard_tasks[s]);
                    let specs = std::mem::take(&mut shard_specs[s]);
                    let cluster = std::mem::take(&mut shard_cluster[s]);
                    let jobs_ev = std::mem::take(&mut shard_jobs[s]);
                    let memory = memories[s];
                    let policy = self.policy;
                    let options = self.options.clone();
                    handles.push(scope.spawn(move || {
                        let mut buf = BufferedEvents::default();
                        let thread_obs: Option<&mut dyn EngineObserver> =
                            buffering.then_some(&mut buf as &mut dyn EngineObserver);
                        let r = run_shard_engine(
                            tasks,
                            &specs,
                            memory,
                            policy,
                            &mut *backend,
                            options,
                            cluster,
                            jobs_ev,
                            thread_obs,
                        );
                        (r, buf)
                    }));
                }
                // join ALL threads in shard order before reporting: a
                // panicking shard becomes a tagged error in its slot and
                // never takes down the process or a sibling's report
                for (s, h) in handles.into_iter().enumerate() {
                    slots[s] = Some(match h.join() {
                        Ok(pair) => pair,
                        Err(payload) => (
                            Err(HydraError::Exec(format!(
                                "shard thread panicked: {}",
                                panic_message(payload.as_ref())
                            ))),
                            BufferedEvents::default(),
                        ),
                    });
                }
            });
            // observer fan-in: replay each shard's private buffer in shard
            // order through the caller's observer with ids remapped to the
            // global namespace — byte-for-byte the stream the sequential
            // shard loop produces (a panicked shard replays what it
            // buffered before dying, which for a scoped panic is nothing)
            let mut results = Vec::with_capacity(n);
            for (s, slot) in slots.into_iter().enumerate() {
                let (result, buf) = slot.expect("every shard thread joined");
                if let Some(o) = obs.as_deref_mut() {
                    o.on_shard_begin(ShardId(s), n);
                    let mut scope = ShardScope {
                        inner: o,
                        devices: &device_maps[s],
                        models: &accepted[s],
                    };
                    buf.replay(&mut scope);
                }
                results.push(result.map_err(|e| tag_shard(e, ShardId(s), &device_maps[s])));
            }
            results
        } else {
            (0..n)
                .map(|s| {
                    run_one_shard(
                        std::mem::take(&mut shard_tasks[s]),
                        &shard_specs[s],
                        memories[s],
                        self.policy,
                        &mut *self.backend,
                        self.options.clone(),
                        std::mem::take(&mut shard_cluster[s]),
                        std::mem::take(&mut shard_jobs[s]),
                        s,
                        n,
                        &device_maps[s],
                        &accepted[s],
                        &mut obs,
                    )
                })
                .collect()
        };
        let mut outcomes = Vec::with_capacity(n);
        for (s, result) in results.into_iter().enumerate() {
            outcomes.push(ShardOutcome {
                shard: ShardId(s),
                devices: std::mem::take(&mut device_maps[s]),
                jobs: std::mem::take(&mut accepted[s]),
                overridden: std::mem::take(&mut overridden[s]),
                backpressured: backpressured[s],
                stolen: std::mem::take(&mut stolen_by_shard[s]),
                outcome: result,
            });
        }
        Ok(outcomes)
    }
}

/// Render a joined thread's panic payload for the tagged shard error:
/// `panic!` carries a `&str` or `String` in practice; anything else gets a
/// placeholder rather than an unwind out of the sharded engine.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Build and run one shard's [`SharpEngine`] against the given observer
/// (already scoped or buffered by the caller). Errors come back untagged —
/// shard tagging happens where the shard id and device map live. This is
/// the body a sequential shard iteration and a shard thread share.
#[allow(clippy::too_many_arguments)]
fn run_shard_engine(
    tasks: Vec<ModelTask>,
    specs: &[DeviceSpec],
    memory: MemoryOptions,
    policy: Policy,
    backend: &mut dyn ExecutionBackend,
    options: EngineOptions,
    cluster_events: Vec<ClusterEvent>,
    job_events: Vec<JobEvent>,
    obs: Option<&mut dyn EngineObserver>,
) -> Result<RunReport> {
    let mut engine =
        SharpEngine::with_devices(tasks, specs, memory, policy.build(), backend, options)?
            .with_cluster_events(cluster_events)
            .with_job_events(job_events);
    engine.run_observed(obs)
}

/// Build and run one shard's [`SharpEngine`] sequentially, streaming events
/// through the caller's observer live via [`ShardScope`]; errors come back
/// tagged with the shard id (device ids inside OOM errors are remapped to
/// global).
#[allow(clippy::too_many_arguments)]
fn run_one_shard(
    tasks: Vec<ModelTask>,
    specs: &[DeviceSpec],
    memory: MemoryOptions,
    policy: Policy,
    backend: &mut dyn ExecutionBackend,
    options: EngineOptions,
    cluster_events: Vec<ClusterEvent>,
    job_events: Vec<JobEvent>,
    shard: usize,
    n_shards: usize,
    devices: &[usize],
    jobs: &[usize],
    obs: &mut Option<&mut dyn EngineObserver>,
) -> Result<RunReport> {
    let run = |obs: &mut Option<&mut dyn EngineObserver>| -> Result<RunReport> {
        match obs {
            Some(o) => {
                let o: &mut dyn EngineObserver = &mut **o;
                o.on_shard_begin(ShardId(shard), n_shards);
                let mut scope = ShardScope { inner: o, devices, models: jobs };
                run_shard_engine(
                    tasks,
                    specs,
                    memory,
                    policy,
                    backend,
                    options,
                    cluster_events,
                    job_events,
                    Some(&mut scope),
                )
            }
            None => run_shard_engine(
                tasks,
                specs,
                memory,
                policy,
                backend,
                options,
                cluster_events,
                job_events,
                None,
            ),
        }
    };
    run(obs).map_err(|e| tag_shard(e, ShardId(shard), devices))
}

/// Tag a shard-engine error with its shard id; OOM device ids are remapped
/// into the global namespace. Message-carrying variants keep their variant
/// (and so their `Display` prefix) so error-class matching still works.
fn tag_shard(e: HydraError, shard: ShardId, devices: &[usize]) -> HydraError {
    match e {
        HydraError::Config(s) => HydraError::Config(format!("{shard}: {s}")),
        HydraError::Manifest(s) => HydraError::Manifest(format!("{shard}: {s}")),
        HydraError::Sched(s) => HydraError::Sched(format!("{shard}: {s}")),
        HydraError::Exec(s) => HydraError::Exec(format!("{shard}: {s}")),
        HydraError::DeviceOom { device, needed, free } => HydraError::DeviceOom {
            device: devices.get(device).copied().unwrap_or(device),
            needed,
            free,
        },
        other => other,
    }
}

/// Observer adapter: remaps one shard's local device/job ids to the global
/// namespace before forwarding to the caller's observer.
struct ShardScope<'o> {
    inner: &'o mut dyn EngineObserver,
    /// shard-local device id -> global device id
    devices: &'o [usize],
    /// shard-local job id -> global job id
    models: &'o [usize],
}

impl ShardScope<'_> {
    fn dev(&self, d: usize) -> usize {
        self.devices.get(d).copied().unwrap_or(d)
    }

    fn model(&self, m: usize) -> usize {
        self.models.get(m).copied().unwrap_or(m)
    }
}

impl EngineObserver for ShardScope<'_> {
    fn on_job_submitted(&mut self, model: usize, name: &str, now: f64) {
        let m = self.model(model);
        self.inner.on_job_submitted(m, name, now);
    }

    fn on_job_shed(&mut self, model: usize, name: &str, tenant: usize, depth: usize, now: f64) {
        let m = self.model(model);
        self.inner.on_job_shed(m, name, tenant, depth, now);
    }

    fn on_job_cancel_requested(&mut self, model: usize, now: f64) {
        let m = self.model(model);
        self.inner.on_job_cancel_requested(m, now);
    }

    fn on_job_arrived(&mut self, model: usize, name: &str, now: f64) {
        let m = self.model(model);
        self.inner.on_job_arrived(m, name, now);
    }

    fn on_decision(&mut self, device: usize, model: usize, prefetch: bool, now: f64) {
        let (d, m) = (self.dev(device), self.model(model));
        self.inner.on_decision(d, m, prefetch, now);
    }

    fn on_unit_retired(&mut self, device: usize, unit: &ShardUnit, now: f64) {
        let mut unit = *unit;
        unit.model = self.model(unit.model);
        let d = self.dev(device);
        self.inner.on_unit_retired(d, &unit, now);
    }

    fn on_job_finished(&mut self, model: usize, now: f64, cancelled: bool) {
        let m = self.model(model);
        self.inner.on_job_finished(m, now, cancelled);
    }

    fn on_spill(&mut self, device: usize, promoted: u64, demoted: u64, tier: MemTier, now: f64) {
        let d = self.dev(device);
        self.inner.on_spill(d, promoted, demoted, tier, now);
    }

    fn on_interval(&mut self, interval: &Interval) {
        let mut iv = *interval;
        iv.device = self.dev(iv.device);
        iv.model = self.model(iv.model);
        self.inner.on_interval(&iv);
    }
}

/// Merge shard sections into one cluster-level [`RunReport`].
///
/// With one section the merge is the identity (the N=1 byte-equivalence
/// obligation). Otherwise: scalar totals accumulate in shard order,
/// makespan is the max, utilization is total compute over total
/// device-seconds, and trace intervals / device windows / job stats are
/// remapped to global ids (intervals shard-major, jobs in global id order).
fn merge_sections(sections: &[ShardSection]) -> RunReport {
    if sections.len() == 1 {
        return sections[0].report.clone();
    }
    let n_jobs = sections.iter().map(|s| s.jobs.len()).sum();
    let mut trace = Trace::default();
    let mut jobs: Vec<Option<JobStat>> = vec![None; n_jobs];
    // per-tenant sections fold like the scalar aggregates: counts add and
    // GPU-seconds accumulate in shard order, so sharded totals conserve
    // exactly against the sum of the sections
    let mut tenants: Vec<TenantStat> = Vec::new();
    fn tenant_row(rows: &mut Vec<TenantStat>, tenant: usize) -> &mut TenantStat {
        for t in rows.len()..=tenant {
            rows.push(TenantStat {
                tenant: t,
                jobs: 0,
                gpu_secs: 0.0,
                units: 0,
                shed: 0,
                slo_jobs: 0,
                slo_met: 0,
            });
        }
        &mut rows[tenant]
    }
    let mut sheds: Vec<Admission> = Vec::new();
    let mut stolen: Vec<StolenJob> = Vec::new();
    let mut makespan = 0.0f64;
    let (mut compute, mut transfer, mut stall, mut wait, mut nvme_secs) =
        (0.0f64, 0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let (mut units, mut promoted, mut demoted, mut nvme_p, mut nvme_d) =
        (0u64, 0u64, 0u64, 0u64, 0u64);
    for sec in sections {
        let r = &sec.report;
        makespan = makespan.max(r.makespan);
        compute += r.compute_secs;
        transfer += r.transfer_secs;
        stall += r.stall_secs;
        wait += r.prefetch_wait_secs;
        nvme_secs += r.nvme_secs;
        units += r.units_executed;
        promoted += r.promoted_bytes;
        demoted += r.demoted_bytes;
        nvme_p += r.nvme_promoted_bytes;
        nvme_d += r.nvme_demoted_bytes;
        for iv in &r.trace.intervals {
            let mut iv = *iv;
            iv.device = sec.devices.get(iv.device).copied().unwrap_or(iv.device);
            iv.model = sec.jobs.get(iv.model).copied().unwrap_or(iv.model);
            trace.intervals.push(iv);
        }
        for (&d, &w) in &r.trace.device_windows {
            let g = sec.devices.get(d).copied().unwrap_or(d);
            trace.device_windows.insert(g, w);
        }
        for (local, stat) in r.jobs.iter().enumerate() {
            let mut stat = stat.clone();
            stat.model = sec.jobs[local];
            jobs[stat.model] = Some(stat);
        }
        for t in &r.tenants {
            let row = tenant_row(&mut tenants, t.tenant);
            row.jobs += t.jobs;
            row.gpu_secs += t.gpu_secs;
            row.units += t.units;
            row.shed += t.shed;
            row.slo_jobs += t.slo_jobs;
            row.slo_met += t.slo_met;
        }
        // Admission carries no job id, so shard sheds concatenate directly
        sheds.extend(r.sheds.iter().copied());
        // steal records already carry global ids; concatenate in shard
        // order (of the thief) like every other fold
        stolen.extend(sec.stolen.iter().copied());
    }
    tenants.retain(|t| t.jobs > 0 || t.shed > 0);
    trace.makespan = makespan;
    let device_secs = trace.device_seconds();
    let utilization = if device_secs > 0.0 { compute / device_secs } else { 0.0 };
    RunReport {
        trace,
        makespan,
        utilization,
        compute_secs: compute,
        transfer_secs: transfer,
        stall_secs: stall,
        prefetch_wait_secs: wait,
        units_executed: units,
        promoted_bytes: promoted,
        demoted_bytes: demoted,
        nvme_promoted_bytes: nvme_p,
        nvme_demoted_bytes: nvme_d,
        nvme_secs,
        scheduler: sections
            .first()
            .map(|s| s.report.scheduler)
            .unwrap_or("sharded-lrtf"),
        jobs: jobs
            .into_iter()
            .map(|j| j.expect("every job routed to exactly one shard"))
            .collect(),
        tenants,
        sheds,
        stolen,
    }
}
