//! The engine's virtual-time event queue: the event kinds, the total
//! (time, submission-seq) order, and the three queue disciplines
//! ([`QueueKind::Heap`] default, [`QueueKind::LinearScan`] reference,
//! [`QueueKind::Calendar`] for heavy same-timestamp churn).
//!
//! All disciplines pop events in identical (time, seq) order by
//! construction — same key, same tie-break — which is what the
//! queue-equivalence tests in `rust/tests/online_sched.rs` and
//! `rust/tests/queue_differential.rs` pin.

use std::collections::{BinaryHeap, VecDeque};

use crate::coordinator::unit::ShardUnit;
use crate::error::{HydraError, Result};
use crate::util::codec::{ByteReader, ByteWriter};

/// Event-queue discipline for the engine's virtual-time loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueKind {
    /// Binary min-heap keyed by (time, submission order): O(log n) per
    /// event. The default.
    Heap,
    /// Linear scan for the earliest event: O(n) per event. Kept as the
    /// reference discipline for the heap-equivalence tests and the hotpath
    /// bench; schedules are identical to [`QueueKind::Heap`] by
    /// construction (same key, same tie-break).
    LinearScan,
    /// Calendar/bucket queue: events hash by timestamp into an epoch of
    /// power-of-two buckets, and everything at the current frontier time
    /// sits in a FIFO that pops in O(1). Tuned for the open-loop arrival
    /// storms where thousands of events share a timestamp; pop order is
    /// provably identical to [`QueueKind::Heap`] (see the `CalendarQueue`
    /// internals in `events.rs`).
    Calendar,
}

impl QueueKind {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            QueueKind::Heap => 0,
            QueueKind::LinearScan => 1,
            QueueKind::Calendar => 2,
        });
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<QueueKind> {
        Ok(match r.get_u8()? {
            0 => QueueKind::Heap,
            1 => QueueKind::LinearScan,
            2 => QueueKind::Calendar,
            t => {
                return Err(HydraError::WalCorrupt(format!(
                    "unknown queue-kind tag {t}"
                )))
            }
        })
    }
}

/// One engine event (crate-internal; the public surface is the observer).
#[derive(Debug, Clone, Copy)]
pub(crate) enum Event {
    /// A device finished its unit (or is ready at start-up / was woken).
    DeviceFree { device: usize },
    /// The unit on `device` retires at this time; model becomes idle.
    UnitRetire { device: usize, unit: ShardUnit },
    /// Index into the cluster-event list.
    Cluster(usize),
    /// A construction-time task reaches its arrival time.
    JobArrive { model: usize },
    /// Index into the pending-submission list.
    JobSubmit(usize),
    /// Tenant cancellation of `model`.
    JobCancel { model: usize },
}

impl Event {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        match self {
            Event::DeviceFree { device } => {
                w.put_u8(0);
                w.put_usize(*device);
            }
            Event::UnitRetire { device, unit } => {
                w.put_u8(1);
                w.put_usize(*device);
                unit.encode(w);
            }
            Event::Cluster(i) => {
                w.put_u8(2);
                w.put_usize(*i);
            }
            Event::JobArrive { model } => {
                w.put_u8(3);
                w.put_usize(*model);
            }
            Event::JobSubmit(i) => {
                w.put_u8(4);
                w.put_usize(*i);
            }
            Event::JobCancel { model } => {
                w.put_u8(5);
                w.put_usize(*model);
            }
        }
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<Event> {
        Ok(match r.get_u8()? {
            0 => Event::DeviceFree { device: r.get_usize()? },
            1 => Event::UnitRetire {
                device: r.get_usize()?,
                unit: ShardUnit::decode(r)?,
            },
            2 => Event::Cluster(r.get_usize()?),
            3 => Event::JobArrive { model: r.get_usize()? },
            4 => Event::JobSubmit(r.get_usize()?),
            5 => Event::JobCancel { model: r.get_usize()? },
            t => {
                return Err(HydraError::WalCorrupt(format!(
                    "unknown event tag {t}"
                )))
            }
        })
    }
}

/// One queued event. Total order: earliest (time, seq) first; `Ord` is
/// implemented *reversed* so `BinaryHeap` (a max-heap) pops the minimum.
#[derive(Debug, Clone, Copy)]
pub(crate) struct QueuedEvent {
    pub(crate) time: f64,
    pub(crate) seq: u64,
    pub(crate) ev: Event,
}

impl PartialEq for QueuedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time).is_eq() && self.seq == other.seq
    }
}

impl Eq for QueuedEvent {}

impl PartialOrd for QueuedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: the earliest (time, seq) is the heap maximum
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Calendar/bucket queue for [`QueueKind::Calendar`].
///
/// Layout:
/// - `fifo` holds every pending event whose time equals `frontier` (the
///   timestamp of the most recently popped event), in ascending `seq`
///   order. Same-timestamp churn — the dominant pattern under open-loop
///   arrival storms — pops from here in O(1).
/// - `buckets` is the current epoch: a power-of-two array covering
///   `[epoch_start, horizon)` with uniform `width`; an event at time `t`
///   lives in bucket `min(floor((t - epoch_start) / width), nb - 1)`.
///   The mapping is monotone in `t`, so the first non-empty bucket at or
///   after `cursor` contains the global bucket minimum.
/// - `overflow` holds events at or beyond `horizon`. `horizon` is kept
///   strictly above every bucketed timestamp, so overflow events are
///   strictly later than everything in the epoch; when the fifo and
///   buckets drain, the overflow is redistributed into a fresh epoch
///   sized to it.
///
/// Correctness argument (identical pop order to `Heap`/`LinearScan`):
/// the engine never pushes into the past (`time >= frontier` always — a
/// discrete-event simulator schedules at or after `now`), and `seq` is
/// globally monotone. Invariant: after every pop, *no* bucket or overflow
/// event has time equal to `frontier` — when a bucket pop advances the
/// frontier, all same-time ties are drained into the fifo (sorted by
/// `seq`), and later pushes at the frontier time append to the fifo with
/// strictly larger `seq`. Hence a non-empty fifo's front is always the
/// global (time, seq) minimum, and when the fifo is empty the minimum is
/// the (time, seq)-least element of the first non-empty bucket (or, once
/// the epoch drains, of the overflow after redistribution).
#[derive(Debug)]
struct CalendarQueue {
    fifo: VecDeque<QueuedEvent>,
    buckets: Vec<Vec<QueuedEvent>>,
    /// Bucket count of the *current* epoch. `buckets.len()` may be larger:
    /// the outer Vec is a reusable arena that never shrinks, so buckets at
    /// and beyond `epoch_nb` are inert leftovers from a larger past epoch.
    epoch_nb: usize,
    /// Total events across `buckets`.
    in_buckets: usize,
    /// First bucket that may be non-empty (only advances within an epoch).
    cursor: usize,
    epoch_start: f64,
    width: f64,
    /// Exclusive time bound of the epoch, strictly above every bucketed
    /// event's timestamp.
    horizon: f64,
    overflow: Vec<QueuedEvent>,
    /// Timestamp of the most recently popped event.
    frontier: f64,
    /// Whether an epoch is live; false until the first rebuild (and after
    /// restoring from a snapshot, which reloads via `overflow`).
    active: bool,
}

impl CalendarQueue {
    fn new() -> CalendarQueue {
        CalendarQueue {
            fifo: VecDeque::new(),
            buckets: Vec::new(),
            epoch_nb: 0,
            in_buckets: 0,
            cursor: 0,
            epoch_start: 0.0,
            width: 1.0,
            horizon: 0.0,
            overflow: Vec::new(),
            frontier: f64::NEG_INFINITY,
            active: false,
        }
    }

    fn push(&mut self, q: QueuedEvent) {
        debug_assert!(
            q.time.total_cmp(&self.frontier) != std::cmp::Ordering::Less,
            "calendar queue: push at {} behind frontier {}",
            q.time,
            self.frontier
        );
        if q.time.total_cmp(&self.frontier).is_eq() {
            self.fifo.push_back(q);
        } else if self.active && q.time < self.horizon {
            // clamp to the current epoch's bucket count, not the arena
            // length — a time that float-rounds to exactly `epoch_nb` must
            // land in the epoch's last bucket, not an inert trailing one
            let nb = self.epoch_nb;
            let idx =
                (((q.time - self.epoch_start) / self.width) as usize).min(nb - 1);
            self.buckets[idx].push(q);
            self.in_buckets += 1;
        } else {
            self.overflow.push(q);
        }
    }

    /// Redistribute the overflow into a fresh epoch sized to it. Called
    /// only when the fifo and buckets are empty and the overflow is not.
    fn rebuild(&mut self) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for q in &self.overflow {
            lo = lo.min(q.time);
            hi = hi.max(q.time);
        }
        let nb = self.overflow.len().clamp(1, 65_536).next_power_of_two();
        let span = hi - lo;
        let mut width = if span > 0.0 { span / nb as f64 } else { 1.0 };
        let mut horizon = lo + width * nb as f64;
        // Float round-down can land the horizon at or below `hi`; widen
        // until it is strictly above, so every bucketed time is < horizon
        // and overflow events stay strictly later than bucketed ones.
        while horizon <= hi {
            width *= 2.0;
            horizon = lo + width * nb as f64;
        }
        self.epoch_start = lo;
        self.width = width;
        self.horizon = horizon;
        // Bucket arena reuse: epochs rebuild every time the bucketed set
        // drains, and `clear()` + `resize_with` used to drop every inner
        // Vec's capacity each time — steady-state churn re-paid the
        // allocation for each hot bucket on every epoch. Clear the inner
        // Vecs in place and only grow the outer Vec when an epoch needs
        // more buckets than any before it. Never shrink: push and pop both
        // index strictly below this epoch's `nb` (every bucketed time is
        // < horizon, and `pop` only advances `cursor` while `in_buckets`
        // says a non-empty bucket remains), and snapshots flatten the
        // buckets, so trailing empties from a larger past epoch are inert.
        for b in &mut self.buckets {
            b.clear();
        }
        if self.buckets.len() < nb {
            self.buckets.resize_with(nb, Vec::new);
        }
        self.epoch_nb = nb;
        self.cursor = 0;
        self.active = true;
        self.in_buckets = self.overflow.len();
        for q in std::mem::take(&mut self.overflow) {
            let idx = (((q.time - lo) / width) as usize).min(nb - 1);
            self.buckets[idx].push(q);
        }
    }

    fn pop(&mut self) -> Option<QueuedEvent> {
        if let Some(q) = self.fifo.pop_front() {
            return Some(q);
        }
        if self.in_buckets == 0 {
            if self.overflow.is_empty() {
                return None;
            }
            self.rebuild();
        }
        while self.buckets[self.cursor].is_empty() {
            self.cursor += 1;
        }
        let b = &mut self.buckets[self.cursor];
        // `Ord` is reversed, so the earliest (time, seq) is the maximum.
        let mut best = 0;
        for i in 1..b.len() {
            if b[i] > b[best] {
                best = i;
            }
        }
        let q = b.swap_remove(best);
        // Advance the frontier and drain same-time ties into the fifo (it
        // is empty here), ascending by seq: every remaining event at this
        // timestamp now pops in O(1), and later same-time pushes append
        // with strictly larger seq.
        let mut i = 0;
        while i < b.len() {
            if b[i].time.total_cmp(&q.time).is_eq() {
                self.fifo.push_back(b.swap_remove(i));
            } else {
                i += 1;
            }
        }
        self.fifo.make_contiguous().sort_unstable_by_key(|e| e.seq);
        self.in_buckets -= 1 + self.fifo.len();
        self.frontier = q.time;
        Some(q)
    }

    /// Pop the next event only if its time equals `time`, which must be
    /// the timestamp of the most recently popped event. By the frontier
    /// invariant every remaining event at that time sits in the fifo.
    fn pop_front_at(&mut self, time: f64) -> Option<QueuedEvent> {
        debug_assert!(
            self.fifo.is_empty() || time.total_cmp(&self.frontier).is_eq(),
            "calendar queue: pop_at({time}) off the frontier {}",
            self.frontier
        );
        match self.fifo.front() {
            Some(f) if f.time.total_cmp(&time).is_eq() => self.fifo.pop_front(),
            _ => None,
        }
    }
}

/// The virtual-time event queue: a binary heap (default), a linear-scan
/// list, or a calendar queue, all with identical pop order, switchable
/// via [`QueueKind`].
#[derive(Debug)]
pub(crate) struct EventQueue {
    kind: QueueKind,
    heap: BinaryHeap<QueuedEvent>,
    list: Vec<QueuedEvent>,
    cal: CalendarQueue,
    seq: u64,
}

impl EventQueue {
    pub(crate) fn new(kind: QueueKind) -> EventQueue {
        EventQueue {
            kind,
            heap: BinaryHeap::new(),
            list: Vec::new(),
            cal: CalendarQueue::new(),
            seq: 0,
        }
    }

    pub(crate) fn push(&mut self, time: f64, ev: Event) {
        let q = QueuedEvent { time, seq: self.seq, ev };
        self.seq += 1;
        match self.kind {
            QueueKind::Heap => self.heap.push(q),
            QueueKind::LinearScan => self.list.push(q),
            QueueKind::Calendar => self.cal.push(q),
        }
    }

    /// Snapshot support: every pending event, sorted ascending by
    /// (time, seq) so the serialized form is canonical regardless of the
    /// queue discipline, plus the submission-sequence counter.
    pub(crate) fn snapshot(&self) -> (Vec<QueuedEvent>, u64) {
        let mut entries: Vec<QueuedEvent> = match self.kind {
            QueueKind::Heap => self.heap.iter().copied().collect(),
            QueueKind::LinearScan => self.list.clone(),
            QueueKind::Calendar => self
                .cal
                .fifo
                .iter()
                .chain(self.cal.buckets.iter().flatten())
                .chain(self.cal.overflow.iter())
                .copied()
                .collect(),
        };
        // `Ord` is reversed (earliest == maximum), so sort descending by
        // `Ord` to get ascending (time, seq)
        entries.sort_by(|a, b| b.cmp(a));
        (entries, self.seq)
    }

    /// Rebuild a queue mid-run from [`EventQueue::snapshot`] output. The
    /// restored queue pops in the exact order the snapshotted one would
    /// have (same keys, same seq tie-breaks), for any discipline.
    pub(crate) fn from_snapshot(
        kind: QueueKind,
        entries: Vec<QueuedEvent>,
        seq: u64,
    ) -> EventQueue {
        let mut q = EventQueue::new(kind);
        q.seq = seq;
        match kind {
            QueueKind::Heap => q.heap.extend(entries),
            QueueKind::LinearScan => q.list = entries,
            // Load everything through the overflow: the first pop
            // redistributes it into a fresh epoch, and the frontier stays
            // at -inf so no restored event is ever "in the past".
            QueueKind::Calendar => q.cal.overflow = entries,
        }
        q
    }

    pub(crate) fn pop(&mut self) -> Option<QueuedEvent> {
        match self.kind {
            QueueKind::Heap => self.heap.pop(),
            QueueKind::LinearScan => {
                let best = self.scan_best()?;
                Some(self.list.swap_remove(best))
            }
            QueueKind::Calendar => self.cal.pop(),
        }
    }

    /// Pop the next event only if its timestamp equals `time` — the
    /// coalesced-dispatch hook: after popping an event at `time`, the
    /// engine drains the whole same-timestamp batch through this before
    /// running its (debug) invariant sweep. Contract: `time` is the
    /// timestamp of the most recently popped event (the calendar
    /// discipline keeps all pending frontier-time events in its fifo and
    /// answers in O(1)).
    pub(crate) fn pop_at(&mut self, time: f64) -> Option<QueuedEvent> {
        match self.kind {
            QueueKind::Heap => match self.heap.peek() {
                Some(p) if p.time.total_cmp(&time).is_eq() => self.heap.pop(),
                _ => None,
            },
            QueueKind::LinearScan => {
                let best = self.scan_best()?;
                if self.list[best].time.total_cmp(&time).is_eq() {
                    Some(self.list.swap_remove(best))
                } else {
                    None
                }
            }
            QueueKind::Calendar => self.cal.pop_front_at(time),
        }
    }

    /// Index of the earliest (time, seq) event in the linear-scan list.
    fn scan_best(&self) -> Option<usize> {
        if self.list.is_empty() {
            return None;
        }
        // `Ord` is reversed, so the earliest event is the maximum.
        let mut best = 0;
        for i in 1..self.list.len() {
            if self.list[i] > self.list[best] {
                best = i;
            }
        }
        Some(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const KINDS: [QueueKind; 3] =
        [QueueKind::Heap, QueueKind::LinearScan, QueueKind::Calendar];

    #[test]
    fn all_disciplines_pop_in_identical_order() {
        let times = [3.0, 1.0, 2.0, 1.0, 0.5, 2.0];
        let mut qs: Vec<EventQueue> =
            KINDS.iter().map(|&k| EventQueue::new(k)).collect();
        for &t in &times {
            for q in &mut qs {
                q.push(t, Event::DeviceFree { device: 0 });
            }
        }
        let mut last = f64::NEG_INFINITY;
        for _ in 0..times.len() {
            let h = qs[0].pop().unwrap();
            for q in &mut qs[1..] {
                let o = q.pop().unwrap();
                assert_eq!((h.time, h.seq), (o.time, o.seq));
            }
            // non-decreasing time; equal times pop in submission order
            assert!(h.time >= last);
            last = h.time;
        }
        for q in &mut qs {
            assert!(q.pop().is_none());
        }
    }

    #[test]
    fn equal_times_break_ties_by_submission_order() {
        for kind in KINDS {
            let mut q = EventQueue::new(kind);
            q.push(1.0, Event::DeviceFree { device: 7 });
            q.push(1.0, Event::DeviceFree { device: 9 });
            assert_eq!(q.pop().unwrap().seq, 0);
            assert_eq!(q.pop().unwrap().seq, 1);
            assert!(q.pop().is_none());
        }
    }

    #[test]
    fn pop_at_drains_exactly_the_same_timestamp_batch() {
        for kind in KINDS {
            let mut q = EventQueue::new(kind);
            for &t in &[1.0, 1.0, 1.0, 2.0, 2.0] {
                q.push(t, Event::DeviceFree { device: 0 });
            }
            let first = q.pop().unwrap();
            assert_eq!((first.time, first.seq), (1.0, 0));
            // frontier-time pushes interleave with the batch drain
            q.push(1.0, Event::DeviceFree { device: 1 });
            let mut seqs = Vec::new();
            while let Some(e) = q.pop_at(first.time) {
                assert_eq!(e.time, 1.0);
                seqs.push(e.seq);
            }
            assert_eq!(seqs, vec![1, 2, 5]);
            let next = q.pop().unwrap();
            assert_eq!((next.time, next.seq), (2.0, 3));
        }
    }

    #[test]
    fn calendar_rebuilds_epochs_over_wide_time_spans() {
        let mut cal = EventQueue::new(QueueKind::Calendar);
        let mut heap = EventQueue::new(QueueKind::Heap);
        // Interleave pushes and pops so the epoch drains and rebuilds
        // from the overflow several times (pushes land beyond the
        // horizon of the epoch built from the first batch).
        let mut t = 0.0;
        for round in 0..64 {
            for i in 0..4 {
                let at = t + (i as f64) * 1e3 * ((round % 7) + 1) as f64;
                cal.push(at, Event::DeviceFree { device: i });
                heap.push(at, Event::DeviceFree { device: i });
            }
            let a = cal.pop().unwrap();
            let b = heap.pop().unwrap();
            assert_eq!((a.time, a.seq), (b.time, b.seq));
            t = a.time;
        }
        loop {
            match (cal.pop(), heap.pop()) {
                (Some(a), Some(b)) => {
                    assert_eq!((a.time, a.seq), (b.time, b.seq))
                }
                (None, None) => break,
                (a, b) => panic!("queue length mismatch: {a:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn random_streams_pop_identically_from_all_three_disciplines() {
        let mut rng = Rng::new(0x8e11);
        for _case in 0..40 {
            let mut qs: Vec<EventQueue> =
                KINDS.iter().map(|&k| EventQueue::new(k)).collect();
            let mut now = 0.0_f64;
            let mut pending = 0usize;
            let mut pushed = 0usize;
            for _op in 0..400 {
                if rng.uniform() < 0.55 || pending == 0 {
                    // A discrete-event engine only schedules at or after
                    // `now`: ~1/3 exactly at the frontier (fifo path),
                    // occasionally far ahead (forces epoch rebuilds).
                    let t = if rng.uniform() < 0.35 {
                        now
                    } else if rng.uniform() < 0.1 {
                        now + 1.0 + rng.uniform() * 1e4
                    } else {
                        now + rng.uniform() * 3.0
                    };
                    for q in &mut qs {
                        q.push(t, Event::DeviceFree { device: pushed });
                    }
                    pushed += 1;
                    pending += 1;
                } else {
                    let a = qs[0].pop().unwrap();
                    for q in &mut qs[1..] {
                        let o = q.pop().unwrap();
                        assert_eq!((a.time, a.seq), (o.time, o.seq));
                    }
                    now = a.time;
                    pending -= 1;
                    // Half the time, drain the whole same-time batch the
                    // way the coalesced dispatch loop does.
                    if rng.uniform() < 0.5 {
                        loop {
                            let x = qs[0].pop_at(now);
                            for q in &mut qs[1..] {
                                let y = q.pop_at(now);
                                match (&x, &y) {
                                    (Some(a), Some(b)) => assert_eq!(
                                        (a.time, a.seq),
                                        (b.time, b.seq)
                                    ),
                                    (None, None) => {}
                                    _ => panic!("pop_at disagreement"),
                                }
                            }
                            match x {
                                Some(_) => pending -= 1,
                                None => break,
                            }
                        }
                    }
                }
            }
            for _ in 0..pending {
                let a = qs[0].pop().unwrap();
                for q in &mut qs[1..] {
                    let o = q.pop().unwrap();
                    assert_eq!((a.time, a.seq), (o.time, o.seq));
                }
            }
            for q in &mut qs {
                assert!(q.pop().is_none());
            }
        }
    }

    #[test]
    fn snapshot_round_trip_preserves_pop_order_across_disciplines() {
        let times = [3.0, 1.0, 2.0, 1.0, 0.5];
        for (i, &kind) in KINDS.iter().enumerate() {
            let mut q = EventQueue::new(kind);
            for (d, &t) in times.iter().enumerate() {
                q.push(t, Event::DeviceFree { device: d });
            }
            q.pop().unwrap(); // snapshot mid-drain
            let (entries, seq) = q.snapshot();
            assert_eq!(entries.len(), times.len() - 1);
            assert!(entries.windows(2).all(|w| w[1] < w[0])); // reversed Ord
            // restoring into the *next* discipline pops identically
            let other = KINDS[(i + 1) % KINDS.len()];
            let mut r = EventQueue::from_snapshot(other, entries, seq);
            while let Some(a) = q.pop() {
                let b = r.pop().unwrap();
                assert_eq!((a.time, a.seq), (b.time, b.seq));
            }
            assert!(r.pop().is_none());
        }
    }

    #[test]
    fn queue_kind_codec_round_trips_and_rejects_unknown_tags() {
        for kind in KINDS {
            let mut w = ByteWriter::new();
            kind.encode(&mut w);
            let buf = w.into_inner();
            let mut r = ByteReader::new(&buf);
            assert_eq!(QueueKind::decode(&mut r).unwrap(), kind);
            r.expect_end().unwrap();
        }
        let mut r = ByteReader::new(&[9]);
        assert!(QueueKind::decode(&mut r).is_err());
    }

    #[test]
    fn event_codec_round_trips_every_variant() {
        let unit = crate::coordinator::unit::UnitGeometry::new(2, 2, 1).unit_at(3, 2);
        let events = [
            Event::DeviceFree { device: 4 },
            Event::UnitRetire { device: 1, unit },
            Event::Cluster(9),
            Event::JobArrive { model: 5 },
            Event::JobSubmit(2),
            Event::JobCancel { model: 7 },
        ];
        let mut w = ByteWriter::new();
        for e in &events {
            e.encode(&mut w);
        }
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        for e in &events {
            let back = Event::decode(&mut r).unwrap();
            assert_eq!(format!("{e:?}"), format!("{back:?}"));
        }
        r.expect_end().unwrap();
    }
}
