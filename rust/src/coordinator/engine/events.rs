//! The engine's virtual-time event queue: the event kinds, the total
//! (time, submission-seq) order, and the two queue disciplines
//! ([`QueueKind::Heap`] default, [`QueueKind::LinearScan`] reference).
//!
//! Both disciplines pop events in identical (time, seq) order by
//! construction — same key, same tie-break — which is what the
//! heap-vs-scan equivalence tests in `rust/tests/online_sched.rs` pin.

use std::collections::BinaryHeap;

use crate::coordinator::unit::ShardUnit;
use crate::error::{HydraError, Result};
use crate::util::codec::{ByteReader, ByteWriter};

/// Event-queue discipline for the engine's virtual-time loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueKind {
    /// Binary min-heap keyed by (time, submission order): O(log n) per
    /// event. The default.
    Heap,
    /// Linear scan for the earliest event: O(n) per event. Kept as the
    /// reference discipline for the heap-equivalence tests and the hotpath
    /// bench; schedules are identical to [`QueueKind::Heap`] by
    /// construction (same key, same tie-break).
    LinearScan,
}

impl QueueKind {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            QueueKind::Heap => 0,
            QueueKind::LinearScan => 1,
        });
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<QueueKind> {
        Ok(match r.get_u8()? {
            0 => QueueKind::Heap,
            1 => QueueKind::LinearScan,
            t => {
                return Err(HydraError::WalCorrupt(format!(
                    "unknown queue-kind tag {t}"
                )))
            }
        })
    }
}

/// One engine event (crate-internal; the public surface is the observer).
#[derive(Debug, Clone, Copy)]
pub(crate) enum Event {
    /// A device finished its unit (or is ready at start-up / was woken).
    DeviceFree { device: usize },
    /// The unit on `device` retires at this time; model becomes idle.
    UnitRetire { device: usize, unit: ShardUnit },
    /// Index into the cluster-event list.
    Cluster(usize),
    /// A construction-time task reaches its arrival time.
    JobArrive { model: usize },
    /// Index into the pending-submission list.
    JobSubmit(usize),
    /// Tenant cancellation of `model`.
    JobCancel { model: usize },
}

impl Event {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        match self {
            Event::DeviceFree { device } => {
                w.put_u8(0);
                w.put_usize(*device);
            }
            Event::UnitRetire { device, unit } => {
                w.put_u8(1);
                w.put_usize(*device);
                unit.encode(w);
            }
            Event::Cluster(i) => {
                w.put_u8(2);
                w.put_usize(*i);
            }
            Event::JobArrive { model } => {
                w.put_u8(3);
                w.put_usize(*model);
            }
            Event::JobSubmit(i) => {
                w.put_u8(4);
                w.put_usize(*i);
            }
            Event::JobCancel { model } => {
                w.put_u8(5);
                w.put_usize(*model);
            }
        }
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<Event> {
        Ok(match r.get_u8()? {
            0 => Event::DeviceFree { device: r.get_usize()? },
            1 => Event::UnitRetire {
                device: r.get_usize()?,
                unit: ShardUnit::decode(r)?,
            },
            2 => Event::Cluster(r.get_usize()?),
            3 => Event::JobArrive { model: r.get_usize()? },
            4 => Event::JobSubmit(r.get_usize()?),
            5 => Event::JobCancel { model: r.get_usize()? },
            t => {
                return Err(HydraError::WalCorrupt(format!(
                    "unknown event tag {t}"
                )))
            }
        })
    }
}

/// One queued event. Total order: earliest (time, seq) first; `Ord` is
/// implemented *reversed* so `BinaryHeap` (a max-heap) pops the minimum.
#[derive(Debug, Clone, Copy)]
pub(crate) struct QueuedEvent {
    pub(crate) time: f64,
    pub(crate) seq: u64,
    pub(crate) ev: Event,
}

impl PartialEq for QueuedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time).is_eq() && self.seq == other.seq
    }
}

impl Eq for QueuedEvent {}

impl PartialOrd for QueuedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: the earliest (time, seq) is the heap maximum
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The virtual-time event queue: a binary heap (default) or a linear-scan
/// list with identical pop order, switchable via [`QueueKind`].
#[derive(Debug)]
pub(crate) struct EventQueue {
    kind: QueueKind,
    heap: BinaryHeap<QueuedEvent>,
    list: Vec<QueuedEvent>,
    seq: u64,
}

impl EventQueue {
    pub(crate) fn new(kind: QueueKind) -> EventQueue {
        EventQueue { kind, heap: BinaryHeap::new(), list: Vec::new(), seq: 0 }
    }

    pub(crate) fn push(&mut self, time: f64, ev: Event) {
        let q = QueuedEvent { time, seq: self.seq, ev };
        self.seq += 1;
        match self.kind {
            QueueKind::Heap => self.heap.push(q),
            QueueKind::LinearScan => self.list.push(q),
        }
    }

    /// Snapshot support: every pending event, sorted ascending by
    /// (time, seq) so the serialized form is canonical regardless of the
    /// queue discipline, plus the submission-sequence counter.
    pub(crate) fn snapshot(&self) -> (Vec<QueuedEvent>, u64) {
        let mut entries: Vec<QueuedEvent> = match self.kind {
            QueueKind::Heap => self.heap.iter().copied().collect(),
            QueueKind::LinearScan => self.list.clone(),
        };
        // `Ord` is reversed (earliest == maximum), so sort descending by
        // `Ord` to get ascending (time, seq)
        entries.sort_by(|a, b| b.cmp(a));
        (entries, self.seq)
    }

    /// Rebuild a queue mid-run from [`EventQueue::snapshot`] output. The
    /// restored queue pops in the exact order the snapshotted one would
    /// have (same keys, same seq tie-breaks), for either discipline.
    pub(crate) fn from_snapshot(
        kind: QueueKind,
        entries: Vec<QueuedEvent>,
        seq: u64,
    ) -> EventQueue {
        let mut q = EventQueue::new(kind);
        q.seq = seq;
        match kind {
            QueueKind::Heap => q.heap.extend(entries),
            QueueKind::LinearScan => q.list = entries,
        }
        q
    }

    pub(crate) fn pop(&mut self) -> Option<QueuedEvent> {
        match self.kind {
            QueueKind::Heap => self.heap.pop(),
            QueueKind::LinearScan => {
                if self.list.is_empty() {
                    return None;
                }
                // `Ord` is reversed, so the earliest event is the maximum.
                let mut best = 0;
                for i in 1..self.list.len() {
                    if self.list[i] > self.list[best] {
                        best = i;
                    }
                }
                Some(self.list.swap_remove(best))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_and_scan_pop_in_identical_order() {
        let times = [3.0, 1.0, 2.0, 1.0, 0.5, 2.0];
        let mut heap = EventQueue::new(QueueKind::Heap);
        let mut scan = EventQueue::new(QueueKind::LinearScan);
        for &t in &times {
            heap.push(t, Event::DeviceFree { device: 0 });
            scan.push(t, Event::DeviceFree { device: 0 });
        }
        let mut last = f64::NEG_INFINITY;
        for _ in 0..times.len() {
            let h = heap.pop().unwrap();
            let s = scan.pop().unwrap();
            assert_eq!((h.time, h.seq), (s.time, s.seq));
            // non-decreasing time; equal times pop in submission order
            assert!(h.time >= last);
            last = h.time;
        }
        assert!(heap.pop().is_none());
        assert!(scan.pop().is_none());
    }

    #[test]
    fn equal_times_break_ties_by_submission_order() {
        let mut q = EventQueue::new(QueueKind::Heap);
        q.push(1.0, Event::DeviceFree { device: 7 });
        q.push(1.0, Event::DeviceFree { device: 9 });
        assert_eq!(q.pop().unwrap().seq, 0);
        assert_eq!(q.pop().unwrap().seq, 1);
    }

    #[test]
    fn snapshot_round_trip_preserves_pop_order_across_disciplines() {
        let times = [3.0, 1.0, 2.0, 1.0, 0.5];
        for kind in [QueueKind::Heap, QueueKind::LinearScan] {
            let mut q = EventQueue::new(kind);
            for (d, &t) in times.iter().enumerate() {
                q.push(t, Event::DeviceFree { device: d });
            }
            q.pop().unwrap(); // snapshot mid-drain
            let (entries, seq) = q.snapshot();
            assert_eq!(entries.len(), times.len() - 1);
            assert!(entries.windows(2).all(|w| w[1] < w[0])); // reversed Ord
            // restoring into the *other* discipline pops identically
            let other = match kind {
                QueueKind::Heap => QueueKind::LinearScan,
                QueueKind::LinearScan => QueueKind::Heap,
            };
            let mut r = EventQueue::from_snapshot(other, entries, seq);
            while let Some(a) = q.pop() {
                let b = r.pop().unwrap();
                assert_eq!((a.time, a.seq), (b.time, b.seq));
            }
            assert!(r.pop().is_none());
        }
    }

    #[test]
    fn event_codec_round_trips_every_variant() {
        let unit = crate::coordinator::unit::UnitGeometry::new(2, 2, 1).unit_at(3, 2);
        let events = [
            Event::DeviceFree { device: 4 },
            Event::UnitRetire { device: 1, unit },
            Event::Cluster(9),
            Event::JobArrive { model: 5 },
            Event::JobSubmit(2),
            Event::JobCancel { model: 7 },
        ];
        let mut w = ByteWriter::new();
        for e in &events {
            e.encode(&mut w);
        }
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        for e in &events {
            let back = Event::decode(&mut r).unwrap();
            assert_eq!(format!("{e:?}"), format!("{back:?}"));
        }
        r.expect_end().unwrap();
    }
}
