//! Automated model partitioning — Algorithm 1 (§4.3).
//!
//! The dynamic greedy approach: iterate layers front-to-back, "pilot run"
//! each growing prefix against a device memory ledger, and cut a shard at
//! the last layer that fit when the probe OOMs. Exactly like the paper, the
//! probe is a *real* allocation attempt (`DeviceLedger::alloc` returns
//! `DeviceOom`), not an a-priori formula; the partitioner also records the
//! per-layer runtime statistics the Scheduler later consumes.
//!
//! With heterogeneous devices, the smallest device bounds the probe so every
//! shard is placeable anywhere (§4.3 "smallest-memory GPU").

use crate::coordinator::memory::{DeviceLedger, Residency};
use crate::coordinator::task::ShardDesc;
use crate::error::{HydraError, Result};

/// One partitionable layer (a "cut point" in the neural graph).
#[derive(Debug, Clone, Copy)]
pub struct LayerDesc {
    /// Resident training-state bytes (weights + grads + optimizer state).
    pub param_bytes: u64,
    /// Transferable weight bytes (what spilling moves; optimizer state
    /// stays in DRAM).
    pub weight_bytes: u64,
    /// Peak intra-layer working memory during a unit (activations produced
    /// inside the layer; dominates footprint per §4.6).
    pub workspace_bytes: u64,
    /// Bytes of the activation this layer hands to the next (the boundary
    /// checkpoint if a cut lands here).
    pub activation_bytes: u64,
    /// Measured/estimated unit costs (seconds).
    pub fwd_cost: f64,
    pub bwd_cost: f64,
}

/// Partitioning policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct PartitionPolicy {
    /// Fraction of device memory protected as the double-buffer zone
    /// (paper default 5%).
    pub buffer_frac: f64,
    /// Max layers per shard (usize::MAX = unbounded; useful in tests and
    /// for forcing fine-grained schedules in ablations).
    pub max_layers_per_shard: usize,
}

impl Default for PartitionPolicy {
    fn default() -> Self {
        PartitionPolicy { buffer_frac: 0.05, max_layers_per_shard: usize::MAX }
    }
}

/// Probe result: the shard boundaries (exclusive end indices) + shard descs.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Exclusive end index of each shard's layer range.
    pub cuts: Vec<usize>,
    /// Per-shard static descriptions for the engine/scheduler.
    pub shards: Vec<ShardDesc>,
}

/// Partition `layers` for the smallest device capacity.
///
/// Mirrors Algorithm 1: greedily grow the current shard one layer at a time,
/// probing a scratch ledger that reproduces the runtime residency layout
/// (buffer zone + shard params + boundary activation + workspace). On OOM,
/// cut before the failing layer and start a new shard.
pub fn partition(
    layers: &[LayerDesc],
    min_device_capacity: u64,
    policy: PartitionPolicy,
) -> Result<Partition> {
    if layers.is_empty() {
        return Err(HydraError::Config("no layers to partition".into()));
    }
    let zone = (min_device_capacity as f64 * policy.buffer_frac) as u64;

    let mut cuts = Vec::new();
    let mut shards = Vec::new();
    let mut start = 0usize;

    while start < layers.len() {
        let mut end = start;
        // Grow while the probe succeeds.
        while end < layers.len() && end - start < policy.max_layers_per_shard {
            if probe(&layers[start..=end], min_device_capacity, zone).is_ok() {
                end += 1;
            } else {
                break;
            }
        }
        if end == start {
            // Even a single layer failed the pilot run.
            let need = one_shard_footprint(&layers[start..=start]) + zone;
            return Err(HydraError::DeviceOom {
                device: 0,
                needed: need,
                free: min_device_capacity,
            });
        }
        let group = &layers[start..end];
        let weights: u64 = group.iter().map(|l| l.weight_bytes).sum();
        shards.push(ShardDesc {
            param_bytes: group.iter().map(|l| l.param_bytes).sum(),
            // fwd promotes weights; bwd promotes weights and demotes
            // gradients of equal size (counted at promote+demote sites)
            fwd_transfer_bytes: weights,
            bwd_transfer_bytes: weights,
            activation_bytes: group.last().unwrap().activation_bytes,
            fwd_cost: group.iter().map(|l| l.fwd_cost).sum(),
            bwd_cost: group.iter().map(|l| l.bwd_cost).sum(),
            n_layers: group.len() as u32,
        });
        cuts.push(end);
        start = end;
    }
    Ok(Partition { cuts, shards })
}

/// The Algorithm-1 "toy pass": allocate the would-be residency set of this
/// layer group into a scratch ledger and report success/OOM.
fn probe(group: &[LayerDesc], capacity: u64, zone: u64) -> Result<()> {
    let mut ledger = DeviceLedger::new(0, capacity);
    if zone > 0 {
        ledger.alloc(Residency::BufferZone, zone)?;
    }
    ledger.alloc(
        Residency::ShardParams { model: 0, shard: 0 },
        group.iter().map(|l| l.param_bytes).sum(),
    )?;
    // Input boundary activation + the largest intra-shard workspace; the
    // bwd pass additionally holds the output cotangent (same size class),
    // so probe for the bwd-shaped peak like the paper's backprop toy pass.
    ledger.alloc(
        Residency::Activation { model: 0 },
        2 * group.iter().map(|l| l.activation_bytes).max().unwrap_or(0),
    )?;
    ledger.alloc(
        Residency::Workspace { model: 0 },
        group.iter().map(|l| l.workspace_bytes).max().unwrap_or(0),
    )?;
    Ok(())
}

fn one_shard_footprint(group: &[LayerDesc]) -> u64 {
    group.iter().map(|l| l.param_bytes).sum::<u64>()
        + 2 * group.iter().map(|l| l.activation_bytes).max().unwrap_or(0)
        + group.iter().map(|l| l.workspace_bytes).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_layers(n: usize, param: u64, ws: u64, act: u64) -> Vec<LayerDesc> {
        (0..n)
            .map(|_| LayerDesc {
                param_bytes: param,
                weight_bytes: param / 2,
                workspace_bytes: ws,
                activation_bytes: act,
                fwd_cost: 1.0,
                bwd_cost: 2.0,
            })
            .collect()
    }

    #[test]
    fn everything_fits_in_one_shard_when_memory_is_large() {
        let layers = uniform_layers(6, 100, 50, 10);
        let p = partition(&layers, 10_000, PartitionPolicy::default()).unwrap();
        assert_eq!(p.shards.len(), 1);
        assert_eq!(p.shards[0].n_layers, 6);
        assert_eq!(p.shards[0].param_bytes, 600);
        assert!((p.shards[0].fwd_cost - 6.0).abs() < 1e-12);
    }

    #[test]
    fn tight_memory_produces_many_shards() {
        // capacity 400, zone 5% = 20; per-layer 100 params + 50 ws + 20 act
        // -> 1st layer: 20+100+40+50 = 210 ok; 2 layers: 310 ok; 3: 410 OOM
        let layers = uniform_layers(6, 100, 50, 20);
        let p = partition(&layers, 400, PartitionPolicy::default()).unwrap();
        assert_eq!(p.shards.len(), 3);
        assert!(p.shards.iter().all(|s| s.n_layers == 2));
        assert_eq!(p.cuts, vec![2, 4, 6]);
    }

    #[test]
    fn single_layer_too_big_is_oom_error() {
        let layers = uniform_layers(2, 1_000, 0, 0);
        let e = partition(&layers, 500, PartitionPolicy::default()).unwrap_err();
        assert!(matches!(e, HydraError::DeviceOom { .. }), "{e:?}");
    }

    #[test]
    fn buffer_zone_shrinks_usable_memory() {
        let layers = uniform_layers(4, 100, 0, 0);
        // without zone: 4*100=400 fits in 430 -> 1 shard
        let no_zone = PartitionPolicy { buffer_frac: 0.0, ..Default::default() };
        assert_eq!(partition(&layers, 430, no_zone).unwrap().shards.len(), 1);
        // with 20% zone (86): only 3 layers fit per shard
        let zone = PartitionPolicy { buffer_frac: 0.2, ..Default::default() };
        let p = partition(&layers, 430, zone).unwrap();
        assert_eq!(p.shards.len(), 2);
        assert_eq!(p.shards[0].n_layers, 3);
    }

    #[test]
    fn max_layers_per_shard_is_respected() {
        let layers = uniform_layers(5, 1, 0, 0);
        let pol = PartitionPolicy { max_layers_per_shard: 2, ..Default::default() };
        let p = partition(&layers, 1_000_000, pol).unwrap();
        assert_eq!(
            p.shards.iter().map(|s| s.n_layers).collect::<Vec<_>>(),
            vec![2, 2, 1]
        );
    }

    #[test]
    fn heterogeneous_layer_sizes_cut_correctly() {
        let mut layers = uniform_layers(4, 100, 0, 0);
        layers[1].param_bytes = 500; // big middle layer
        let pol = PartitionPolicy { buffer_frac: 0.0, ..Default::default() };
        let p = partition(&layers, 600, pol).unwrap();
        // [l0+l1 = 600 fits], [l2+l3 = 200]
        assert_eq!(p.cuts, vec![2, 4]);
        assert_eq!(p.shards[0].param_bytes, 600);
    }

    #[test]
    fn costs_accumulate_per_shard() {
        let layers = uniform_layers(4, 1, 0, 0);
        let pol = PartitionPolicy { max_layers_per_shard: 3, ..Default::default() };
        let p = partition(&layers, 1_000, pol).unwrap();
        assert!((p.shards[0].fwd_cost - 3.0).abs() < 1e-12);
        assert!((p.shards[0].bwd_cost - 6.0).abs() < 1e-12);
        assert!((p.shards[1].fwd_cost - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_model_is_config_error() {
        assert!(partition(&[], 100, PartitionPolicy::default()).is_err());
    }
}
