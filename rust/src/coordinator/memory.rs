//! Memory manager: the paper's *model spilling* substrate (§4.2, §4.5).
//!
//! Every device has a byte-accurate ledger with an enforced capacity; model
//! shards are *promoted* from the DRAM pool into a device ledger before
//! their unit runs and *demoted* back afterwards (unless cached for reuse —
//! the §4.6 "serendipitous bonus"). The partitioner probes against this
//! ledger exactly like Algorithm 1 probes a real GPU, and the double-buffer
//! reserves its zone here. Capacities are per-ledger, so heterogeneous
//! pools (unequal device memories) account correctly: each device's buffer
//! zone and free space are derived from its own capacity.

use std::collections::BTreeMap;

use crate::error::{HydraError, Result};

/// What a ledger entry holds (for traces and accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Residency {
    /// Parameters (+ optimizer state) of (model, shard).
    ShardParams { model: usize, shard: u32 },
    /// Boundary activation checkpoint of a model's in-flight mini-batch.
    Activation { model: usize },
    /// Workspace for the running unit (activations produced inside the
    /// shard — the §4.6 "as much as 99%" of footprint; never transferred).
    Workspace { model: usize },
    /// Reserved double-buffer zone.
    BufferZone,
}

/// Byte-accurate per-device memory ledger.
#[derive(Debug, Clone)]
pub struct DeviceLedger {
    pub device: usize,
    capacity: u64,
    used: u64,
    entries: BTreeMap<Residency, u64>,
}

impl DeviceLedger {
    /// A fresh ledger for `device` with `capacity` bytes. Heterogeneous
    /// pools simply build ledgers with different capacities — all
    /// accounting below is per-ledger.
    pub fn new(device: usize, capacity: u64) -> DeviceLedger {
        DeviceLedger { device, capacity, used: 0, entries: BTreeMap::new() }
    }

    /// Total device capacity in bytes.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Bytes currently allocated.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Bytes still available.
    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    /// Whether residency `r` is currently held.
    pub fn contains(&self, r: &Residency) -> bool {
        self.entries.contains_key(r)
    }

    /// Bytes held by residency `r` (0 if absent).
    pub fn bytes_of(&self, r: &Residency) -> u64 {
        self.entries.get(r).copied().unwrap_or(0)
    }

    /// Allocate; errors with DeviceOom if over capacity (a *real* error
    /// path — Algorithm 1's pilot runs rely on it).
    pub fn alloc(&mut self, r: Residency, bytes: u64) -> Result<()> {
        if self.entries.contains_key(&r) {
            return Err(HydraError::Exec(format!(
                "device {}: duplicate residency {r:?}", self.device)));
        }
        if bytes > self.free() {
            return Err(HydraError::DeviceOom {
                device: self.device,
                needed: bytes,
                free: self.free(),
            });
        }
        self.used += bytes;
        self.entries.insert(r, bytes);
        Ok(())
    }

    /// Free; returns the freed byte count.
    pub fn release(&mut self, r: &Residency) -> u64 {
        let bytes = self.entries.remove(r).unwrap_or(0);
        self.used -= bytes;
        bytes
    }

    /// All shard-param residencies currently held (for eviction decisions).
    pub fn resident_shards(&self) -> Vec<(usize, u32, u64)> {
        self.entries
            .iter()
            .filter_map(|(r, b)| match r {
                Residency::ShardParams { model, shard } => Some((*model, *shard, *b)),
                _ => None,
            })
            .collect()
    }
}

/// The DRAM tier: tracks spilled bytes so we can assert the paper's "fits in
/// DRAM" precondition and report spill traffic.
#[derive(Debug, Clone)]
pub struct DramPool {
    capacity: u64,
    used: u64,
    /// Cumulative promote/demote traffic in bytes (for EXPERIMENTS.md).
    pub promoted_bytes: u64,
    pub demoted_bytes: u64,
}

impl DramPool {
    /// A DRAM tier of `capacity` bytes.
    pub fn new(capacity: u64) -> DramPool {
        DramPool { capacity, used: 0, promoted_bytes: 0, demoted_bytes: 0 }
    }

    /// Bytes homed in DRAM.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Bytes still available.
    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    /// Home a model's full parameter set in DRAM (start of training).
    pub fn home(&mut self, bytes: u64) -> Result<()> {
        if bytes > self.free() {
            return Err(HydraError::Exec(format!(
                "DRAM exhausted: need {bytes}, free {}", self.free())));
        }
        self.used += bytes;
        Ok(())
    }

    /// Release a model's parameter set (job eviction / teardown).
    pub fn unhome(&mut self, bytes: u64) {
        self.used = self.used.saturating_sub(bytes);
    }

    /// Account DRAM->device promotion traffic.
    pub fn note_promote(&mut self, bytes: u64) {
        self.promoted_bytes += bytes;
    }

    /// Account device->DRAM demotion traffic.
    pub fn note_demote(&mut self, bytes: u64) {
        self.demoted_bytes += bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_release_track_usage() {
        let mut l = DeviceLedger::new(0, 1000);
        l.alloc(Residency::ShardParams { model: 1, shard: 0 }, 400).unwrap();
        assert_eq!(l.used(), 400);
        assert_eq!(l.free(), 600);
        l.alloc(Residency::Activation { model: 1 }, 100).unwrap();
        assert_eq!(l.free(), 500);
        assert_eq!(l.release(&Residency::ShardParams { model: 1, shard: 0 }), 400);
        assert_eq!(l.used(), 100);
    }

    #[test]
    fn oom_is_an_error_not_a_panic() {
        let mut l = DeviceLedger::new(3, 100);
        let e = l.alloc(Residency::Workspace { model: 0 }, 200).unwrap_err();
        match e {
            HydraError::DeviceOom { device, needed, free } => {
                assert_eq!((device, needed, free), (3, 200, 100));
            }
            other => panic!("wrong error {other:?}"),
        }
        assert_eq!(l.used(), 0); // failed alloc leaves ledger unchanged
    }

    #[test]
    fn duplicate_residency_rejected() {
        let mut l = DeviceLedger::new(0, 1000);
        let r = Residency::ShardParams { model: 0, shard: 1 };
        l.alloc(r, 10).unwrap();
        assert!(l.alloc(r, 10).is_err());
    }

    #[test]
    fn resident_shards_lists_only_params() {
        let mut l = DeviceLedger::new(0, 1000);
        l.alloc(Residency::ShardParams { model: 0, shard: 1 }, 10).unwrap();
        l.alloc(Residency::ShardParams { model: 2, shard: 0 }, 20).unwrap();
        l.alloc(Residency::BufferZone, 50).unwrap();
        let mut rs = l.resident_shards();
        rs.sort();
        assert_eq!(rs, vec![(0, 1, 10), (2, 0, 20)]);
    }

    #[test]
    fn dram_pool_enforces_capacity() {
        let mut d = DramPool::new(100);
        d.home(80).unwrap();
        assert!(d.home(30).is_err());
        d.unhome(80);
        assert!(d.home(30).is_ok());
    }

    #[test]
    fn traffic_counters_accumulate() {
        let mut d = DramPool::new(100);
        d.note_promote(10);
        d.note_promote(5);
        d.note_demote(7);
        assert_eq!(d.promoted_bytes, 15);
        assert_eq!(d.demoted_bytes, 7);
    }

    #[test]
    fn release_missing_is_zero() {
        let mut l = DeviceLedger::new(0, 10);
        assert_eq!(l.release(&Residency::BufferZone), 0);
    }
}
