//! Memory manager: the paper's *model spilling* substrate (§4.2, §4.5),
//! generalized into a tiered HBM -> DRAM -> NVMe hierarchy.
//!
//! Every device has a byte-accurate ledger with an enforced capacity; model
//! shards are *promoted* from the host tiers into a device ledger before
//! their unit runs and *demoted* back afterwards (unless cached for reuse —
//! the §4.6 "serendipitous bonus"). The partitioner probes against this
//! ledger exactly like Algorithm 1 probes a real GPU, and the double-buffer
//! reserves its zone here. Capacities are per-ledger, so heterogeneous
//! pools (unequal device memories) account correctly.
//!
//! Below the ledgers sits the [`MemoryHierarchy`], which replaces the old
//! two-tier `DramPool`: shard parameters are *homed* per shard (DRAM
//! preferred, NVMe overflow), and when an NVMe tier is configured DRAM
//! becomes an evicting cache over it — LRU with pinning for staged /
//! device-resident shards, eviction write-back charged on the NVMe link —
//! instead of a hard "fits in DRAM" precondition. Promote/demote traffic is
//! accounted per tier ([`TierTraffic`]) so reports can separate PCIe spill
//! volume from NVMe stall volume.
//!
//! Storage is slab-based (ISSUE 8): model and shard ids are dense, so the
//! per-shard entries live in a `Vec` slab with a free list and an
//! id-indexed lookup table instead of a `BTreeMap` — every hot-path access
//! (residency probe, pin, LRU touch) is two array indexings. The codec and
//! `Debug` forms iterate in key order, so snapshots and the house
//! Debug-byte-identity proofs are independent of slab fragmentation.

use crate::error::{HydraError, Result};
use crate::util::codec::{ByteReader, ByteWriter};

/// Link cost model for cross-tier transfers (DRAM<->device over PCIe,
/// NVMe<->DRAM over the SSD link). Lives here so the memory hierarchy can
/// own its tier links; the engine re-exports it as
/// `coordinator::sharp::TransferModel` for compatibility.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferModel {
    /// Sustained link bandwidth in bytes per second.
    pub bandwidth_bytes_per_sec: f64,
    /// Fixed per-transfer latency in seconds.
    pub latency_secs: f64,
}

impl TransferModel {
    /// PCIe gen3 x16-class link (the paper's testbed host link).
    pub fn pcie_gen3() -> TransferModel {
        TransferModel { bandwidth_bytes_per_sec: 12.0e9, latency_secs: 20e-6 }
    }

    /// PCIe gen4 x16-class link (A4000/A6000-era hosts).
    pub fn pcie_gen4() -> TransferModel {
        TransferModel { bandwidth_bytes_per_sec: 24.0e9, latency_secs: 20e-6 }
    }

    /// Datacenter NVMe-class link (~3 GB/s sustained, ~100 us latency).
    pub fn nvme() -> TransferModel {
        TransferModel { bandwidth_bytes_per_sec: 3.0e9, latency_secs: 100e-6 }
    }

    /// Instantaneous transfers (pure-scheduling studies, Fig 7).
    pub fn zero_cost() -> TransferModel {
        TransferModel { bandwidth_bytes_per_sec: f64::INFINITY, latency_secs: 0.0 }
    }

    /// Seconds to move `bytes` over this link.
    pub fn secs(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.latency_secs + bytes as f64 / self.bandwidth_bytes_per_sec
        }
    }

    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_f64(self.bandwidth_bytes_per_sec);
        w.put_f64(self.latency_secs);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<TransferModel> {
        Ok(TransferModel {
            bandwidth_bytes_per_sec: r.get_f64()?,
            latency_secs: r.get_f64()?,
        })
    }
}

/// Which hierarchy link a spill event moved over (for per-tier observer
/// accounting: `Dram` is the DRAM<->device PCIe hop, `Nvme` the
/// NVMe<->DRAM hop).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemTier {
    /// DRAM <-> device (PCIe-class) transfers.
    Dram,
    /// NVMe <-> DRAM (SSD-class) transfers.
    Nvme,
}

impl MemTier {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            MemTier::Dram => 0,
            MemTier::Nvme => 1,
        });
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<MemTier> {
        match r.get_u8()? {
            0 => Ok(MemTier::Dram),
            1 => Ok(MemTier::Nvme),
            t => Err(HydraError::WalCorrupt(format!("unknown tier tag {t}"))),
        }
    }
}

/// Capacity + link of one backing tier (the NVMe tier today).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierSpec {
    /// Usable tier capacity in bytes.
    pub capacity_bytes: u64,
    /// Link between this tier and the tier above it (NVMe<->DRAM).
    pub link: TransferModel,
}

impl TierSpec {
    /// An NVMe tier of `capacity_bytes` with the default NVMe-class link.
    pub fn nvme(capacity_bytes: u64) -> TierSpec {
        TierSpec { capacity_bytes, link: TransferModel::nvme() }
    }

    /// An effectively unlimited, zero-cost tier — used by the equivalence
    /// tests to prove the hierarchy degenerates to the two-tier engine.
    pub fn infinite() -> TierSpec {
        TierSpec { capacity_bytes: u64::MAX, link: TransferModel::zero_cost() }
    }

    /// Parse the `--nvme` / config form `"<capacity-gib>[:<gbps>]"`, e.g.
    /// `"4096:3.5"` = 4 TiB at 3.5 GB/s (bandwidth defaults to the
    /// NVMe-class link when omitted).
    pub fn parse(s: &str) -> Result<TierSpec> {
        let bad = |what: &str| {
            HydraError::Config(format!(
                "bad NVMe tier spec {s:?}: {what} (expected <capacity-gib>[:<gbps>], \
                 e.g. \"4096:3.5\")"
            ))
        };
        let (cap, bw) = match s.split_once(':') {
            Some((c, b)) => (c, Some(b)),
            None => (s, None),
        };
        let cap_gib: f64 = cap.parse().map_err(|_| bad("capacity is not a number"))?;
        if !cap_gib.is_finite() || cap_gib <= 0.0 {
            return Err(bad("capacity must be positive"));
        }
        let link = match bw {
            None => TransferModel::nvme(),
            Some(b) => {
                let gbps: f64 = b.parse().map_err(|_| bad("bandwidth is not a number"))?;
                if !gbps.is_finite() || gbps <= 0.0 {
                    return Err(bad("bandwidth must be positive"));
                }
                TransferModel { bandwidth_bytes_per_sec: gbps * 1e9, latency_secs: 100e-6 }
            }
        };
        Ok(TierSpec {
            capacity_bytes: (cap_gib * (1u64 << 30) as f64) as u64,
            link,
        })
    }

    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u64(self.capacity_bytes);
        self.link.encode(w);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<TierSpec> {
        Ok(TierSpec {
            capacity_bytes: r.get_u64()?,
            link: TransferModel::decode(r)?,
        })
    }
}

/// Host-memory configuration of an engine run: the DRAM tier plus an
/// optional NVMe backing tier. `u64` converts into the DRAM-only form, so
/// legacy `dram_bytes` call sites keep working.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryOptions {
    /// Size of the DRAM tier models spill to.
    pub dram_bytes: u64,
    /// Optional NVMe backing tier; `None` keeps the paper's "fits in DRAM"
    /// precondition as a hard error.
    pub nvme: Option<TierSpec>,
}

impl MemoryOptions {
    /// The legacy two-tier configuration: DRAM only, no backing tier.
    pub fn dram_only(dram_bytes: u64) -> MemoryOptions {
        MemoryOptions { dram_bytes, nvme: None }
    }

    /// DRAM over an NVMe backing tier.
    pub fn with_nvme(dram_bytes: u64, nvme: TierSpec) -> MemoryOptions {
        MemoryOptions { dram_bytes, nvme: Some(nvme) }
    }

    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u64(self.dram_bytes);
        w.put_bool(self.nvme.is_some());
        if let Some(t) = &self.nvme {
            t.encode(w);
        }
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<MemoryOptions> {
        let dram_bytes = r.get_u64()?;
        let nvme = if r.get_bool()? { Some(TierSpec::decode(r)?) } else { None };
        Ok(MemoryOptions { dram_bytes, nvme })
    }
}

impl From<u64> for MemoryOptions {
    fn from(dram_bytes: u64) -> MemoryOptions {
        MemoryOptions::dram_only(dram_bytes)
    }
}

/// Cumulative byte traffic over one hierarchy link.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierTraffic {
    /// Bytes moved *up* the hierarchy (toward the device).
    pub promoted_bytes: u64,
    /// Bytes moved *down* the hierarchy (away from the device).
    pub demoted_bytes: u64,
}

impl TierTraffic {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u64(self.promoted_bytes);
        w.put_u64(self.demoted_bytes);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<TierTraffic> {
        Ok(TierTraffic {
            promoted_bytes: r.get_u64()?,
            demoted_bytes: r.get_u64()?,
        })
    }
}

/// Outcome of staging a shard up into DRAM: the synchronous NVMe-link time
/// and the bytes that moved (all zero on a DRAM hit or without an NVMe
/// tier).
#[derive(Debug, Clone, Copy)]
pub struct TierFetch {
    /// Seconds of NVMe-link time (eviction write-back + fetch read).
    pub secs: f64,
    /// Bytes read NVMe->DRAM.
    pub fetched_bytes: u64,
    /// Bytes written DRAM->NVMe by evictions this fetch forced.
    pub evicted_bytes: u64,
}

impl TierFetch {
    /// A DRAM hit: nothing moved.
    pub const NONE: TierFetch = TierFetch { secs: 0.0, fetched_bytes: 0, evicted_bytes: 0 };
}

/// Per-shard residency bookkeeping (only maintained when an NVMe tier is
/// configured; the DRAM-only path keeps the legacy aggregate counter).
#[derive(Debug, Clone, Copy)]
struct ShardEntry {
    /// Parameter bytes of the shard (weights + gradients + optimizer
    /// state — the home-tier footprint).
    bytes: u64,
    /// Whether the shard currently lives in DRAM (else NVMe).
    in_dram: bool,
    /// Pin count: staged prefetches and device-resident copies pin the
    /// DRAM slot (write-backs land there), making it ineligible for
    /// eviction.
    pins: u32,
    /// LRU clock of the last touch.
    last_touch: u64,
}

impl ShardEntry {
    fn encode(&self, w: &mut ByteWriter) {
        w.put_u64(self.bytes);
        w.put_bool(self.in_dram);
        w.put_u32(self.pins);
        w.put_u64(self.last_touch);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<ShardEntry> {
        Ok(ShardEntry {
            bytes: r.get_u64()?,
            in_dram: r.get_bool()?,
            pins: r.get_u32()?,
            last_touch: r.get_u64()?,
        })
    }
}

/// The tiered host-memory manager: a DRAM tier that is either the hard
/// home of every model (no NVMe: the legacy two-tier behaviour, bit for
/// bit) or an evicting cache over an NVMe backing tier.
///
/// Eviction policy: LRU over unpinned DRAM-resident shards, preferring the
/// larger shard on recency ties (evicting fewer, bigger shards minimizes
/// total write-back cost on the byte-proportional NVMe link). Pinned
/// shards — staged in a double-buffer zone or resident on a device — are
/// never evicted: demote write-backs must land in their DRAM slot.
#[derive(Clone)]
pub struct MemoryHierarchy {
    dram_capacity: u64,
    dram_used: u64,
    nvme: Option<TierSpec>,
    nvme_used: u64,
    /// DRAM<->device traffic (the legacy promote/demote counters).
    pub dram_traffic: TierTraffic,
    /// NVMe<->DRAM traffic (zero without an NVMe tier).
    pub nvme_traffic: TierTraffic,
    /// Entry slab: dense storage with a free list; `index` maps
    /// (model, shard) to a slot. Iteration-order-sensitive consumers
    /// (codec, `Debug`, the LRU victim scan's key tie-break) go through
    /// [`MemoryHierarchy::iter_key_order`] or carry explicit keys, so slab
    /// fragmentation never shows up in behaviour or bytes.
    slots: Vec<SlabSlot>,
    free: Vec<u32>,
    /// model -> shard -> slot index ([`NO_SLOT`] when absent).
    index: Vec<Vec<u32>>,
    clock: u64,
}

/// Sentinel for an empty `index` cell.
const NO_SLOT: u32 = u32::MAX;

/// One slab slot: the (model, shard) key plus its entry. `live` is false
/// while the slot sits on the free list.
#[derive(Debug, Clone)]
struct SlabSlot {
    model: usize,
    shard: u32,
    live: bool,
    entry: ShardEntry,
}

impl std::fmt::Debug for MemoryHierarchy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Canonical form: entries print as a key-ordered map, exactly like
        // the `BTreeMap`-backed struct this slab replaced, regardless of
        // slot fragmentation (the mid-run codec round-trip tests compare
        // these strings byte for byte).
        struct Entries<'a>(&'a MemoryHierarchy);
        impl std::fmt::Debug for Entries<'_> {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                f.debug_map().entries(self.0.iter_key_order()).finish()
            }
        }
        f.debug_struct("MemoryHierarchy")
            .field("dram_capacity", &self.dram_capacity)
            .field("dram_used", &self.dram_used)
            .field("nvme", &self.nvme)
            .field("nvme_used", &self.nvme_used)
            .field("dram_traffic", &self.dram_traffic)
            .field("nvme_traffic", &self.nvme_traffic)
            .field("entries", &Entries(self))
            .field("clock", &self.clock)
            .finish()
    }
}

impl MemoryHierarchy {
    /// Build the hierarchy from a [`MemoryOptions`] (or a bare `dram_bytes`
    /// via `From<u64>`).
    pub fn new(options: impl Into<MemoryOptions>) -> MemoryHierarchy {
        let options = options.into();
        MemoryHierarchy {
            dram_capacity: options.dram_bytes,
            dram_used: 0,
            nvme: options.nvme,
            nvme_used: 0,
            dram_traffic: TierTraffic::default(),
            nvme_traffic: TierTraffic::default(),
            slots: Vec::new(),
            free: Vec::new(),
            index: Vec::new(),
            clock: 0,
        }
    }

    /// Slot index of (`model`, `shard`), if homed.
    #[inline]
    fn slot_of(&self, model: usize, shard: u32) -> Option<usize> {
        let s = *self.index.get(model)?.get(shard as usize)?;
        (s != NO_SLOT).then_some(s as usize)
    }

    #[inline]
    fn entry(&self, model: usize, shard: u32) -> Option<&ShardEntry> {
        self.slot_of(model, shard).map(|i| &self.slots[i].entry)
    }

    #[inline]
    fn entry_mut(&mut self, model: usize, shard: u32) -> Option<&mut ShardEntry> {
        self.slot_of(model, shard).map(|i| &mut self.slots[i].entry)
    }

    /// Number of live entries.
    fn live_entries(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Install an entry for (`model`, `shard`), reusing a free slot when
    /// one exists. The cell must be empty.
    fn insert_entry(&mut self, model: usize, shard: u32, entry: ShardEntry) {
        if self.index.len() <= model {
            self.index.resize_with(model + 1, Vec::new);
        }
        let row = &mut self.index[model];
        if row.len() <= shard as usize {
            row.resize(shard as usize + 1, NO_SLOT);
        }
        debug_assert_eq!(row[shard as usize], NO_SLOT, "cell ({model},{shard}) occupied");
        let slot = match self.free.pop() {
            Some(i) => {
                let s = &mut self.slots[i as usize];
                s.model = model;
                s.shard = shard;
                s.live = true;
                s.entry = entry;
                i
            }
            None => {
                self.slots.push(SlabSlot { model, shard, live: true, entry });
                (self.slots.len() - 1) as u32
            }
        };
        row[shard as usize] = slot;
    }

    /// Remove the entry for (`model`, `shard`), returning it and recycling
    /// its slot.
    fn remove_entry(&mut self, model: usize, shard: u32) -> Option<ShardEntry> {
        let cell = self.index.get_mut(model)?.get_mut(shard as usize)?;
        let slot = *cell;
        if slot == NO_SLOT {
            return None;
        }
        *cell = NO_SLOT;
        self.free.push(slot);
        let s = &mut self.slots[slot as usize];
        s.live = false;
        Some(s.entry)
    }

    /// All live entries in ascending (model, shard) key order — the
    /// `BTreeMap` iteration order the codec and `Debug` forms preserve.
    fn iter_key_order(
        &self,
    ) -> impl Iterator<Item = ((usize, u32), &ShardEntry)> + '_ {
        self.index.iter().enumerate().flat_map(move |(m, row)| {
            row.iter().enumerate().filter_map(move |(s, &slot)| {
                (slot != NO_SLOT)
                    .then(|| ((m, s as u32), &self.slots[slot as usize].entry))
            })
        })
    }

    /// DRAM tier capacity.
    pub fn dram_capacity(&self) -> u64 {
        self.dram_capacity
    }

    /// Bytes currently resident in DRAM.
    pub fn dram_used(&self) -> u64 {
        self.dram_used
    }

    /// Bytes of DRAM headroom.
    pub fn dram_free(&self) -> u64 {
        self.dram_capacity - self.dram_used
    }

    /// NVMe tier capacity, if one is configured.
    pub fn nvme_capacity(&self) -> Option<u64> {
        self.nvme.map(|t| t.capacity_bytes)
    }

    /// Bytes currently resident on NVMe.
    pub fn nvme_used(&self) -> u64 {
        self.nvme_used
    }

    /// Whether an NVMe backing tier is configured.
    pub fn nvme_configured(&self) -> bool {
        self.nvme.is_some()
    }

    /// Whether shard (`model`, `shard`) is currently DRAM-resident
    /// (`None` when untracked: unhomed, or no NVMe tier).
    pub fn is_dram_resident(&self, model: usize, shard: u32) -> Option<bool> {
        self.entry(model, shard).map(|e| e.in_dram)
    }

    /// Pin count of shard (`model`, `shard`); 0 when untracked.
    pub fn pins(&self, model: usize, shard: u32) -> u32 {
        self.entry(model, shard).map(|e| e.pins).unwrap_or(0)
    }

    /// Home a model's shards (job submission). DRAM is preferred; with an
    /// NVMe tier, shards that do not fit overflow there. All-or-nothing:
    /// a failure homes none of the shards.
    pub fn home_model(&mut self, model: usize, shard_bytes: &[u64]) -> Result<()> {
        let Some(tier) = self.nvme else {
            let total: u64 = shard_bytes.iter().sum();
            if total > self.dram_free() {
                return Err(HydraError::Exec(format!(
                    "DRAM exhausted: need {total}, free {} (configure an NVMe \
                     tier to home parameters beyond DRAM)",
                    self.dram_free()
                )));
            }
            self.dram_used += total;
            return Ok(());
        };
        // dry-run placement first so a mid-model failure homes nothing
        let mut dram_free = self.dram_free();
        let mut nvme_free = tier.capacity_bytes - self.nvme_used;
        let mut placement = Vec::with_capacity(shard_bytes.len());
        for (i, &bytes) in shard_bytes.iter().enumerate() {
            if self.slot_of(model, i as u32).is_some() {
                return Err(HydraError::Exec(format!(
                    "duplicate home of model {model} shard {i}"
                )));
            }
            if bytes <= dram_free {
                dram_free -= bytes;
                placement.push(true);
            } else if bytes <= nvme_free {
                nvme_free -= bytes;
                placement.push(false);
            } else {
                return Err(HydraError::Exec(format!(
                    "memory hierarchy exhausted homing model {model}: shard {i} \
                     needs {bytes} bytes (DRAM free {dram_free}, NVMe free \
                     {nvme_free})"
                )));
            }
        }
        for (i, (&bytes, &in_dram)) in shard_bytes.iter().zip(&placement).enumerate() {
            self.clock += 1;
            if in_dram {
                self.dram_used += bytes;
            } else {
                self.nvme_used += bytes;
            }
            self.insert_entry(
                model,
                i as u32,
                ShardEntry { bytes, in_dram, pins: 0, last_touch: self.clock },
            );
        }
        Ok(())
    }

    /// Release a model's shards (job finish / cancellation). Releasing a
    /// model that is not homed is a *real* error — the old `DramPool`
    /// saturated silently here, masking double-release bugs.
    pub fn unhome_model(&mut self, model: usize, shard_bytes: &[u64]) -> Result<()> {
        if self.nvme.is_none() {
            let total: u64 = shard_bytes.iter().sum();
            if total > self.dram_used {
                return Err(HydraError::Exec(format!(
                    "double release: unhoming {total} bytes of model {model} with \
                     only {} homed",
                    self.dram_used
                )));
            }
            self.dram_used -= total;
            return Ok(());
        }
        for i in 0..shard_bytes.len() {
            let Some(e) = self.remove_entry(model, i as u32) else {
                return Err(HydraError::Exec(format!(
                    "double release: model {model} shard {i} is not homed"
                )));
            };
            if e.in_dram {
                self.dram_used -= e.bytes;
            } else {
                self.nvme_used -= e.bytes;
            }
        }
        // Drop the model's index row: ids are never reused, so under a
        // million-job storm the lookup table does not accrete dead rows'
        // shard vectors.
        if let Some(row) = self.index.get_mut(model) {
            *row = Vec::new();
        }
        Ok(())
    }

    /// Stage shard (`model`, `shard`) into DRAM and pin it there (a device
    /// is about to prefetch or promote it). On a DRAM hit this is
    /// pin+touch only; on an NVMe miss, LRU-evicts unpinned shards until
    /// the fetch fits and returns the synchronous NVMe-link seconds
    /// (write-backs + read). Without an NVMe tier: a free no-op.
    pub fn fetch_to_dram(&mut self, model: usize, shard: u32) -> Result<TierFetch> {
        let Some(tier) = self.nvme else {
            return Ok(TierFetch::NONE);
        };
        self.clock += 1;
        let clock = self.clock;
        let (bytes, in_dram) = match self.entry(model, shard) {
            Some(e) => (e.bytes, e.in_dram),
            None => {
                return Err(HydraError::Exec(format!(
                    "fetch of unhomed shard (model {model}, shard {shard})"
                )))
            }
        };
        if in_dram {
            let e = self.entry_mut(model, shard).expect("checked above");
            e.pins += 1;
            e.last_touch = clock;
            return Ok(TierFetch::NONE);
        }
        let mut evicted_bytes = 0u64;
        while self.dram_free() < bytes {
            // zero-byte shards free nothing: skipping them guarantees the
            // loop terminates (either DRAM frees up or candidates run out).
            // Scanning the slab visits live slots in arbitrary order; the
            // comparator is a total order over unique keys, so the victim
            // is the same one the key-ordered map scan picked.
            let victim = self
                .slots
                .iter()
                .filter(|s| {
                    s.live && s.entry.in_dram && s.entry.pins == 0 && s.entry.bytes > 0
                })
                .min_by(|a, b| {
                    a.entry
                        .last_touch
                        .cmp(&b.entry.last_touch)
                        .then(b.entry.bytes.cmp(&a.entry.bytes))
                        .then((a.model, a.shard).cmp(&(b.model, b.shard)))
                })
                .map(|s| ((s.model, s.shard), s.entry.bytes));
            let Some((vk, vb)) = victim else {
                return Err(HydraError::Exec(format!(
                    "memory hierarchy thrashing: shard (model {model}, shard \
                     {shard}) needs {bytes} bytes of DRAM but every resident \
                     shard is pinned ({} used of {}); configure more DRAM",
                    self.dram_used, self.dram_capacity
                )));
            };
            if vb > tier.capacity_bytes - self.nvme_used {
                return Err(HydraError::Exec(format!(
                    "NVMe tier full: cannot write back {vb} bytes ({} used of {})",
                    self.nvme_used, tier.capacity_bytes
                )));
            }
            let v = self.entry_mut(vk.0, vk.1).expect("victim exists");
            v.in_dram = false;
            self.dram_used -= vb;
            self.nvme_used += vb;
            evicted_bytes += vb;
        }
        let e = self.entry_mut(model, shard).expect("checked above");
        e.in_dram = true;
        e.pins += 1;
        e.last_touch = clock;
        self.nvme_used -= bytes;
        self.dram_used += bytes;
        self.nvme_traffic.promoted_bytes += bytes;
        self.nvme_traffic.demoted_bytes += evicted_bytes;
        let mut secs = tier.link.secs(bytes);
        if evicted_bytes > 0 {
            secs += tier.link.secs(evicted_bytes);
        }
        Ok(TierFetch { secs, fetched_bytes: bytes, evicted_bytes })
    }

    /// Unpin shard (`model`, `shard`) — its device copy was demoted or its
    /// staging was revoked. A no-op for untracked shards (DRAM-only mode,
    /// or the model already unhomed at job finish).
    pub fn release_device_copy(&mut self, model: usize, shard: u32) {
        if self.nvme.is_none() {
            return;
        }
        self.clock += 1;
        let clock = self.clock;
        if let Some(e) = self.entry_mut(model, shard) {
            debug_assert!(e.pins > 0, "unpin of unpinned shard ({model}, {shard})");
            e.pins = e.pins.saturating_sub(1);
            e.last_touch = clock;
        }
    }

    /// Account DRAM->device promotion traffic.
    pub fn note_promote(&mut self, bytes: u64) {
        self.dram_traffic.promoted_bytes += bytes;
    }

    /// Account device->DRAM demotion traffic.
    pub fn note_demote(&mut self, bytes: u64) {
        self.dram_traffic.demoted_bytes += bytes;
    }

    /// Check the accounting invariants (per-tier used counters match the
    /// entry map and never exceed capacity). Property tests call this
    /// after every operation.
    pub fn validate(&self) -> Result<()> {
        if self.dram_used > self.dram_capacity {
            return Err(HydraError::Exec(format!(
                "DRAM over capacity: {} > {}",
                self.dram_used, self.dram_capacity
            )));
        }
        if let Some(t) = self.nvme {
            if self.nvme_used > t.capacity_bytes {
                return Err(HydraError::Exec(format!(
                    "NVMe over capacity: {} > {}",
                    self.nvme_used, t.capacity_bytes
                )));
            }
            let live = self.slots.iter().filter(|s| s.live);
            let dram_sum: u64 = live
                .clone()
                .filter(|s| s.entry.in_dram)
                .map(|s| s.entry.bytes)
                .sum();
            let nvme_sum: u64 = live
                .clone()
                .filter(|s| !s.entry.in_dram)
                .map(|s| s.entry.bytes)
                .sum();
            if dram_sum != self.dram_used || nvme_sum != self.nvme_used {
                return Err(HydraError::Exec(format!(
                    "tier accounting drift: entries say dram {dram_sum} / nvme \
                     {nvme_sum}, counters say {} / {}",
                    self.dram_used, self.nvme_used
                )));
            }
        }
        let dead = self.slots.iter().filter(|s| !s.live).count();
        if dead != self.free.len() {
            return Err(HydraError::Exec(format!(
                "slab drift: {dead} dead slots but a free list of {}",
                self.free.len()
            )));
        }
        Ok(())
    }

    /// Serialize the full hierarchy state — capacities, per-tier usage and
    /// traffic counters, every shard entry (pins and LRU clocks included) —
    /// for durability snapshots.
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u64(self.dram_capacity);
        w.put_u64(self.dram_used);
        w.put_bool(self.nvme.is_some());
        if let Some(t) = &self.nvme {
            t.encode(w);
        }
        w.put_u64(self.nvme_used);
        self.dram_traffic.encode(w);
        self.nvme_traffic.encode(w);
        // key order: canonical bytes regardless of slab fragmentation, so
        // a snapshot -> restore -> re-encode cycle is byte-stable
        w.put_usize(self.live_entries());
        for ((model, shard), e) in self.iter_key_order() {
            w.put_usize(model);
            w.put_u32(shard);
            e.encode(w);
        }
        w.put_u64(self.clock);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<MemoryHierarchy> {
        let dram_capacity = r.get_u64()?;
        let dram_used = r.get_u64()?;
        let nvme = if r.get_bool()? { Some(TierSpec::decode(r)?) } else { None };
        let nvme_used = r.get_u64()?;
        let dram_traffic = TierTraffic::decode(r)?;
        let nvme_traffic = TierTraffic::decode(r)?;
        let mut h = MemoryHierarchy {
            dram_capacity,
            dram_used,
            nvme,
            nvme_used,
            dram_traffic,
            nvme_traffic,
            slots: Vec::new(),
            free: Vec::new(),
            index: Vec::new(),
            clock: 0,
        };
        // each entry: key (8 + 4) + ShardEntry (8 + 1 + 4 + 8)
        let n = r.get_count(33)?;
        h.slots.reserve(n);
        for _ in 0..n {
            let model = r.get_usize()?;
            let shard = r.get_u32()?;
            // Bound the id-indexed lookup table a checksummed-but-bogus
            // payload can make us allocate, and reject duplicate keys the
            // old map silently overwrote.
            if model > (1usize << 24) || shard > (1u32 << 24) {
                return Err(HydraError::WalCorrupt(format!(
                    "snapshot hierarchy: implausible key ({model}, {shard})"
                )));
            }
            if h.slot_of(model, shard).is_some() {
                return Err(HydraError::WalCorrupt(format!(
                    "snapshot hierarchy: duplicate entry ({model}, {shard})"
                )));
            }
            h.insert_entry(model, shard, ShardEntry::decode(r)?);
        }
        h.clock = r.get_u64()?;
        h.validate()
            .map_err(|e| HydraError::WalCorrupt(format!("snapshot hierarchy: {e}")))?;
        Ok(h)
    }
}

/// What a ledger entry holds (for traces and accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Residency {
    /// Parameters (+ optimizer state) of (model, shard).
    ShardParams { model: usize, shard: u32 },
    /// Boundary activation checkpoint of a model's in-flight mini-batch.
    Activation { model: usize },
    /// Workspace for the running unit (activations produced inside the
    /// shard — the §4.6 "as much as 99%" of footprint; never transferred).
    Workspace { model: usize },
    /// Reserved double-buffer zone.
    BufferZone,
}

impl Residency {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        match self {
            Residency::ShardParams { model, shard } => {
                w.put_u8(0);
                w.put_usize(*model);
                w.put_u32(*shard);
            }
            Residency::Activation { model } => {
                w.put_u8(1);
                w.put_usize(*model);
            }
            Residency::Workspace { model } => {
                w.put_u8(2);
                w.put_usize(*model);
            }
            Residency::BufferZone => w.put_u8(3),
        }
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<Residency> {
        Ok(match r.get_u8()? {
            0 => Residency::ShardParams {
                model: r.get_usize()?,
                shard: r.get_u32()?,
            },
            1 => Residency::Activation { model: r.get_usize()? },
            2 => Residency::Workspace { model: r.get_usize()? },
            3 => Residency::BufferZone,
            t => {
                return Err(HydraError::WalCorrupt(format!(
                    "unknown residency tag {t}"
                )))
            }
        })
    }
}

/// Byte-accurate per-device memory ledger.
///
/// A ledger holds a handful of residencies (the resident shard, the
/// activation pair, workspace, buffer zone), so the entries live in a
/// `Vec` kept sorted by residency key — `BTreeMap` iteration order, hence
/// canonical codec bytes and `Debug` form — where a binary search plus a
/// short memmove beats tree-node traffic on every alloc/release.
#[derive(Debug, Clone)]
pub struct DeviceLedger {
    pub device: usize,
    capacity: u64,
    used: u64,
    entries: Vec<(Residency, u64)>,
}

impl DeviceLedger {
    /// A fresh ledger for `device` with `capacity` bytes. Heterogeneous
    /// pools simply build ledgers with different capacities — all
    /// accounting below is per-ledger.
    pub fn new(device: usize, capacity: u64) -> DeviceLedger {
        DeviceLedger { device, capacity, used: 0, entries: Vec::new() }
    }

    /// Position of residency `r`, `Ok` when held.
    #[inline]
    fn find(&self, r: &Residency) -> std::result::Result<usize, usize> {
        self.entries.binary_search_by(|(k, _)| k.cmp(r))
    }

    /// Total device capacity in bytes.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Bytes currently allocated.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Bytes still available.
    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    /// Whether residency `r` is currently held.
    pub fn contains(&self, r: &Residency) -> bool {
        self.find(r).is_ok()
    }

    /// Bytes held by residency `r` (0 if absent).
    pub fn bytes_of(&self, r: &Residency) -> u64 {
        match self.find(r) {
            Ok(i) => self.entries[i].1,
            Err(_) => 0,
        }
    }

    /// Allocate; errors with DeviceOom if over capacity (a *real* error
    /// path — Algorithm 1's pilot runs rely on it).
    pub fn alloc(&mut self, r: Residency, bytes: u64) -> Result<()> {
        let pos = match self.find(&r) {
            Ok(_) => {
                return Err(HydraError::Exec(format!(
                    "device {}: duplicate residency {r:?}", self.device)));
            }
            Err(pos) => pos,
        };
        if bytes > self.free() {
            return Err(HydraError::DeviceOom {
                device: self.device,
                needed: bytes,
                free: self.free(),
            });
        }
        self.used += bytes;
        self.entries.insert(pos, (r, bytes));
        Ok(())
    }

    /// Free; returns the freed byte count.
    pub fn release(&mut self, r: &Residency) -> u64 {
        match self.find(r) {
            Ok(i) => {
                // ordered removal keeps the sorted (canonical) order
                let (_, bytes) = self.entries.remove(i);
                self.used -= bytes;
                bytes
            }
            Err(_) => 0,
        }
    }

    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_usize(self.device);
        w.put_u64(self.capacity);
        w.put_usize(self.entries.len());
        for (res, bytes) in &self.entries {
            res.encode(w);
            w.put_u64(*bytes);
        }
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<DeviceLedger> {
        let device = r.get_usize()?;
        let capacity = r.get_u64()?;
        // each entry: residency tag (>=1) + bytes (8)
        let n = r.get_count(9)?;
        let mut entries = Vec::with_capacity(n);
        let mut used = 0u64;
        for _ in 0..n {
            let res = Residency::decode(r)?;
            let bytes = r.get_u64()?;
            used = used
                .checked_add(bytes)
                .filter(|&u| u <= capacity)
                .ok_or_else(|| {
                    HydraError::WalCorrupt(format!(
                        "snapshot ledger for device {device} over capacity"
                    ))
                })?;
            if let Some((last, _)) = entries.last() {
                // canonical payloads are strictly key-sorted (the encoder
                // writes them that way); anything else is corruption the
                // old map-based decoder would have papered over
                if *last >= res {
                    return Err(HydraError::WalCorrupt(format!(
                        "snapshot ledger for device {device}: entries out of order"
                    )));
                }
            }
            entries.push((res, bytes));
        }
        Ok(DeviceLedger { device, capacity, used, entries })
    }

    /// All shard-param residencies currently held (for eviction decisions).
    pub fn resident_shards(&self) -> Vec<(usize, u32, u64)> {
        self.entries
            .iter()
            .filter_map(|(r, b)| match r {
                Residency::ShardParams { model, shard } => Some((*model, *shard, *b)),
                _ => None,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_release_track_usage() {
        let mut l = DeviceLedger::new(0, 1000);
        l.alloc(Residency::ShardParams { model: 1, shard: 0 }, 400).unwrap();
        assert_eq!(l.used(), 400);
        assert_eq!(l.free(), 600);
        l.alloc(Residency::Activation { model: 1 }, 100).unwrap();
        assert_eq!(l.free(), 500);
        assert_eq!(l.release(&Residency::ShardParams { model: 1, shard: 0 }), 400);
        assert_eq!(l.used(), 100);
    }

    #[test]
    fn oom_is_an_error_not_a_panic() {
        let mut l = DeviceLedger::new(3, 100);
        let e = l.alloc(Residency::Workspace { model: 0 }, 200).unwrap_err();
        match e {
            HydraError::DeviceOom { device, needed, free } => {
                assert_eq!((device, needed, free), (3, 200, 100));
            }
            other => panic!("wrong error {other:?}"),
        }
        assert_eq!(l.used(), 0); // failed alloc leaves ledger unchanged
    }

    #[test]
    fn duplicate_residency_rejected() {
        let mut l = DeviceLedger::new(0, 1000);
        let r = Residency::ShardParams { model: 0, shard: 1 };
        l.alloc(r, 10).unwrap();
        assert!(l.alloc(r, 10).is_err());
    }

    #[test]
    fn resident_shards_lists_only_params() {
        let mut l = DeviceLedger::new(0, 1000);
        l.alloc(Residency::ShardParams { model: 0, shard: 1 }, 10).unwrap();
        l.alloc(Residency::ShardParams { model: 2, shard: 0 }, 20).unwrap();
        l.alloc(Residency::BufferZone, 50).unwrap();
        let mut rs = l.resident_shards();
        rs.sort();
        assert_eq!(rs, vec![(0, 1, 10), (2, 0, 20)]);
    }

    #[test]
    fn release_missing_is_zero() {
        let mut l = DeviceLedger::new(0, 10);
        assert_eq!(l.release(&Residency::BufferZone), 0);
    }

    // --- MemoryHierarchy ---------------------------------------------------

    #[test]
    fn dram_only_enforces_capacity_like_the_old_pool() {
        let mut h = MemoryHierarchy::new(100u64);
        h.home_model(0, &[80]).unwrap();
        assert!(h.home_model(1, &[30]).is_err());
        h.unhome_model(0, &[80]).unwrap();
        assert!(h.home_model(1, &[30]).is_ok());
        assert_eq!(h.dram_used(), 30);
    }

    #[test]
    fn dram_only_double_release_is_an_error() {
        let mut h = MemoryHierarchy::new(100u64);
        h.home_model(0, &[60]).unwrap();
        h.unhome_model(0, &[60]).unwrap();
        assert!(h.unhome_model(0, &[60]).is_err());
    }

    #[test]
    fn dram_only_fetch_is_free() {
        let mut h = MemoryHierarchy::new(100u64);
        h.home_model(0, &[60]).unwrap();
        let f = h.fetch_to_dram(0, 0).unwrap();
        assert_eq!(f.secs, 0.0);
        assert_eq!(f.fetched_bytes, 0);
        assert_eq!(h.nvme_traffic, TierTraffic::default());
    }

    #[test]
    fn traffic_counters_accumulate() {
        let mut h = MemoryHierarchy::new(100u64);
        h.note_promote(10);
        h.note_promote(5);
        h.note_demote(7);
        assert_eq!(h.dram_traffic.promoted_bytes, 15);
        assert_eq!(h.dram_traffic.demoted_bytes, 7);
    }

    #[test]
    fn homing_overflows_to_nvme() {
        let mut h =
            MemoryHierarchy::new(MemoryOptions::with_nvme(100, TierSpec::nvme(1000)));
        h.home_model(0, &[60, 60]).unwrap(); // second shard overflows
        assert_eq!(h.dram_used(), 60);
        assert_eq!(h.nvme_used(), 60);
        assert_eq!(h.is_dram_resident(0, 0), Some(true));
        assert_eq!(h.is_dram_resident(0, 1), Some(false));
        h.validate().unwrap();
    }

    #[test]
    fn hierarchy_exhaustion_homes_nothing() {
        let mut h =
            MemoryHierarchy::new(MemoryOptions::with_nvme(100, TierSpec::nvme(50)));
        assert!(h.home_model(0, &[90, 60, 60]).is_err()); // third shard fits nowhere
        assert_eq!(h.dram_used(), 0);
        assert_eq!(h.nvme_used(), 0);
        assert!(h.is_dram_resident(0, 0).is_none());
    }

    #[test]
    fn fetch_moves_shard_up_and_charges_the_link() {
        let mut h =
            MemoryHierarchy::new(MemoryOptions::with_nvme(100, TierSpec::nvme(1000)));
        h.home_model(0, &[100]).unwrap(); // DRAM full
        h.home_model(1, &[50]).unwrap(); // -> NVMe
        let f = h.fetch_to_dram(1, 0).unwrap();
        // evicts model 0 (unpinned LRU), then reads model 1's shard
        assert_eq!(f.fetched_bytes, 50);
        assert_eq!(f.evicted_bytes, 100);
        assert!(f.secs > 0.0);
        assert_eq!(h.is_dram_resident(1, 0), Some(true));
        assert_eq!(h.is_dram_resident(0, 0), Some(false));
        assert_eq!(h.nvme_traffic.promoted_bytes, 50);
        assert_eq!(h.nvme_traffic.demoted_bytes, 100);
        assert_eq!(h.pins(1, 0), 1);
        h.validate().unwrap();
    }

    #[test]
    fn pinned_shards_are_never_evicted() {
        let mut h =
            MemoryHierarchy::new(MemoryOptions::with_nvme(100, TierSpec::nvme(1000)));
        h.home_model(0, &[100]).unwrap();
        h.home_model(1, &[50]).unwrap(); // -> NVMe
        h.fetch_to_dram(0, 0).unwrap(); // pins the only DRAM resident
        let err = h.fetch_to_dram(1, 0).unwrap_err();
        assert!(format!("{err}").contains("pinned"), "{err}");
        h.release_device_copy(0, 0);
        assert!(h.fetch_to_dram(1, 0).is_ok());
        h.validate().unwrap();
    }

    #[test]
    fn dram_hit_pins_without_traffic() {
        let mut h =
            MemoryHierarchy::new(MemoryOptions::with_nvme(100, TierSpec::nvme(1000)));
        h.home_model(0, &[40]).unwrap();
        let f = h.fetch_to_dram(0, 0).unwrap();
        assert_eq!(f.secs, 0.0);
        assert_eq!(h.pins(0, 0), 1);
        h.fetch_to_dram(0, 0).unwrap(); // second device caches it too
        assert_eq!(h.pins(0, 0), 2);
        assert_eq!(h.nvme_traffic, TierTraffic::default());
    }

    #[test]
    fn unhome_with_entries_is_strict_and_releases_both_tiers() {
        let mut h =
            MemoryHierarchy::new(MemoryOptions::with_nvme(100, TierSpec::nvme(1000)));
        h.home_model(0, &[60, 60]).unwrap();
        h.unhome_model(0, &[60, 60]).unwrap();
        assert_eq!(h.dram_used(), 0);
        assert_eq!(h.nvme_used(), 0);
        assert!(h.unhome_model(0, &[60, 60]).is_err());
    }

    #[test]
    fn release_after_unhome_is_a_noop() {
        let mut h =
            MemoryHierarchy::new(MemoryOptions::with_nvme(100, TierSpec::nvme(1000)));
        h.home_model(0, &[40]).unwrap();
        h.fetch_to_dram(0, 0).unwrap();
        h.unhome_model(0, &[40]).unwrap();
        h.release_device_copy(0, 0); // device cache outlived the job
        h.validate().unwrap();
    }

    #[test]
    fn tier_spec_parses_cap_and_bandwidth() {
        let t = TierSpec::parse("4096:3.5").unwrap();
        assert_eq!(t.capacity_bytes, 4096 << 30);
        assert!((t.link.bandwidth_bytes_per_sec - 3.5e9).abs() < 1e-3);
        let t = TierSpec::parse("512").unwrap();
        assert_eq!(t.capacity_bytes, 512 << 30);
        assert_eq!(t.link, TransferModel::nvme());
        assert!(TierSpec::parse("abc").is_err());
        assert!(TierSpec::parse("0").is_err());
        assert!(TierSpec::parse("10:-1").is_err());
    }

    #[test]
    fn codec_round_trips_hierarchy_and_ledger_mid_run() {
        let mut h =
            MemoryHierarchy::new(MemoryOptions::with_nvme(100, TierSpec::nvme(1000)));
        h.home_model(0, &[60, 60]).unwrap();
        h.fetch_to_dram(0, 0).unwrap(); // pin + traffic
        let mut l = DeviceLedger::new(2, 1000);
        l.alloc(Residency::ShardParams { model: 0, shard: 1 }, 10).unwrap();
        l.alloc(Residency::BufferZone, 50).unwrap();
        let mut w = ByteWriter::new();
        h.encode(&mut w);
        l.encode(&mut w);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        let h2 = MemoryHierarchy::decode(&mut r).unwrap();
        let l2 = DeviceLedger::decode(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(format!("{h:?}"), format!("{h2:?}"));
        assert_eq!(format!("{l:?}"), format!("{l2:?}"));
        assert_eq!(l2.used(), 60);
    }

    #[test]
    fn memory_options_from_u64_is_dram_only() {
        let m: MemoryOptions = (4 << 30u64).into();
        assert_eq!(m, MemoryOptions::dram_only(4 << 30));
        assert!(m.nvme.is_none());
    }
}
