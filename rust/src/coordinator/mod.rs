//! The Hydra coordinator — the paper's L3 contribution.
//!
//! Components (paper §3): the Automated Partitioner ([`partitioner`]), the
//! Memory Manager ([`memory`], [`engine::prefetch`]) and the Scheduler
//! ([`sched`], [`engine`] — re-exported as [`sharp`]), plus streaming run
//! observation ([`observer`]). The user-facing API is
//! [`crate::session::Session`]; the paper's Figure-4 style
//! [`ModelOrchestrator`] remains as a deprecated shim over it.

pub mod durability;
pub mod engine;
pub mod memory;
pub mod metrics;
pub mod observer;
pub mod partitioner;
pub mod sched;
pub mod sharp;
pub mod task;
pub mod unit;

use crate::coordinator::partitioner::PartitionPolicy;
use crate::coordinator::sched::Policy;
use crate::coordinator::sharp::{DeviceSpec, EngineOptions, RunReport};
use crate::error::{HydraError, Result};
use crate::exec::real::RealModelSpec;
use crate::session::{Backend, Session};

/// Cluster description for real runs: per-device specs (memory capacity,
/// relative speed, optional link override) plus the DRAM pool. Capacities
/// are simulated; compute is real — see DESIGN.md §1.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// One spec per device; heterogeneous pools are first-class.
    pub devices: Vec<DeviceSpec>,
    /// Size of the host DRAM tier models spill to.
    pub dram_bytes: u64,
}

impl Cluster {
    /// A homogeneous pool of `n_devices` reference-speed devices.
    pub fn uniform(n_devices: usize, mem_per_device: u64, dram_bytes: u64) -> Cluster {
        Cluster {
            devices: vec![DeviceSpec::uniform(mem_per_device); n_devices],
            dram_bytes,
        }
    }

    /// A heterogeneous pool from explicit device specs.
    pub fn heterogeneous(devices: Vec<DeviceSpec>, dram_bytes: u64) -> Cluster {
        Cluster { devices, dram_bytes }
    }

    /// Number of devices in the pool.
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Per-device memory capacities.
    pub fn device_mem(&self) -> Vec<u64> {
        self.devices.iter().map(|d| d.mem_bytes).collect()
    }

    /// Capacity of the smallest device — the §4.3 partitioning bound.
    /// Returns 0 on an empty pool, which is why [`Cluster::validate`] runs
    /// at `Session::builder(..).build()`: a zero bound would flow into
    /// partitioning as zero capacity and fail far from the real cause.
    pub fn min_device_mem(&self) -> u64 {
        self.devices.iter().map(|d| d.mem_bytes).min().unwrap_or(0)
    }

    /// Reject unusable clusters with a clear configuration error: empty
    /// device lists, zero-memory devices, and non-positive/non-finite
    /// speeds.
    pub fn validate(&self) -> Result<()> {
        if self.devices.is_empty() {
            return Err(HydraError::Config(
                "cluster has no devices (an empty pool would give the \
                 partitioner a zero-capacity memory bound)"
                    .into(),
            ));
        }
        for (i, d) in self.devices.iter().enumerate() {
            if d.mem_bytes == 0 {
                return Err(HydraError::Config(format!(
                    "cluster device {i} has zero memory"
                )));
            }
            if !d.speed.is_finite() || d.speed <= 0.0 {
                return Err(HydraError::Config(format!(
                    "cluster device {i}: speed {} must be finite and positive",
                    d.speed
                )));
            }
        }
        Ok(())
    }
}

/// Everything a caller needs to inspect after training.
pub struct TrainingReport {
    /// Engine-level schedule report (makespan, utilization, job stats).
    pub run: RunReport,
    /// Per-model loss logs: (step, loss) pairs in retirement order.
    pub losses: Vec<Vec<(u64, f32)>>,
}

/// High-level multi-model training API, mirroring the paper's Figure 4.
///
/// Deprecated: this is now a thin shim over [`crate::session::Session`],
/// which unifies the real and simulated backends behind one typed builder
/// (`Session::builder(cluster).backend(..).policy(..).submit(..).run()`).
/// It remains for one release so existing callers keep compiling.
#[deprecated(
    since = "0.2.0",
    note = "use hydra::session::Session: \
            Session::builder(cluster).backend(Backend::Real { manifest }) \
            .policy(policy).submit(spec)?.run()"
)]
pub struct ModelOrchestrator {
    manifest_dir: String,
    specs: Vec<RealModelSpec>,
    /// Algorithm-1 partitioning knobs.
    pub partition_policy: PartitionPolicy,
    /// SHARP engine knobs (mode, double-buffering, transfer model, ...).
    pub engine_options: EngineOptions,
    /// Scheduling policy name, parsed through [`Policy::from_str`] at run
    /// time (the `Session` API takes the [`Policy`] enum directly).
    pub scheduler: String,
    /// AutoML-style early stopping: models whose epoch-mean loss falls
    /// behind the median after `min_epochs` are dropped (§4.7.2).
    pub early_stop_median_after: Option<u32>,
}

#[allow(deprecated)]
impl ModelOrchestrator {
    /// Create an orchestrator over the artifact manifest at `manifest_dir`.
    pub fn new(manifest_dir: impl Into<String>) -> ModelOrchestrator {
        ModelOrchestrator {
            manifest_dir: manifest_dir.into(),
            specs: Vec::new(),
            partition_policy: PartitionPolicy::default(),
            engine_options: EngineOptions::default(),
            scheduler: Policy::default().name().to_string(),
            early_stop_median_after: None,
        }
    }

    /// Register one model training task.
    pub fn add_task(&mut self, spec: RealModelSpec) -> &mut Self {
        self.specs.push(spec);
        self
    }

    /// Number of registered tasks.
    pub fn n_tasks(&self) -> usize {
        self.specs.len()
    }

    /// Train all registered models to completion over the cluster.
    /// Delegates to [`Session`] — pilot runs -> Algorithm-1 partitioning ->
    /// SHARP engine -> PJRT execution are all composed there now.
    pub fn train_models(&self, cluster: &Cluster) -> Result<TrainingReport> {
        if self.specs.is_empty() {
            return Err(HydraError::Config("no tasks registered".into()));
        }
        let mut builder = Session::builder(cluster.clone())
            .backend(Backend::Real { manifest: self.manifest_dir.clone() })
            .policy(self.scheduler.parse::<Policy>()?)
            .options(self.engine_options.clone())
            .partition_policy(self.partition_policy);
        if let Some(min_epochs) = self.early_stop_median_after {
            builder = builder.early_stop_median_after(min_epochs);
        }
        let mut session = builder.build()?;
        for spec in &self.specs {
            session.submit(spec.clone())?;
        }
        let report = session.run()?;
        Ok(TrainingReport { run: report.run, losses: report.losses })
    }
}
