//! The Hydra coordinator — the paper's L3 contribution.
//!
//! Components (paper §3): the user-facing API ([`ModelOrchestrator`]), the
//! Automated Partitioner ([`partitioner`]), the Memory Manager ([`memory`],
//! [`buffer`]) and the Scheduler ([`sched`], [`sharp`]).

pub mod buffer;
pub mod memory;
pub mod metrics;
pub mod partitioner;
pub mod sched;
pub mod sharp;
pub mod task;
pub mod unit;

use crate::coordinator::partitioner::PartitionPolicy;
use crate::coordinator::sharp::{DeviceSpec, EngineOptions, RunReport, SharpEngine};
use crate::error::{HydraError, Result};
use crate::exec::real::{RealBackend, RealModelSpec};

/// High-level multi-model training API, mirroring the paper's Figure 4.
///
/// Register tasks, then [`ModelOrchestrator::train_models`] composes the
/// whole stack: pilot runs -> Algorithm-1 partitioning -> ModelTask queues
/// -> SHARP engine with spilling and double-buffering -> PJRT execution of
/// every shard unit.
///
/// ```
/// use hydra::coordinator::ModelOrchestrator;
/// use hydra::exec::real::RealModelSpec;
/// use hydra::train::optimizer::OptKind;
///
/// let mut orch = ModelOrchestrator::new("artifacts");
/// orch.add_task(RealModelSpec {
///     name: "bert-lr3".into(),
///     config: "tiny-lm-b8".into(),
///     lr: 1e-3,
///     opt: OptKind::Sgd,
///     epochs: 1,
///     minibatches_per_epoch: 4,
///     seed: 0,
///     inference: false,
///     arrival: 0.0,
/// });
/// orch.scheduler = "sharded-lrtf".to_string();
/// assert_eq!(orch.n_tasks(), 1);
/// // orch.train_models(&cluster) then runs everything (needs artifacts/).
/// ```
pub struct ModelOrchestrator {
    manifest_dir: String,
    specs: Vec<RealModelSpec>,
    /// Algorithm-1 partitioning knobs.
    pub partition_policy: PartitionPolicy,
    /// SHARP engine knobs (mode, double-buffering, transfer model, ...).
    pub engine_options: EngineOptions,
    /// Scheduling policy name (see [`sched::by_name`]).
    pub scheduler: String,
    /// AutoML-style early stopping: models whose epoch-mean loss falls
    /// behind the median after `min_epochs` are dropped (§4.7.2).
    pub early_stop_median_after: Option<u32>,
}

/// Cluster description for real runs: per-device specs (memory capacity,
/// relative speed, optional link override) plus the DRAM pool. Capacities
/// are simulated; compute is real — see DESIGN.md §1.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// One spec per device; heterogeneous pools are first-class.
    pub devices: Vec<DeviceSpec>,
    /// Size of the host DRAM tier models spill to.
    pub dram_bytes: u64,
}

impl Cluster {
    /// A homogeneous pool of `n_devices` reference-speed devices.
    pub fn uniform(n_devices: usize, mem_per_device: u64, dram_bytes: u64) -> Cluster {
        Cluster {
            devices: vec![DeviceSpec::uniform(mem_per_device); n_devices],
            dram_bytes,
        }
    }

    /// A heterogeneous pool from explicit device specs.
    pub fn heterogeneous(devices: Vec<DeviceSpec>, dram_bytes: u64) -> Cluster {
        Cluster { devices, dram_bytes }
    }

    /// Number of devices in the pool.
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Per-device memory capacities.
    pub fn device_mem(&self) -> Vec<u64> {
        self.devices.iter().map(|d| d.mem_bytes).collect()
    }

    /// Capacity of the smallest device — the §4.3 partitioning bound.
    pub fn min_device_mem(&self) -> u64 {
        self.devices.iter().map(|d| d.mem_bytes).min().unwrap_or(0)
    }
}

/// Everything a caller needs to inspect after training.
pub struct TrainingReport {
    /// Engine-level schedule report (makespan, utilization, job stats).
    pub run: RunReport,
    /// Per-model loss logs: (step, loss) pairs in retirement order.
    pub losses: Vec<Vec<(u64, f32)>>,
}

impl ModelOrchestrator {
    /// Create an orchestrator over the artifact manifest at `manifest_dir`.
    pub fn new(manifest_dir: impl Into<String>) -> ModelOrchestrator {
        ModelOrchestrator {
            manifest_dir: manifest_dir.into(),
            specs: Vec::new(),
            partition_policy: PartitionPolicy::default(),
            engine_options: EngineOptions::default(),
            scheduler: "sharded-lrtf".to_string(),
            early_stop_median_after: None,
        }
    }

    /// Register one model training task.
    pub fn add_task(&mut self, spec: RealModelSpec) -> &mut Self {
        self.specs.push(spec);
        self
    }

    /// Number of registered tasks.
    pub fn n_tasks(&self) -> usize {
        self.specs.len()
    }

    /// Train all registered models to completion over the cluster.
    ///
    /// This is where the whole stack composes: pilot runs -> Algorithm-1
    /// partitioning -> ModelTask queues -> SHARP engine with spilling and
    /// double-buffering -> real PJRT execution of every shard unit. Tasks
    /// with a non-zero [`RealModelSpec::arrival`] enter the schedule online
    /// at that virtual time.
    pub fn train_models(&self, cluster: &Cluster) -> Result<TrainingReport> {
        if self.specs.is_empty() {
            return Err(HydraError::Config("no tasks registered".into()));
        }
        let (mut backend, tasks) = RealBackend::build(
            &self.manifest_dir,
            &self.specs,
            cluster.min_device_mem(),
            self.partition_policy,
        )?;
        if let Some(min_epochs) = self.early_stop_median_after {
            backend.early_stop =
                Some(crate::exec::real::MedianRule { min_epochs });
        }
        let scheduler = sched::by_name(&self.scheduler)
            .ok_or_else(|| HydraError::Config(format!(
                "unknown scheduler {:?}", self.scheduler)))?;
        let mut engine = SharpEngine::with_devices(
            tasks,
            &cluster.devices,
            cluster.dram_bytes,
            scheduler,
            &mut backend,
            self.engine_options.clone(),
        )?;
        let run = engine.run()?;
        let losses = (0..self.specs.len())
            .map(|m| backend.loss_log(m).to_vec())
            .collect();
        Ok(TrainingReport { run, losses })
    }
}
