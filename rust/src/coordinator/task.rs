//! Model tasks: one entry of the user's multi-model workload (Figure 4's
//! `ModelTask`), plus the runtime bookkeeping the scheduler needs —
//! queue-front tracking, remaining-time accounting, running/idle state.

use crate::coordinator::unit::{Phase, ShardUnit, UnitGeometry};
use crate::error::{HydraError, Result};
use crate::util::codec::{ByteReader, ByteWriter};

/// Per-shard static description produced by the partitioner.
#[derive(Debug, Clone)]
pub struct ShardDesc {
    /// Resident bytes on a device while this shard's unit runs (weights +
    /// gradient buffer + optimizer state) — what the memory ledger charges.
    pub param_bytes: u64,
    /// Bytes actually moved DRAM->device for a forward unit (weights only:
    /// optimizer state stays in DRAM, ZeRO-Offload-style, exactly like the
    /// real backend's Rust-side optimizer).
    pub fwd_transfer_bytes: u64,
    /// Bytes moved for a backward unit (weights in, gradients out).
    pub bwd_transfer_bytes: u64,
    /// Bytes of the boundary activation checkpoint handed to the next unit.
    pub activation_bytes: u64,
    /// Estimated forward-unit compute seconds (from the pilot run / cost
    /// model); bwd units are assumed `bwd_factor` times this.
    pub fwd_cost: f64,
    /// Estimated backward-unit compute seconds.
    pub bwd_cost: f64,
    /// Number of model layers folded into this shard.
    pub n_layers: u32,
}

impl ShardDesc {
    /// Bytes spilling moves for a unit of the given phase.
    pub fn transfer_bytes(&self, phase: Phase) -> u64 {
        match phase {
            Phase::Fwd => self.fwd_transfer_bytes,
            Phase::Bwd => self.bwd_transfer_bytes,
        }
    }

    /// Estimated compute seconds of a unit of the given phase (on the
    /// reference device; the engine divides by the device's speed).
    pub fn cost(&self, phase: Phase) -> f64 {
        match phase {
            Phase::Fwd => self.fwd_cost,
            Phase::Bwd => self.bwd_cost,
        }
    }

    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u64(self.param_bytes);
        w.put_u64(self.fwd_transfer_bytes);
        w.put_u64(self.bwd_transfer_bytes);
        w.put_u64(self.activation_bytes);
        w.put_f64(self.fwd_cost);
        w.put_f64(self.bwd_cost);
        w.put_u32(self.n_layers);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<ShardDesc> {
        Ok(ShardDesc {
            param_bytes: r.get_u64()?,
            fwd_transfer_bytes: r.get_u64()?,
            bwd_transfer_bytes: r.get_u64()?,
            activation_bytes: r.get_u64()?,
            fwd_cost: r.get_f64()?,
            bwd_cost: r.get_f64()?,
            n_layers: r.get_u32()?,
        })
    }
}

/// Lifecycle state of a model task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// Front unit is eligible for scheduling.
    Idle,
    /// A unit of this model is running (or buffered) on a device — the
    /// paper's model-training-isolation constraint (§4.7.1 (b,c)).
    Running,
    /// All units retired.
    Done,
}

impl TaskState {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            TaskState::Idle => 0,
            TaskState::Running => 1,
            TaskState::Done => 2,
        });
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<TaskState> {
        match r.get_u8()? {
            0 => Ok(TaskState::Idle),
            1 => Ok(TaskState::Running),
            2 => Ok(TaskState::Done),
            t => Err(HydraError::WalCorrupt(format!("unknown task state tag {t}"))),
        }
    }
}

/// A model training task with scheduler bookkeeping.
#[derive(Debug, Clone)]
pub struct ModelTask {
    /// Task id == index into the engine's task vector.
    pub id: usize,
    /// Human-readable tag, e.g. "bert-lr1e-4-b8".
    pub name: String,
    /// Artifact config this model instance executes (real backend).
    pub config_name: String,
    /// Per-shard static descriptions from the partitioner.
    pub shards: Vec<ShardDesc>,
    /// Unit-queue geometry (shards x mini-batches x epochs).
    pub geometry: UnitGeometry,
    /// Hyperparameters owned by the runtime side (never baked into HLO).
    pub lr: f32,
    /// Virtual time this job enters the system (0.0 = present from the
    /// start, the paper's batch setting). The engine keeps the task out of
    /// the eligible set until its arrival time passes, which is what turns
    /// the batch scheduler into an online one.
    arrival: f64,
    /// Next queue position to schedule.
    next_idx: u64,
    state: TaskState,
    /// Sum of remaining unit costs (the paper's remaining train time, kept
    /// incrementally so Sharded-LRTF decisions are O(1) per model).
    remaining_time: f64,
    /// Completed-unit counter (== next_idx unless a unit is in flight).
    completed: u64,
    /// Owning tenant (0 = the default single-tenant namespace). Tenant ids
    /// index dense per-tenant accounting vectors in the engine, so they must
    /// stay small — [`ModelTask::with_tenant`] enforces a bound.
    tenant: usize,
    /// Weighted-fair-queueing weight of this job (1.0 = the default). A
    /// tenant's GPU-second share under `Policy::WeightedFair` converges to
    /// its weight's fraction of the active weight sum.
    weight: f64,
    /// Optional latency SLO: the job meets its deadline when it finishes
    /// within `deadline` virtual seconds of its arrival (NaN = no SLO).
    deadline: f64,
}

/// Upper bound on tenant ids: they index dense per-tenant vectors in the
/// engine, so an absurd id would be an accidental giant allocation.
pub const MAX_TENANT_ID: usize = 1 << 20;

impl ModelTask {
    /// A training task over `shards`, running `epochs` x
    /// `minibatches_per_epoch` mini-batches (arrival 0.0; see
    /// [`ModelTask::with_arrival`]).
    pub fn new(
        id: usize,
        name: impl Into<String>,
        config_name: impl Into<String>,
        shards: Vec<ShardDesc>,
        minibatches_per_epoch: u32,
        epochs: u32,
        lr: f32,
    ) -> ModelTask {
        assert!(!shards.is_empty());
        let geometry =
            UnitGeometry::new(shards.len() as u32, minibatches_per_epoch, epochs);
        let per_mb: f64 =
            shards.iter().map(|s| s.fwd_cost + s.bwd_cost).sum();
        let remaining_time =
            per_mb * (minibatches_per_epoch as f64) * (epochs as f64);
        ModelTask {
            id,
            name: name.into(),
            config_name: config_name.into(),
            shards,
            geometry,
            lr,
            arrival: 0.0,
            next_idx: 0,
            state: TaskState::Idle,
            remaining_time,
            completed: 0,
            tenant: 0,
            weight: 1.0,
            deadline: f64::NAN,
        }
    }

    /// An inference task: forward-only units over `batches` batches
    /// (paper §6 — spilling/partitioning/orchestration already suffice
    /// for out-of-the-box large-model inference).
    pub fn new_inference(
        id: usize,
        name: impl Into<String>,
        config_name: impl Into<String>,
        shards: Vec<ShardDesc>,
        batches: u32,
    ) -> ModelTask {
        assert!(!shards.is_empty());
        let geometry = UnitGeometry::new_inference(shards.len() as u32, batches);
        let per_batch: f64 = shards.iter().map(|s| s.fwd_cost).sum();
        let remaining_time = per_batch * batches as f64;
        ModelTask {
            id,
            name: name.into(),
            config_name: config_name.into(),
            shards,
            geometry,
            lr: 0.0,
            arrival: 0.0,
            next_idx: 0,
            state: TaskState::Idle,
            remaining_time,
            completed: 0,
            tenant: 0,
            weight: 1.0,
            deadline: f64::NAN,
        }
    }

    /// Set the arrival time (builder style) for online workloads.
    ///
    /// Panics if `arrival` is negative or non-finite.
    pub fn with_arrival(mut self, arrival: f64) -> ModelTask {
        assert!(arrival.is_finite() && arrival >= 0.0, "bad arrival {arrival}");
        self.arrival = arrival;
        self
    }

    /// Assign the job to `tenant` with weighted-fair-queueing weight
    /// `weight` (builder style). The defaults — tenant 0, weight 1.0 —
    /// mean "no tenant metadata": setting them explicitly is a no-op.
    ///
    /// Panics if `tenant` exceeds [`MAX_TENANT_ID`] or `weight` is not a
    /// finite positive number (mirroring [`ModelTask::with_arrival`]).
    pub fn with_tenant(mut self, tenant: usize, weight: f64) -> ModelTask {
        assert!(tenant <= MAX_TENANT_ID, "bad tenant id {tenant}");
        assert!(weight.is_finite() && weight > 0.0, "bad tenant weight {weight}");
        self.tenant = tenant;
        self.weight = weight;
        self
    }

    /// Set a latency SLO (builder style): the job meets its deadline when
    /// it finishes within `deadline` virtual seconds of its arrival.
    ///
    /// Panics if `deadline` is not a finite positive number.
    pub fn with_deadline(mut self, deadline: f64) -> ModelTask {
        assert!(deadline.is_finite() && deadline > 0.0, "bad deadline {deadline}");
        self.deadline = deadline;
        self
    }

    /// Virtual time this job enters the system.
    pub fn arrival(&self) -> f64 {
        self.arrival
    }

    /// Owning tenant (0 = the default namespace).
    pub fn tenant(&self) -> usize {
        self.tenant
    }

    /// Weighted-fair-queueing weight (1.0 = the default).
    pub fn weight(&self) -> f64 {
        self.weight
    }

    /// Latency SLO in seconds from arrival, if one was set.
    pub fn deadline(&self) -> Option<f64> {
        self.deadline.is_finite().then_some(self.deadline)
    }

    /// Whether this job carries any tenant metadata — a non-default tenant,
    /// weight, or an SLO. Reports only grow a per-tenant section when some
    /// job (or the admission option) opts in, keeping metadata-free runs
    /// Debug-byte-identical to pre-tenant reports.
    pub fn has_tenant_meta(&self) -> bool {
        self.tenant != 0 || self.weight != 1.0 || self.deadline.is_finite()
    }

    /// Current lifecycle state.
    pub fn state(&self) -> TaskState {
        self.state
    }

    /// Total units over the whole run (the paper's M_i).
    pub fn total_units(&self) -> u64 {
        self.geometry.total_units()
    }

    /// Units retired so far.
    pub fn completed_units(&self) -> u64 {
        self.completed
    }

    /// Remaining total train time (Sharded-LRTF's key, Algorithm 2).
    pub fn remaining_time(&self) -> f64 {
        self.remaining_time
    }

    /// The unit at the front of the queue, if any.
    pub fn front_unit(&self) -> Option<ShardUnit> {
        (self.next_idx < self.total_units())
            .then(|| self.geometry.unit_at(self.id, self.next_idx))
    }

    /// Static description of shard `idx`.
    pub fn shard(&self, idx: u32) -> &ShardDesc {
        &self.shards[idx as usize]
    }

    /// Cost estimate of the front unit.
    pub fn front_cost(&self) -> Option<f64> {
        self.front_unit().map(|u| self.shard(u.shard).cost(u.phase))
    }

    /// Mark the front unit as claimed by a device (scheduled or buffered).
    /// Returns the claimed unit. Panics if not Idle (isolation invariant).
    pub fn claim_front(&mut self) -> ShardUnit {
        assert_eq!(self.state, TaskState::Idle, "model {} not idle", self.id);
        let u = self.front_unit().expect("claim on finished task");
        self.state = TaskState::Running;
        self.next_idx += 1;
        u
    }

    /// Mark a claimed unit as retired; updates remaining time and state.
    pub fn retire(&mut self, unit: &ShardUnit) {
        assert_eq!(self.state, TaskState::Running);
        debug_assert_eq!(unit.seq_idx + 1, self.next_idx);
        self.remaining_time -= self.shard(unit.shard).cost(unit.phase);
        if self.remaining_time < 0.0 {
            self.remaining_time = 0.0;
        }
        self.completed += 1;
        self.state = if self.next_idx >= self.total_units() {
            TaskState::Done
        } else {
            TaskState::Idle
        };
    }

    /// Cancel a claim without running it (failure injection / device loss).
    pub fn unclaim(&mut self, unit: &ShardUnit) {
        assert_eq!(self.state, TaskState::Running);
        debug_assert_eq!(unit.seq_idx + 1, self.next_idx);
        self.next_idx -= 1;
        self.state = TaskState::Idle;
    }

    /// Early-stop: drop all remaining units (Hyperband-style, §4.7.2).
    /// Also the mechanism behind tenant-initiated cancellation in the online
    /// setting — the engine defers it until any in-flight unit retires, so
    /// it only ever fires from the `Idle` state.
    pub fn early_stop(&mut self) {
        if self.state != TaskState::Done && self.state != TaskState::Running {
            self.remaining_time = 0.0;
            self.next_idx = self.total_units();
            self.state = TaskState::Done;
        }
    }

    /// Total bytes of this model's parameters across shards.
    pub fn total_param_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.param_bytes).sum()
    }

    /// Serialize the whole task — static description *and* the scheduler's
    /// runtime bookkeeping (queue front, remaining time, lifecycle state) —
    /// for durability snapshots and WAL genesis records.
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_usize(self.id);
        w.put_str(&self.name);
        w.put_str(&self.config_name);
        w.put_usize(self.shards.len());
        for s in &self.shards {
            s.encode(w);
        }
        self.geometry.encode(w);
        w.put_f32(self.lr);
        w.put_f64(self.arrival);
        w.put_u64(self.next_idx);
        self.state.encode(w);
        w.put_f64(self.remaining_time);
        w.put_u64(self.completed);
        w.put_usize(self.tenant);
        w.put_f64(self.weight);
        w.put_f64(self.deadline);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<ModelTask> {
        let id = r.get_usize()?;
        let name = r.get_str()?;
        let config_name = r.get_str()?;
        // each ShardDesc occupies at least 4*8 + 2*8 + 4 bytes
        let n = r.get_count(52)?;
        if n == 0 {
            return Err(HydraError::WalCorrupt("task with zero shards".into()));
        }
        let mut shards = Vec::with_capacity(n);
        for _ in 0..n {
            shards.push(ShardDesc::decode(r)?);
        }
        let geometry = UnitGeometry::decode(r)?;
        if geometry.n_shards as usize != shards.len() {
            return Err(HydraError::WalCorrupt(format!(
                "geometry says {} shards but {} are described",
                geometry.n_shards,
                shards.len()
            )));
        }
        Ok(ModelTask {
            id,
            name,
            config_name,
            shards,
            geometry,
            lr: r.get_f32()?,
            arrival: r.get_f64()?,
            next_idx: r.get_u64()?,
            state: TaskState::decode(r)?,
            remaining_time: r.get_f64()?,
            completed: r.get_u64()?,
            tenant: {
                let t = r.get_usize()?;
                if t > MAX_TENANT_ID {
                    return Err(HydraError::WalCorrupt(format!(
                        "implausible tenant id {t}"
                    )));
                }
                t
            },
            weight: r.get_f64()?,
            deadline: r.get_f64()?,
        })
    }
}

/// Immutable scheduler view of one model (what `Scheduler::pick` sees).
#[derive(Debug, Clone, Copy)]
pub struct ModelSnapshot {
    /// Model task id.
    pub id: usize,
    /// Remaining total train time (Sharded-LRTF's key).
    pub remaining_time: f64,
    /// Units not yet retired.
    pub remaining_units: u64,
    /// Cost estimate of the front unit.
    pub front_cost: f64,
    /// Shard index of the front unit (for affinity-aware policies).
    pub front_shard: u32,
    /// Phase of the front unit.
    pub front_phase: Phase,
    /// Arrival time of the job (0.0 for batch workloads). Lets FIFO order
    /// by true arrival under online submissions instead of model id.
    pub arrival: f64,
    /// Owning tenant — indexes the per-tenant accrued-GPU-seconds slice a
    /// `PickContext` carries for weighted-fair policies.
    pub tenant: usize,
    /// Weighted-fair-queueing weight of the job.
    pub weight: f64,
}

impl ModelSnapshot {
    /// Snapshot an idle task; `None` if it is running or done.
    pub fn of(task: &ModelTask) -> Option<ModelSnapshot> {
        let u = task.front_unit()?;
        if task.state() != TaskState::Idle {
            return None;
        }
        Some(ModelSnapshot {
            id: task.id,
            remaining_time: task.remaining_time(),
            remaining_units: task.total_units() - task.completed_units(),
            front_cost: task.shard(u.shard).cost(u.phase),
            front_shard: u.shard,
            front_phase: u.phase,
            arrival: task.arrival(),
            tenant: task.tenant(),
            weight: task.weight(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_task(shards: usize, mbs: u32, epochs: u32) -> ModelTask {
        let sd = (0..shards)
            .map(|i| ShardDesc {
                param_bytes: 1000,
                fwd_transfer_bytes: 400,
                bwd_transfer_bytes: 800,
                activation_bytes: 100,
                fwd_cost: 1.0 + i as f64,
                bwd_cost: 2.0 * (1.0 + i as f64),
                n_layers: 1,
            })
            .collect();
        ModelTask::new(0, "t", "cfg", sd, mbs, epochs, 1e-3)
    }

    #[test]
    fn remaining_time_initialises_to_total() {
        let t = mk_task(2, 3, 2);
        // per minibatch: (1+2) + (2+4) = 9; * 3 mbs * 2 epochs = 54
        assert!((t.remaining_time() - 54.0).abs() < 1e-9);
    }

    #[test]
    fn claim_retire_cycle_updates_state() {
        let mut t = mk_task(2, 1, 1);
        assert_eq!(t.state(), TaskState::Idle);
        let u = t.claim_front();
        assert_eq!(u.shard, 0);
        assert_eq!(u.phase, Phase::Fwd);
        assert_eq!(t.state(), TaskState::Running);
        t.retire(&u);
        assert_eq!(t.state(), TaskState::Idle);
        assert!((t.remaining_time() - 8.0).abs() < 1e-9); // 9 - 1
    }

    #[test]
    fn completes_after_all_units() {
        let mut t = mk_task(2, 1, 1);
        for _ in 0..4 {
            let u = t.claim_front();
            t.retire(&u);
        }
        assert_eq!(t.state(), TaskState::Done);
        assert!(t.front_unit().is_none());
        assert!(t.remaining_time().abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "not idle")]
    fn double_claim_panics() {
        let mut t = mk_task(2, 1, 1);
        t.claim_front();
        t.claim_front();
    }

    #[test]
    fn unclaim_restores_front() {
        let mut t = mk_task(2, 1, 1);
        let u = t.claim_front();
        t.unclaim(&u);
        assert_eq!(t.state(), TaskState::Idle);
        assert_eq!(t.front_unit().unwrap().seq_idx, 0);
    }

    #[test]
    fn early_stop_finishes_task() {
        let mut t = mk_task(2, 5, 5);
        let u = t.claim_front();
        t.retire(&u);
        t.early_stop();
        assert_eq!(t.state(), TaskState::Done);
        assert_eq!(t.remaining_time(), 0.0);
    }

    #[test]
    fn arrival_defaults_to_zero_and_builds() {
        let t = mk_task(1, 1, 1);
        assert_eq!(t.arrival(), 0.0);
        let t = t.with_arrival(12.5);
        assert_eq!(t.arrival(), 12.5);
        assert_eq!(ModelSnapshot::of(&t).unwrap().arrival, 12.5);
    }

    #[test]
    #[should_panic(expected = "bad arrival")]
    fn negative_arrival_panics() {
        let _ = mk_task(1, 1, 1).with_arrival(-1.0);
    }

    #[test]
    fn tenant_metadata_defaults_off_and_builds() {
        let t = mk_task(1, 1, 1);
        assert_eq!(t.tenant(), 0);
        assert_eq!(t.weight(), 1.0);
        assert!(t.deadline().is_none());
        assert!(!t.has_tenant_meta());
        // setting the defaults explicitly is still "no metadata"
        assert!(!mk_task(1, 1, 1).with_tenant(0, 1.0).has_tenant_meta());
        let t = t.with_tenant(3, 2.5).with_deadline(60.0);
        assert!(t.has_tenant_meta());
        assert_eq!(t.tenant(), 3);
        assert_eq!(t.weight(), 2.5);
        assert_eq!(t.deadline(), Some(60.0));
        let s = ModelSnapshot::of(&t).unwrap();
        assert_eq!((s.tenant, s.weight), (3, 2.5));
    }

    #[test]
    #[should_panic(expected = "bad tenant weight")]
    fn zero_weight_panics() {
        let _ = mk_task(1, 1, 1).with_tenant(1, 0.0);
    }

    #[test]
    #[should_panic(expected = "bad deadline")]
    fn nan_deadline_panics() {
        let _ = mk_task(1, 1, 1).with_deadline(f64::NAN);
    }

    #[test]
    fn codec_round_trips_tenant_metadata() {
        let t = mk_task(1, 2, 1).with_tenant(7, 4.0).with_deadline(120.0);
        let mut w = ByteWriter::new();
        t.encode(&mut w);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        let back = ModelTask::decode(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(format!("{t:?}"), format!("{back:?}"));
        assert_eq!(back.tenant(), 7);
        assert_eq!(back.weight(), 4.0);
        assert_eq!(back.deadline(), Some(120.0));
    }

    #[test]
    fn codec_round_trips_mid_run_bookkeeping() {
        let mut t = mk_task(2, 3, 2).with_arrival(4.25);
        let u = t.claim_front();
        t.retire(&u);
        let mut w = ByteWriter::new();
        t.encode(&mut w);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        let back = ModelTask::decode(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(format!("{t:?}"), format!("{back:?}"));
        assert_eq!(back.completed_units(), 1);
        assert_eq!(back.state(), TaskState::Idle);
    }

    #[test]
    fn snapshot_only_for_idle() {
        let mut t = mk_task(2, 1, 1);
        assert!(ModelSnapshot::of(&t).is_some());
        let _u = t.claim_front();
        assert!(ModelSnapshot::of(&t).is_none());
    }
}
