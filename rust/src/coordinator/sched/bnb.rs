//! Exact scheduler — the "MILP optimal" comparator of Figure 7.
//!
//! The paper encodes SHARP scheduling as an MILP (§4.7.1, constraints
//! (a)–(e)) and solves it with Gurobi under a 100 s timeout, reporting the
//! incumbent. Gurobi is unavailable here; this branch-and-bound solver has
//! the same semantics: minimise makespan of T sequential unit-chains over P
//! identical devices, subject to (a) per-model unit order, (b,c) device
//! isolation, (d) non-negative starts, (e) makespan envelope.
//!
//! Enumeration is over *active schedules* (every unit starts as early as
//! possible given the decision order), which is complete for makespan
//! minimisation. Bounds: chain bound + aggregate work bound. Like the
//! paper, we return the best incumbent when the time budget expires.

use std::time::{Duration, Instant};

/// Abstract instance: per-model unit runtime lists, device count.
#[derive(Debug, Clone)]
pub struct Problem {
    /// Per-model sequential unit runtimes.
    pub units: Vec<Vec<f64>>,
    /// Number of identical devices.
    pub devices: usize,
}

impl Problem {
    /// Sum of all unit runtimes.
    pub fn total_work(&self) -> f64 {
        self.units.iter().map(|u| u.iter().sum::<f64>()).sum()
    }

    /// Longest single-model chain.
    pub fn longest_chain(&self) -> f64 {
        self.units
            .iter()
            .map(|u| u.iter().sum::<f64>())
            .fold(0.0, f64::max)
    }

    /// The classic machine-scheduling lower bound.
    pub fn lower_bound(&self) -> f64 {
        (self.total_work() / self.devices as f64).max(self.longest_chain())
    }
}

/// Solver outcome.
#[derive(Debug, Clone, Copy)]
pub struct Solution {
    /// Best makespan found (incumbent on timeout).
    pub makespan: f64,
    /// Whether the search finished within budget.
    pub proven_optimal: bool,
    /// Branch-and-bound nodes explored.
    pub nodes: u64,
}

const EPS: f64 = 1e-9;

struct Search<'a> {
    p: &'a Problem,
    next_unit: Vec<usize>,
    model_free: Vec<f64>,
    device_free: Vec<f64>,
    remaining: Vec<f64>,
    best: f64,
    nodes: u64,
    deadline: Instant,
    timed_out: bool,
}

impl<'a> Search<'a> {
    fn lb(&self) -> f64 {
        let dmin = self.device_free.iter().cloned().fold(f64::INFINITY, f64::min);
        // chain bound
        let mut lb = self.device_free.iter().cloned().fold(0.0, f64::max);
        for i in 0..self.p.units.len() {
            if self.remaining[i] > 0.0 {
                lb = lb.max(self.model_free[i].max(dmin) + self.remaining[i]);
            }
        }
        // aggregate work bound: all remaining work + device head-starts
        let head: f64 = self.device_free.iter().map(|d| d - dmin).sum();
        let total: f64 = self.remaining.iter().sum();
        lb.max(dmin + (total + head) / self.p.devices as f64)
    }

    /// One application of a branch decision (for undo on backtrack).
    fn make_frame(&self) -> Frame {
        // Branch: assign some unfinished model's next unit to the earliest
        // device. Identical devices => fixing the earliest device loses no
        // active schedules.
        let (d, _) = self
            .device_free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        // Candidate models, ordered by a heuristic (longest remaining first)
        // so the first incumbent is strong. Candidates whose model_free is
        // later than d_free start with deliberate idle time — still active
        // schedules, must be explored.
        let mut cands: Vec<usize> = (0..self.p.units.len())
            .filter(|&i| self.next_unit[i] < self.p.units[i].len())
            .collect();
        cands.sort_by(|&a, &b| {
            self.remaining[b].partial_cmp(&self.remaining[a]).unwrap()
        });
        Frame { d, d_free: self.device_free[d], cands, next: 0, applied: None }
    }

    fn undo(&mut self, frame: &mut Frame) {
        if let Some((i, old_mf, dur)) = frame.applied.take() {
            self.next_unit[i] -= 1;
            self.model_free[i] = old_mf;
            self.device_free[frame.d] = frame.d_free;
            self.remaining[i] += dur;
        }
    }

    /// Iterative DFS with explicit stack: search depth equals the number of
    /// scheduled units (tens of thousands at Fig-7 scale), far beyond the
    /// thread stack a recursive formulation would tolerate.
    fn search(&mut self) {
        let mut stack: Vec<Frame> = vec![self.make_frame()];
        while !stack.is_empty() {
            let top = stack.len() - 1;
            // undo the previous application at this frame, if any
            let mut frame = std::mem::replace(&mut stack[top], Frame::dummy());
            self.undo(&mut frame);
            if self.timed_out || frame.next >= frame.cands.len() {
                stack.pop();
                continue;
            }
            let i = frame.cands[frame.next];
            frame.next += 1;

            // apply decision: model i's next unit on device frame.d
            let start = frame.d_free.max(self.model_free[i]);
            let dur = self.p.units[i][self.next_unit[i]];
            let end = start + dur;
            self.next_unit[i] += 1;
            let old_mf = self.model_free[i];
            self.model_free[i] = end;
            self.device_free[frame.d] = end;
            self.remaining[i] -= dur;
            frame.applied = Some((i, old_mf, dur));
            stack[top] = frame;

            self.nodes += 1;
            if self.nodes % 4096 == 0 && Instant::now() >= self.deadline {
                self.timed_out = true;
            }

            // leaf? (index-based: float residue in `remaining` must not
            // affect completion detection)
            if (0..self.p.units.len())
                .all(|m| self.next_unit[m] >= self.p.units[m].len())
            {
                let mk = self.device_free.iter().cloned().fold(0.0, f64::max);
                if mk < self.best - EPS {
                    self.best = mk;
                }
                continue; // undo happens when this frame is revisited
            }
            if self.lb() >= self.best - EPS {
                continue; // pruned
            }
            stack.push(self.make_frame());
        }
    }
}

/// Explicit DFS frame (see `Search::search`).
struct Frame {
    d: usize,
    d_free: f64,
    cands: Vec<usize>,
    next: usize,
    /// (model, old model_free, duration) of the currently applied decision.
    applied: Option<(usize, f64, f64)>,
}

impl Frame {
    fn dummy() -> Frame {
        Frame { d: 0, d_free: 0.0, cands: Vec::new(), next: 0, applied: None }
    }
}

/// Solve to optimality or best-incumbent-within-budget.
///
/// `incumbent`: a known feasible makespan (e.g. from Sharded-LRTF) used to
/// warm-start pruning, mirroring how one would warm-start Gurobi.
pub fn solve(p: &Problem, budget: Duration, incumbent: Option<f64>) -> Solution {
    assert!(p.devices > 0);
    let mut s = Search {
        p,
        next_unit: vec![0; p.units.len()],
        model_free: vec![0.0; p.units.len()],
        device_free: vec![0.0; p.devices],
        remaining: p.units.iter().map(|u| u.iter().sum()).collect(),
        best: incumbent.unwrap_or(f64::INFINITY) + EPS,
        nodes: 0,
        deadline: Instant::now() + budget,
        timed_out: false,
    };
    s.search();
    let mut makespan = if s.best.is_finite() {
        s.best
    } else {
        incumbent.unwrap_or(f64::INFINITY)
    };
    if let Some(inc) = incumbent {
        makespan = makespan.min(inc); // warm start remains feasible
    }
    Solution { makespan, proven_optimal: !s.timed_out, nodes: s.nodes }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prob(units: &[&[f64]], devices: usize) -> Problem {
        Problem { units: units.iter().map(|u| u.to_vec()).collect(), devices }
    }

    #[test]
    fn single_model_single_device_is_chain_sum() {
        let p = prob(&[&[1.0, 2.0, 3.0]], 1);
        let s = solve(&p, Duration::from_secs(5), None);
        assert!(s.proven_optimal);
        assert!((s.makespan - 6.0).abs() < 1e-9);
    }

    #[test]
    fn independent_models_parallelise_perfectly() {
        let p = prob(&[&[2.0, 2.0], &[2.0, 2.0]], 2);
        let s = solve(&p, Duration::from_secs(5), None);
        assert!(s.proven_optimal);
        assert!((s.makespan - 4.0).abs() < 1e-9);
    }

    #[test]
    fn more_models_than_devices_packs_work() {
        // 3 models x 2 units x 1.0 on 2 devices: total 6, LB = 3;
        // chains of 2 => achievable: d1: A,A,C  d2: B,B,C -> 3.0? C's units
        // must be sequential: C1 at t=2 on d1, C2 at t=3 -> mk 4? or
        // interleave: d1: A1 B1 C2?? Let's trust the solver + LB check.
        let p = prob(&[&[1.0, 1.0], &[1.0, 1.0], &[1.0, 1.0]], 2);
        let s = solve(&p, Duration::from_secs(10), None);
        assert!(s.proven_optimal);
        assert!((s.makespan - 3.0).abs() < 1e-9, "{}", s.makespan);
    }

    #[test]
    fn chain_dominates_when_one_model_is_huge() {
        let p = prob(&[&[10.0, 10.0], &[1.0]], 4);
        let s = solve(&p, Duration::from_secs(5), None);
        assert!(s.proven_optimal);
        assert!((s.makespan - 20.0).abs() < 1e-9);
    }

    #[test]
    fn never_below_lower_bound_randomised() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for _ in 0..25 {
            let t = rng.range_u64(1, 4) as usize;
            let d = rng.range_u64(1, 3) as usize;
            let units: Vec<Vec<f64>> = (0..t)
                .map(|_| {
                    (0..rng.range_u64(1, 4))
                        .map(|_| rng.range_f64(0.5, 3.0))
                        .collect()
                })
                .collect();
            let p = Problem { units, devices: d };
            let s = solve(&p, Duration::from_secs(2), None);
            assert!(
                s.makespan >= p.lower_bound() - 1e-6,
                "makespan {} < lb {}",
                s.makespan,
                p.lower_bound()
            );
        }
    }

    #[test]
    fn incumbent_bounds_result() {
        let p = prob(&[&[1.0, 1.0], &[1.0, 1.0]], 1);
        // feasible: 4.0 total work on 1 device
        let s = solve(&p, Duration::from_secs(5), Some(4.0));
        assert!((s.makespan - 4.0).abs() < 1e-9);
    }

    #[test]
    fn timeout_returns_incumbent_not_worse() {
        // big instance, zero budget: must return the warm-start incumbent
        let units: Vec<Vec<f64>> = (0..10).map(|_| vec![1.0; 50]).collect();
        let p = Problem { units, devices: 3 };
        let s = solve(&p, Duration::from_millis(0), Some(500.0));
        assert!(s.makespan <= 500.0 + 1e-9);
    }
}
