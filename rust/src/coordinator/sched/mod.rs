//! Scheduling policies for SHARP (§4.7).
//!
//! A scheduler is consulted whenever a device frees up: it picks one model
//! from the *eligible* set (front-of-queue, not running elsewhere, arrival
//! time passed). The engine enforces all MILP constraints (sequential order
//! per model, device isolation) and — in the online setting — keeps
//! not-yet-arrived and cancelled jobs out of the eligible set, so policies
//! stay correct under dynamic arrivals without any changes: they only ever
//! order what is runnable *now*.

pub mod bnb;

use std::fmt;
use std::str::FromStr;

use crate::coordinator::task::ModelSnapshot;
use crate::error::HydraError;
use crate::util::rng::Rng;

/// Context a policy may use when picking (device affinity etc.).
#[derive(Debug, Clone, Copy)]
pub struct PickContext<'a> {
    /// Virtual time of the decision.
    pub now: f64,
    /// Device the unit would run on.
    pub device: usize,
    /// Compute speed of that device relative to the reference GPU the unit
    /// costs were calibrated on (1.0 on homogeneous pools). Lets
    /// heterogeneity-aware policies prefer fast devices for long jobs.
    pub speed: f64,
    /// (model, shard) already resident on this device, if any — lets
    /// affinity-aware policies exploit the §4.6 no-move bonus.
    pub resident: Option<&'a [(usize, u32)]>,
    /// Accrued GPU-seconds per tenant (indexed by tenant id), maintained by
    /// the engine as compute intervals are charged. [`WeightedFair`] orders
    /// by virtual finish time over this slice; tenants past the end of the
    /// slice (or a `None` slice) have accrued nothing yet.
    pub tenant_gpu_secs: Option<&'a [f64]>,
}

/// A scheduling policy. Returns an index into `eligible`, or None to leave
/// the device idle (no policy in this crate ever does when work exists).
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;
    fn pick(
        &mut self,
        eligible: &[ModelSnapshot],
        ctx: PickContext<'_>,
        rng: &mut Rng,
    ) -> Option<usize>;
}

// ---------------------------------------------------------------------------
// Sharded-LRTF — Algorithm 2, the paper's scheduler
// ---------------------------------------------------------------------------

/// Sharded Longest-Remaining-Time-First: pick the eligible model with the
/// largest total remaining train time. O(|eligible|) per decision; the
/// remaining-time values themselves are maintained incrementally by
/// `ModelTask::retire`, so there is no per-decision recomputation.
#[derive(Debug, Default)]
pub struct ShardedLrtf;

impl Scheduler for ShardedLrtf {
    fn name(&self) -> &'static str {
        "sharded-lrtf"
    }

    fn pick(
        &mut self,
        eligible: &[ModelSnapshot],
        _ctx: PickContext<'_>,
        _rng: &mut Rng,
    ) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, m) in eligible.iter().enumerate() {
            match best {
                // ties broken by lower model id for determinism
                Some((_, t)) if m.remaining_time <= t => {}
                _ => best = Some((i, m.remaining_time)),
            }
        }
        best.map(|(i, _)| i)
    }
}

// ---------------------------------------------------------------------------
// Baseline policies (Fig 7 comparisons + extras)
// ---------------------------------------------------------------------------

/// Uniform random choice among eligible models (paper's "Random").
#[derive(Debug, Default)]
pub struct RandomSched;

impl Scheduler for RandomSched {
    fn name(&self) -> &'static str {
        "random"
    }

    fn pick(
        &mut self,
        eligible: &[ModelSnapshot],
        _ctx: PickContext<'_>,
        rng: &mut Rng,
    ) -> Option<usize> {
        if eligible.is_empty() {
            None
        } else {
            Some(rng.below(eligible.len() as u64) as usize)
        }
    }
}

/// First-come-first-served: earliest arrival first, model id as the
/// deterministic tie-break. For batch workloads every arrival is 0.0, so
/// this reduces to the seed behaviour (lowest id); under online Poisson
/// traffic it is true submission-order FIFO.
#[derive(Debug, Default)]
pub struct FifoSched;

impl Scheduler for FifoSched {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(
        &mut self,
        eligible: &[ModelSnapshot],
        _ctx: PickContext<'_>,
        _rng: &mut Rng,
    ) -> Option<usize> {
        let mut best: Option<(usize, f64, usize)> = None;
        for (i, m) in eligible.iter().enumerate() {
            let better = match best {
                None => true,
                Some((_, t, id)) => {
                    m.arrival < t || (m.arrival == t && m.id < id)
                }
            };
            if better {
                best = Some((i, m.arrival, m.id));
            }
        }
        best.map(|(i, _, _)| i)
    }
}

/// Shortest-Remaining-Time-First (the classic makespan anti-pattern here —
/// kept as an ablation showing *why* LRTF's ordering matters in §4.7.2's
/// case-degradation argument).
#[derive(Debug, Default)]
pub struct SrtfSched;

impl Scheduler for SrtfSched {
    fn name(&self) -> &'static str {
        "srtf"
    }

    fn pick(
        &mut self,
        eligible: &[ModelSnapshot],
        _ctx: PickContext<'_>,
        _rng: &mut Rng,
    ) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, m) in eligible.iter().enumerate() {
            match best {
                Some((_, t)) if m.remaining_time >= t => {}
                _ => best = Some((i, m.remaining_time)),
            }
        }
        best.map(|(i, _)| i)
    }
}

/// LRTF with device affinity: prefer a model whose front shard is already
/// resident on this device (§4.6 no-transfer bonus), falling back to plain
/// LRTF. An extension beyond the paper, benchmarked in the ablations.
#[derive(Debug, Default)]
pub struct AffinityLrtf;

impl Scheduler for AffinityLrtf {
    fn name(&self) -> &'static str {
        "affinity-lrtf"
    }

    fn pick(
        &mut self,
        eligible: &[ModelSnapshot],
        ctx: PickContext<'_>,
        rng: &mut Rng,
    ) -> Option<usize> {
        if let Some(resident) = ctx.resident {
            let mut best: Option<(usize, f64)> = None;
            for (i, m) in eligible.iter().enumerate() {
                if resident.contains(&(m.id, m.front_shard)) {
                    match best {
                        Some((_, t)) if m.remaining_time <= t => {}
                        _ => best = Some((i, m.remaining_time)),
                    }
                }
            }
            if let Some((i, _)) = best {
                return Some(i);
            }
        }
        ShardedLrtf.pick(eligible, ctx, rng)
    }
}

// ---------------------------------------------------------------------------
// Weighted fair queueing over accumulated GPU-seconds per tenant
// ---------------------------------------------------------------------------

/// Weighted fair queueing: pick the eligible job with the smallest *virtual
/// finish time* `(accrued_gpu_secs[tenant] + front_cost) / weight`, ties
/// broken by lower job id for determinism.
///
/// The accrued-GPU-seconds slice in [`PickContext`] is the per-tenant
/// virtual clock: a tenant that has consumed more than its weighted share
/// carries a later virtual time, so its jobs lose ties against starved
/// tenants until the shares re-converge. Jobs without tenant metadata all
/// sit in tenant 0 with weight 1.0, where the ordering degenerates to
/// cheapest-front-unit-first with FIFO-by-id ties.
#[derive(Debug, Default)]
pub struct WeightedFair;

impl Scheduler for WeightedFair {
    fn name(&self) -> &'static str {
        "weighted-fair"
    }

    fn pick(
        &mut self,
        eligible: &[ModelSnapshot],
        ctx: PickContext<'_>,
        _rng: &mut Rng,
    ) -> Option<usize> {
        let accrued = |tenant: usize| -> f64 {
            ctx.tenant_gpu_secs
                .and_then(|a| a.get(tenant))
                .copied()
                .unwrap_or(0.0)
        };
        let mut best: Option<(usize, f64, usize)> = None;
        for (i, m) in eligible.iter().enumerate() {
            let vft = (accrued(m.tenant) + m.front_cost) / m.weight;
            let better = match best {
                None => true,
                Some((_, v, id)) => vft < v || (vft == v && m.id < id),
            };
            if better {
                best = Some((i, vft, m.id));
            }
        }
        best.map(|(i, _, _)| i)
    }
}

// ---------------------------------------------------------------------------
// Typed policy surface
// ---------------------------------------------------------------------------

/// The scheduling policies this crate ships, as a typed enum — the
/// [`crate::session::Session`] builder's `.policy(..)` argument and the only
/// place scheduler names are spelled out. String surfaces (CLI flags, JSON
/// specs) parse through [`Policy::from_str`]; everything downstream carries
/// the enum.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Policy {
    /// Sharded Longest-Remaining-Time-First (Algorithm 2, the default).
    #[default]
    ShardedLrtf,
    /// LRTF with §4.6 device-affinity tie-break (extension).
    AffinityLrtf,
    /// First-come-first-served by true arrival time.
    Fifo,
    /// Shortest-Remaining-Time-First (anti-pattern ablation).
    Srtf,
    /// Uniform random choice (paper baseline).
    Random,
    /// Weighted fair queueing over accumulated per-tenant GPU-seconds
    /// (multi-tenant extension).
    WeightedFair,
}

impl Policy {
    /// Every policy, in presentation order (round-trip tested against
    /// [`Policy::from_str`]).
    pub const ALL: [Policy; 6] = [
        Policy::ShardedLrtf,
        Policy::AffinityLrtf,
        Policy::Fifo,
        Policy::Srtf,
        Policy::Random,
        Policy::WeightedFair,
    ];

    /// Canonical name (matches `Scheduler::name` of the built instance).
    pub fn name(self) -> &'static str {
        match self {
            Policy::ShardedLrtf => "sharded-lrtf",
            Policy::AffinityLrtf => "affinity-lrtf",
            Policy::Fifo => "fifo",
            Policy::Srtf => "srtf",
            Policy::Random => "random",
            Policy::WeightedFair => "weighted-fair",
        }
    }

    /// Instantiate the scheduler this policy names.
    pub fn build(self) -> Box<dyn Scheduler> {
        match self {
            Policy::ShardedLrtf => Box::new(ShardedLrtf),
            Policy::AffinityLrtf => Box::new(AffinityLrtf),
            Policy::Fifo => Box::new(FifoSched),
            Policy::Srtf => Box::new(SrtfSched),
            Policy::Random => Box::new(RandomSched),
            Policy::WeightedFair => Box::new(WeightedFair),
        }
    }
}

impl fmt::Display for Policy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // pad() (not write_str) so width/alignment specifiers work in the
        // figure tables
        f.pad(self.name())
    }
}

impl FromStr for Policy {
    type Err = HydraError;

    /// The one string->policy shim: accepts every canonical name plus the
    /// historical `"lrtf"` alias.
    fn from_str(s: &str) -> Result<Policy, HydraError> {
        match s {
            "sharded-lrtf" | "lrtf" => Ok(Policy::ShardedLrtf),
            "affinity-lrtf" => Ok(Policy::AffinityLrtf),
            "fifo" => Ok(Policy::Fifo),
            "srtf" => Ok(Policy::Srtf),
            "random" => Ok(Policy::Random),
            "weighted-fair" | "wfq" => Ok(Policy::WeightedFair),
            other => Err(HydraError::Config(format!(
                "unknown scheduler {other:?} (expected one of: sharded-lrtf, \
                 affinity-lrtf, fifo, srtf, random, weighted-fair)"
            ))),
        }
    }
}

/// Construct a policy by name. Legacy shim over [`Policy::from_str`] +
/// [`Policy::build`] — new code should parse a [`Policy`] and carry the enum.
pub fn by_name(name: &str) -> Option<Box<dyn Scheduler>> {
    name.parse::<Policy>().ok().map(Policy::build)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::unit::Phase;

    fn snap(id: usize, remaining: f64) -> ModelSnapshot {
        ModelSnapshot {
            id,
            remaining_time: remaining,
            remaining_units: 10,
            front_cost: 1.0,
            front_shard: 0,
            front_phase: Phase::Fwd,
            arrival: 0.0,
            tenant: 0,
            weight: 1.0,
        }
    }

    fn ctx() -> PickContext<'static> {
        PickContext {
            now: 0.0,
            device: 0,
            speed: 1.0,
            resident: None,
            tenant_gpu_secs: None,
        }
    }

    #[test]
    fn lrtf_picks_longest() {
        let mut s = ShardedLrtf;
        let es = [snap(0, 5.0), snap(1, 9.0), snap(2, 3.0)];
        assert_eq!(s.pick(&es, ctx(), &mut Rng::new(0)), Some(1));
    }

    #[test]
    fn lrtf_breaks_ties_by_lower_id() {
        let mut s = ShardedLrtf;
        let es = [snap(3, 5.0), snap(1, 5.0)];
        // first index with strictly greater time wins; ties keep earlier
        assert_eq!(s.pick(&es, ctx(), &mut Rng::new(0)), Some(0));
    }

    #[test]
    fn srtf_picks_shortest() {
        let mut s = SrtfSched;
        let es = [snap(0, 5.0), snap(1, 9.0), snap(2, 3.0)];
        assert_eq!(s.pick(&es, ctx(), &mut Rng::new(0)), Some(2));
    }

    #[test]
    fn fifo_picks_lowest_id() {
        let mut s = FifoSched;
        let es = [snap(7, 5.0), snap(2, 9.0), snap(9, 3.0)];
        assert_eq!(s.pick(&es, ctx(), &mut Rng::new(0)), Some(1));
    }

    #[test]
    fn fifo_orders_by_arrival_before_id() {
        let mut s = FifoSched;
        let mut a = snap(7, 5.0);
        a.arrival = 1.0;
        let mut b = snap(2, 9.0);
        b.arrival = 4.0;
        // id 7 arrived first: true FIFO must pick it over the lower id
        assert_eq!(s.pick(&[a, b], ctx(), &mut Rng::new(0)), Some(0));
    }

    #[test]
    fn random_is_deterministic_per_seed_and_in_range() {
        let mut s = RandomSched;
        let es = [snap(0, 1.0), snap(1, 1.0), snap(2, 1.0)];
        let picks1: Vec<_> = (0..10)
            .map(|i| s.pick(&es, ctx(), &mut Rng::new(i)).unwrap())
            .collect();
        let picks2: Vec<_> = (0..10)
            .map(|i| s.pick(&es, ctx(), &mut Rng::new(i)).unwrap())
            .collect();
        assert_eq!(picks1, picks2);
        assert!(picks1.iter().all(|&p| p < 3));
        assert!(picks1.iter().any(|&p| p != picks1[0])); // some variety
    }

    fn tenant_snap(id: usize, tenant: usize, weight: f64, cost: f64) -> ModelSnapshot {
        let mut s = snap(id, 10.0);
        s.tenant = tenant;
        s.weight = weight;
        s.front_cost = cost;
        s
    }

    #[test]
    fn wfq_picks_smallest_virtual_finish_time() {
        let mut s = WeightedFair;
        // tenant 0 has burned 30 GPU-s, tenant 1 only 2: tenant 1 is owed
        let accrued = [30.0, 2.0];
        let c = PickContext {
            now: 0.0,
            device: 0,
            speed: 1.0,
            resident: None,
            tenant_gpu_secs: Some(&accrued),
        };
        let es = [tenant_snap(0, 0, 1.0, 1.0), tenant_snap(1, 1, 1.0, 1.0)];
        assert_eq!(s.pick(&es, c, &mut Rng::new(0)), Some(1));
    }

    #[test]
    fn wfq_weight_scales_the_virtual_clock() {
        let mut s = WeightedFair;
        // both tenants at 10 accrued GPU-s, but tenant 0 carries weight 10:
        // its virtual time (10+1)/10 = 1.1 beats tenant 1's (10+1)/1 = 11
        let accrued = [10.0, 10.0];
        let c = PickContext {
            now: 0.0,
            device: 0,
            speed: 1.0,
            resident: None,
            tenant_gpu_secs: Some(&accrued),
        };
        let es = [tenant_snap(3, 1, 1.0, 1.0), tenant_snap(5, 0, 10.0, 1.0)];
        assert_eq!(s.pick(&es, c, &mut Rng::new(0)), Some(1));
    }

    #[test]
    fn wfq_ties_break_by_lower_job_id() {
        let mut s = WeightedFair;
        // identical tenants, weights and costs -> lowest id wins
        let es = [tenant_snap(9, 0, 1.0, 2.0), tenant_snap(4, 0, 1.0, 2.0)];
        assert_eq!(s.pick(&es, ctx(), &mut Rng::new(0)), Some(1));
    }

    #[test]
    fn wfq_without_accrual_slice_treats_tenants_as_fresh() {
        let mut s = WeightedFair;
        // no slice: every tenant's clock is 0, cheaper front unit wins
        let es = [tenant_snap(0, 2, 1.0, 5.0), tenant_snap(1, 7, 1.0, 1.0)];
        assert_eq!(s.pick(&es, ctx(), &mut Rng::new(0)), Some(1));
    }

    #[test]
    fn empty_eligible_returns_none() {
        for name in
            ["sharded-lrtf", "random", "fifo", "srtf", "affinity-lrtf", "weighted-fair"]
        {
            let mut s = by_name(name).unwrap();
            assert_eq!(s.pick(&[], ctx(), &mut Rng::new(0)), None, "{name}");
        }
    }

    #[test]
    fn affinity_prefers_resident_shard() {
        let mut s = AffinityLrtf;
        let es = [snap(0, 9.0), snap(1, 2.0)];
        let resident = [(1usize, 0u32)];
        let c = PickContext {
            now: 0.0,
            device: 0,
            speed: 1.0,
            resident: Some(&resident),
            tenant_gpu_secs: None,
        };
        assert_eq!(s.pick(&es, c, &mut Rng::new(0)), Some(1));
        // without residency info falls back to LRTF
        assert_eq!(s.pick(&es, ctx(), &mut Rng::new(0)), Some(0));
    }

    #[test]
    fn by_name_rejects_unknown() {
        assert!(by_name("gurobi").is_none());
    }

    #[test]
    fn policy_roundtrips_and_matches_scheduler_names() {
        for p in Policy::ALL {
            assert_eq!(p.name().parse::<Policy>().unwrap(), p);
            assert_eq!(p.to_string(), p.name());
            assert_eq!(p.build().name(), p.name());
        }
        assert_eq!("lrtf".parse::<Policy>().unwrap(), Policy::ShardedLrtf);
        assert!("gurobi".parse::<Policy>().is_err());
    }
}
