//! SHARP — Shard Alternator Parallelism (§4.4): the event-driven engine
//! that blends the shard-unit queues of many models over a pool of devices.
//!
//! The engine runs in *virtual time*: every decision (eligibility, memory
//! promotion/demotion, double-buffer prefetch, stalls) is identical whether
//! the execution backend is the discrete-event cost model (`SimBackend`) or
//! the real PJRT runtime (`RealBackend`, which reports measured wallclock as
//! the unit duration). That is what lets one engine both *reproduce the
//! paper's figures* at 8-GPU scale and *actually train* models on this
//! machine (DESIGN.md §1).
//!
//! Invariants enforced here (and property-tested in rust/tests):
//!   1. sequential order of a model's shard units (MILP constraint (a)),
//!   2. device isolation — one unit per device at a time (b, c),
//!   3. model isolation — one in-flight unit per model,
//!   4. ledgers never exceed device capacity,
//!   5. every unit executes exactly once.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::coordinator::buffer::DoubleBuffer;
use crate::coordinator::memory::{DeviceLedger, DramPool, Residency};
use crate::coordinator::metrics::{Interval, IntervalKind, Trace};
use crate::coordinator::sched::{PickContext, Scheduler};
use crate::coordinator::task::{ModelSnapshot, ModelTask, TaskState};
use crate::coordinator::unit::{Phase, ShardUnit};
use crate::error::{HydraError, Result};
use crate::exec::ExecutionBackend;
use crate::util::rng::Rng;

/// Link cost model for DRAM<->device transfers (PCIe class by default).
#[derive(Debug, Clone, Copy)]
pub struct TransferModel {
    pub bandwidth_bytes_per_sec: f64,
    pub latency_secs: f64,
}

impl TransferModel {
    pub fn pcie_gen3() -> TransferModel {
        TransferModel { bandwidth_bytes_per_sec: 12.0e9, latency_secs: 20e-6 }
    }

    /// Instantaneous transfers (pure-scheduling studies, Fig 7).
    pub fn zero_cost() -> TransferModel {
        TransferModel { bandwidth_bytes_per_sec: f64::INFINITY, latency_secs: 0.0 }
    }

    pub fn secs(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.latency_secs + bytes as f64 / self.bandwidth_bytes_per_sec
        }
    }
}

/// Parallelism mode: SHARP blending vs the spilling-only ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelMode {
    /// Full SHARP: all idle models are eligible on any free device.
    Sharp,
    /// Ablation (Table 3 "without SHARP"): models run one-after-another;
    /// only the lowest-id unfinished model is ever eligible, so sequential
    /// shard dependencies leave at most one device busy.
    Sequential,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    pub mode: ParallelMode,
    pub double_buffer: bool,
    /// Fraction of device memory reserved as the prefetch zone (§4.6).
    pub buffer_frac: f64,
    pub transfer: TransferModel,
    pub seed: u64,
    /// Record per-interval trace entries (disable for very long sims to
    /// bound memory; aggregates are still collected).
    pub record_intervals: bool,
    /// Paper-fidelity mode: spilling moves the *full* shard state (weights +
    /// gradients + optimizer state) instead of weights-only. Hydra's default
    /// (false) keeps optimizer state in DRAM with a Rust-side update — the
    /// same design the real backend implements — which shrinks transfer
    /// volume ~3x. Used by the Table 3 ablation to recover the paper's
    /// no-double-buffering penalty.
    pub full_state_transfers: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            mode: ParallelMode::Sharp,
            double_buffer: true,
            buffer_frac: 0.05,
            transfer: TransferModel::pcie_gen3(),
            seed: 0,
            record_intervals: true,
            full_state_transfers: false,
        }
    }
}

/// A fault-injection / elasticity event (§4.7's dynamic setting).
#[derive(Debug, Clone, Copy)]
pub enum ClusterEvent {
    /// Device joins at `time` with the given memory capacity.
    Arrive { time: f64, mem_bytes: u64 },
    /// Device `device` is lost at `time` (takes effect when its in-flight
    /// unit retires; the unit itself completes — fail-stop between units).
    Fail { time: f64, device: usize },
}

#[derive(Debug)]
struct DeviceState {
    id: usize,
    ledger: DeviceLedger,
    buffer: DoubleBuffer,
    /// (model, shard) whose parameters are resident from the previous unit.
    resident: Option<(usize, u32)>,
    /// Unit pre-claimed for this device by the double-buffer path.
    pending: Option<ShardUnit>,
    alive: bool,
    /// Set while a unit is in flight.
    busy: bool,
    fail_pending: bool,
    /// Bytes that flow back to DRAM when the resident shard is evicted.
    last_demote_bytes: u64,
}

/// Totally ordered f64 key for the event heap.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Key(f64);

impl Eq for Key {}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[derive(Debug, Clone, Copy)]
enum Event {
    /// A device finished its unit (or is ready at start-up).
    DeviceFree { device: usize },
    /// The unit on `device` retires at this time; model becomes idle.
    UnitRetire { device: usize, unit: ShardUnit },
    Cluster(usize), // index into the cluster-event list
}

/// Result summary of an engine run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub trace: Trace,
    pub makespan: f64,
    pub utilization: f64,
    pub compute_secs: f64,
    pub transfer_secs: f64,
    pub stall_secs: f64,
    pub units_executed: u64,
    pub promoted_bytes: u64,
    pub demoted_bytes: u64,
    pub scheduler: &'static str,
}

/// The SHARP engine.
pub struct SharpEngine<'a> {
    pub tasks: Vec<ModelTask>,
    devices: Vec<DeviceState>,
    dram: DramPool,
    options: EngineOptions,
    scheduler: Box<dyn Scheduler>,
    backend: &'a mut dyn ExecutionBackend,
    cluster_events: Vec<ClusterEvent>,
    // run state
    heap: BinaryHeap<Reverse<(Key, u64, usize)>>, // (time, seq, event idx)
    events: Vec<Event>,
    seq: u64,
    trace: Trace,
    units_executed: u64,
    agg_compute: f64,
    agg_transfer: f64,
    agg_stall: f64,
    rng: Rng,
}

impl<'a> SharpEngine<'a> {
    pub fn new(
        tasks: Vec<ModelTask>,
        device_mem: &[u64],
        dram_bytes: u64,
        scheduler: Box<dyn Scheduler>,
        backend: &'a mut dyn ExecutionBackend,
        options: EngineOptions,
    ) -> Result<SharpEngine<'a>> {
        if device_mem.is_empty() {
            return Err(HydraError::Config("no devices".into()));
        }
        let mut dram = DramPool::new(dram_bytes);
        for t in &tasks {
            dram.home(t.total_param_bytes())?;
        }
        let mut devices = Vec::new();
        for (id, &mem) in device_mem.iter().enumerate() {
            devices.push(Self::mk_device(id, mem, &options)?);
        }
        let rng = Rng::new(options.seed);
        Ok(SharpEngine {
            tasks,
            devices,
            dram,
            options,
            scheduler,
            backend,
            cluster_events: Vec::new(),
            heap: BinaryHeap::new(),
            events: Vec::new(),
            seq: 0,
            trace: Trace::default(),
            units_executed: 0,
            agg_compute: 0.0,
            agg_transfer: 0.0,
            agg_stall: 0.0,
            rng,
        })
    }

    fn mk_device(id: usize, mem: u64, options: &EngineOptions) -> Result<DeviceState> {
        let mut ledger = DeviceLedger::new(id, mem);
        let zone = (mem as f64 * options.buffer_frac) as u64;
        let buffer = DoubleBuffer::new(options.double_buffer, zone, &mut ledger)?;
        Ok(DeviceState {
            id,
            ledger,
            buffer,
            resident: None,
            pending: None,
            alive: true,
            busy: false,
            fail_pending: false,
            last_demote_bytes: 0,
        })
    }

    /// Register arrival/failure events before `run`.
    pub fn with_cluster_events(mut self, events: Vec<ClusterEvent>) -> Self {
        self.cluster_events = events;
        self
    }

    fn push_event(&mut self, time: f64, ev: Event) {
        let idx = self.events.len();
        self.events.push(ev);
        self.heap.push(Reverse((Key(time), self.seq, idx)));
        self.seq += 1;
    }

    /// Eligible model snapshots under the current parallel mode.
    fn eligible(&self) -> Vec<ModelSnapshot> {
        match self.options.mode {
            ParallelMode::Sharp => self
                .tasks
                .iter()
                .filter_map(ModelSnapshot::of)
                .collect(),
            ParallelMode::Sequential => {
                // only the lowest-id unfinished model may run
                for t in &self.tasks {
                    if t.state() != TaskState::Done {
                        return ModelSnapshot::of(t).into_iter().collect();
                    }
                }
                Vec::new()
            }
        }
    }

    /// Run to completion; returns the report.
    pub fn run(&mut self) -> Result<RunReport> {
        for d in 0..self.devices.len() {
            self.trace.set_device_window(d, 0.0, f64::INFINITY);
            self.push_event(0.0, Event::DeviceFree { device: d });
        }
        for (i, ev) in self.cluster_events.clone().into_iter().enumerate() {
            let time = match ev {
                ClusterEvent::Arrive { time, .. } | ClusterEvent::Fail { time, .. } => time,
            };
            self.push_event(time, Event::Cluster(i));
        }

        while let Some(Reverse((Key(now), _, idx))) = self.heap.pop() {
            match self.events[idx] {
                Event::DeviceFree { device } => self.on_device_free(device, now)?,
                Event::UnitRetire { device, unit } => self.on_unit_retire(device, unit, now)?,
                Event::Cluster(i) => self.on_cluster_event(i, now)?,
            }
        }

        // Sanity: every task finished (unless devices all died).
        let alive = self.devices.iter().any(|d| d.alive);
        let done = self.tasks.iter().all(|t| t.state() == TaskState::Done);
        if alive && !done {
            return Err(HydraError::Sched(
                "engine drained events with unfinished tasks".into(),
            ));
        }

        self.trace.close_device_windows();
        let device_secs = self.trace.device_seconds();
        let utilization =
            if device_secs > 0.0 { self.agg_compute / device_secs } else { 0.0 };
        Ok(RunReport {
            makespan: self.trace.makespan,
            utilization,
            compute_secs: self.agg_compute,
            transfer_secs: self.agg_transfer,
            stall_secs: self.agg_stall,
            units_executed: self.units_executed,
            promoted_bytes: self.dram.promoted_bytes,
            demoted_bytes: self.dram.demoted_bytes,
            scheduler: self.scheduler.name(),
            trace: std::mem::take(&mut self.trace),
        })
    }

    fn on_cluster_event(&mut self, i: usize, now: f64) -> Result<()> {
        match self.cluster_events[i] {
            ClusterEvent::Arrive { mem_bytes, .. } => {
                let id = self.devices.len();
                self.devices.push(Self::mk_device(id, mem_bytes, &self.options)?);
                self.trace.set_device_window(id, now, f64::INFINITY);
                self.push_event(now, Event::DeviceFree { device: id });
            }
            ClusterEvent::Fail { device, .. } => {
                if device < self.devices.len() && self.devices[device].alive {
                    if self.devices[device].busy {
                        // fail-stop between units: take effect on retire
                        self.devices[device].fail_pending = true;
                    } else {
                        self.kill_device(device, now);
                    }
                }
            }
        }
        Ok(())
    }

    fn kill_device(&mut self, device: usize, now: f64) {
        let pending = self.devices[device].pending.take();
        self.devices[device].alive = false;
        self.devices[device].buffer.clear();
        self.devices[device].resident = None;
        if let Some(u) = pending {
            // return the pre-claimed unit to its model's queue
            self.tasks[u.model].unclaim(&u);
        }
        let start = self.trace.device_windows.get(&device).map(|w| w.0).unwrap_or(0.0);
        self.trace.set_device_window(device, start, now);
        // pre-claimed model may now be runnable elsewhere
        self.wake_idle_devices(now);
    }

    /// Wake every idle live device (a model may have become eligible).
    fn wake_idle_devices(&mut self, now: f64) {
        let idle: Vec<usize> = self
            .devices
            .iter()
            .filter(|d| d.alive && !d.busy)
            .map(|d| d.id)
            .collect();
        for d in idle {
            self.push_event(now, Event::DeviceFree { device: d });
        }
    }

    fn on_device_free(&mut self, device: usize, now: f64) -> Result<()> {
        if !self.devices[device].alive || self.devices[device].busy {
            return Ok(());
        }
        // 1. a pre-claimed (double-buffered) unit takes priority
        let unit = if let Some(u) = self.devices[device].pending.take() {
            Some(u)
        } else {
            let eligible = self.eligible();
            let resident: Vec<(usize, u32)> =
                self.devices[device].resident.into_iter().collect();
            let ctx = PickContext { now, device, resident: Some(&resident) };
            match self.scheduler.pick(&eligible, ctx, &mut self.rng) {
                Some(i) => {
                    let id = eligible[i].id;
                    Some(self.tasks[id].claim_front())
                }
                None => None, // idle until a retire wakes us
            }
        };
        let Some(unit) = unit else { return Ok(()) };
        self.start_unit(device, unit, now)
    }

    /// Promote memory, account transfers/stalls, execute, schedule retire.
    fn start_unit(&mut self, device: usize, unit: ShardUnit, now: f64) -> Result<()> {
        let task_shard = self.tasks[unit.model].shard(unit.shard).clone();
        let mut t = now;

        // --- parameter promotion -----------------------------------------
        let promote_bytes = if self.options.full_state_transfers {
            task_shard.param_bytes
        } else {
            task_shard.transfer_bytes(unit.phase)
        };
        let cached = self.devices[device].resident == Some((unit.model, unit.shard));
        if !cached {
            // demote whatever was resident (a bwd unit's gradients/updated
            // weights flow back; fwd demotion is a discard of clean weights)
            if let Some((m, s)) = self.devices[device].resident.take() {
                self.devices[device]
                    .ledger
                    .release(&Residency::ShardParams { model: m, shard: s });
                let wb = self.devices[device].last_demote_bytes;
                self.dram.note_demote(wb);
                if !self.options.double_buffer && wb > 0 {
                    // synchronous write-back (no overlap without DB)
                    let dt = self.options.transfer.secs(wb);
                    self.record(device, t, t + dt, unit, IntervalKind::Transfer);
                    t += dt;
                }
            }
            // promote: either consume the prefetched copy or transfer now
            let stall = self.devices[device]
                .buffer
                .consume(unit.model, unit.shard, t);
            let dt = match stall {
                Some(stall) => {
                    if stall > 0.0 {
                        self.record(device, t, t + stall, unit, IntervalKind::BufferStall);
                    }
                    stall
                }
                None => {
                    let dt = self.options.transfer.secs(promote_bytes);
                    if dt > 0.0 {
                        self.record(device, t, t + dt, unit, IntervalKind::Transfer);
                    }
                    dt
                }
            };
            t += dt;
            self.dram.note_promote(promote_bytes);
            self.devices[device]
                .ledger
                .alloc(
                    Residency::ShardParams { model: unit.model, shard: unit.shard },
                    task_shard.param_bytes,
                )?;
            self.devices[device].resident = Some((unit.model, unit.shard));
        }
        // what flows back to DRAM when this residency is evicted: bwd units
        // produce gradients/updated weights; fwd residency is clean
        self.devices[device].last_demote_bytes = if self.options.full_state_transfers {
            task_shard.param_bytes
        } else {
            match unit.phase {
                Phase::Bwd => task_shard.bwd_transfer_bytes,
                Phase::Fwd => 0,
            }
        };

        // --- boundary activation ------------------------------------------
        // Needed unless this model's previous unit ran on this device and the
        // checkpoint never left (§4.6 bonus). We approximate with: cached
        // shard => activation also local (fwd+bwd pairs share the device).
        let needs_act = unit.shard > 0 || unit.phase == Phase::Bwd;
        if needs_act && !cached {
            let dt = self.options.transfer.secs(task_shard.activation_bytes);
            if dt > 0.0 {
                self.record(device, t, t + dt, unit, IntervalKind::Transfer);
                t += dt;
            }
        }
        self.devices[device]
            .ledger
            .alloc(Residency::Activation { model: unit.model }, 2 * task_shard.activation_bytes)?;

        // --- execute -------------------------------------------------------
        let dur = self.backend.execute_unit(&self.tasks[unit.model], &unit)?;
        self.devices[device].busy = true;
        self.record(device, t, t + dur, unit, IntervalKind::Compute);
        let end = t + dur;

        // --- double-buffer prefetch of the *next* unit ----------------------
        if self.options.double_buffer {
            self.try_stage_prefetch(device, t);
        }

        self.push_event(end, Event::UnitRetire { device, unit });
        Ok(())
    }

    /// While `device` computes, pick and claim the next unit for it and
    /// start the prefetch transfer into the buffer zone (§4.6: "the
    /// Scheduler is actually picking shard units for double-buffering").
    fn try_stage_prefetch(&mut self, device: usize, now: f64) {
        if self.devices[device].pending.is_some() || self.devices[device].fail_pending {
            return;
        }
        // Don't steal an eligible model from a device that could run it
        // *right now* — prefetching is only a win when every device is busy
        // (claiming for the buffer would otherwise serialise work that task
        // parallelism would run immediately).
        if self.devices.iter().any(|d| d.alive && !d.busy) {
            return;
        }
        let eligible = self.eligible();
        if eligible.is_empty() {
            return;
        }
        let resident: Vec<(usize, u32)> =
            self.devices[device].resident.into_iter().collect();
        let ctx = PickContext { now, device, resident: Some(&resident) };
        let Some(i) = self.scheduler.pick(&eligible, ctx, &mut self.rng) else {
            return;
        };
        let id = eligible[i].id;
        let unit = self.tasks[id].claim_front();
        let bytes = if self.options.full_state_transfers {
            self.tasks[id].shard(unit.shard).param_bytes
        } else {
            self.tasks[id].shard(unit.shard).transfer_bytes(unit.phase)
        };
        // only stage what fits the protected zone; otherwise fall back to a
        // synchronous transfer at start time (consume returns None then)
        if bytes <= self.devices[device].buffer.zone_bytes {
            let dt = self.options.transfer.secs(bytes);
            self.devices[device].buffer.stage(id, unit.shard, bytes, now, dt);
        }
        self.devices[device].pending = Some(unit);
    }

    fn on_unit_retire(&mut self, device: usize, unit: ShardUnit, now: f64) -> Result<()> {
        self.units_executed += 1;
        self.devices[device].busy = false;
        self.devices[device]
            .ledger
            .release(&Residency::Activation { model: unit.model });
        self.tasks[unit.model].retire(&unit);
        self.backend.on_unit_retired(&self.tasks[unit.model], &unit);

        // epoch boundary: last unit of the epoch just retired (training:
        // bwd of shard 0 on the final mini-batch; inference: fwd of the
        // last shard) — give the backend its early-stop vote (§4.7.2)
        let g = self.tasks[unit.model].geometry;
        let epoch_done = unit.minibatch + 1 == g.minibatches_per_epoch
            && match unit.phase {
                Phase::Bwd => unit.shard == 0,
                Phase::Fwd => g.inference_only && unit.shard + 1 == g.n_shards,
            };
        if epoch_done
            && self.tasks[unit.model].state() == TaskState::Idle
            && self.backend.should_early_stop(&self.tasks[unit.model], unit.epoch)
        {
            self.tasks[unit.model].early_stop();
        }

        if self.devices[device].fail_pending {
            self.kill_device(device, now);
        } else {
            self.push_event(now, Event::DeviceFree { device });
        }
        // The retired model is idle again: other idle devices may now have
        // eligible work.
        self.wake_idle_devices(now);
        Ok(())
    }

    fn record(&mut self, device: usize, start: f64, end: f64, unit: ShardUnit, kind: IntervalKind) {
        if end > self.trace.makespan {
            self.trace.makespan = end;
        }
        match kind {
            IntervalKind::Compute => self.agg_compute += end - start,
            IntervalKind::Transfer => self.agg_transfer += end - start,
            IntervalKind::BufferStall => self.agg_stall += end - start,
        }
        if self.options.record_intervals {
            self.trace.record(Interval {
                device,
                start,
                end,
                model: unit.model,
                shard: unit.shard,
                phase: unit.phase,
                unit_seq: unit.seq_idx,
                kind,
            });
        }
    }
}
