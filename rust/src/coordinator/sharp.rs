//! SHARP — Shard Alternator Parallelism (§4.4): the event-driven engine
//! that blends the shard-unit queues of many models over a pool of devices.
//!
//! The engine runs in *virtual time*: every decision (eligibility, memory
//! promotion/demotion, double-buffer prefetch, stalls) is identical whether
//! the execution backend is the discrete-event cost model (`SimBackend`) or
//! the real PJRT runtime (`RealBackend`, which reports measured wallclock as
//! the unit duration). That is what lets one engine both *reproduce the
//! paper's figures* at 8-GPU scale and *actually train* models on this
//! machine (DESIGN.md §1).
//!
//! Beyond the paper's batch setting, the engine is **online and
//! multi-tenant**: jobs carry arrival times ([`ModelTask::with_arrival`]),
//! can be submitted and cancelled while the engine runs ([`JobEvent`]), and
//! devices may be **heterogeneous** ([`DeviceSpec`]: per-device memory,
//! relative compute speed, and host-link bandwidth). Per-job latency
//! statistics come back in [`RunReport::jobs`].
//!
//! Host memory is a tiered [`MemoryHierarchy`]
//! ([`crate::coordinator::memory`]): with an NVMe backing tier configured
//! ([`MemoryOptions`]), model sets larger than DRAM still run — DRAM acts
//! as an evicting cache, DRAM misses stage NVMe->DRAM->HBM (overlapped
//! with compute by the double-buffer when prefetched, synchronous
//! [`IntervalKind::NvmeTransfer`] intervals otherwise), and per-tier
//! traffic lands in [`RunReport::nvme_promoted_bytes`] /
//! [`RunReport::nvme_demoted_bytes`]. Without an NVMe tier the engine is
//! bit-for-bit the legacy two-tier system.
//!
//! The dispatch hot path is incremental: a binary-heap event queue
//! (O(log n) push/pop), a ready-set of eligible models, and a parked-set of
//! idle devices replace the seed engine's linear scans over all devices and
//! all tasks on every decision. Every engine event additionally streams
//! through an [`EngineObserver`] ([`SharpEngine::run_with`]): trace
//! bookkeeping is just one observer impl, and live progress/gantt streaming
//! for online runs is another. [`QueueKind::LinearScan`] keeps the O(n)
//! event-selection discipline available as a reference implementation — the
//! two produce identical schedules (property- and equivalence-tested in
//! rust/tests) because both pop events in (time, submission-order) order.
//!
//! Invariants enforced here (and property-tested in rust/tests):
//!   1. sequential order of a model's shard units (MILP constraint (a)),
//!   2. device isolation — one unit per device at a time (b, c),
//!   3. model isolation — one in-flight unit per model,
//!   4. ledgers never exceed device capacity,
//!   5. every unit executes exactly once (unless its job is cancelled),
//!   6. no unit of a job starts before the job's arrival time.

use std::collections::{BTreeSet, BinaryHeap};

use crate::coordinator::buffer::DoubleBuffer;
use crate::coordinator::memory::{
    DeviceLedger, MemTier, MemoryHierarchy, MemoryOptions, Residency,
};
use crate::coordinator::metrics::{Interval, IntervalKind, Trace};
use crate::coordinator::observer::{EngineObserver, NoopObserver, Tee, TraceRecorder};
use crate::coordinator::sched::{PickContext, Scheduler};
use crate::coordinator::task::{ModelSnapshot, ModelTask, TaskState};
use crate::coordinator::unit::{Phase, ShardUnit};
use crate::error::{HydraError, Result};
use crate::exec::ExecutionBackend;
use crate::util::rng::Rng;

pub use crate::coordinator::memory::TransferModel;

/// Static description of one accelerator in a (possibly heterogeneous) pool.
///
/// The memory ledger, double-buffer zone sizing, transfer accounting and
/// unit durations are all derived per device from this spec, so mixed pools
/// (e.g. A4000s next to A6000s) schedule correctly: bigger devices get
/// bigger prefetch zones, faster devices retire units sooner, and every
/// transfer is charged against the device's own host link.
#[derive(Debug, Clone, Copy)]
pub struct DeviceSpec {
    /// Usable device memory in bytes (the ledger capacity).
    pub mem_bytes: u64,
    /// Compute speed relative to the reference GPU that calibrated the
    /// `ShardDesc` unit costs (1.0 = the reference itself, 2.0 = twice as
    /// fast). Unit durations are divided by this factor.
    pub speed: f64,
    /// Host-link override for this device; `None` uses
    /// [`EngineOptions::transfer`].
    pub link: Option<TransferModel>,
}

impl DeviceSpec {
    /// A reference-speed device with the engine-wide default link.
    pub fn uniform(mem_bytes: u64) -> DeviceSpec {
        DeviceSpec { mem_bytes, speed: 1.0, link: None }
    }
}

/// Parallelism mode: SHARP blending vs the spilling-only ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelMode {
    /// Full SHARP: all idle models are eligible on any free device.
    Sharp,
    /// Ablation (Table 3 "without SHARP"): models run one-after-another;
    /// only the lowest-id unfinished (arrived) model is ever eligible, so
    /// sequential shard dependencies leave at most one device busy.
    Sequential,
}

/// Event-queue discipline for the engine's virtual-time loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueKind {
    /// Binary min-heap keyed by (time, submission order): O(log n) per
    /// event. The default.
    Heap,
    /// Linear scan for the earliest event: O(n) per event. Kept as the
    /// reference discipline for the heap-equivalence tests and the hotpath
    /// bench; schedules are identical to [`QueueKind::Heap`] by
    /// construction (same key, same tie-break).
    LinearScan,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// SHARP blending vs the sequential ablation.
    pub mode: ParallelMode,
    /// Enable §4.6 double-buffered prefetch.
    pub double_buffer: bool,
    /// Fraction of device memory reserved as the prefetch zone (§4.6).
    pub buffer_frac: f64,
    /// Engine-wide DRAM<->device link (overridable per device via
    /// [`DeviceSpec::link`]).
    pub transfer: TransferModel,
    /// Seed for the engine's RNG stream (Random scheduler etc.).
    pub seed: u64,
    /// Record per-interval trace entries into the report
    /// (`RunReport::trace`). Implemented as an opt-in
    /// [`crate::coordinator::observer::TraceRecorder`] observer, so turning
    /// it off removes the bookkeeping from the hot path entirely (disable
    /// for very long sims to bound memory; scalar aggregates are still
    /// collected).
    pub record_intervals: bool,
    /// Paper-fidelity mode: spilling moves the *full* shard state (weights +
    /// gradients + optimizer state) instead of weights-only. Hydra's default
    /// (false) keeps optimizer state in DRAM with a Rust-side update — the
    /// same design the real backend implements — which shrinks transfer
    /// volume ~3x. Used by the Table 3 ablation to recover the paper's
    /// no-double-buffering penalty.
    pub full_state_transfers: bool,
    /// Event-queue discipline (heap by default; linear scan as reference).
    pub queue: QueueKind,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            mode: ParallelMode::Sharp,
            double_buffer: true,
            buffer_frac: 0.05,
            transfer: TransferModel::pcie_gen3(),
            seed: 0,
            record_intervals: true,
            full_state_transfers: false,
            queue: QueueKind::Heap,
        }
    }
}

/// A fault-injection / elasticity event (§4.7's dynamic setting).
#[derive(Debug, Clone, Copy)]
pub enum ClusterEvent {
    /// Device joins at `time` with the given memory capacity (reference
    /// speed; use [`SharpEngine::with_devices`] for heterogeneous pools
    /// known up front).
    Arrive {
        /// Virtual time the device joins.
        time: f64,
        /// Memory capacity of the joining device.
        mem_bytes: u64,
    },
    /// Device `device` is lost at `time` (takes effect when its in-flight
    /// unit retires; the unit itself completes — fail-stop between units).
    Fail {
        /// Virtual time of the loss.
        time: f64,
        /// Index of the failing device.
        device: usize,
    },
}

/// A tenant-facing job-queue event: submissions and cancellations that take
/// effect *while the engine runs* (the online multi-tenant setting).
///
/// Jobs known up front carry their arrival via [`ModelTask::with_arrival`];
/// `Submit` additionally allows tasks the engine has never seen (e.g. a
/// tenant showing up mid-run), and `Cancel` revokes a job at unit
/// granularity: an in-flight unit completes, everything else is dropped.
#[derive(Debug, Clone)]
pub enum JobEvent {
    /// Submit `task` at `time`. The task's id must equal the number of
    /// tasks the engine will know at that point (construction tasks +
    /// earlier submissions), i.e. ids follow submission order.
    Submit {
        /// Virtual time of the submission.
        time: f64,
        /// The job being submitted.
        task: ModelTask,
    },
    /// Cancel `model` at `time`. Idempotent; cancelling a finished job is a
    /// no-op.
    Cancel {
        /// Virtual time of the cancellation.
        time: f64,
        /// Task id to cancel.
        model: usize,
    },
}

/// Per-job outcome statistics for the online setting.
#[derive(Debug, Clone)]
pub struct JobStat {
    /// Task id.
    pub model: usize,
    /// Task name (tenant-facing tag).
    pub name: String,
    /// Arrival (submission) time.
    pub arrival: f64,
    /// Virtual time the job finished (last unit retired, or the moment a
    /// cancellation took effect). `NaN` if the run ended with the job
    /// unfinished (e.g. every device failed).
    pub finished: f64,
    /// Whether the job was cancelled.
    pub cancelled: bool,
    /// Earliest tenant cancel request, if any was issued — recorded even
    /// when the request was a no-op because the job had already finished
    /// (`cancelled` stays false then). This is how
    /// `Session::cancel_at`-after-completion is observable in the report
    /// instead of vanishing silently.
    pub cancel_requested: Option<f64>,
    /// Units this job actually executed.
    pub units_executed: u64,
}

impl JobStat {
    /// Job latency (finish - arrival), clamped at 0 so a job cancelled
    /// *before* its arrival reports zero rather than a negative latency;
    /// `NaN` for unfinished jobs.
    pub fn latency(&self) -> f64 {
        let l = self.finished - self.arrival;
        // NaN compares false, so unfinished jobs keep their NaN latency
        if l < 0.0 {
            0.0
        } else {
            l
        }
    }
}

#[derive(Debug)]
struct DeviceState {
    spec: DeviceSpec,
    ledger: DeviceLedger,
    buffer: DoubleBuffer,
    /// (model, shard) whose parameters are resident from the previous unit.
    resident: Option<(usize, u32)>,
    /// Unit pre-claimed for this device by the double-buffer path.
    pending: Option<ShardUnit>,
    alive: bool,
    /// Set while a unit is in flight.
    busy: bool,
    fail_pending: bool,
    /// Bytes that flow back to DRAM when the resident shard is evicted.
    last_demote_bytes: u64,
}

#[derive(Debug, Clone, Copy)]
enum Event {
    /// A device finished its unit (or is ready at start-up / was woken).
    DeviceFree { device: usize },
    /// The unit on `device` retires at this time; model becomes idle.
    UnitRetire { device: usize, unit: ShardUnit },
    /// Index into the cluster-event list.
    Cluster(usize),
    /// A construction-time task reaches its arrival time.
    JobArrive { model: usize },
    /// Index into the pending-submission list.
    JobSubmit(usize),
    /// Tenant cancellation of `model`.
    JobCancel { model: usize },
}

/// One queued event. Total order: earliest (time, seq) first; `Ord` is
/// implemented *reversed* so `BinaryHeap` (a max-heap) pops the minimum.
#[derive(Debug, Clone, Copy)]
struct QueuedEvent {
    time: f64,
    seq: u64,
    ev: Event,
}

impl PartialEq for QueuedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time).is_eq() && self.seq == other.seq
    }
}

impl Eq for QueuedEvent {}

impl PartialOrd for QueuedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: the earliest (time, seq) is the heap maximum
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The virtual-time event queue: a binary heap (default) or a linear-scan
/// list with identical pop order, switchable via [`QueueKind`].
#[derive(Debug)]
struct EventQueue {
    kind: QueueKind,
    heap: BinaryHeap<QueuedEvent>,
    list: Vec<QueuedEvent>,
    seq: u64,
}

impl EventQueue {
    fn new(kind: QueueKind) -> EventQueue {
        EventQueue { kind, heap: BinaryHeap::new(), list: Vec::new(), seq: 0 }
    }

    fn push(&mut self, time: f64, ev: Event) {
        let q = QueuedEvent { time, seq: self.seq, ev };
        self.seq += 1;
        match self.kind {
            QueueKind::Heap => self.heap.push(q),
            QueueKind::LinearScan => self.list.push(q),
        }
    }

    fn pop(&mut self) -> Option<QueuedEvent> {
        match self.kind {
            QueueKind::Heap => self.heap.pop(),
            QueueKind::LinearScan => {
                if self.list.is_empty() {
                    return None;
                }
                // `Ord` is reversed, so the earliest event is the maximum.
                let mut best = 0;
                for i in 1..self.list.len() {
                    if self.list[i] > self.list[best] {
                        best = i;
                    }
                }
                Some(self.list.swap_remove(best))
            }
        }
    }
}

/// Result summary of an engine run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Full execution trace (intervals, device windows, makespan).
    pub trace: Trace,
    /// Virtual time the last interval ends.
    pub makespan: f64,
    /// Compute seconds / available device seconds.
    pub utilization: f64,
    /// Total shard-unit compute seconds.
    pub compute_secs: f64,
    /// Total synchronous transfer seconds.
    pub transfer_secs: f64,
    /// Total double-buffer stall seconds.
    pub stall_secs: f64,
    /// Shard units retired.
    pub units_executed: u64,
    /// DRAM->device promotion traffic.
    pub promoted_bytes: u64,
    /// Device->DRAM demotion traffic.
    pub demoted_bytes: u64,
    /// NVMe->DRAM fetch traffic (zero without an NVMe tier).
    pub nvme_promoted_bytes: u64,
    /// DRAM->NVMe eviction write-back traffic.
    pub nvme_demoted_bytes: u64,
    /// Seconds devices spent blocked on synchronous NVMe staging.
    pub nvme_secs: f64,
    /// Name of the scheduling policy used.
    pub scheduler: &'static str,
    /// Per-job arrival/finish/cancellation statistics (online setting;
    /// batch runs have arrival 0.0 everywhere).
    pub jobs: Vec<JobStat>,
}

/// The SHARP engine.
pub struct SharpEngine<'a> {
    /// The model tasks (public for post-run inspection in tests/figures).
    pub tasks: Vec<ModelTask>,
    devices: Vec<DeviceState>,
    memory: MemoryHierarchy,
    options: EngineOptions,
    scheduler: Box<dyn Scheduler>,
    backend: &'a mut dyn ExecutionBackend,
    cluster_events: Vec<ClusterEvent>,
    job_events: Vec<JobEvent>,
    // run state
    queue: EventQueue,
    pending_submissions: Vec<Option<ModelTask>>,
    /// Models whose front unit is eligible right now (arrived + idle).
    ready: BTreeSet<usize>,
    /// Per-model: has the arrival time passed?
    arrived: Vec<bool>,
    /// Per-model: has a cancellation been issued?
    job_cancelled: Vec<bool>,
    /// Per-model earliest cancel-request time (NaN = never requested);
    /// recorded even for no-op requests against finished jobs.
    cancel_requested: Vec<f64>,
    /// Cancellations waiting for an in-flight unit to retire.
    cancel_pending: BTreeSet<usize>,
    /// Per-model finish time (NaN until finished).
    finish_times: Vec<f64>,
    /// Devices that are alive, idle, and found no work at their last wake.
    parked: BTreeSet<usize>,
    /// Count of alive devices not currently computing.
    free_devices: usize,
    trace: Trace,
    units_executed: u64,
    agg_compute: f64,
    agg_transfer: f64,
    agg_stall: f64,
    agg_nvme: f64,
    rng: Rng,
}

impl<'a> SharpEngine<'a> {
    /// Build an engine over a homogeneous pool (`device_mem[i]` bytes each,
    /// reference speed, engine-wide link). The seed API; see
    /// [`SharpEngine::with_devices`] for heterogeneous pools. `memory` is
    /// either a bare `dram_bytes: u64` (the legacy two-tier setup) or a
    /// full [`MemoryOptions`] with an NVMe backing tier.
    pub fn new(
        tasks: Vec<ModelTask>,
        device_mem: &[u64],
        memory: impl Into<MemoryOptions>,
        scheduler: Box<dyn Scheduler>,
        backend: &'a mut dyn ExecutionBackend,
        options: EngineOptions,
    ) -> Result<SharpEngine<'a>> {
        let specs: Vec<DeviceSpec> =
            device_mem.iter().map(|&m| DeviceSpec::uniform(m)).collect();
        Self::with_devices(tasks, &specs, memory, scheduler, backend, options)
    }

    /// Build an engine over an explicit (possibly heterogeneous) device
    /// pool. Tasks must be partitioned so every shard fits the smallest
    /// device (the §4.3 "smallest-memory GPU" contract — see
    /// [`crate::sim::build_tasks_pool`]).
    pub fn with_devices(
        tasks: Vec<ModelTask>,
        specs: &[DeviceSpec],
        memory: impl Into<MemoryOptions>,
        scheduler: Box<dyn Scheduler>,
        backend: &'a mut dyn ExecutionBackend,
        options: EngineOptions,
    ) -> Result<SharpEngine<'a>> {
        if specs.is_empty() {
            return Err(HydraError::Config("no devices".into()));
        }
        for (m, t) in tasks.iter().enumerate() {
            if t.id != m {
                return Err(HydraError::Config(format!(
                    "task {m} has id {} (ids must be dense and in order)",
                    t.id
                )));
            }
        }
        let mut memory = MemoryHierarchy::new(memory);
        for t in &tasks {
            memory.home_model(t.id, &Self::shard_bytes(t))?;
        }
        let mut devices = Vec::new();
        for (id, &spec) in specs.iter().enumerate() {
            devices.push(Self::mk_device(id, spec, &options)?);
        }
        let rng = Rng::new(options.seed);
        let n_tasks = tasks.len();
        let n_devices = devices.len();
        Ok(SharpEngine {
            tasks,
            devices,
            memory,
            options: options.clone(),
            scheduler,
            backend,
            cluster_events: Vec::new(),
            job_events: Vec::new(),
            queue: EventQueue::new(options.queue),
            pending_submissions: Vec::new(),
            ready: BTreeSet::new(),
            arrived: vec![false; n_tasks],
            job_cancelled: vec![false; n_tasks],
            cancel_requested: vec![f64::NAN; n_tasks],
            cancel_pending: BTreeSet::new(),
            finish_times: vec![f64::NAN; n_tasks],
            parked: BTreeSet::new(),
            free_devices: n_devices,
            trace: Trace::default(),
            units_executed: 0,
            agg_compute: 0.0,
            agg_transfer: 0.0,
            agg_stall: 0.0,
            agg_nvme: 0.0,
            rng,
        })
    }

    /// Per-shard home-tier footprints of a task (what the hierarchy homes
    /// and unhomes).
    fn shard_bytes(task: &ModelTask) -> Vec<u64> {
        task.shards.iter().map(|s| s.param_bytes).collect()
    }

    fn mk_device(id: usize, spec: DeviceSpec, options: &EngineOptions) -> Result<DeviceState> {
        if !spec.speed.is_finite() || spec.speed <= 0.0 {
            return Err(HydraError::Config(format!(
                "device {id}: speed {} must be finite and positive",
                spec.speed
            )));
        }
        let mut ledger = DeviceLedger::new(id, spec.mem_bytes);
        let zone = (spec.mem_bytes as f64 * options.buffer_frac) as u64;
        let buffer = DoubleBuffer::new(options.double_buffer, zone, &mut ledger)?;
        Ok(DeviceState {
            spec,
            ledger,
            buffer,
            resident: None,
            pending: None,
            alive: true,
            busy: false,
            fail_pending: false,
            last_demote_bytes: 0,
        })
    }

    /// Register arrival/failure events before `run`.
    pub fn with_cluster_events(mut self, events: Vec<ClusterEvent>) -> Self {
        self.cluster_events = events;
        self
    }

    /// Register online job submissions/cancellations before `run`.
    pub fn with_job_events(mut self, events: Vec<JobEvent>) -> Self {
        self.job_events = events;
        self
    }

    /// The effective host link of `device`.
    fn link(&self, device: usize) -> TransferModel {
        self.devices[device].spec.link.unwrap_or(self.options.transfer)
    }

    /// Eligible model snapshots under the current parallel mode. Built from
    /// the incrementally-maintained ready-set, so the cost is
    /// O(|eligible|), not O(|all tasks|).
    fn eligible(&self) -> Vec<ModelSnapshot> {
        match self.options.mode {
            ParallelMode::Sharp => self
                .ready
                .iter()
                .filter_map(|&id| ModelSnapshot::of(&self.tasks[id]))
                .collect(),
            ParallelMode::Sequential => {
                // strictly one model in flight across the whole pool: while
                // any model runs, nothing else is eligible (otherwise a
                // lower-id job arriving mid-unit would put two devices to
                // work and corrupt the no-SHARP ablation)
                if self.tasks.iter().any(|t| t.state() == TaskState::Running) {
                    return Vec::new();
                }
                // then: the lowest-id unfinished *arrived* model
                for t in &self.tasks {
                    if t.state() != TaskState::Done && self.arrived[t.id] {
                        return ModelSnapshot::of(t).into_iter().collect();
                    }
                }
                Vec::new()
            }
        }
    }

    /// Mark `model` finished at `now` (first transition only) and release
    /// its homed parameters from the hierarchy — online streams with churn
    /// would otherwise exhaust the tiers and reject later submissions.
    /// Releasing twice is a real error (the old pool saturated silently).
    fn finish_job(
        &mut self,
        model: usize,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        if self.finish_times[model].is_nan() {
            self.finish_times[model] = now;
            let bytes = Self::shard_bytes(&self.tasks[model]);
            self.memory.unhome_model(model, &bytes)?;
            obs.on_job_finished(model, now, self.job_cancelled[model]);
        }
        Ok(())
    }

    /// Wake one parked device (a model just became eligible). Waking
    /// exactly one is sufficient — at most one model becomes eligible per
    /// event — and keeps the wake cost O(log n) instead of the seed
    /// engine's O(devices) broadcast.
    fn wake_one(&mut self, now: f64) {
        if let Some(&d) = self.parked.iter().next() {
            self.parked.remove(&d);
            self.queue.push(now, Event::DeviceFree { device: d });
        }
    }

    /// Run to completion; returns the report. Per-interval trace recording
    /// honours [`EngineOptions::record_intervals`] by installing a
    /// [`TraceRecorder`] observer — see [`SharpEngine::run_with`] for the
    /// underlying observer-threaded loop.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_observed(None)
    }

    /// Run with an optional external observer. This is the one place the
    /// [`EngineOptions::record_intervals`] semantics live: when set, a
    /// [`TraceRecorder`] is installed (teed with `obs` if both are present)
    /// and its intervals become `RunReport::trace.intervals`.
    pub fn run_observed(
        &mut self,
        obs: Option<&mut dyn EngineObserver>,
    ) -> Result<RunReport> {
        if !self.options.record_intervals {
            return match obs {
                Some(o) => self.run_with(o),
                None => self.run_with(&mut NoopObserver),
            };
        }
        let mut rec = TraceRecorder::default();
        let mut report = match obs {
            Some(o) => self.run_with(&mut Tee(o, &mut rec))?,
            None => self.run_with(&mut rec)?,
        };
        report.trace.intervals = rec.intervals;
        Ok(report)
    }

    /// Run to completion, streaming every engine event through `obs`.
    ///
    /// The report's `trace.intervals` stays empty on this path — interval
    /// bookkeeping belongs to the observer (pass a [`TraceRecorder`], or use
    /// [`SharpEngine::run`] which wires one from the options). Makespan,
    /// device windows, utilization and the scalar aggregates are always
    /// maintained engine-side.
    pub fn run_with(&mut self, obs: &mut dyn EngineObserver) -> Result<RunReport> {
        for d in 0..self.devices.len() {
            self.trace.set_device_window(d, 0.0, f64::INFINITY);
            self.queue.push(0.0, Event::DeviceFree { device: d });
        }
        for (i, ev) in self.cluster_events.clone().into_iter().enumerate() {
            let time = match ev {
                ClusterEvent::Arrive { time, .. } | ClusterEvent::Fail { time, .. } => time,
            };
            self.queue.push(time, Event::Cluster(i));
        }
        // Online jobs: construction-time tasks with future arrivals stay out
        // of the ready-set until their arrival event fires.
        self.ready.clear();
        for m in 0..self.tasks.len() {
            let arrival = self.tasks[m].arrival();
            if arrival > 0.0 {
                self.arrived[m] = false;
                self.queue.push(arrival, Event::JobArrive { model: m });
            } else {
                self.arrived[m] = true;
                obs.on_job_arrived(m, &self.tasks[m].name, 0.0);
                if self.tasks[m].state() == TaskState::Idle {
                    self.ready.insert(m);
                }
            }
        }
        let job_events = std::mem::take(&mut self.job_events);
        for ev in job_events {
            match ev {
                JobEvent::Submit { time, task } => {
                    let idx = self.pending_submissions.len();
                    self.pending_submissions.push(Some(task));
                    self.queue.push(time, Event::JobSubmit(idx));
                }
                JobEvent::Cancel { time, model } => {
                    self.queue.push(time, Event::JobCancel { model });
                }
            }
        }

        while let Some(q) = self.queue.pop() {
            let now = q.time;
            match q.ev {
                Event::DeviceFree { device } => self.on_device_free(device, now, obs)?,
                Event::UnitRetire { device, unit } => {
                    self.on_unit_retire(device, unit, now, obs)?
                }
                Event::Cluster(i) => self.on_cluster_event(i, now)?,
                Event::JobArrive { model } => self.on_job_arrive(model, now, obs),
                Event::JobSubmit(idx) => self.on_job_submit(idx, now, obs)?,
                Event::JobCancel { model } => self.on_job_cancel(model, now, obs)?,
            }
        }

        // Sanity: every task finished (unless devices all died).
        let alive = self.devices.iter().any(|d| d.alive);
        let done = self.tasks.iter().all(|t| t.state() == TaskState::Done);
        if alive && !done {
            return Err(HydraError::Sched(
                "engine drained events with unfinished tasks".into(),
            ));
        }

        self.trace.close_device_windows();
        let device_secs = self.trace.device_seconds();
        let utilization =
            if device_secs > 0.0 { self.agg_compute / device_secs } else { 0.0 };
        let jobs: Vec<JobStat> = self
            .tasks
            .iter()
            .enumerate()
            .map(|(m, t)| JobStat {
                model: m,
                name: t.name.clone(),
                arrival: t.arrival(),
                finished: self.finish_times[m],
                cancelled: self.job_cancelled[m],
                cancel_requested: (!self.cancel_requested[m].is_nan())
                    .then_some(self.cancel_requested[m]),
                units_executed: t.completed_units(),
            })
            .collect();
        Ok(RunReport {
            makespan: self.trace.makespan,
            utilization,
            compute_secs: self.agg_compute,
            transfer_secs: self.agg_transfer,
            stall_secs: self.agg_stall,
            units_executed: self.units_executed,
            promoted_bytes: self.memory.dram_traffic.promoted_bytes,
            demoted_bytes: self.memory.dram_traffic.demoted_bytes,
            nvme_promoted_bytes: self.memory.nvme_traffic.promoted_bytes,
            nvme_demoted_bytes: self.memory.nvme_traffic.demoted_bytes,
            nvme_secs: self.agg_nvme,
            scheduler: self.scheduler.name(),
            jobs,
            trace: std::mem::take(&mut self.trace),
        })
    }

    fn on_cluster_event(&mut self, i: usize, now: f64) -> Result<()> {
        match self.cluster_events[i] {
            ClusterEvent::Arrive { mem_bytes, .. } => {
                let id = self.devices.len();
                self.devices
                    .push(Self::mk_device(id, DeviceSpec::uniform(mem_bytes), &self.options)?);
                self.free_devices += 1;
                self.trace.set_device_window(id, now, f64::INFINITY);
                self.queue.push(now, Event::DeviceFree { device: id });
            }
            ClusterEvent::Fail { device, .. } => {
                if device < self.devices.len() && self.devices[device].alive {
                    if self.devices[device].busy {
                        // fail-stop between units: take effect on retire
                        self.devices[device].fail_pending = true;
                    } else {
                        self.kill_device(device, now);
                    }
                }
            }
        }
        Ok(())
    }

    fn kill_device(&mut self, device: usize, now: f64) {
        let pending = self.devices[device].pending.take();
        if let Some(st) = self.devices[device].buffer.staged().copied() {
            self.memory.release_device_copy(st.model, st.shard);
        }
        if let Some((m, sh)) = self.devices[device].resident.take() {
            self.memory.release_device_copy(m, sh);
        }
        self.devices[device].alive = false;
        self.devices[device].buffer.clear();
        self.parked.remove(&device);
        self.free_devices -= 1;
        if let Some(u) = pending {
            // return the pre-claimed unit to its model's queue; the model
            // may now be runnable elsewhere
            self.tasks[u.model].unclaim(&u);
            self.ready.insert(u.model);
            self.wake_one(now);
        }
        let start = self.trace.device_windows.get(&device).map(|w| w.0).unwrap_or(0.0);
        self.trace.set_device_window(device, start, now);
    }

    fn on_job_arrive(&mut self, model: usize, now: f64, obs: &mut dyn EngineObserver) {
        self.arrived[model] = true;
        // a job cancelled before its arrival never becomes eligible: no
        // arrival notification after its on_job_finished(cancelled=true)
        if !self.job_cancelled[model] && self.tasks[model].state() == TaskState::Idle {
            obs.on_job_arrived(model, &self.tasks[model].name, now);
            self.ready.insert(model);
            self.wake_one(now);
        }
    }

    fn on_job_submit(
        &mut self,
        idx: usize,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        let Some(task) = self.pending_submissions[idx].take() else {
            return Ok(());
        };
        let id = self.tasks.len();
        if task.id != id {
            return Err(HydraError::Sched(format!(
                "submitted task has id {} but {id} tasks are registered \
                 (ids must follow submission order)",
                task.id
            )));
        }
        self.memory.home_model(task.id, &Self::shard_bytes(&task))?;
        self.tasks.push(task);
        self.job_cancelled.push(false);
        self.cancel_requested.push(f64::NAN);
        self.finish_times.push(f64::NAN);
        // a submission may carry its own later arrival time; gate on it
        let arrival = self.tasks[id].arrival();
        if arrival > now {
            self.arrived.push(false);
            self.queue.push(arrival, Event::JobArrive { model: id });
        } else {
            self.arrived.push(true);
            obs.on_job_arrived(id, &self.tasks[id].name, now);
            if self.tasks[id].state() == TaskState::Idle {
                self.ready.insert(id);
                self.wake_one(now);
            }
        }
        Ok(())
    }

    fn on_job_cancel(
        &mut self,
        model: usize,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        if model >= self.tasks.len() {
            return Err(HydraError::Sched(format!(
                "cancel of unknown model {model}"
            )));
        }
        // every request is recorded (earliest wins), even the no-op ones
        // against already-finished jobs — the report stays auditable
        if self.cancel_requested[model].is_nan() {
            self.cancel_requested[model] = now;
        }
        if self.job_cancelled[model] || self.tasks[model].state() == TaskState::Done {
            return Ok(()); // idempotent; cancelling a finished job is a no-op
        }
        self.job_cancelled[model] = true;
        match self.tasks[model].state() {
            TaskState::Idle => {
                self.ready.remove(&model);
                self.tasks[model].early_stop();
                self.finish_job(model, now, obs)?;
            }
            TaskState::Running => {
                // The claim is either a pre-claimed double-buffer prefetch
                // (revoked immediately) or a genuinely in-flight unit
                // (completes first; cancellation is unit-granular).
                let mut revoked = false;
                for d in 0..self.devices.len() {
                    if self.devices[d].pending.map(|u| u.model) == Some(model) {
                        let u = self.devices[d].pending.take().expect("checked");
                        if let Some(st) = self.devices[d].buffer.staged().copied() {
                            if st.model == model {
                                // the staged fetch pinned the shard in DRAM
                                self.memory.release_device_copy(st.model, st.shard);
                                self.devices[d].buffer.clear();
                            }
                        }
                        self.tasks[model].unclaim(&u);
                        self.tasks[model].early_stop();
                        self.finish_job(model, now, obs)?;
                        revoked = true;
                        break;
                    }
                }
                if !revoked {
                    self.cancel_pending.insert(model);
                }
            }
            TaskState::Done => {}
        }
        Ok(())
    }

    fn on_device_free(
        &mut self,
        device: usize,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        if !self.devices[device].alive || self.devices[device].busy {
            return Ok(());
        }
        self.parked.remove(&device);
        // 1. a pre-claimed (double-buffered) unit takes priority
        let unit = if let Some(u) = self.devices[device].pending.take() {
            Some(u)
        } else {
            let eligible = self.eligible();
            let resident: Vec<(usize, u32)> =
                self.devices[device].resident.into_iter().collect();
            let ctx = PickContext {
                now,
                device,
                speed: self.devices[device].spec.speed,
                resident: Some(&resident),
            };
            match self.scheduler.pick(&eligible, ctx, &mut self.rng) {
                Some(i) => {
                    let id = eligible[i].id;
                    self.ready.remove(&id);
                    obs.on_decision(device, id, false, now);
                    Some(self.tasks[id].claim_front())
                }
                None => None, // park until a wake-up
            }
        };
        match unit {
            Some(unit) => self.start_unit(device, unit, now, obs),
            None => {
                self.parked.insert(device);
                Ok(())
            }
        }
    }

    /// Promote memory, account transfers/stalls, execute, schedule retire.
    fn start_unit(
        &mut self,
        device: usize,
        unit: ShardUnit,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        let task_shard = self.tasks[unit.model].shard(unit.shard).clone();
        let link = self.link(device);
        let mut t = now;

        // --- parameter promotion -----------------------------------------
        let promote_bytes = if self.options.full_state_transfers {
            task_shard.param_bytes
        } else {
            task_shard.transfer_bytes(unit.phase)
        };
        let cached = self.devices[device].resident == Some((unit.model, unit.shard));
        if !cached {
            // demote whatever was resident (a bwd unit's gradients/updated
            // weights flow back; fwd demotion is a discard of clean weights)
            if let Some((m, s)) = self.devices[device].resident.take() {
                self.devices[device]
                    .ledger
                    .release(&Residency::ShardParams { model: m, shard: s });
                let wb = self.devices[device].last_demote_bytes;
                self.memory.note_demote(wb);
                if wb > 0 {
                    obs.on_spill(device, 0, wb, MemTier::Dram, t);
                }
                if !self.options.double_buffer && wb > 0 {
                    // synchronous write-back (no overlap without DB)
                    let dt = link.secs(wb);
                    self.record(device, t, t + dt, unit, IntervalKind::Transfer, obs);
                    t += dt;
                }
                // write-back landed: the old resident's DRAM slot unpins
                // and becomes an eviction candidate for the fetch below
                self.memory.release_device_copy(m, s);
            }
            // promote: either consume the prefetched copy or transfer now
            let stall = self.devices[device]
                .buffer
                .consume(unit.model, unit.shard, t);
            // like demotions above, spill events carry the time the
            // transfer starts
            if promote_bytes > 0 {
                obs.on_spill(device, promote_bytes, 0, MemTier::Dram, t);
            }
            let dt = match stall {
                Some(stall) => {
                    // the staged prefetch already fetched (and pinned) the
                    // shard in DRAM; any NVMe leg was folded into its
                    // transfer time, overlapped with compute like §4.6
                    if stall > 0.0 {
                        self.record(device, t, t + stall, unit, IntervalKind::BufferStall, obs);
                    }
                    stall
                }
                None => {
                    // DRAM miss with nothing prefetched: stage the shard up
                    // from NVMe synchronously, charged on the NVMe link
                    let fetch = self.memory.fetch_to_dram(unit.model, unit.shard)?;
                    if fetch.fetched_bytes > 0 {
                        obs.on_spill(
                            device,
                            fetch.fetched_bytes,
                            fetch.evicted_bytes,
                            MemTier::Nvme,
                            t,
                        );
                    }
                    if fetch.secs > 0.0 {
                        self.record(
                            device,
                            t,
                            t + fetch.secs,
                            unit,
                            IntervalKind::NvmeTransfer,
                            obs,
                        );
                        t += fetch.secs;
                    }
                    let dt = link.secs(promote_bytes);
                    if dt > 0.0 {
                        self.record(device, t, t + dt, unit, IntervalKind::Transfer, obs);
                    }
                    dt
                }
            };
            t += dt;
            self.memory.note_promote(promote_bytes);
            self.devices[device]
                .ledger
                .alloc(
                    Residency::ShardParams { model: unit.model, shard: unit.shard },
                    task_shard.param_bytes,
                )?;
            self.devices[device].resident = Some((unit.model, unit.shard));
        }
        // what flows back to DRAM when this residency is evicted: bwd units
        // produce gradients/updated weights; fwd residency is clean
        self.devices[device].last_demote_bytes = if self.options.full_state_transfers {
            task_shard.param_bytes
        } else {
            match unit.phase {
                Phase::Bwd => task_shard.bwd_transfer_bytes,
                Phase::Fwd => 0,
            }
        };

        // --- boundary activation ------------------------------------------
        // Needed unless this model's previous unit ran on this device and the
        // checkpoint never left (§4.6 bonus). We approximate with: cached
        // shard => activation also local (fwd+bwd pairs share the device).
        let needs_act = unit.shard > 0 || unit.phase == Phase::Bwd;
        if needs_act && !cached {
            let dt = link.secs(task_shard.activation_bytes);
            if dt > 0.0 {
                self.record(device, t, t + dt, unit, IntervalKind::Transfer, obs);
                t += dt;
            }
        }
        self.devices[device]
            .ledger
            .alloc(Residency::Activation { model: unit.model }, 2 * task_shard.activation_bytes)?;

        // --- execute -------------------------------------------------------
        // Unit costs are calibrated on the reference GPU; faster devices in
        // a heterogeneous pool retire the same unit proportionally sooner.
        let dur = self.backend.execute_unit(&self.tasks[unit.model], &unit)?
            / self.devices[device].spec.speed;
        self.devices[device].busy = true;
        self.free_devices -= 1;
        self.record(device, t, t + dur, unit, IntervalKind::Compute, obs);
        let end = t + dur;

        // --- double-buffer prefetch of the *next* unit ----------------------
        if self.options.double_buffer {
            self.try_stage_prefetch(device, t, obs);
        }

        self.queue.push(end, Event::UnitRetire { device, unit });
        Ok(())
    }

    /// While `device` computes, pick and claim the next unit for it and
    /// start the prefetch transfer into the buffer zone (§4.6: "the
    /// Scheduler is actually picking shard units for double-buffering").
    fn try_stage_prefetch(&mut self, device: usize, now: f64, obs: &mut dyn EngineObserver) {
        if self.devices[device].pending.is_some() || self.devices[device].fail_pending {
            return;
        }
        // Don't steal an eligible model from a device that could run it
        // *right now* — prefetching is only a win when every device is busy
        // (claiming for the buffer would otherwise serialise work that task
        // parallelism would run immediately).
        if self.free_devices > 0 {
            return;
        }
        let eligible = self.eligible();
        if eligible.is_empty() {
            return;
        }
        let resident: Vec<(usize, u32)> =
            self.devices[device].resident.into_iter().collect();
        let ctx = PickContext {
            now,
            device,
            speed: self.devices[device].spec.speed,
            resident: Some(&resident),
        };
        let Some(i) = self.scheduler.pick(&eligible, ctx, &mut self.rng) else {
            return;
        };
        let id = eligible[i].id;
        self.ready.remove(&id);
        obs.on_decision(device, id, true, now);
        let unit = self.tasks[id].claim_front();
        let bytes = if self.options.full_state_transfers {
            self.tasks[id].shard(unit.shard).param_bytes
        } else {
            self.tasks[id].shard(unit.shard).transfer_bytes(unit.phase)
        };
        // only stage what fits the protected zone; otherwise fall back to a
        // synchronous transfer at start time (consume returns None then)
        if bytes <= self.devices[device].buffer.zone_bytes {
            // a mismatched consume can leave an abandoned staging behind;
            // unpin it before overwriting
            if let Some(st) = self.devices[device].buffer.staged().copied() {
                self.memory.release_device_copy(st.model, st.shard);
            }
            // multi-hop staging: pull the shard NVMe->DRAM (pinning it) and
            // fold the NVMe leg into the prefetch time, so compute hides
            // the whole DRAM-miss path exactly like §4.6 hides PCIe. If
            // DRAM is too contended to fetch now, skip staging — start_unit
            // retries synchronously once the demote has freed a slot.
            if let Ok(fetch) = self.memory.fetch_to_dram(id, unit.shard) {
                if fetch.fetched_bytes > 0 {
                    obs.on_spill(
                        device,
                        fetch.fetched_bytes,
                        fetch.evicted_bytes,
                        MemTier::Nvme,
                        now,
                    );
                }
                let dt = fetch.secs + self.link(device).secs(bytes);
                if !self.devices[device].buffer.stage(id, unit.shard, bytes, now, dt) {
                    self.memory.release_device_copy(id, unit.shard);
                }
            }
        }
        self.devices[device].pending = Some(unit);
    }

    fn on_unit_retire(
        &mut self,
        device: usize,
        unit: ShardUnit,
        now: f64,
        obs: &mut dyn EngineObserver,
    ) -> Result<()> {
        self.units_executed += 1;
        self.devices[device].busy = false;
        self.free_devices += 1;
        self.devices[device]
            .ledger
            .release(&Residency::Activation { model: unit.model });
        self.tasks[unit.model].retire(&unit);
        self.backend.on_unit_retired(&self.tasks[unit.model], &unit);
        obs.on_unit_retired(device, &unit, now);

        // epoch boundary: last unit of the epoch just retired — give the
        // backend its early-stop vote (§4.7.2)
        let epoch_done = self.tasks[unit.model].geometry.closes_epoch(&unit);
        if epoch_done
            && self.tasks[unit.model].state() == TaskState::Idle
            && self.backend.should_early_stop(&self.tasks[unit.model], unit.epoch)
        {
            self.tasks[unit.model].early_stop();
        }

        // a cancellation issued while this unit was in flight lands now
        if self.cancel_pending.remove(&unit.model) {
            self.tasks[unit.model].early_stop();
        }
        match self.tasks[unit.model].state() {
            TaskState::Idle => {
                self.ready.insert(unit.model);
            }
            TaskState::Done => {
                self.finish_job(unit.model, now, obs)?;
            }
            TaskState::Running => {}
        }

        if self.devices[device].fail_pending {
            self.kill_device(device, now);
        } else {
            self.queue.push(now, Event::DeviceFree { device });
        }
        // The retired model is idle again: one parked device may now have
        // eligible work.
        if self.tasks[unit.model].state() == TaskState::Idle {
            self.wake_one(now);
        }
        Ok(())
    }

    /// Account an interval: scalar aggregates + makespan stay engine-side
    /// (they feed the report); per-interval bookkeeping is the observer's.
    fn record(
        &mut self,
        device: usize,
        start: f64,
        end: f64,
        unit: ShardUnit,
        kind: IntervalKind,
        obs: &mut dyn EngineObserver,
    ) {
        if end > self.trace.makespan {
            self.trace.makespan = end;
        }
        match kind {
            IntervalKind::Compute => self.agg_compute += end - start,
            IntervalKind::Transfer => self.agg_transfer += end - start,
            IntervalKind::BufferStall => self.agg_stall += end - start,
            IntervalKind::NvmeTransfer => self.agg_nvme += end - start,
        }
        obs.on_interval(&Interval {
            device,
            start,
            end,
            model: unit.model,
            shard: unit.shard,
            phase: unit.phase,
            unit_seq: unit.seq_idx,
            kind,
        });
    }
}
