//! Compatibility surface of the SHARP engine.
//!
//! The implementation moved to [`crate::coordinator::engine`], split into
//! one module per concern — `events` (queue), `device` (specs, lifecycle),
//! `jobs` (submit/cancel/finish), `prefetch` (the depth-k pipeline that
//! absorbed the old `buffer.rs` double buffer) and `core` (the engine and
//! its run loop). This module re-exports the whole public surface so every
//! existing `coordinator::sharp::...` call site compiles unchanged.

pub use crate::coordinator::engine::{
    Admission, ClusterEvent, DeviceSpec, EngineOptions, JobEvent, JobStat,
    ParallelMode, PrefetchPipeline, PrefetchSlot, QueueKind, Route, RunReport,
    ShardBusy, ShardId, ShardMailbox, ShardOutcome, ShardSection, SharpEngine,
    ShardedEngine, ShardedReport, StagedShard, StolenJob, TenantStat,
};

pub use crate::coordinator::memory::TransferModel;
