//! Durability subsystem: event WAL, snapshots, deterministic replay and
//! crash recovery.
//!
//! The engine runs in virtual time and is a deterministic function of its
//! inputs, so durability splits cleanly in two:
//!
//! * the **WAL** ([`wal`]) makes a run *auditable and recoverable* — the
//!   first record is the complete run recipe (genesis), every later record
//!   is one engine event, each length-prefixed and CRC-checksummed so a
//!   torn tail is detected and clipped, never trusted;
//! * **snapshots** ([`snapshot`]) make recovery *cheap* — a periodic full
//!   engine-state dump in an atomically-replaced sidecar bounds the
//!   re-execution suffix after a crash.
//!
//! [`replay`] ties them together: `replay(wal)` re-runs the genesis and is
//! Debug-byte-identical to the original report; `recover(wal)` restores
//! the latest snapshot and runs forward (falling back to replay), which is
//! what `hydra recover` and the fault-injection drills exercise.
//!
//! Wired in via [`crate::session::SessionBuilder::durability`], the
//! `"wal"` / `"snapshot_every"` engine config keys, and the `--wal` /
//! `--snapshot-every` CLI flags.

pub mod replay;
pub mod snapshot;
pub mod wal;

use std::path::PathBuf;

pub use replay::{recover, replay, Recovered};
pub use snapshot::{read_snapshot, snapshot_path, write_snapshot, Snapshot};
pub use wal::{scan_wal, Genesis, RunSpec, ScannedWal, WalRecord, WalWriter};

pub(crate) use replay::run_durable;

/// Where and how often a session persists its durability state.
#[derive(Debug, Clone)]
pub struct DurabilityOptions {
    /// WAL path. The snapshot sidecar lives next to it at `<wal>.snap`;
    /// sharded runs add `<wal>.shard<k>` per shard.
    pub wal: PathBuf,
    /// Take a full engine-state snapshot every this many dispatched
    /// events. `0` (the default) disables snapshots: the WAL alone still
    /// supports full replay, recovery just re-runs from the genesis.
    pub snapshot_every: u64,
}

impl DurabilityOptions {
    /// Durability with the WAL at `wal` and snapshots disabled.
    pub fn new(wal: impl Into<PathBuf>) -> DurabilityOptions {
        DurabilityOptions { wal: wal.into(), snapshot_every: 0 }
    }

    /// Enable snapshots every `n` dispatched events (`0` disables).
    pub fn snapshot_every(mut self, n: u64) -> DurabilityOptions {
        self.snapshot_every = n;
        self
    }
}
