//! The event write-ahead log: an append-only file of length-prefixed,
//! CRC-checksummed records.
//!
//! Layout: an 8-byte magic (`HYWAL003`) followed by records of
//! `[u32 len][u32 crc][payload]`, where `crc = crc32(payload)` and
//! `payload[0]` is the record kind. The first record is always a *genesis*
//! record carrying the complete run recipe ([`RunSpec`] for engine runs,
//! the spec JSON for searches); every subsequent record is one engine
//! event, appended by [`WalWriter`] — an [`EngineObserver`] tapped into
//! the run loop. Because the engine is a deterministic function of its
//! genesis, `replay(wal)` needs nothing but the first record; the event
//! suffix is what makes the log auditable and what the torn-write scanner
//! ([`scan_wal`]) validates byte by byte.
//!
//! Sharded runs rotate: the main WAL holds the genesis plus a
//! [`WalRecord::ShardBegin`] mark per shard, and each shard's event stream
//! lands in its own `<path>.shard<k>` sidecar (ids already remapped to the
//! global namespace by the sharded engine's observer scope).

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::coordinator::engine::routing::ShardId;
use crate::coordinator::memory::{MemTier, MemoryOptions};
use crate::coordinator::metrics::Interval;
use crate::coordinator::observer::EngineObserver;
use crate::coordinator::sched::Policy;
use crate::coordinator::sharp::{
    ClusterEvent, DeviceSpec, EngineOptions, JobEvent, RunReport, SharpEngine,
    ShardSection, ShardedEngine,
};
use crate::coordinator::task::ModelTask;
use crate::coordinator::unit::ShardUnit;
use crate::error::{HydraError, Result};
use crate::exec::{ExecutionBackend, SimBackend};
use crate::util::codec::{crc32, ByteReader, ByteWriter};

/// File magic of a Hydra event WAL.
pub const WAL_MAGIC: &[u8; 8] = b"HYWAL003";

/// The complete recipe of one engine run — everything
/// [`crate::session::Session::run`] feeds the engine, captured in the WAL's
/// genesis record so a crashed run can be re-driven from nothing. The
/// engine is deterministic given this spec, which is what the determinism
/// audit in `rust/tests/determinism.rs` pins.
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Construction-time tasks (ids dense and in order).
    pub tasks: Vec<ModelTask>,
    /// The device pool.
    pub devices: Vec<DeviceSpec>,
    /// Host memory hierarchy (DRAM + optional NVMe tier).
    pub memory: MemoryOptions,
    /// Scheduling policy (stateless; rebuilt via [`Policy::build`]).
    pub policy: Policy,
    /// Engine knobs, including the shard count.
    pub options: EngineOptions,
    /// Elasticity / fault-injection events.
    pub cluster_events: Vec<ClusterEvent>,
    /// Online submissions and cancellations.
    pub job_events: Vec<JobEvent>,
    /// Sim-backend noise amplitude (0.0 = deterministic).
    pub noise: f64,
    /// Sim-backend noise-stream seed.
    pub backend_seed: u64,
}

impl RunSpec {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_usize(self.tasks.len());
        for t in &self.tasks {
            t.encode(w);
        }
        w.put_usize(self.devices.len());
        for d in &self.devices {
            d.encode(w);
        }
        self.memory.encode(w);
        w.put_str(self.policy.name());
        self.options.encode(w);
        w.put_usize(self.cluster_events.len());
        for e in &self.cluster_events {
            e.encode(w);
        }
        w.put_usize(self.job_events.len());
        for e in &self.job_events {
            e.encode(w);
        }
        w.put_f64(self.noise);
        w.put_u64(self.backend_seed);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<RunSpec> {
        let n = r.get_count(32)?;
        let mut tasks = Vec::with_capacity(n);
        for _ in 0..n {
            tasks.push(ModelTask::decode(r)?);
        }
        let n = r.get_count(17)?;
        let mut devices = Vec::with_capacity(n);
        for _ in 0..n {
            devices.push(DeviceSpec::decode(r)?);
        }
        let memory = MemoryOptions::decode(r)?;
        let policy_name = r.get_str()?;
        let policy = policy_name.parse::<Policy>().map_err(|_| {
            HydraError::WalCorrupt(format!("genesis names unknown policy {policy_name:?}"))
        })?;
        let options = EngineOptions::decode(r)?;
        let n = r.get_count(9)?;
        let mut cluster_events = Vec::with_capacity(n);
        for _ in 0..n {
            cluster_events.push(ClusterEvent::decode(r)?);
        }
        let n = r.get_count(9)?;
        let mut job_events = Vec::with_capacity(n);
        for _ in 0..n {
            job_events.push(JobEvent::decode(r)?);
        }
        Ok(RunSpec {
            tasks,
            devices,
            memory,
            policy,
            options,
            cluster_events,
            job_events,
            noise: r.get_f64()?,
            backend_seed: r.get_u64()?,
        })
    }

    /// Re-run this spec from nothing on a fresh sim backend — the pure
    /// replay primitive. Deterministic: two calls produce Debug-identical
    /// [`RunReport`]s.
    pub fn run(&self, obs: Option<&mut dyn EngineObserver>) -> Result<RunReport> {
        let mut backend = SimBackend::new(self.noise, self.backend_seed);
        Ok(self.run_on(&mut backend, obs)?.0)
    }

    /// Drive the spec on an explicit backend; returns the report and — for
    /// sharded specs — the per-shard sections.
    pub(crate) fn run_on(
        &self,
        backend: &mut dyn ExecutionBackend,
        obs: Option<&mut dyn EngineObserver>,
    ) -> Result<(RunReport, Vec<ShardSection>)> {
        if self.options.shards > 1 {
            let report = ShardedEngine::with_devices(
                self.tasks.clone(),
                &self.devices,
                self.memory,
                self.policy,
                backend,
                self.options.clone(),
            )?
            .with_cluster_events(self.cluster_events.clone())
            .with_job_events(self.job_events.clone())
            .run_observed(obs)?;
            Ok((report.merged, report.sections))
        } else {
            let mut engine = SharpEngine::with_devices(
                self.tasks.clone(),
                &self.devices,
                self.memory,
                self.policy.build(),
                backend,
                self.options.clone(),
            )?
            .with_cluster_events(self.cluster_events.clone())
            .with_job_events(self.job_events.clone());
            Ok((engine.run_observed(obs)?, Vec::new()))
        }
    }
}

/// What a run's WAL can be rebuilt from: its first record.
#[derive(Debug, Clone)]
pub enum Genesis {
    /// An engine run (simulate / programmatic sessions).
    Run(RunSpec),
    /// A model-selection search: the `SearchWorkload` spec JSON, re-run via
    /// [`crate::config::SearchWorkload::parse`].
    Search(String),
}

/// One WAL record. Kinds 0/1 are the genesis; everything else mirrors an
/// [`EngineObserver`] event one-to-one.
#[derive(Debug, Clone)]
pub enum WalRecord {
    /// Kind 0: complete engine-run recipe (always the first record).
    GenesisRun(RunSpec),
    /// Kind 1: model-selection search spec JSON (always the first record).
    GenesisSearch(String),
    /// Kind 2: a sharded run is about to drive `shard` of `n_shards`; its
    /// event stream continues in `<path>.shard<k>`.
    ShardBegin {
        /// Shard index.
        shard: usize,
        /// Total shard count.
        n_shards: usize,
    },
    /// Kind 3: mid-run submission accepted.
    JobSubmitted {
        /// Assigned engine model id.
        model: usize,
        /// Tenant-facing job name.
        name: String,
        /// Virtual time.
        now: f64,
    },
    /// Kind 4: a job entered the eligible set.
    JobArrived {
        /// Engine model id.
        model: usize,
        /// Tenant-facing job name.
        name: String,
        /// Virtual time.
        now: f64,
    },
    /// Kind 5: scheduler decision.
    Decision {
        /// Device picked for.
        device: usize,
        /// Model picked.
        model: usize,
        /// Whether this was a prefetch pre-claim.
        prefetch: bool,
        /// Virtual time.
        now: f64,
    },
    /// Kind 6: a shard unit retired.
    UnitRetired {
        /// Device the unit ran on.
        device: usize,
        /// The retired unit.
        unit: ShardUnit,
        /// Virtual time.
        now: f64,
    },
    /// Kind 7: a job finished (or its cancellation took effect).
    JobFinished {
        /// Engine model id.
        model: usize,
        /// Virtual time.
        now: f64,
        /// True when the finish was a cancellation landing.
        cancelled: bool,
    },
    /// Kind 8: a tenant cancel request (idempotent duplicates included).
    JobCancelRequested {
        /// Engine model id.
        model: usize,
        /// Virtual time.
        now: f64,
    },
    /// Kind 9: spill traffic on one hierarchy link.
    Spill {
        /// Device the transfer serves.
        device: usize,
        /// Bytes promoted toward the device.
        promoted: u64,
        /// Bytes demoted away from it.
        demoted: u64,
        /// Which link (DRAM<->HBM or NVMe<->DRAM).
        tier: MemTier,
        /// Virtual time the transfer starts.
        now: f64,
    },
    /// Kind 10: a recorded device-time interval.
    Interval(Interval),
    /// Kind 11: a snapshot of the engine state was persisted to the `.snap`
    /// sidecar after this many dispatched events.
    SnapshotMark {
        /// Events dispatched when the snapshot was taken.
        events_dispatched: u64,
    },
    /// Kind 12: the run finished cleanly. A WAL without one is a crash.
    RunEnd {
        /// Final makespan.
        makespan: f64,
    },
}

impl WalRecord {
    fn encode_payload(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        match self {
            WalRecord::GenesisRun(spec) => {
                w.put_u8(0);
                spec.encode(&mut w);
            }
            WalRecord::GenesisSearch(text) => {
                w.put_u8(1);
                w.put_str(text);
            }
            WalRecord::ShardBegin { shard, n_shards } => {
                w.put_u8(2);
                w.put_usize(*shard);
                w.put_usize(*n_shards);
            }
            WalRecord::JobSubmitted { model, name, now } => {
                w.put_u8(3);
                w.put_usize(*model);
                w.put_str(name);
                w.put_f64(*now);
            }
            WalRecord::JobArrived { model, name, now } => {
                w.put_u8(4);
                w.put_usize(*model);
                w.put_str(name);
                w.put_f64(*now);
            }
            WalRecord::Decision { device, model, prefetch, now } => {
                w.put_u8(5);
                w.put_usize(*device);
                w.put_usize(*model);
                w.put_bool(*prefetch);
                w.put_f64(*now);
            }
            WalRecord::UnitRetired { device, unit, now } => {
                w.put_u8(6);
                w.put_usize(*device);
                unit.encode(&mut w);
                w.put_f64(*now);
            }
            WalRecord::JobFinished { model, now, cancelled } => {
                w.put_u8(7);
                w.put_usize(*model);
                w.put_f64(*now);
                w.put_bool(*cancelled);
            }
            WalRecord::JobCancelRequested { model, now } => {
                w.put_u8(8);
                w.put_usize(*model);
                w.put_f64(*now);
            }
            WalRecord::Spill { device, promoted, demoted, tier, now } => {
                w.put_u8(9);
                w.put_usize(*device);
                w.put_u64(*promoted);
                w.put_u64(*demoted);
                tier.encode(&mut w);
                w.put_f64(*now);
            }
            WalRecord::Interval(iv) => {
                w.put_u8(10);
                iv.encode(&mut w);
            }
            WalRecord::SnapshotMark { events_dispatched } => {
                w.put_u8(11);
                w.put_u64(*events_dispatched);
            }
            WalRecord::RunEnd { makespan } => {
                w.put_u8(12);
                w.put_f64(*makespan);
            }
        }
        w.into_inner()
    }

    fn decode_payload(payload: &[u8]) -> Result<WalRecord> {
        let mut r = ByteReader::new(payload);
        let rec = match r.get_u8()? {
            0 => WalRecord::GenesisRun(RunSpec::decode(&mut r)?),
            1 => WalRecord::GenesisSearch(r.get_str()?),
            2 => WalRecord::ShardBegin {
                shard: r.get_usize()?,
                n_shards: r.get_usize()?,
            },
            3 => WalRecord::JobSubmitted {
                model: r.get_usize()?,
                name: r.get_str()?,
                now: r.get_f64()?,
            },
            4 => WalRecord::JobArrived {
                model: r.get_usize()?,
                name: r.get_str()?,
                now: r.get_f64()?,
            },
            5 => WalRecord::Decision {
                device: r.get_usize()?,
                model: r.get_usize()?,
                prefetch: r.get_bool()?,
                now: r.get_f64()?,
            },
            6 => WalRecord::UnitRetired {
                device: r.get_usize()?,
                unit: ShardUnit::decode(&mut r)?,
                now: r.get_f64()?,
            },
            7 => WalRecord::JobFinished {
                model: r.get_usize()?,
                now: r.get_f64()?,
                cancelled: r.get_bool()?,
            },
            8 => WalRecord::JobCancelRequested {
                model: r.get_usize()?,
                now: r.get_f64()?,
            },
            9 => WalRecord::Spill {
                device: r.get_usize()?,
                promoted: r.get_u64()?,
                demoted: r.get_u64()?,
                tier: MemTier::decode(&mut r)?,
                now: r.get_f64()?,
            },
            10 => WalRecord::Interval(Interval::decode(&mut r)?),
            11 => WalRecord::SnapshotMark { events_dispatched: r.get_u64()? },
            12 => WalRecord::RunEnd { makespan: r.get_f64()? },
            t => {
                return Err(HydraError::WalCorrupt(format!(
                    "unknown record kind {t}"
                )))
            }
        };
        r.expect_end()?;
        Ok(rec)
    }
}

/// Streaming WAL appender: every engine event flows through its
/// [`EngineObserver`] impl and lands as one checksummed record. IO errors
/// are latched on first occurrence (observer hooks cannot fail) and
/// surfaced by [`WalWriter::finish`] — the run itself continues either way,
/// so a full disk degrades durability, never the schedule.
pub struct WalWriter {
    base: PathBuf,
    main: BufWriter<File>,
    /// Current per-shard sidecar during a sharded run.
    shard: Option<BufWriter<File>>,
    err: Option<HydraError>,
}

fn create_wal_file(path: &Path) -> Result<BufWriter<File>> {
    let mut f = BufWriter::new(File::create(path)?);
    f.write_all(WAL_MAGIC)?;
    Ok(f)
}

fn frame(payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&crc32(payload).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

impl WalWriter {
    /// Create (or truncate) a WAL at `path` and write the magic. The caller
    /// appends a genesis record next.
    pub fn create(path: impl Into<PathBuf>) -> Result<WalWriter> {
        let base = path.into();
        let main = create_wal_file(&base)?;
        Ok(WalWriter { base, main, shard: None, err: None })
    }

    /// Open an existing WAL for appending (record-only mode: the genesis
    /// was written by whoever created the file — e.g. a search writes its
    /// spec genesis, then every trial-driving engine run appends its
    /// events here). Creates the file with a magic if it does not exist;
    /// rejects files that are not Hydra WALs.
    pub fn append_to(path: impl Into<PathBuf>) -> Result<WalWriter> {
        let base = path.into();
        let main = match File::open(&base) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => create_wal_file(&base)?,
            Err(e) => return Err(e.into()),
            Ok(mut existing) => {
                let mut magic = [0u8; 8];
                existing.read_exact(&mut magic).map_err(|_| {
                    HydraError::WalCorrupt(format!(
                        "{}: not a Hydra WAL (shorter than the magic)",
                        base.display()
                    ))
                })?;
                if &magic != WAL_MAGIC {
                    return Err(HydraError::WalCorrupt(format!(
                        "{}: not a Hydra WAL (bad magic)",
                        base.display()
                    )));
                }
                drop(existing);
                BufWriter::new(OpenOptions::new().append(true).open(&base)?)
            }
        };
        Ok(WalWriter { base, main, shard: None, err: None })
    }

    /// The WAL path this writer appends to.
    pub fn path(&self) -> &Path {
        &self.base
    }

    /// Append one record to the active stream (the shard sidecar during a
    /// sharded run, the main WAL otherwise). Errors are latched.
    pub fn append(&mut self, rec: &WalRecord) {
        if self.err.is_some() {
            return;
        }
        let buf = frame(&rec.encode_payload());
        let target: &mut BufWriter<File> = match self.shard.as_mut() {
            Some(s) => s,
            None => &mut self.main,
        };
        if let Err(e) = target.write_all(&buf) {
            self.err = Some(e.into());
        }
    }

    /// Flush buffered records to the OS. Called after every snapshot so the
    /// WAL on disk is never behind the snapshot that marks it.
    pub fn flush(&mut self) {
        if self.err.is_some() {
            return;
        }
        if let Some(s) = self.shard.as_mut() {
            if let Err(e) = s.flush() {
                self.err = Some(e.into());
                return;
            }
        }
        if let Err(e) = self.main.flush() {
            self.err = Some(e.into());
        }
    }

    /// Flush everything and surface the first latched IO error, if any.
    pub fn finish(mut self) -> Result<()> {
        self.flush();
        match self.err.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl EngineObserver for WalWriter {
    fn on_job_submitted(&mut self, model: usize, name: &str, now: f64) {
        self.append(&WalRecord::JobSubmitted { model, name: name.to_string(), now });
    }

    fn on_job_cancel_requested(&mut self, model: usize, now: f64) {
        self.append(&WalRecord::JobCancelRequested { model, now });
    }

    fn on_job_arrived(&mut self, model: usize, name: &str, now: f64) {
        self.append(&WalRecord::JobArrived { model, name: name.to_string(), now });
    }

    fn on_decision(&mut self, device: usize, model: usize, prefetch: bool, now: f64) {
        self.append(&WalRecord::Decision { device, model, prefetch, now });
    }

    fn on_unit_retired(&mut self, device: usize, unit: &ShardUnit, now: f64) {
        self.append(&WalRecord::UnitRetired { device, unit: *unit, now });
    }

    fn on_job_finished(&mut self, model: usize, now: f64, cancelled: bool) {
        self.append(&WalRecord::JobFinished { model, now, cancelled });
    }

    fn on_spill(&mut self, device: usize, promoted: u64, demoted: u64, tier: MemTier, now: f64) {
        self.append(&WalRecord::Spill { device, promoted, demoted, tier, now });
    }

    fn on_interval(&mut self, interval: &Interval) {
        self.append(&WalRecord::Interval(*interval));
    }

    fn on_shard_begin(&mut self, shard: ShardId, n_shards: usize) {
        self.append(&WalRecord::ShardBegin { shard: shard.0, n_shards });
        if n_shards <= 1 || self.err.is_some() {
            return;
        }
        // rotate: this shard's event stream gets its own tagged sidecar
        self.flush();
        let mut path = self.base.clone().into_os_string();
        path.push(format!(".shard{}", shard.0));
        match create_wal_file(Path::new(&path)) {
            Ok(mut f) => {
                let begin = WalRecord::ShardBegin { shard: shard.0, n_shards };
                if let Err(e) = f.write_all(&frame(&begin.encode_payload())) {
                    self.err = Some(e.into());
                }
                self.shard = Some(f);
            }
            Err(e) => self.err = Some(e),
        }
    }
}

/// A scanned WAL: the genesis, every intact event record after it, and —
/// when the tail was torn or corrupted — the typed error describing where
/// validity ended. Scanning never panics on hostile bytes: anything up to
/// the last complete checksummed record is returned.
#[derive(Debug)]
pub struct ScannedWal {
    /// The run recipe from the first record.
    pub genesis: Genesis,
    /// Intact event records after the genesis, in append order.
    pub records: Vec<WalRecord>,
    /// `Some` when the scan stopped early at a torn/corrupt record; always
    /// a [`HydraError::WalCorrupt`].
    pub torn: Option<HydraError>,
}

/// Scan a WAL file, validating framing and checksums record by record.
///
/// Errors (`Err`) only for an unreadable file, a bad magic, or a
/// torn/corrupt *genesis* — without the first record there is nothing to
/// recover. Corruption after the genesis is not an error: the scan stops
/// at the first bad byte and reports it in [`ScannedWal::torn`].
pub fn scan_wal(path: &Path) -> Result<ScannedWal> {
    let buf = std::fs::read(path)?;
    if buf.len() < WAL_MAGIC.len() || &buf[..WAL_MAGIC.len()] != WAL_MAGIC {
        return Err(HydraError::WalCorrupt(format!(
            "{}: not a Hydra WAL (bad magic)",
            path.display()
        )));
    }
    let mut off = WAL_MAGIC.len();
    let mut genesis: Option<Genesis> = None;
    let mut records = Vec::new();
    let mut torn = None;
    while off < buf.len() {
        let rest = &buf[off..];
        if rest.len() < 8 {
            torn = Some(HydraError::WalCorrupt(format!(
                "torn record header at byte {off} ({} trailing bytes)",
                rest.len()
            )));
            break;
        }
        let len = u32::from_le_bytes([rest[0], rest[1], rest[2], rest[3]]) as usize;
        let crc = u32::from_le_bytes([rest[4], rest[5], rest[6], rest[7]]);
        if rest.len() - 8 < len {
            torn = Some(HydraError::WalCorrupt(format!(
                "torn record at byte {off}: payload needs {len} bytes, {} left",
                rest.len() - 8
            )));
            break;
        }
        let payload = &rest[8..8 + len];
        if crc32(payload) != crc {
            torn = Some(HydraError::WalCorrupt(format!(
                "checksum mismatch at byte {off}"
            )));
            break;
        }
        match WalRecord::decode_payload(payload) {
            Ok(rec) => match (&genesis, rec) {
                (None, WalRecord::GenesisRun(spec)) => genesis = Some(Genesis::Run(spec)),
                (None, WalRecord::GenesisSearch(text)) => {
                    genesis = Some(Genesis::Search(text))
                }
                (None, other) => {
                    return Err(HydraError::WalCorrupt(format!(
                        "first record is {other:?}, expected a genesis"
                    )))
                }
                (Some(_), rec) => records.push(rec),
            },
            Err(e) => {
                // checksum held but the payload would not decode — a
                // corrupt (or future-versioned) record; stop here
                torn = Some(e);
                break;
            }
        }
        off += 8 + len;
    }
    match genesis {
        Some(genesis) => Ok(ScannedWal { genesis, records, torn }),
        None => Err(match torn {
            Some(HydraError::WalCorrupt(m)) => {
                HydraError::WalCorrupt(format!("genesis record unrecoverable: {m}"))
            }
            _ => HydraError::WalCorrupt(format!(
                "{}: empty WAL (no genesis record)",
                path.display()
            )),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::ShardDesc;
    use crate::coordinator::Cluster;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("hydra-wal-test-{}-{name}", std::process::id()));
        p
    }

    pub(crate) fn tiny_spec() -> RunSpec {
        let shard = ShardDesc {
            param_bytes: 1 << 20,
            fwd_transfer_bytes: 1 << 20,
            bwd_transfer_bytes: 1 << 20,
            activation_bytes: 1 << 10,
            fwd_cost: 1.0,
            bwd_cost: 2.0,
            n_layers: 1,
        };
        let cluster = Cluster::uniform(2, 1 << 30, 8 << 30);
        RunSpec {
            tasks: vec![
                ModelTask::new(0, "a", "sim", vec![shard.clone()], 2, 1, 1e-3),
                ModelTask::new(1, "b", "sim", vec![shard], 1, 1, 1e-3),
            ],
            devices: cluster.devices,
            memory: MemoryOptions::dram_only(cluster.dram_bytes),
            policy: Policy::default(),
            options: EngineOptions::default(),
            cluster_events: Vec::new(),
            job_events: Vec::new(),
            noise: 0.0,
            backend_seed: 0,
        }
    }

    #[test]
    fn genesis_round_trips_and_replays_identically() {
        let spec = tiny_spec();
        let mut w = ByteWriter::new();
        spec.encode(&mut w);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        let back = RunSpec::decode(&mut r).unwrap();
        r.expect_end().unwrap();
        let a = spec.run(None).unwrap();
        let b = back.run(None).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn wal_writer_logs_a_run_and_scan_reads_it_back() {
        let path = tmp("roundtrip");
        let spec = tiny_spec();
        let mut wal = WalWriter::create(&path).unwrap();
        wal.append(&WalRecord::GenesisRun(spec.clone()));
        let report = spec.run(Some(&mut wal)).unwrap();
        wal.append(&WalRecord::RunEnd { makespan: report.makespan });
        wal.finish().unwrap();

        let scanned = scan_wal(&path).unwrap();
        assert!(scanned.torn.is_none());
        assert!(matches!(scanned.genesis, Genesis::Run(_)));
        // 2 jobs x (arrive + finish) + 6 retires + decisions + intervals + end
        let retires = scanned
            .records
            .iter()
            .filter(|r| matches!(r, WalRecord::UnitRetired { .. }))
            .count();
        assert_eq!(retires as u64, report.units_executed);
        assert!(matches!(
            scanned.records.last(),
            Some(WalRecord::RunEnd { .. })
        ));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn every_record_kind_round_trips() {
        let unit = crate::coordinator::unit::UnitGeometry::new(1, 2, 1).unit_at(0, 1);
        let iv = Interval {
            device: 1,
            start: 0.5,
            end: 1.5,
            model: 0,
            shard: 0,
            phase: crate::coordinator::unit::Phase::Bwd,
            unit_seq: 3,
            kind: crate::coordinator::metrics::IntervalKind::Transfer,
        };
        let records = vec![
            WalRecord::GenesisRun(tiny_spec()),
            WalRecord::GenesisSearch("{\"search\":{}}".into()),
            WalRecord::ShardBegin { shard: 1, n_shards: 4 },
            WalRecord::JobSubmitted { model: 3, name: "late".into(), now: 2.0 },
            WalRecord::JobArrived { model: 3, name: "late".into(), now: 2.5 },
            WalRecord::Decision { device: 0, model: 3, prefetch: true, now: 3.0 },
            WalRecord::UnitRetired { device: 0, unit, now: 4.0 },
            WalRecord::JobFinished { model: 3, now: 5.0, cancelled: true },
            WalRecord::JobCancelRequested { model: 3, now: 4.5 },
            WalRecord::Spill {
                device: 1,
                promoted: 10,
                demoted: 20,
                tier: MemTier::Nvme,
                now: 1.0,
            },
            WalRecord::Interval(iv),
            WalRecord::SnapshotMark { events_dispatched: 99 },
            WalRecord::RunEnd { makespan: 123.5 },
        ];
        for rec in &records {
            let payload = rec.encode_payload();
            let back = WalRecord::decode_payload(&payload).unwrap();
            match (rec, &back) {
                (WalRecord::GenesisRun(a), WalRecord::GenesisRun(b)) => {
                    // ModelTask's Debug includes runtime state; spec-level
                    // equality via re-encoding
                    let (mut wa, mut wb) = (ByteWriter::new(), ByteWriter::new());
                    a.encode(&mut wa);
                    b.encode(&mut wb);
                    assert_eq!(wa.as_slice(), wb.as_slice());
                }
                _ => assert_eq!(format!("{rec:?}"), format!("{back:?}")),
            }
        }
    }

    #[test]
    fn scan_rejects_bad_magic_and_missing_genesis() {
        let path = tmp("bad-magic");
        std::fs::write(&path, b"NOTAWAL!").unwrap();
        assert!(matches!(
            scan_wal(&path),
            Err(HydraError::WalCorrupt(_))
        ));
        std::fs::write(&path, WAL_MAGIC).unwrap();
        let err = scan_wal(&path).unwrap_err();
        assert!(format!("{err}").contains("genesis"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn append_to_refuses_non_wal_files() {
        let path = tmp("not-a-wal");
        std::fs::write(&path, b"hello world").unwrap();
        assert!(matches!(
            WalWriter::append_to(&path),
            Err(HydraError::WalCorrupt(_))
        ));
        std::fs::remove_file(&path).ok();
    }
}
