//! Replay and crash recovery.
//!
//! Three entry points, all built on the same fact — the engine is a
//! deterministic function of its genesis:
//!
//! * [`run_durable`]: the durable run loop. Writes the genesis, streams
//!   every engine event into the WAL, and (single-engine runs) persists a
//!   full state snapshot every `snapshot_every` dispatched events.
//! * [`replay`]: pure replay. Reads nothing but the genesis record and
//!   re-runs it; the result is Debug-byte-identical to the original run.
//! * [`recover`]: crash recovery. Prefers snapshot + forward-run (bounded
//!   work: only the suffix after the last snapshot re-executes); falls
//!   back to genesis replay when there is no usable snapshot — including
//!   sharded runs, whose N interleaved engines have no single-point state.
//!
//! Recovery path:
//!
//! ```text
//!             scan_wal(path)
//!                  |
//!         +--------+---------+
//!         v                  v
//!   Genesis::Run        Genesis::Search
//!         |                  |
//!   .snap sidecar?      re-run spec JSON
//!    |          |       (durability off)
//!    v          v
//!  restore    replay
//!  + step     genesis
//!  forward    from 0
//!    |          |
//!    +----+-----+
//!         v
//!  identical RunReport
//! ```

use std::path::Path;

use crate::coordinator::memory::MemTier;
use crate::coordinator::metrics::Interval;
use crate::coordinator::observer::{EngineObserver, NoopObserver, TraceRecorder};
use crate::coordinator::sharp::{RunReport, ShardId, ShardSection, SharpEngine};
use crate::coordinator::unit::ShardUnit;
use crate::error::{HydraError, Result};
use crate::exec::SimBackend;
use crate::selection::SearchReport;
use crate::util::codec::{ByteReader, ByteWriter};

use super::snapshot::{read_snapshot, snapshot_path, write_snapshot, Snapshot};
use super::wal::{scan_wal, Genesis, RunSpec, WalRecord, WalWriter};
use super::DurabilityOptions;

/// The observer a durable run installs: every event goes to the WAL, then
/// to the trace recorder (when the run records intervals), then to the
/// user's own observer.
pub(crate) struct DurableTap<'o> {
    pub(crate) wal: WalWriter,
    pub(crate) rec: Option<TraceRecorder>,
    pub(crate) user: Option<&'o mut dyn EngineObserver>,
}

impl EngineObserver for DurableTap<'_> {
    fn on_job_submitted(&mut self, model: usize, name: &str, now: f64) {
        self.wal.on_job_submitted(model, name, now);
        if let Some(u) = self.user.as_deref_mut() {
            u.on_job_submitted(model, name, now);
        }
    }

    fn on_job_cancel_requested(&mut self, model: usize, now: f64) {
        self.wal.on_job_cancel_requested(model, now);
        if let Some(u) = self.user.as_deref_mut() {
            u.on_job_cancel_requested(model, now);
        }
    }

    fn on_job_arrived(&mut self, model: usize, name: &str, now: f64) {
        self.wal.on_job_arrived(model, name, now);
        if let Some(u) = self.user.as_deref_mut() {
            u.on_job_arrived(model, name, now);
        }
    }

    fn on_decision(&mut self, device: usize, model: usize, prefetch: bool, now: f64) {
        self.wal.on_decision(device, model, prefetch, now);
        if let Some(u) = self.user.as_deref_mut() {
            u.on_decision(device, model, prefetch, now);
        }
    }

    fn on_unit_retired(&mut self, device: usize, unit: &ShardUnit, now: f64) {
        self.wal.on_unit_retired(device, unit, now);
        if let Some(u) = self.user.as_deref_mut() {
            u.on_unit_retired(device, unit, now);
        }
    }

    fn on_job_finished(&mut self, model: usize, now: f64, cancelled: bool) {
        self.wal.on_job_finished(model, now, cancelled);
        if let Some(u) = self.user.as_deref_mut() {
            u.on_job_finished(model, now, cancelled);
        }
    }

    fn on_spill(&mut self, device: usize, promoted: u64, demoted: u64, tier: MemTier, now: f64) {
        self.wal.on_spill(device, promoted, demoted, tier, now);
        if let Some(u) = self.user.as_deref_mut() {
            u.on_spill(device, promoted, demoted, tier, now);
        }
    }

    fn on_interval(&mut self, interval: &Interval) {
        self.wal.on_interval(interval);
        if let Some(rec) = self.rec.as_mut() {
            rec.intervals.push(*interval);
        }
        if let Some(u) = self.user.as_deref_mut() {
            u.on_interval(interval);
        }
    }

    fn on_shard_begin(&mut self, shard: ShardId, n_shards: usize) {
        self.wal.on_shard_begin(shard, n_shards);
        if let Some(u) = self.user.as_deref_mut() {
            u.on_shard_begin(shard, n_shards);
        }
    }
}

/// Persist the engine's complete state to the snapshot sidecar.
fn take_snapshot(
    path: &Path,
    dispatched: u64,
    engine: &SharpEngine<'_>,
    rec: Option<&TraceRecorder>,
) -> Result<()> {
    let backend_rng = engine.backend.sim_rng_state().ok_or_else(|| {
        HydraError::Config(
            "durability snapshots need the sim backend (the real backend's \
             wallclock is not replayable)"
                .into(),
        )
    })?;
    let mut w = ByteWriter::new();
    engine.encode_state(&mut w);
    let snap = Snapshot {
        events_dispatched: dispatched,
        backend_rng,
        intervals: rec.map(|r| r.intervals.clone()).unwrap_or_default(),
        engine_state: w.into_inner(),
    };
    write_snapshot(path, &snap)
}

/// Run `spec` durably: genesis + every event into a fresh WAL at
/// `dur.wal`, snapshots every `dur.snapshot_every` dispatched events
/// (single-engine runs; sharded runs log per-shard WALs but have no
/// single-point snapshot and recover by genesis replay). The report is
/// byte-identical to a non-durable run of the same spec.
pub(crate) fn run_durable(
    spec: &RunSpec,
    dur: &DurabilityOptions,
    user: Option<&mut dyn EngineObserver>,
) -> Result<(RunReport, Vec<ShardSection>)> {
    let mut wal = WalWriter::create(&dur.wal)?;
    wal.append(&WalRecord::GenesisRun(spec.clone()));
    let mut backend = SimBackend::new(spec.noise, spec.backend_seed);

    if spec.options.shards > 1 {
        let mut tap = DurableTap { wal, rec: None, user };
        let (report, sections) = spec.run_on(&mut backend, Some(&mut tap))?;
        tap.wal.append(&WalRecord::RunEnd { makespan: report.makespan });
        tap.wal.finish()?;
        return Ok((report, sections));
    }

    let snap_path = snapshot_path(&dur.wal);
    let mut tap = DurableTap {
        wal,
        rec: spec.options.record_intervals.then(TraceRecorder::default),
        user,
    };
    let mut engine = SharpEngine::with_devices(
        spec.tasks.clone(),
        &spec.devices,
        spec.memory,
        spec.policy.build(),
        &mut backend,
        spec.options.clone(),
    )?
    .with_cluster_events(spec.cluster_events.clone())
    .with_job_events(spec.job_events.clone());

    engine.prime(&mut tap);
    let mut dispatched: u64 = 0;
    while engine.step(&mut tap)? {
        dispatched += 1;
        if dur.snapshot_every > 0 && dispatched % dur.snapshot_every == 0 {
            tap.wal.append(&WalRecord::SnapshotMark { events_dispatched: dispatched });
            // the WAL on disk must never lag the snapshot that marks it
            tap.wal.flush();
            take_snapshot(&snap_path, dispatched, &engine, tap.rec.as_ref())?;
        }
    }
    let mut report = engine.finalize()?;
    if let Some(rec) = tap.rec.take() {
        report.trace.intervals = rec.intervals;
    }
    tap.wal.append(&WalRecord::RunEnd { makespan: report.makespan });
    tap.wal.finish()?;
    Ok((report, Vec::new()))
}

/// Pure replay: re-run the WAL's genesis from nothing and return the
/// report, Debug-byte-identical to the original run's. Ignores snapshots
/// and the event suffix entirely — determinism is the proof.
pub fn replay(wal: &Path) -> Result<RunReport> {
    match scan_wal(wal)?.genesis {
        Genesis::Run(spec) => spec.run(None),
        Genesis::Search(_) => Err(HydraError::Config(
            "this WAL records a model-selection search, not an engine run; \
             use `hydra recover` instead"
                .into(),
        )),
    }
}

/// What [`recover`] produced: an engine run's report or a re-driven
/// search's report, depending on the WAL's genesis.
#[derive(Debug)]
pub enum Recovered {
    /// The WAL recorded an engine run.
    Run(RunReport),
    /// The WAL recorded a model-selection search.
    Search(SearchReport),
}

/// Recover the run (or search) a WAL belongs to after a crash.
///
/// Engine runs resume from the snapshot sidecar when one is present and
/// intact — only the suffix after the snapshot re-executes — and fall back
/// to genesis replay otherwise (missing/corrupt sidecar, sharded runs).
/// Search WALs re-drive the recorded spec JSON with durability disabled
/// (recovery must never clobber the WAL it is reading). Either way the
/// result is byte-identical to what the uninterrupted run would have
/// produced.
pub fn recover(wal: &Path) -> Result<Recovered> {
    match scan_wal(wal)?.genesis {
        Genesis::Run(spec) => Ok(Recovered::Run(recover_run(wal, &spec)?)),
        Genesis::Search(text) => {
            let mut workload = crate::config::SearchWorkload::parse(&text)?;
            workload.durability = None;
            Ok(Recovered::Search(workload.run()?))
        }
    }
}

fn recover_run(wal: &Path, spec: &RunSpec) -> Result<RunReport> {
    if spec.options.shards <= 1 {
        match read_snapshot(&snapshot_path(wal)) {
            Ok(Some(snap)) => match resume_from(spec, &snap) {
                Ok(report) => return Ok(report),
                // corrupt snapshot state: degrade to full replay
                Err(HydraError::WalCorrupt(_)) => {}
                Err(e) => return Err(e),
            },
            Ok(None) => {}
            // corrupt sidecar framing: likewise degrade to full replay
            Err(HydraError::WalCorrupt(_)) => {}
            Err(e) => return Err(e),
        }
    }
    spec.run(None)
}

/// Rebuild the engine from the genesis skeleton + snapshot state and run
/// it forward to completion.
fn resume_from(spec: &RunSpec, snap: &Snapshot) -> Result<RunReport> {
    let mut backend = SimBackend::from_state(spec.noise, snap.backend_rng);
    let mut engine = SharpEngine::with_devices(
        spec.tasks.clone(),
        &spec.devices,
        spec.memory,
        spec.policy.build(),
        &mut backend,
        spec.options.clone(),
    )?
    // Cluster events stay registered: queued `Event::Cluster(i)` entries in
    // the restored queue index into this list. Job events deliberately do
    // NOT: a resumed engine never primes, and the snapshot's queue already
    // carries every submit/cancel event.
    .with_cluster_events(spec.cluster_events.clone());
    let mut r = ByteReader::new(&snap.engine_state);
    engine.restore_state(&mut r)?;
    r.expect_end()?;

    if spec.options.record_intervals {
        let mut rec = TraceRecorder { intervals: snap.intervals.clone() };
        while engine.step(&mut rec)? {}
        let mut report = engine.finalize()?;
        report.trace.intervals = rec.intervals;
        Ok(report)
    } else {
        let mut obs = NoopObserver;
        while engine.step(&mut obs)? {}
        engine.finalize()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::Policy;
    use crate::coordinator::sharp::{ClusterEvent, EngineOptions, JobEvent};
    use crate::coordinator::task::{ModelTask, ShardDesc};
    use crate::coordinator::Cluster;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("hydra-replay-test-{}-{name}", std::process::id()));
        p
    }

    fn shard(mb: u64) -> ShardDesc {
        ShardDesc {
            param_bytes: mb << 20,
            fwd_transfer_bytes: mb << 20,
            bwd_transfer_bytes: mb << 20,
            activation_bytes: 1 << 16,
            fwd_cost: 0.4,
            bwd_cost: 0.8,
            n_layers: 2,
        }
    }

    /// A busy spec: three construction tasks (one late-arriving), a mid-run
    /// submission, a cancellation, a device failure, noise, intervals.
    fn busy_spec(shards: usize) -> RunSpec {
        let cluster = Cluster::uniform(4, 64 << 20, 1 << 30);
        let tasks = vec![
            ModelTask::new(0, "m0", "sim", vec![shard(8), shard(8)], 3, 2, 1e-3),
            ModelTask::new(1, "m1", "sim", vec![shard(16)], 4, 2, 1e-3),
            ModelTask::new(2, "m2", "sim", vec![shard(4), shard(4)], 2, 2, 1e-3)
                .with_arrival(1.5),
        ];
        let late = ModelTask::new(3, "late", "sim", vec![shard(8)], 2, 1, 1e-3);
        RunSpec {
            tasks,
            devices: cluster.devices,
            memory: crate::coordinator::memory::MemoryOptions::dram_only(
                cluster.dram_bytes,
            ),
            policy: Policy::default(),
            options: EngineOptions {
                record_intervals: true,
                shards,
                ..EngineOptions::default()
            },
            cluster_events: vec![ClusterEvent::Fail { time: 2.5, device: 3 }],
            job_events: vec![
                JobEvent::Submit { time: 1.0, task: late },
                JobEvent::Cancel { time: 3.0, model: 1 },
            ],
            noise: 0.05,
            backend_seed: 11,
        }
    }

    #[test]
    fn durable_run_matches_plain_run_and_replay() {
        let wal = tmp("replay-identity");
        let spec = busy_spec(1);
        let baseline = spec.run(None).unwrap();
        let dur = DurabilityOptions::new(&wal).snapshot_every(16);
        let (durable, sections) = run_durable(&spec, &dur, None).unwrap();
        assert!(sections.is_empty());
        assert_eq!(format!("{baseline:?}"), format!("{durable:?}"));
        let replayed = replay(&wal).unwrap();
        assert_eq!(format!("{baseline:?}"), format!("{replayed:?}"));
        std::fs::remove_file(&wal).ok();
        std::fs::remove_file(snapshot_path(&wal)).ok();
    }

    #[test]
    fn recover_resumes_from_mid_run_snapshot_byte_identically() {
        let wal = tmp("resume");
        let spec = busy_spec(1);
        let baseline = spec.run(None).unwrap();
        // small interval => the sidecar retains a genuinely mid-run state
        let dur = DurabilityOptions::new(&wal).snapshot_every(7);
        run_durable(&spec, &dur, None).unwrap();
        let snap = read_snapshot(&snapshot_path(&wal)).unwrap().unwrap();
        assert!(snap.events_dispatched >= 7);
        let resumed = match recover(&wal).unwrap() {
            Recovered::Run(r) => r,
            other => panic!("expected a run, got {other:?}"),
        };
        assert_eq!(format!("{baseline:?}"), format!("{resumed:?}"));
        std::fs::remove_file(&wal).ok();
        std::fs::remove_file(snapshot_path(&wal)).ok();
    }

    #[test]
    fn recover_without_sidecar_replays_from_genesis() {
        let wal = tmp("no-sidecar");
        let spec = busy_spec(1);
        let baseline = spec.run(None).unwrap();
        let dur = DurabilityOptions::new(&wal); // snapshots disabled
        run_durable(&spec, &dur, None).unwrap();
        assert!(read_snapshot(&snapshot_path(&wal)).unwrap().is_none());
        let recovered = match recover(&wal).unwrap() {
            Recovered::Run(r) => r,
            other => panic!("expected a run, got {other:?}"),
        };
        assert_eq!(format!("{baseline:?}"), format!("{recovered:?}"));
        std::fs::remove_file(&wal).ok();
    }

    #[test]
    fn corrupt_sidecar_degrades_to_genesis_replay() {
        let wal = tmp("corrupt-sidecar");
        let spec = busy_spec(1);
        let baseline = spec.run(None).unwrap();
        let dur = DurabilityOptions::new(&wal).snapshot_every(7);
        run_durable(&spec, &dur, None).unwrap();
        let sp = snapshot_path(&wal);
        let mut bytes = std::fs::read(&sp).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&sp, &bytes).unwrap();
        let recovered = match recover(&wal).unwrap() {
            Recovered::Run(r) => r,
            other => panic!("expected a run, got {other:?}"),
        };
        assert_eq!(format!("{baseline:?}"), format!("{recovered:?}"));
        std::fs::remove_file(&wal).ok();
        std::fs::remove_file(&sp).ok();
    }

    #[test]
    fn sharded_durable_run_replays_and_recovers_from_genesis() {
        for n in [2usize, 4] {
            let wal = tmp(&format!("sharded-{n}"));
            let spec = busy_spec(n);
            let baseline = spec.run(None).unwrap();
            let dur = DurabilityOptions::new(&wal).snapshot_every(8);
            let (durable, sections) = run_durable(&spec, &dur, None).unwrap();
            assert_eq!(sections.len(), n);
            assert_eq!(format!("{baseline:?}"), format!("{durable:?}"));
            let replayed = replay(&wal).unwrap();
            assert_eq!(format!("{baseline:?}"), format!("{replayed:?}"));
            let recovered = match recover(&wal).unwrap() {
                Recovered::Run(r) => r,
                other => panic!("expected a run, got {other:?}"),
            };
            assert_eq!(format!("{baseline:?}"), format!("{recovered:?}"));
            // per-shard sidecar WALs exist and carry their ShardBegin mark
            for k in 0..n {
                let mut p = wal.clone().into_os_string();
                p.push(format!(".shard{k}"));
                let p = PathBuf::from(p);
                let bytes = std::fs::read(&p).unwrap();
                assert_eq!(&bytes[..8], super::super::wal::WAL_MAGIC);
                std::fs::remove_file(&p).ok();
            }
            std::fs::remove_file(&wal).ok();
        }
    }
}
