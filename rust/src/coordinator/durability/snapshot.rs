//! Engine-state snapshots: a sidecar file (`<wal>.snap`) holding one
//! checksummed dump of the complete mid-run engine state, rewritten
//! atomically (tmp + rename) every [`snapshot_every`] dispatched events.
//!
//! Recovery pairs the snapshot with its WAL: restore the state, then run
//! the engine forward to completion — bounded work proportional to the
//! crash-to-snapshot distance instead of the whole run. A missing sidecar
//! is not an error (recovery replays from the genesis); a corrupt one is
//! reported as [`HydraError::WalCorrupt`] and recovery likewise falls back
//! to full replay.
//!
//! [`snapshot_every`]: super::DurabilityOptions::snapshot_every

use std::path::{Path, PathBuf};

use crate::coordinator::metrics::Interval;
use crate::error::{HydraError, Result};
use crate::util::codec::{crc32, ByteReader, ByteWriter};

/// File magic of a Hydra snapshot sidecar.
pub const SNAP_MAGIC: &[u8; 8] = b"HYSNAP02";

/// One complete mid-run engine state.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Events the engine had dispatched when this was taken — pairs the
    /// snapshot with its [`WalRecord::SnapshotMark`] in the log.
    ///
    /// [`WalRecord::SnapshotMark`]: super::wal::WalRecord::SnapshotMark
    pub events_dispatched: u64,
    /// The sim backend's noise-stream PRNG state.
    pub backend_rng: [u64; 4],
    /// Intervals recorded so far (empty unless the run records them) —
    /// the [`TraceRecorder`] is outside the engine, so its accumulation
    /// rides here.
    ///
    /// [`TraceRecorder`]: crate::coordinator::observer::TraceRecorder
    pub intervals: Vec<Interval>,
    /// Opaque engine dump ([`SharpEngine::encode_state`]).
    ///
    /// [`SharpEngine::encode_state`]: crate::coordinator::sharp::SharpEngine
    pub engine_state: Vec<u8>,
}

impl Snapshot {
    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u64(self.events_dispatched);
        for s in self.backend_rng {
            w.put_u64(s);
        }
        w.put_usize(self.intervals.len());
        for iv in &self.intervals {
            iv.encode(&mut w);
        }
        w.put_bytes(&self.engine_state);
        w.into_inner()
    }

    fn decode(payload: &[u8]) -> Result<Snapshot> {
        let mut r = ByteReader::new(payload);
        let events_dispatched = r.get_u64()?;
        let mut backend_rng = [0u64; 4];
        for s in &mut backend_rng {
            *s = r.get_u64()?;
        }
        let n = r.get_count(42)?;
        let mut intervals = Vec::with_capacity(n);
        for _ in 0..n {
            intervals.push(Interval::decode(&mut r)?);
        }
        let engine_state = r.get_bytes()?.to_vec();
        r.expect_end()?;
        Ok(Snapshot { events_dispatched, backend_rng, intervals, engine_state })
    }
}

/// The sidecar path for a WAL: `<wal>.snap` (appended, not substituted, so
/// `run.wal` -> `run.wal.snap` and extensionless paths work too).
pub fn snapshot_path(wal: &Path) -> PathBuf {
    let mut p = wal.to_path_buf().into_os_string();
    p.push(".snap");
    PathBuf::from(p)
}

/// Atomically persist `snap` at `path`: write `<path>.tmp`, fsync-free
/// rename over the old sidecar. A crash mid-write leaves either the
/// previous intact snapshot or a stray tmp file — never a half-written
/// sidecar at the final path.
pub fn write_snapshot(path: &Path, snap: &Snapshot) -> Result<()> {
    let payload = snap.encode();
    let mut buf = Vec::with_capacity(SNAP_MAGIC.len() + 8 + payload.len());
    buf.extend_from_slice(SNAP_MAGIC);
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&crc32(&payload).to_le_bytes());
    buf.extend_from_slice(&payload);
    let mut tmp = path.to_path_buf().into_os_string();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, &buf)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Read the snapshot at `path`. `Ok(None)` when the sidecar does not exist
/// (the run never reached a snapshot interval); [`HydraError::WalCorrupt`]
/// when it exists but fails the magic, framing or checksum — callers fall
/// back to genesis replay on that.
pub fn read_snapshot(path: &Path) -> Result<Option<Snapshot>> {
    let buf = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let corrupt = |m: &str| {
        HydraError::WalCorrupt(format!("{}: {m}", path.display()))
    };
    if buf.len() < SNAP_MAGIC.len() + 8 {
        return Err(corrupt("snapshot shorter than its header"));
    }
    if &buf[..SNAP_MAGIC.len()] != SNAP_MAGIC {
        return Err(corrupt("not a Hydra snapshot (bad magic)"));
    }
    let rest = &buf[SNAP_MAGIC.len()..];
    let len = u32::from_le_bytes([rest[0], rest[1], rest[2], rest[3]]) as usize;
    let crc = u32::from_le_bytes([rest[4], rest[5], rest[6], rest[7]]);
    if rest.len() - 8 != len {
        return Err(corrupt("snapshot length disagrees with its header"));
    }
    let payload = &rest[8..];
    if crc32(payload) != crc {
        return Err(corrupt("snapshot checksum mismatch"));
    }
    let snap = Snapshot::decode(payload)?;
    Ok(Some(snap))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::IntervalKind;
    use crate::coordinator::unit::Phase;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("hydra-snap-test-{}-{name}", std::process::id()));
        p
    }

    fn sample() -> Snapshot {
        Snapshot {
            events_dispatched: 42,
            backend_rng: [1, 2, 3, 4],
            intervals: vec![Interval {
                device: 0,
                start: 1.0,
                end: 2.0,
                model: 1,
                shard: 0,
                phase: Phase::Fwd,
                unit_seq: 7,
                kind: IntervalKind::Compute,
            }],
            engine_state: vec![9, 8, 7, 6, 5],
        }
    }

    #[test]
    fn snapshot_round_trips_through_disk() {
        let path = tmp("roundtrip");
        write_snapshot(&path, &sample()).unwrap();
        let back = read_snapshot(&path).unwrap().unwrap();
        assert_eq!(format!("{:?}", sample()), format!("{back:?}"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_sidecar_is_none_and_corrupt_is_typed() {
        let path = tmp("missing");
        std::fs::remove_file(&path).ok();
        assert!(read_snapshot(&path).unwrap().is_none());

        write_snapshot(&path, &sample()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_snapshot(&path),
            Err(HydraError::WalCorrupt(_))
        ));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sidecar_path_appends_snap() {
        assert_eq!(
            snapshot_path(Path::new("/x/run.wal")),
            PathBuf::from("/x/run.wal.snap")
        );
        assert_eq!(
            snapshot_path(Path::new("run")),
            PathBuf::from("run.snap")
        );
    }
}
