//! Execution traces and derived metrics: makespan, per-device busy time,
//! GPU utilization (Fig 8's second panel), transfer/stall accounting, and
//! an ASCII Gantt renderer for the Fig 3/6-style schedule illustrations.

use std::collections::BTreeMap;

use crate::coordinator::unit::Phase;
use crate::error::{HydraError, Result};
use crate::util::codec::{ByteReader, ByteWriter};

/// What a device interval was spent on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntervalKind {
    /// Shard-unit compute.
    Compute,
    /// Synchronous DRAM<->device transfer (spilling cost).
    Transfer,
    /// Waiting on an in-flight double-buffer prefetch.
    BufferStall,
    /// Synchronous NVMe<->DRAM staging (DRAM-miss fetch + forced eviction
    /// write-backs) blocking the device's promote path.
    NvmeTransfer,
}

impl IntervalKind {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            IntervalKind::Compute => 0,
            IntervalKind::Transfer => 1,
            IntervalKind::BufferStall => 2,
            IntervalKind::NvmeTransfer => 3,
        });
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<IntervalKind> {
        match r.get_u8()? {
            0 => Ok(IntervalKind::Compute),
            1 => Ok(IntervalKind::Transfer),
            2 => Ok(IntervalKind::BufferStall),
            3 => Ok(IntervalKind::NvmeTransfer),
            t => Err(HydraError::WalCorrupt(format!(
                "unknown interval kind tag {t}"
            ))),
        }
    }
}

/// One device-time interval in the schedule.
#[derive(Debug, Clone, Copy)]
pub struct Interval {
    /// Device the interval occurred on.
    pub device: usize,
    /// Virtual start time.
    pub start: f64,
    /// Virtual end time.
    pub end: f64,
    /// Model the interval served.
    pub model: usize,
    /// Shard index within the model.
    pub shard: u32,
    /// Forward or backward.
    pub phase: Phase,
    /// Queue position of the unit (for ordering invariants in tests).
    pub unit_seq: u64,
    /// What the time was spent on.
    pub kind: IntervalKind,
}

impl Interval {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_usize(self.device);
        w.put_f64(self.start);
        w.put_f64(self.end);
        w.put_usize(self.model);
        w.put_u32(self.shard);
        self.phase.encode(w);
        w.put_u64(self.unit_seq);
        self.kind.encode(w);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<Interval> {
        Ok(Interval {
            device: r.get_usize()?,
            start: r.get_f64()?,
            end: r.get_f64()?,
            model: r.get_usize()?,
            shard: r.get_u32()?,
            phase: Phase::decode(r)?,
            unit_seq: r.get_u64()?,
            kind: IntervalKind::decode(r)?,
        })
    }
}

/// Full execution trace of a run.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// Every recorded device-time interval.
    pub intervals: Vec<Interval>,
    /// Device lifetime windows [start, end) for utilization denominators
    /// (devices may arrive/leave mid-run).
    pub device_windows: BTreeMap<usize, (f64, f64)>,
    /// Virtual time the last interval ends.
    pub makespan: f64,
}

impl Trace {
    /// Append an interval, extending the makespan.
    pub fn record(&mut self, iv: Interval) {
        debug_assert!(iv.end >= iv.start);
        if iv.end > self.makespan {
            self.makespan = iv.end;
        }
        self.intervals.push(iv);
    }

    /// Set the lifetime window of `device` (infinity = until run end).
    pub fn set_device_window(&mut self, device: usize, start: f64, end: f64) {
        self.device_windows.insert(device, (start, end));
    }

    /// Clamp open-ended device windows to the final makespan.
    pub fn close_device_windows(&mut self) {
        let mk = self.makespan;
        for (_, (_, end)) in self.device_windows.iter_mut() {
            if !end.is_finite() {
                *end = mk;
            }
        }
    }

    /// Total compute seconds across devices.
    pub fn compute_time(&self) -> f64 {
        self.time_of(IntervalKind::Compute)
    }

    /// Total synchronous transfer seconds.
    pub fn transfer_time(&self) -> f64 {
        self.time_of(IntervalKind::Transfer)
    }

    /// Total double-buffer stall seconds.
    pub fn stall_time(&self) -> f64 {
        self.time_of(IntervalKind::BufferStall)
    }

    /// Total synchronous NVMe staging seconds (zero without an NVMe tier).
    pub fn nvme_time(&self) -> f64 {
        self.time_of(IntervalKind::NvmeTransfer)
    }

    fn time_of(&self, kind: IntervalKind) -> f64 {
        self.intervals
            .iter()
            .filter(|iv| iv.kind == kind)
            .map(|iv| iv.end - iv.start)
            .sum()
    }

    /// Total device-seconds available across all device windows.
    pub fn device_seconds(&self) -> f64 {
        self.device_windows
            .values()
            .map(|&(s, e)| (e.min(self.makespan) - s).max(0.0))
            .sum()
    }

    /// GPU utilization: compute time / available device time (the paper's
    /// Fig 8 metric; transfers and stalls count as idle).
    pub fn utilization(&self) -> f64 {
        let denom = self.device_seconds();
        if denom <= 0.0 {
            0.0
        } else {
            self.compute_time() / denom
        }
    }

    /// Number of compute intervals (one per retired unit when interval
    /// recording is on).
    pub fn units_executed(&self) -> usize {
        self.intervals
            .iter()
            .filter(|iv| iv.kind == IntervalKind::Compute)
            .count()
    }

    /// Per-device busy (compute) seconds.
    pub fn per_device_compute(&self) -> BTreeMap<usize, f64> {
        let mut m = BTreeMap::new();
        for iv in &self.intervals {
            if iv.kind == IntervalKind::Compute {
                *m.entry(iv.device).or_insert(0.0) += iv.end - iv.start;
            }
        }
        m
    }

    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_usize(self.intervals.len());
        for iv in &self.intervals {
            iv.encode(w);
        }
        w.put_usize(self.device_windows.len());
        for (&d, &(s, e)) in &self.device_windows {
            w.put_usize(d);
            w.put_f64(s);
            w.put_f64(e);
        }
        w.put_f64(self.makespan);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<Trace> {
        // each interval: 2 usize + 2 f64 + u32 + phase + u64 + kind
        let n = r.get_count(42)?;
        let mut intervals = Vec::with_capacity(n);
        for _ in 0..n {
            intervals.push(Interval::decode(r)?);
        }
        let n = r.get_count(24)?;
        let mut device_windows = BTreeMap::new();
        for _ in 0..n {
            let d = r.get_usize()?;
            device_windows.insert(d, (r.get_f64()?, r.get_f64()?));
        }
        Ok(Trace { intervals, device_windows, makespan: r.get_f64()? })
    }

    /// ASCII Gantt chart (Fig 3 / Fig 6 style). Each row is a device; each
    /// column a time bucket; cells show the model letter for compute,
    /// '·' transfer, '~' stall, '%' NVMe staging, ' ' idle.
    pub fn gantt(&self, width: usize) -> String {
        if self.makespan <= 0.0 || self.intervals.is_empty() {
            return String::from("(empty trace)\n");
        }
        let devices: Vec<usize> = self.device_windows.keys().copied().collect();
        let scale = width as f64 / self.makespan;
        let mut out = String::new();
        for &d in &devices {
            let mut row = vec![' '; width];
            for iv in self.intervals.iter().filter(|iv| iv.device == d) {
                let a = (iv.start * scale) as usize;
                let b = ((iv.end * scale) as usize).min(width.saturating_sub(1));
                for c in row.iter_mut().take(b + 1).skip(a.min(width - 1)) {
                    *c = match iv.kind {
                        IntervalKind::Compute => model_letter(iv.model),
                        IntervalKind::Transfer => '·',
                        IntervalKind::BufferStall => '~',
                        IntervalKind::NvmeTransfer => '%',
                    };
                }
            }
            out.push_str(&format!("dev{d:>2} |{}|\n", row.iter().collect::<String>()));
        }
        out.push_str(&format!(
            "        0{:>width$.2}s\n",
            self.makespan,
            width = width - 1
        ));
        out
    }
}

fn model_letter(model: usize) -> char {
    (b'A' + (model % 26) as u8) as char
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iv(device: usize, start: f64, end: f64, model: usize, kind: IntervalKind) -> Interval {
        Interval { device, start, end, model, shard: 0, phase: Phase::Fwd, unit_seq: 0, kind }
    }

    #[test]
    fn makespan_tracks_latest_end() {
        let mut t = Trace::default();
        t.record(iv(0, 0.0, 2.0, 0, IntervalKind::Compute));
        t.record(iv(1, 1.0, 5.0, 1, IntervalKind::Compute));
        assert_eq!(t.makespan, 5.0);
    }

    #[test]
    fn utilization_counts_only_compute() {
        let mut t = Trace::default();
        t.set_device_window(0, 0.0, f64::INFINITY);
        t.set_device_window(1, 0.0, f64::INFINITY);
        t.record(iv(0, 0.0, 4.0, 0, IntervalKind::Compute));
        t.record(iv(1, 0.0, 1.0, 1, IntervalKind::Transfer));
        t.record(iv(1, 1.0, 2.0, 1, IntervalKind::Compute));
        t.record(iv(1, 2.0, 4.0, 1, IntervalKind::BufferStall));
        t.close_device_windows();
        // makespan 4, device-seconds 8, compute 5
        assert!((t.utilization() - 5.0 / 8.0).abs() < 1e-12);
        assert_eq!(t.compute_time(), 5.0);
        assert_eq!(t.transfer_time(), 1.0);
        assert_eq!(t.stall_time(), 2.0);
        assert_eq!(t.nvme_time(), 0.0);
    }

    #[test]
    fn nvme_intervals_are_idle_time_with_their_own_total() {
        let mut t = Trace::default();
        t.set_device_window(0, 0.0, f64::INFINITY);
        t.record(iv(0, 0.0, 3.0, 0, IntervalKind::NvmeTransfer));
        t.record(iv(0, 3.0, 4.0, 0, IntervalKind::Compute));
        t.close_device_windows();
        assert_eq!(t.nvme_time(), 3.0);
        assert!((t.utilization() - 0.25).abs() < 1e-12);
        assert!(t.gantt(8).contains('%'));
    }

    #[test]
    fn device_windows_bound_denominator() {
        let mut t = Trace::default();
        t.set_device_window(0, 0.0, f64::INFINITY);
        t.set_device_window(1, 2.0, f64::INFINITY); // arrived late
        t.record(iv(0, 0.0, 4.0, 0, IntervalKind::Compute));
        t.close_device_windows();
        assert!((t.device_seconds() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn per_device_compute_aggregates() {
        let mut t = Trace::default();
        t.record(iv(0, 0.0, 1.0, 0, IntervalKind::Compute));
        t.record(iv(0, 2.0, 3.0, 1, IntervalKind::Compute));
        t.record(iv(1, 0.0, 0.5, 2, IntervalKind::Compute));
        let m = t.per_device_compute();
        assert!((m[&0] - 2.0).abs() < 1e-12);
        assert!((m[&1] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn gantt_renders_rows() {
        let mut t = Trace::default();
        t.set_device_window(0, 0.0, f64::INFINITY);
        t.set_device_window(1, 0.0, f64::INFINITY);
        t.record(iv(0, 0.0, 1.0, 0, IntervalKind::Compute));
        t.record(iv(1, 0.5, 1.0, 1, IntervalKind::Compute));
        t.close_device_windows();
        let g = t.gantt(20);
        assert!(g.contains("dev 0"));
        assert!(g.contains('A'));
        assert!(g.contains('B'));
    }

    #[test]
    fn codec_round_trips_a_trace() {
        let mut t = Trace::default();
        t.set_device_window(0, 0.0, f64::INFINITY);
        t.record(iv(0, 0.0, 1.0, 0, IntervalKind::Compute));
        t.record(iv(0, 1.0, 2.5, 1, IntervalKind::NvmeTransfer));
        let mut w = ByteWriter::new();
        t.encode(&mut w);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        let back = Trace::decode(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(format!("{t:?}"), format!("{back:?}"));
    }

    #[test]
    fn empty_trace_gantt_is_safe() {
        let t = Trace::default();
        assert_eq!(t.gantt(10), "(empty trace)\n");
        assert_eq!(t.utilization(), 0.0);
    }
}
