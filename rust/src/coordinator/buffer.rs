//! Double-buffering (§4.6): each device reserves a buffer zone; while a unit
//! computes, the *next* scheduled unit's shard parameters are prefetched into
//! the zone, hiding DRAM->device latency. On retire, the buffered shard is
//! promoted zone->active at zero cost.
//!
//! The timing math lives in the SHARP engine; this module owns the zone
//! lifecycle and the stall computation, so it can be unit-tested in
//! isolation and disabled wholesale for Table 3's ablation.

use crate::coordinator::memory::{DeviceLedger, Residency};
use crate::error::Result;

/// Per-device double-buffer state. The zone is sized from the owning
/// device's own capacity (a fraction of [`DeviceLedger::capacity`]), so in
/// heterogeneous pools bigger devices stage bigger prefetches.
#[derive(Debug, Clone)]
pub struct DoubleBuffer {
    /// Whether prefetching is active (Table 3 ablation disables it).
    pub enabled: bool,
    /// Bytes reserved in the device ledger for the loading zone.
    pub zone_bytes: u64,
    /// Shard currently staged in the zone, with the virtual time its
    /// transfer completes.
    staged: Option<StagedShard>,
}

/// A shard parked in the buffer zone mid-prefetch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StagedShard {
    /// Model the staged shard belongs to.
    pub model: usize,
    /// Shard index within the model.
    pub shard: u32,
    /// Bytes being transferred.
    pub bytes: u64,
    /// Virtual time when the prefetch transfer finishes.
    pub ready_at: f64,
}

impl DoubleBuffer {
    /// Reserve the zone in the ledger (done once at startup, mirroring the
    /// partitioner's §4.6 "protect a buffer space during partitioning").
    pub fn new(enabled: bool, zone_bytes: u64, ledger: &mut DeviceLedger) -> Result<DoubleBuffer> {
        if enabled {
            ledger.alloc(Residency::BufferZone, zone_bytes)?;
        }
        Ok(DoubleBuffer { enabled, zone_bytes, staged: None })
    }

    /// The shard currently staged, if any.
    pub fn staged(&self) -> Option<&StagedShard> {
        self.staged.as_ref()
    }

    /// Begin prefetching a shard into the zone at time `now`; the transfer
    /// takes `transfer_secs`. Overwrites any previous staging (the engine
    /// never stages two shards at once per device). Returns whether the
    /// shard was staged: a shard larger than the zone (or a disabled
    /// buffer) is refused — in release builds too, so callers fall back to
    /// a synchronous transfer instead of silently overcommitting the zone.
    #[must_use]
    pub fn stage(
        &mut self,
        model: usize,
        shard: u32,
        bytes: u64,
        now: f64,
        transfer_secs: f64,
    ) -> bool {
        if !self.enabled || bytes > self.zone_bytes {
            return false;
        }
        self.staged = Some(StagedShard { model, shard, bytes, ready_at: now + transfer_secs });
        true
    }

    /// At unit start time `now`, consume the staged shard if it matches.
    /// Returns the *stall* the device incurs waiting for the prefetch to
    /// finish (0 when compute fully hid the transfer — the §4.6 payoff).
    pub fn consume(&mut self, model: usize, shard: u32, now: f64) -> Option<f64> {
        match self.staged {
            Some(s) if s.model == model && s.shard == shard => {
                self.staged = None;
                Some((s.ready_at - now).max(0.0))
            }
            _ => None,
        }
    }

    /// Drop any staging (device loss / model early-stop).
    pub fn clear(&mut self) {
        self.staged = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ledger() -> DeviceLedger {
        DeviceLedger::new(0, 1_000)
    }

    #[test]
    fn zone_reserved_in_ledger() {
        let mut l = ledger();
        let _b = DoubleBuffer::new(true, 50, &mut l).unwrap();
        assert_eq!(l.used(), 50);
        assert!(l.contains(&Residency::BufferZone));
    }

    #[test]
    fn disabled_buffer_reserves_nothing() {
        let mut l = ledger();
        let _b = DoubleBuffer::new(false, 50, &mut l).unwrap();
        assert_eq!(l.used(), 0);
    }

    #[test]
    fn transfer_hidden_behind_compute_has_zero_stall() {
        let mut l = ledger();
        let mut b = DoubleBuffer::new(true, 100, &mut l).unwrap();
        // prefetch starts at t=0, takes 2s; unit starts at t=5 (compute hid it)
        assert!(b.stage(3, 1, 80, 0.0, 2.0));
        let stall = b.consume(3, 1, 5.0).unwrap();
        assert_eq!(stall, 0.0);
        assert!(b.staged().is_none());
    }

    #[test]
    fn slow_transfer_produces_partial_stall() {
        let mut l = ledger();
        let mut b = DoubleBuffer::new(true, 100, &mut l).unwrap();
        assert!(b.stage(3, 1, 80, 0.0, 7.0));
        let stall = b.consume(3, 1, 5.0).unwrap();
        assert!((stall - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mismatched_consume_returns_none() {
        let mut l = ledger();
        let mut b = DoubleBuffer::new(true, 100, &mut l).unwrap();
        assert!(b.stage(3, 1, 80, 0.0, 1.0));
        assert!(b.consume(4, 1, 2.0).is_none());
        // staging preserved for the matching consumer
        assert!(b.staged().is_some());
    }

    #[test]
    fn oversized_shard_is_refused_not_overcommitted() {
        let mut l = ledger();
        let mut b = DoubleBuffer::new(true, 100, &mut l).unwrap();
        // larger than the zone: refused in release builds too
        assert!(!b.stage(3, 1, 200, 0.0, 1.0));
        assert!(b.staged().is_none());
        assert!(b.consume(3, 1, 2.0).is_none());
    }

    #[test]
    fn disabled_buffer_refuses_staging() {
        let mut l = ledger();
        let mut b = DoubleBuffer::new(false, 100, &mut l).unwrap();
        assert!(!b.stage(1, 0, 10, 0.0, 1.0));
        assert!(b.staged().is_none());
    }

    #[test]
    fn clear_drops_staging() {
        let mut l = ledger();
        let mut b = DoubleBuffer::new(true, 100, &mut l).unwrap();
        assert!(b.stage(1, 0, 10, 0.0, 1.0));
        b.clear();
        assert!(b.staged().is_none());
    }
}
