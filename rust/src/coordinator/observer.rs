//! Streaming observation of a running SHARP engine.
//!
//! [`EngineObserver`] is the engine's event tap: every scheduling decision,
//! spill, retired unit, job arrival/finish and recorded interval flows
//! through it *as it happens* in virtual time. The engine itself keeps only
//! scalar aggregates (makespan, compute/transfer/stall seconds) on the hot
//! path — per-interval trace bookkeeping is just one observer
//! ([`TraceRecorder`]), so callers that do not need a trace pay nothing for
//! it (quantified in `rust/benches/hotpath.rs`), while callers that want
//! live gantt/progress streaming for online runs implement the trait and
//! pass it to `run_with`.

use crate::coordinator::engine::routing::ShardId;
use crate::coordinator::memory::MemTier;
use crate::coordinator::metrics::Interval;
use crate::coordinator::unit::ShardUnit;

/// Observer of engine events, called synchronously from the engine's
/// virtual-time loop. All methods default to no-ops so implementations
/// override only what they care about; implementations must be cheap — they
/// run on the dispatch hot path.
pub trait EngineObserver {
    /// A job the engine first learned about mid-run ([`crate::coordinator
    /// ::engine::jobs::JobEvent::Submit`]) was accepted and assigned
    /// `model`. Fires before the matching [`EngineObserver::on_job_arrived`]
    /// (which may be deferred until the job's arrival time passes). Jobs
    /// known up front never emit this.
    fn on_job_submitted(&mut self, _model: usize, _name: &str, _now: f64) {}

    /// A mid-run submission was rejected by admission control: its tenant
    /// already had `depth` unfinished jobs queued
    /// ([`crate::coordinator::engine::EngineOptions::admission_depth`]).
    /// The job still occupies `model` in the dense id space but finishes
    /// instantly with zero units; neither
    /// [`EngineObserver::on_job_submitted`] nor
    /// [`EngineObserver::on_job_arrived`] fires for it.
    fn on_job_shed(
        &mut self,
        _model: usize,
        _name: &str,
        _tenant: usize,
        _depth: usize,
        _now: f64,
    ) {
    }

    /// A tenant requested cancellation of `model`
    /// ([`crate::coordinator::engine::jobs::JobEvent::Cancel`]). Fires on
    /// every request, idempotent duplicates included; the effect (if any)
    /// is reported by [`EngineObserver::on_job_finished`] with
    /// `cancelled == true`.
    fn on_job_cancel_requested(&mut self, _model: usize, _now: f64) {}

    /// A job entered the eligible set (its arrival time passed, or it was
    /// submitted mid-run with an arrival in the past).
    fn on_job_arrived(&mut self, _model: usize, _name: &str, _now: f64) {}

    /// The scheduler picked `model` for `device` — either to run now
    /// (`prefetch == false`) or as a double-buffer pre-claim
    /// (`prefetch == true`).
    fn on_decision(&mut self, _device: usize, _model: usize, _prefetch: bool, _now: f64) {}

    /// A shard unit retired on `device` at `now`.
    fn on_unit_retired(&mut self, _device: usize, _unit: &ShardUnit, _now: f64) {}

    /// A job finished (all units retired, or a cancellation took effect).
    /// Fires exactly once per job.
    fn on_job_finished(&mut self, _model: usize, _now: f64, _cancelled: bool) {}

    /// Spill traffic on one hierarchy link, serving `device`. For
    /// [`MemTier::Dram`]: `promoted` bytes moved DRAM->device and/or
    /// `demoted` bytes flowed back device->DRAM. For [`MemTier::Nvme`]:
    /// `promoted` bytes were fetched NVMe->DRAM and `demoted` bytes were
    /// written back DRAM->NVMe by the evictions that fetch forced. `now` is
    /// the virtual time the corresponding transfer starts.
    fn on_spill(
        &mut self,
        _device: usize,
        _promoted: u64,
        _demoted: u64,
        _tier: MemTier,
        _now: f64,
    ) {
    }

    /// A device-time interval (compute / transfer / buffer-stall) was
    /// recorded. This is the trace feed: [`TraceRecorder`] collects these
    /// into [`crate::coordinator::metrics::Trace::intervals`].
    fn on_interval(&mut self, _interval: &Interval) {}

    /// A sharded run ([`crate::coordinator::engine::sharded::ShardedEngine`])
    /// is about to drive shard `shard` of `n_shards`: every event until the
    /// next call belongs to that shard (with device/job ids already
    /// remapped to the global namespace). Single-engine runs never emit
    /// this.
    fn on_shard_begin(&mut self, _shard: ShardId, _n_shards: usize) {}
}

/// The do-nothing observer: the engine's hot path with zero bookkeeping.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopObserver;

impl EngineObserver for NoopObserver {}

/// Collects per-interval trace entries — the pre-redesign
/// `record_intervals: true` behaviour as an opt-in observer. The engine's
/// `run()` installs one automatically when
/// `EngineOptions::record_intervals` is set, so existing callers see
/// identical `RunReport`s.
#[derive(Debug, Clone, Default)]
pub struct TraceRecorder {
    /// Every interval observed, in recording order.
    pub intervals: Vec<Interval>,
}

impl EngineObserver for TraceRecorder {
    fn on_interval(&mut self, interval: &Interval) {
        self.intervals.push(*interval);
    }
}

/// Fan out engine events to two observers (used by
/// [`crate::coordinator::sharp::SharpEngine::run_observed`] to combine a
/// caller's observer with the built-in trace recorder).
pub struct Tee<'a>(pub &'a mut dyn EngineObserver, pub &'a mut dyn EngineObserver);

impl EngineObserver for Tee<'_> {
    fn on_job_submitted(&mut self, model: usize, name: &str, now: f64) {
        self.0.on_job_submitted(model, name, now);
        self.1.on_job_submitted(model, name, now);
    }

    fn on_job_shed(&mut self, model: usize, name: &str, tenant: usize, depth: usize, now: f64) {
        self.0.on_job_shed(model, name, tenant, depth, now);
        self.1.on_job_shed(model, name, tenant, depth, now);
    }

    fn on_job_cancel_requested(&mut self, model: usize, now: f64) {
        self.0.on_job_cancel_requested(model, now);
        self.1.on_job_cancel_requested(model, now);
    }

    fn on_job_arrived(&mut self, model: usize, name: &str, now: f64) {
        self.0.on_job_arrived(model, name, now);
        self.1.on_job_arrived(model, name, now);
    }

    fn on_decision(&mut self, device: usize, model: usize, prefetch: bool, now: f64) {
        self.0.on_decision(device, model, prefetch, now);
        self.1.on_decision(device, model, prefetch, now);
    }

    fn on_unit_retired(&mut self, device: usize, unit: &ShardUnit, now: f64) {
        self.0.on_unit_retired(device, unit, now);
        self.1.on_unit_retired(device, unit, now);
    }

    fn on_job_finished(&mut self, model: usize, now: f64, cancelled: bool) {
        self.0.on_job_finished(model, now, cancelled);
        self.1.on_job_finished(model, now, cancelled);
    }

    fn on_spill(&mut self, device: usize, promoted: u64, demoted: u64, tier: MemTier, now: f64) {
        self.0.on_spill(device, promoted, demoted, tier, now);
        self.1.on_spill(device, promoted, demoted, tier, now);
    }

    fn on_interval(&mut self, interval: &Interval) {
        self.0.on_interval(interval);
        self.1.on_interval(interval);
    }

    fn on_shard_begin(&mut self, shard: ShardId, n_shards: usize) {
        self.0.on_shard_begin(shard, n_shards);
        self.1.on_shard_begin(shard, n_shards);
    }
}

/// One buffered engine event, ids in whatever namespace the producing
/// engine used (shard-local for a threaded shard run). The variants mirror
/// the [`EngineObserver`] methods one-to-one; names are owned so the buffer
/// is `Send` and outlives the engine that produced it.
#[derive(Debug, Clone)]
enum BufferedEvent {
    JobSubmitted { model: usize, name: String, now: f64 },
    JobShed { model: usize, name: String, tenant: usize, depth: usize, now: f64 },
    JobCancelRequested { model: usize, now: f64 },
    JobArrived { model: usize, name: String, now: f64 },
    Decision { device: usize, model: usize, prefetch: bool, now: f64 },
    UnitRetired { device: usize, unit: ShardUnit, now: f64 },
    JobFinished { model: usize, now: f64, cancelled: bool },
    Spill { device: usize, promoted: u64, demoted: u64, tier: MemTier, now: f64 },
    Interval(Interval),
}

/// Records every engine event for later, ordered replay — the observer
/// fan-in half of threaded sharded execution. Each shard thread streams
/// into its own private `BufferedEvents` (no cross-thread observer calls
/// ever happen), and after all threads join, the sharded engine replays the
/// buffers *in shard order* through the caller's real observer. The replay
/// is byte-for-byte the event stream the sequential shard loop would have
/// produced, which is what keeps streaming consumers (`WalWriter`,
/// `TraceRecorder`, gantt/progress) correct without being `Send`.
#[derive(Debug, Clone, Default)]
pub struct BufferedEvents {
    events: Vec<BufferedEvent>,
}

impl BufferedEvents {
    /// Number of buffered events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Replay the buffer into `obs` in recording order.
    pub fn replay(&self, obs: &mut dyn EngineObserver) {
        for ev in &self.events {
            match ev {
                BufferedEvent::JobSubmitted { model, name, now } => {
                    obs.on_job_submitted(*model, name, *now)
                }
                BufferedEvent::JobShed { model, name, tenant, depth, now } => {
                    obs.on_job_shed(*model, name, *tenant, *depth, *now)
                }
                BufferedEvent::JobCancelRequested { model, now } => {
                    obs.on_job_cancel_requested(*model, *now)
                }
                BufferedEvent::JobArrived { model, name, now } => {
                    obs.on_job_arrived(*model, name, *now)
                }
                BufferedEvent::Decision { device, model, prefetch, now } => {
                    obs.on_decision(*device, *model, *prefetch, *now)
                }
                BufferedEvent::UnitRetired { device, unit, now } => {
                    obs.on_unit_retired(*device, unit, *now)
                }
                BufferedEvent::JobFinished { model, now, cancelled } => {
                    obs.on_job_finished(*model, *now, *cancelled)
                }
                BufferedEvent::Spill { device, promoted, demoted, tier, now } => {
                    obs.on_spill(*device, *promoted, *demoted, *tier, *now)
                }
                BufferedEvent::Interval(iv) => obs.on_interval(iv),
            }
        }
    }
}

impl EngineObserver for BufferedEvents {
    fn on_job_submitted(&mut self, model: usize, name: &str, now: f64) {
        self.events.push(BufferedEvent::JobSubmitted { model, name: name.into(), now });
    }

    fn on_job_shed(&mut self, model: usize, name: &str, tenant: usize, depth: usize, now: f64) {
        self.events.push(BufferedEvent::JobShed {
            model,
            name: name.into(),
            tenant,
            depth,
            now,
        });
    }

    fn on_job_cancel_requested(&mut self, model: usize, now: f64) {
        self.events.push(BufferedEvent::JobCancelRequested { model, now });
    }

    fn on_job_arrived(&mut self, model: usize, name: &str, now: f64) {
        self.events.push(BufferedEvent::JobArrived { model, name: name.into(), now });
    }

    fn on_decision(&mut self, device: usize, model: usize, prefetch: bool, now: f64) {
        self.events.push(BufferedEvent::Decision { device, model, prefetch, now });
    }

    fn on_unit_retired(&mut self, device: usize, unit: &ShardUnit, now: f64) {
        self.events.push(BufferedEvent::UnitRetired { device, unit: *unit, now });
    }

    fn on_job_finished(&mut self, model: usize, now: f64, cancelled: bool) {
        self.events.push(BufferedEvent::JobFinished { model, now, cancelled });
    }

    fn on_spill(&mut self, device: usize, promoted: u64, demoted: u64, tier: MemTier, now: f64) {
        self.events.push(BufferedEvent::Spill { device, promoted, demoted, tier, now });
    }

    fn on_interval(&mut self, interval: &Interval) {
        self.events.push(BufferedEvent::Interval(*interval));
    }

    // on_shard_begin is deliberately NOT buffered: a shard thread's engine
    // never emits it (only the sharded front door does, on the real
    // observer, right before replaying this buffer).
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::IntervalKind;
    use crate::coordinator::unit::Phase;

    fn iv(start: f64, end: f64) -> Interval {
        Interval {
            device: 0,
            start,
            end,
            model: 0,
            shard: 0,
            phase: Phase::Fwd,
            unit_seq: 0,
            kind: IntervalKind::Compute,
        }
    }

    #[test]
    fn trace_recorder_collects_in_order() {
        let mut rec = TraceRecorder::default();
        rec.on_interval(&iv(0.0, 1.0));
        rec.on_interval(&iv(1.0, 2.0));
        assert_eq!(rec.intervals.len(), 2);
        assert_eq!(rec.intervals[1].start, 1.0);
    }

    #[test]
    fn buffered_events_replay_in_recording_order() {
        let mut buf = BufferedEvents::default();
        buf.on_job_arrived(1, "a", 0.0);
        buf.on_interval(&iv(0.0, 1.0));
        buf.on_job_finished(1, 1.0, false);
        assert_eq!(buf.len(), 3);
        let mut rec = TraceRecorder::default();
        buf.replay(&mut rec);
        assert_eq!(rec.intervals.len(), 1);
        // replay is non-destructive: the same buffer replays again
        buf.replay(&mut rec);
        assert_eq!(rec.intervals.len(), 2);
        assert!(!buf.is_empty());
    }

    #[test]
    fn tee_feeds_both_observers() {
        let mut a = TraceRecorder::default();
        let mut b = TraceRecorder::default();
        {
            let mut tee = Tee(&mut a, &mut b);
            tee.on_interval(&iv(0.0, 1.0));
            tee.on_job_finished(3, 1.0, false);
        }
        assert_eq!(a.intervals.len(), 1);
        assert_eq!(b.intervals.len(), 1);
    }
}
