//! Shard units — the paper's basic unit of computation (§4.4): the forward
//! or backward pass of one model shard on one mini-batch.
//!
//! A model's training run is a totally ordered queue of shard units that
//! unifies ordering within a mini-batch (fwd shards then bwd shards), across
//! mini-batches, and across epochs (§4.7). We never materialise the queue —
//! it can reach tens of millions of entries (§4.4) — instead a unit is
//! *derived* from its position index in O(1).

use crate::error::{HydraError, Result};
use crate::util::codec::{ByteReader, ByteWriter};

/// Direction of a shard unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Forward pass.
    Fwd,
    /// Backward pass.
    Bwd,
}

impl Phase {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            Phase::Fwd => 0,
            Phase::Bwd => 1,
        });
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<Phase> {
        match r.get_u8()? {
            0 => Ok(Phase::Fwd),
            1 => Ok(Phase::Bwd),
            t => Err(HydraError::WalCorrupt(format!("unknown phase tag {t}"))),
        }
    }
}

/// A fully-resolved shard unit description.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardUnit {
    /// Index of the owning model task.
    pub model: usize,
    /// Position in the model's unit queue (0-based).
    pub seq_idx: u64,
    /// Epoch number (0-based).
    pub epoch: u32,
    /// Mini-batch within the epoch (0-based).
    pub minibatch: u32,
    /// Shard index within the model (0-based, front-to-back).
    pub shard: u32,
    pub phase: Phase,
}

impl ShardUnit {
    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_usize(self.model);
        w.put_u64(self.seq_idx);
        w.put_u32(self.epoch);
        w.put_u32(self.minibatch);
        w.put_u32(self.shard);
        self.phase.encode(w);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<ShardUnit> {
        Ok(ShardUnit {
            model: r.get_usize()?,
            seq_idx: r.get_u64()?,
            epoch: r.get_u32()?,
            minibatch: r.get_u32()?,
            shard: r.get_u32()?,
            phase: Phase::decode(r)?,
        })
    }
}

/// Geometry of a model's unit queue: derives units from positions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UnitGeometry {
    /// Number of shards the model was partitioned into.
    pub n_shards: u32,
    /// Mini-batches per epoch (inference: batches total).
    pub minibatches_per_epoch: u32,
    /// Training epochs (inference: 1).
    pub epochs: u32,
    /// Training (fwd+bwd per mini-batch) vs inference (fwd only) — the
    /// paper's §6 observation that spilling/partitioning/orchestration
    /// already suffice for out-of-the-box large-model inference.
    pub inference_only: bool,
}

impl UnitGeometry {
    /// Training geometry: fwd+bwd over every shard, per mini-batch.
    pub fn new(n_shards: u32, minibatches_per_epoch: u32, epochs: u32) -> Self {
        assert!(n_shards > 0 && minibatches_per_epoch > 0 && epochs > 0);
        UnitGeometry { n_shards, minibatches_per_epoch, epochs, inference_only: false }
    }

    /// Inference geometry: forward-only over `batches` batches.
    pub fn new_inference(n_shards: u32, batches: u32) -> Self {
        assert!(n_shards > 0 && batches > 0);
        UnitGeometry {
            n_shards,
            minibatches_per_epoch: batches,
            epochs: 1,
            inference_only: true,
        }
    }

    /// Units per mini-batch: fwd (+ bwd when training) over every shard.
    pub fn units_per_minibatch(&self) -> u64 {
        if self.inference_only {
            self.n_shards as u64
        } else {
            2 * self.n_shards as u64
        }
    }

    pub fn units_per_epoch(&self) -> u64 {
        self.units_per_minibatch() * self.minibatches_per_epoch as u64
    }

    /// Total shard units for the whole training run (the paper's M_i).
    pub fn total_units(&self) -> u64 {
        self.units_per_epoch() * self.epochs as u64
    }

    /// Whether `unit` is the last unit of its epoch: the final mini-batch's
    /// bwd of shard 0 when training, or its fwd of the last shard when
    /// inference-only. The engine consults early-stop votes exactly here,
    /// and the selection driver records per-epoch losses at the same
    /// boundary — one predicate, shared so the two can never drift.
    pub fn closes_epoch(&self, unit: &ShardUnit) -> bool {
        unit.minibatch + 1 == self.minibatches_per_epoch
            && match unit.phase {
                Phase::Bwd => unit.shard == 0,
                Phase::Fwd => self.inference_only && unit.shard + 1 == self.n_shards,
            }
    }

    /// Derive the unit at queue position `seq_idx` for model `model`.
    pub fn unit_at(&self, model: usize, seq_idx: u64) -> ShardUnit {
        debug_assert!(seq_idx < self.total_units());
        let upe = self.units_per_epoch();
        let upm = self.units_per_minibatch();
        let epoch = (seq_idx / upe) as u32;
        let in_epoch = seq_idx % upe;
        let minibatch = (in_epoch / upm) as u32;
        let in_mb = in_epoch % upm;
        let (shard, phase) = if in_mb < self.n_shards as u64 {
            (in_mb as u32, Phase::Fwd)
        } else {
            debug_assert!(!self.inference_only);
            // bwd walks the shards in reverse: S-1, S-2, ..., 0
            ((2 * self.n_shards as u64 - 1 - in_mb) as u32, Phase::Bwd)
        };
        ShardUnit { model, seq_idx, epoch, minibatch, shard, phase }
    }

    pub(crate) fn encode(&self, w: &mut ByteWriter) {
        w.put_u32(self.n_shards);
        w.put_u32(self.minibatches_per_epoch);
        w.put_u32(self.epochs);
        w.put_bool(self.inference_only);
    }

    pub(crate) fn decode(r: &mut ByteReader<'_>) -> Result<UnitGeometry> {
        let g = UnitGeometry {
            n_shards: r.get_u32()?,
            minibatches_per_epoch: r.get_u32()?,
            epochs: r.get_u32()?,
            inference_only: r.get_bool()?,
        };
        if g.n_shards == 0 || g.minibatches_per_epoch == 0 || g.epochs == 0 {
            return Err(HydraError::WalCorrupt("zero-sized unit geometry".into()));
        }
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_counts() {
        let g = UnitGeometry::new(3, 4, 2);
        assert_eq!(g.units_per_minibatch(), 6);
        assert_eq!(g.units_per_epoch(), 24);
        assert_eq!(g.total_units(), 48);
    }

    #[test]
    fn first_minibatch_order_is_fwd_then_reverse_bwd() {
        let g = UnitGeometry::new(3, 2, 1);
        let seq: Vec<(u32, Phase)> =
            (0..6).map(|i| {
                let u = g.unit_at(0, i);
                (u.shard, u.phase)
            }).collect();
        assert_eq!(seq, vec![
            (0, Phase::Fwd), (1, Phase::Fwd), (2, Phase::Fwd),
            (2, Phase::Bwd), (1, Phase::Bwd), (0, Phase::Bwd),
        ]);
    }

    #[test]
    fn epoch_and_minibatch_derivation() {
        let g = UnitGeometry::new(2, 3, 2);
        // 4 units per minibatch, 12 per epoch
        let u = g.unit_at(7, 13);
        assert_eq!(u.model, 7);
        assert_eq!(u.epoch, 1);
        assert_eq!(u.minibatch, 0);
        assert_eq!(u.shard, 1);
        assert_eq!(u.phase, Phase::Fwd);
        let u = g.unit_at(7, 23);
        assert_eq!(u.epoch, 1);
        assert_eq!(u.minibatch, 2);
        assert_eq!(u.shard, 0);
        assert_eq!(u.phase, Phase::Bwd);
    }

    #[test]
    fn closes_epoch_fires_once_per_epoch() {
        let g = UnitGeometry::new(3, 2, 2);
        let boundaries: Vec<u64> = (0..g.total_units())
            .filter(|&i| g.closes_epoch(&g.unit_at(0, i)))
            .collect();
        // exactly one boundary per epoch: the last minibatch's bwd of
        // shard 0, i.e. the final unit of each epoch
        assert_eq!(
            boundaries,
            vec![g.units_per_epoch() - 1, 2 * g.units_per_epoch() - 1]
        );
    }

    #[test]
    fn codec_round_trips_units_and_geometry() {
        let g = UnitGeometry::new(4, 5, 3);
        let u = g.unit_at(7, 23);
        let mut w = ByteWriter::new();
        u.encode(&mut w);
        g.encode(&mut w);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert_eq!(ShardUnit::decode(&mut r).unwrap(), u);
        assert_eq!(UnitGeometry::decode(&mut r).unwrap(), g);
        r.expect_end().unwrap();
    }

    #[test]
    fn every_position_round_trips_monotonically() {
        let g = UnitGeometry::new(4, 5, 3);
        let mut last: Option<ShardUnit> = None;
        for i in 0..g.total_units() {
            let u = g.unit_at(0, i);
            assert_eq!(u.seq_idx, i);
            if let Some(prev) = last {
                assert!((u.epoch, u.minibatch) >= (prev.epoch, prev.minibatch));
            }
            last = Some(u);
        }
    }
}
// (appended) inference-geometry tests live alongside the training ones.
#[cfg(test)]
mod inference_tests {
    use super::*;

    #[test]
    fn inference_geometry_is_fwd_only() {
        let g = UnitGeometry::new_inference(3, 4);
        assert_eq!(g.units_per_minibatch(), 3);
        assert_eq!(g.total_units(), 12);
        for i in 0..g.total_units() {
            let u = g.unit_at(0, i);
            assert_eq!(u.phase, Phase::Fwd);
            assert_eq!(u.shard as u64, i % 3);
        }
    }

    #[test]
    fn inference_epochs_close_on_the_last_shard_fwd() {
        let g = UnitGeometry::new_inference(2, 3);
        let boundaries: Vec<u64> = (0..g.total_units())
            .filter(|&i| g.closes_epoch(&g.unit_at(0, i)))
            .collect();
        // forward-only: the final batch's last-shard fwd closes the epoch
        assert_eq!(boundaries, vec![g.total_units() - 1]);
    }

    #[test]
    fn inference_batches_advance() {
        let g = UnitGeometry::new_inference(2, 3);
        assert_eq!(g.unit_at(0, 4).minibatch, 2);
        assert_eq!(g.unit_at(0, 4).shard, 0);
    }
}
