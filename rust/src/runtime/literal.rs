//! HostTensor <-> xla::Literal conversion. This is the DRAM->device promotion
//! boundary of the real execution backend.

use crate::error::Result;
use crate::tensor::{DType, HostTensor, TensorData};

/// Convert a host tensor into an XLA literal (bytes are copied).
pub fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let (ty, bytes): (xla::ElementType, &[u8]) = match &t.data {
        TensorData::F32(v) => (xla::ElementType::F32, bytemuck_f32(v)),
        TensorData::I32(v) => (xla::ElementType::S32, bytemuck_i32(v)),
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, bytes)?)
}

/// Convert an XLA literal back into a host tensor.
pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v: Vec<f32> = lit.to_vec()?;
            Ok(HostTensor::from_f32(&dims, v))
        }
        xla::ElementType::S32 => {
            let v: Vec<i32> = lit.to_vec()?;
            Ok(HostTensor::from_i32(&dims, v))
        }
        other => Err(crate::error::HydraError::Exec(format!(
            "unsupported literal element type {other:?}"
        ))),
    }
}

pub fn dtype_of(d: DType) -> xla::ElementType {
    match d {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
    }
}

fn bytemuck_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trip() {
        let t = HostTensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = to_literal(&t).unwrap();
        let back = from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn i32_round_trip() {
        let t = HostTensor::from_i32(&[4], vec![-1, 0, 7, 42]);
        let back = from_literal(&to_literal(&t).unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_round_trip() {
        let t = HostTensor::scalar_f32(2.25);
        let back = from_literal(&to_literal(&t).unwrap()).unwrap();
        assert_eq!(back.scalar_value(), 2.25);
        assert!(back.shape.is_empty());
    }
}
