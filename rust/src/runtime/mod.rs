//! Runtime layer: manifest-driven loading and execution of the AOT-compiled
//! HLO artifacts via the PJRT C API (`xla` crate).
//!
//! Python authored and lowered the computations at build time (`make
//! artifacts`); this module is everything the training path needs —
//! Python is never on the request path.

pub mod client;
pub mod literal;
pub mod manifest;

pub use client::{LoadedExecutable, RuntimeClient};
pub use manifest::{
    ConfigArtifacts, ExecutableSpec, InitSpec, IoSpec, Manifest, ModelConfig,
    ModelKind, ParamSpec,
};
