//! PJRT runtime client: loads AOT HLO-text artifacts, compiles them once,
//! caches the executables, and runs shard units.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* interchange
//! (xla_extension 0.5.1 rejects jax>=0.5 serialized protos), compile on the
//! CPU PJRT client, execute with literals, unwrap the return tuple.

use std::collections::HashMap;
use std::rc::Rc;
use std::time::{Duration, Instant};

use crate::error::{HydraError, Result};
use crate::runtime::literal::{from_literal, to_literal};
use crate::runtime::manifest::{ConfigArtifacts, ExecutableSpec, Manifest};
use crate::tensor::HostTensor;

/// A compiled shard entry point plus its manifest spec.
pub struct LoadedExecutable {
    pub spec: ExecutableSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedExecutable {
    /// Run with host tensors; returns host tensors (tuple flattened).
    pub fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(HydraError::Exec(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape != spec.shape {
                return Err(HydraError::Exec(format!(
                    "{}: input {} shape mismatch: got {:?}, want {:?}",
                    self.spec.name, spec.name, t.shape, spec.shape
                )));
            }
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| to_literal(t)).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        // aot.py lowers with return_tuple=True: single tuple output.
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            return Err(HydraError::Exec(format!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            )));
        }
        parts.iter().map(from_literal).collect()
    }

    /// Run and measure wallclock (the real backend's shard-unit cost probe).
    pub fn run_timed(&self, inputs: &[&HostTensor]) -> Result<(Vec<HostTensor>, Duration)> {
        let t0 = Instant::now();
        let out = self.run(inputs)?;
        Ok((out, t0.elapsed()))
    }
}

/// PJRT client + executable cache, keyed by (config, entry point).
pub struct RuntimeClient {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<(String, String), Rc<LoadedExecutable>>,
}

impl RuntimeClient {
    pub fn new(manifest: Manifest) -> Result<RuntimeClient> {
        let client = xla::PjRtClient::cpu()?;
        Ok(RuntimeClient { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn config(&self, name: &str) -> Result<&ConfigArtifacts> {
        self.manifest.config(name)
    }

    /// Load + compile (or fetch from cache) one entry point of one config.
    pub fn load(&mut self, config: &str, entry: &str) -> Result<Rc<LoadedExecutable>> {
        let key = (config.to_string(), entry.to_string());
        if let Some(exe) = self.cache.get(&key) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.config(config)?.executable(entry)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let loaded = Rc::new(LoadedExecutable { spec, exe });
        self.cache.insert(key, loaded.clone());
        Ok(loaded)
    }

    /// Pre-compile every entry point of a config (startup warm-up so compile
    /// time never lands on the training path).
    pub fn preload_config(&mut self, config: &str) -> Result<()> {
        let entries: Vec<String> = self
            .manifest
            .config(config)?
            .executables
            .keys()
            .cloned()
            .collect();
        for e in entries {
            self.load(config, &e)?;
        }
        Ok(())
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.len()
    }
}
