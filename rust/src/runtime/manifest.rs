//! Typed view of `artifacts/manifest.json`, the ABI between the Python AOT
//! pipeline and the Rust runtime. See python/compile/aot.py for the writer.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{HydraError, Result};
use crate::tensor::DType;
use crate::util::json::Json;

/// Mirror of python compile.configs.ModelConfig.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub kind: ModelKind,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub vocab: usize,
    pub patch_dim: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Lm,
    Cls,
}

impl ModelConfig {
    /// Tokens processed per mini-batch (for throughput reporting).
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq
    }
}

/// One parameter array of a shard kind, with its initialiser.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitSpec,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InitSpec {
    Normal { std: f32 },
    Zeros,
    Ones,
}

impl ParamSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> u64 {
        (self.element_count() * 4) as u64
    }
}

/// One compiled HLO entry point.
#[derive(Debug, Clone)]
pub struct ExecutableSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub sha256: String,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// One config's full artifact family.
#[derive(Debug, Clone)]
pub struct ConfigArtifacts {
    pub config: ModelConfig,
    /// Param specs per shard kind: "embed" | "block" | "head".
    pub params: BTreeMap<String, Vec<ParamSpec>>,
    pub executables: BTreeMap<String, ExecutableSpec>,
    pub kernel_vmem_bytes: BTreeMap<String, u64>,
}

impl ConfigArtifacts {
    pub fn param_specs(&self, shard_kind: &str) -> &[ParamSpec] {
        &self.params[shard_kind]
    }

    pub fn executable(&self, name: &str) -> Result<&ExecutableSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| HydraError::Manifest(format!(
                "config {} missing executable {name}", self.config.name)))
    }

    /// Total parameter count of one model instance of this config.
    pub fn total_params(&self) -> usize {
        let one = |k: &str| -> usize {
            self.params[k].iter().map(|p| p.element_count()).sum()
        };
        one("embed") + self.config.n_layers * one("block") + one("head")
    }
}

/// Parsed manifest with artifact directory context.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigArtifacts>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| HydraError::Manifest("missing version".into()))?;
        if version != 1 {
            return Err(HydraError::Manifest(format!("unsupported version {version}")));
        }
        let mut configs = BTreeMap::new();
        let cfgs = j
            .get("configs")
            .and_then(Json::as_obj)
            .ok_or_else(|| HydraError::Manifest("missing configs".into()))?;
        for (name, entry) in cfgs {
            configs.insert(name.clone(), parse_config_entry(name, entry)?);
        }
        Ok(Manifest { dir, configs })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigArtifacts> {
        self.configs
            .get(name)
            .ok_or_else(|| HydraError::Manifest(format!(
                "unknown config {name:?}; available: {:?}",
                self.configs.keys().collect::<Vec<_>>())))
    }

    pub fn hlo_path(&self, exe: &ExecutableSpec) -> PathBuf {
        self.dir.join(&exe.file)
    }
}

fn merr(msg: impl Into<String>) -> HydraError {
    HydraError::Manifest(msg.into())
}

fn parse_usize(j: &Json, field: &str) -> Result<usize> {
    j.get(field)
        .and_then(Json::as_usize)
        .ok_or_else(|| merr(format!("bad field {field}")))
}

fn parse_config_entry(name: &str, entry: &Json) -> Result<ConfigArtifacts> {
    let c = entry.get("config").ok_or_else(|| merr("missing config"))?;
    let kind = match c.get("kind").and_then(Json::as_str) {
        Some("lm") => ModelKind::Lm,
        Some("cls") => ModelKind::Cls,
        other => return Err(merr(format!("bad kind {other:?}"))),
    };
    let config = ModelConfig {
        name: name.to_string(),
        kind,
        d_model: parse_usize(c, "d_model")?,
        n_heads: parse_usize(c, "n_heads")?,
        n_layers: parse_usize(c, "n_layers")?,
        d_ff: parse_usize(c, "d_ff")?,
        seq: parse_usize(c, "seq")?,
        batch: parse_usize(c, "batch")?,
        vocab: parse_usize(c, "vocab")?,
        patch_dim: parse_usize(c, "patch_dim").unwrap_or(0),
    };

    let mut params = BTreeMap::new();
    let pgroups = entry
        .get("params")
        .and_then(Json::as_obj)
        .ok_or_else(|| merr("missing params"))?;
    for (kind, list) in pgroups {
        let mut specs = Vec::new();
        for p in list.as_arr().ok_or_else(|| merr("params not array"))? {
            specs.push(parse_param_spec(p)?);
        }
        params.insert(kind.clone(), specs);
    }

    let mut executables = BTreeMap::new();
    let exes = entry
        .get("executables")
        .and_then(Json::as_obj)
        .ok_or_else(|| merr("missing executables"))?;
    for (ename, e) in exes {
        executables.insert(ename.clone(), parse_exe_spec(ename, e)?);
    }

    let mut kernel_vmem_bytes = BTreeMap::new();
    if let Some(vm) = entry.get("kernel_vmem_bytes").and_then(Json::as_obj) {
        for (k, v) in vm {
            kernel_vmem_bytes
                .insert(k.clone(), v.as_u64().ok_or_else(|| merr("bad vmem"))?);
        }
    }

    Ok(ConfigArtifacts { config, params, executables, kernel_vmem_bytes })
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| merr("shape not array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| merr("bad dim")))
        .collect()
}

fn parse_param_spec(p: &Json) -> Result<ParamSpec> {
    let init_obj = p.get("init").ok_or_else(|| merr("missing init"))?;
    let init = match init_obj.get("kind").and_then(Json::as_str) {
        Some("normal") => InitSpec::Normal {
            std: init_obj
                .get("std")
                .and_then(Json::as_f64)
                .ok_or_else(|| merr("missing std"))? as f32,
        },
        Some("zeros") => InitSpec::Zeros,
        Some("ones") => InitSpec::Ones,
        other => return Err(merr(format!("bad init kind {other:?}"))),
    };
    Ok(ParamSpec {
        name: p
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| merr("missing param name"))?
            .to_string(),
        shape: parse_shape(p.get("shape").ok_or_else(|| merr("missing shape"))?)?,
        init,
    })
}

fn parse_io_spec(io: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: io
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        shape: parse_shape(io.get("shape").ok_or_else(|| merr("io missing shape"))?)?,
        dtype: DType::parse(
            io.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
        )
        .map_err(merr)?,
    })
}

fn parse_exe_spec(name: &str, e: &Json) -> Result<ExecutableSpec> {
    let ios = |field: &str| -> Result<Vec<IoSpec>> {
        e.get(field)
            .and_then(Json::as_arr)
            .ok_or_else(|| merr(format!("missing {field}")))?
            .iter()
            .map(parse_io_spec)
            .collect()
    };
    Ok(ExecutableSpec {
        name: name.to_string(),
        file: e
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| merr("missing file"))?
            .to_string(),
        inputs: ios("inputs")?,
        outputs: ios("outputs")?,
        sha256: e
            .get("sha256")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) const SAMPLE: &str = r#"{
      "version": 1,
      "configs": {
        "tiny-lm-b4": {
          "config": {"name":"tiny-lm-b4","kind":"lm","d_model":64,"n_heads":4,
                     "n_layers":4,"d_ff":256,"seq":32,"batch":4,"vocab":256,
                     "patch_dim":0},
          "params": {
            "embed": [
              {"name":"tok_emb","shape":[256,64],"init":{"kind":"normal","std":0.02}},
              {"name":"pos_emb","shape":[32,64],"init":{"kind":"normal","std":0.02}}
            ],
            "block": [
              {"name":"ln1_g","shape":[64],"init":{"kind":"ones"}}
            ],
            "head": [
              {"name":"w_out","shape":[64,256],"init":{"kind":"normal","std":0.02}}
            ]
          },
          "executables": {
            "embed_fwd": {
              "file": "tiny-lm-b4.embed_fwd.hlo.txt",
              "inputs": [
                {"name":"tok_emb","shape":[256,64],"dtype":"f32"},
                {"name":"pos_emb","shape":[32,64],"dtype":"f32"},
                {"name":"data","shape":[4,32],"dtype":"i32"}
              ],
              "outputs": [{"name":"h","shape":[4,32,64],"dtype":"f32"}],
              "sha256": "abc"
            }
          },
          "kernel_vmem_bytes": {"flash_attention": 9216}
        }
      }
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let c = m.config("tiny-lm-b4").unwrap();
        assert_eq!(c.config.kind, ModelKind::Lm);
        assert_eq!(c.config.d_model, 64);
        assert_eq!(c.params["embed"].len(), 2);
        assert_eq!(c.params["embed"][0].init, InitSpec::Normal { std: 0.02 });
        let e = c.executable("embed_fwd").unwrap();
        assert_eq!(e.inputs[2].dtype, DType::I32);
        assert_eq!(e.outputs[0].shape, vec![4, 32, 64]);
        assert_eq!(c.kernel_vmem_bytes["flash_attention"], 9216);
    }

    #[test]
    fn unknown_config_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn param_sizes() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let c = m.config("tiny-lm-b4").unwrap();
        assert_eq!(c.params["embed"][0].element_count(), 256 * 64);
        assert_eq!(c.params["embed"][0].size_bytes(), 256 * 64 * 4);
        // total = embed + 4 * block + head
        let expect = (256 * 64 + 32 * 64) + 4 * 64 + 64 * 256;
        assert_eq!(c.total_params(), expect);
    }
}
