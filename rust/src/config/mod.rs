//! Declarative workload specifications: a JSON file describing the model
//! tasks, cluster, and engine knobs, consumed by `hydra run --spec <file>`.
//! This is the "real config system" a deployment would drive Hydra with —
//! [`WorkloadSpec::session`] builds the programmatic
//! [`crate::session::Session`] underneath.
//!
//! ```json
//! {
//!   "cluster": { "devices": 2, "device_mem_mib": 2, "dram_mib": 4096 },
//!   "engine": { "scheduler": "sharded-lrtf", "double_buffer": true,
//!               "sequential": false, "buffer_frac": 0.05,
//!               "prefetch_depth": 1, "early_stop_median_after": 2,
//!               "queue": "heap" },
//!   "tasks": [
//!     { "name": "bert-a", "config": "tiny-lm-b8", "lr": 0.05,
//!       "opt": "sgd", "epochs": 1, "minibatches": 8, "seed": 1 },
//!     { "name": "late", "config": "tiny-lm-b8", "lr": 0.05,
//!       "opt": "sgd", "minibatches": 8, "arrival": 30.0 },
//!     { "name": "probe", "config": "tiny-lm-b4", "lr": 0.0,
//!       "opt": "sgd", "minibatches": 4, "inference": true }
//!   ]
//! }
//! ```
//!
//! The cluster may carry an NVMe backing tier — `"nvme": "4096:3.5"`
//! (capacity in GiB, bandwidth in GB/s, bandwidth optional) — which turns
//! DRAM into an evicting cache so the task set's aggregate parameters may
//! exceed `dram_mib`.
//!
//! Clusters may be heterogeneous: `"device_mem_mib_each": [4, 2, 8]` gives
//! per-device memories, `"device_classes": ["a4000", "a6000"]` builds a
//! mixed pool of named GPU classes (per-class memory, relative speed, and
//! host-link bandwidth; speeds are relative to the slowest listed class),
//! and `"pool": "a4000:4,a6000:2"` is the compact class:count form shared
//! with the `hydra simulate --online --pool` flag. Tasks may carry an
//! `"arrival"` time in virtual seconds — the online multi-tenant setting —
//! plus tenant metadata: `"tenant"` (owning tenant id), `"weight"` (fair
//! share under `"scheduler": "weighted-fair"`) and `"deadline"` (latency
//! SLO in virtual seconds after arrival; attainment lands in the report's
//! per-tenant section). `"engine": { "admission_depth": k }` sheds a
//! tenant's mid-run submissions once it has `k` unfinished jobs queued.
//! `"engine": { "shards": n, "threads": true }` runs the n coordinator
//! shards on one OS thread each (byte-identical merged report, better
//! wall-clock), and `"stealing": true` adds admission-time work stealing
//! between shards.
//!
//! Model-selection searches have their own spec, [`SearchWorkload`]: the
//! same `"cluster"`/`"engine"` objects plus a `"search"` object (space +
//! algorithm + eta/rungs) instead of `"tasks"`, consumed by
//! `hydra search --spec <file>`.

use crate::coordinator::durability::{DurabilityOptions, WalRecord, WalWriter};
use crate::coordinator::memory::TierSpec;
use crate::coordinator::sched::Policy;
use crate::coordinator::sharp::{DeviceSpec, EngineOptions, ParallelMode, QueueKind};
use crate::coordinator::task::MAX_TENANT_ID;
use crate::coordinator::Cluster;
use crate::error::{HydraError, Result};
use crate::exec::real::RealModelSpec;
use crate::selection::{Algo, Search, SearchReport, SearchSpace};
use crate::session::{Backend, Session};
use crate::sim::GpuSpec;
use crate::train::optimizer::OptKind;
use crate::util::json::Json;

/// A fully parsed workload specification.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub cluster: Cluster,
    pub engine: EngineOptions,
    /// Typed scheduling policy (parsed from the spec's `"scheduler"`).
    pub policy: Policy,
    /// Optional NVMe backing tier below DRAM (cluster key `"nvme":
    /// "<capacity-gib>[:<gbps>]"`) — lets the task set's aggregate
    /// parameters exceed `dram_mib`.
    pub nvme: Option<TierSpec>,
    pub early_stop_median_after: Option<u32>,
    pub tasks: Vec<RealModelSpec>,
}

fn cerr(msg: impl Into<String>) -> HydraError {
    HydraError::Config(msg.into())
}

/// A sharded front door partitions the device pool, so more shards than
/// devices would leave some shards with an empty pool. Rejected here so a
/// spec fails at parse time with the same message `Session::build` uses.
fn check_shards_fit(engine: &EngineOptions, cluster: &Cluster) -> Result<()> {
    if engine.shards > cluster.devices.len() {
        return Err(cerr(format!(
            "{} shards over {} devices (each shard needs at least one device)",
            engine.shards,
            cluster.devices.len()
        )));
    }
    Ok(())
}

impl WorkloadSpec {
    pub fn load(path: &str) -> Result<WorkloadSpec> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<WorkloadSpec> {
        let j = Json::parse(text)?;
        let (cluster, nvme, _reference) = parse_cluster(&j)?;
        let (engine, policy, early_stop, durability) = parse_engine(&j)?;
        check_shards_fit(&engine, &cluster)?;
        if durability.is_some() {
            return Err(cerr(
                "engine.wal durability applies to sim runs and searches; \
                 real-backend workloads execute measured wallclock, which is \
                 not replayable",
            ));
        }

        // --- tasks ------------------------------------------------------------
        let tasks_json = j
            .get("tasks")
            .and_then(Json::as_arr)
            .ok_or_else(|| cerr("missing tasks array"))?;
        if tasks_json.is_empty() {
            return Err(cerr("tasks array is empty"));
        }
        let tasks: Vec<RealModelSpec> = tasks_json
            .iter()
            .enumerate()
            .map(|(i, t)| parse_task(i, t))
            .collect::<Result<_>>()?;

        Ok(WorkloadSpec {
            cluster,
            engine,
            policy,
            nvme,
            early_stop_median_after: early_stop,
            tasks,
        })
    }

    /// Build the real-backend [`Session`] this spec describes, with every
    /// task submitted; call `.run()` (or `.run_with(..)`) on the result.
    pub fn session(&self, manifest_dir: &str) -> Result<Session> {
        let mut builder = Session::builder(self.cluster.clone())
            .backend(Backend::Real { manifest: manifest_dir.to_string() })
            .policy(self.policy)
            .options(self.engine.clone());
        if let Some(tier) = self.nvme {
            builder = builder.nvme(tier);
        }
        if let Some(min_epochs) = self.early_stop_median_after {
            builder = builder.early_stop_median_after(min_epochs);
        }
        let mut session = builder.build()?;
        for t in &self.tasks {
            session.submit(t.clone())?;
        }
        Ok(session)
    }

    /// Build the orchestrator this spec describes.
    #[deprecated(since = "0.2.0", note = "use WorkloadSpec::session")]
    #[allow(deprecated)]
    pub fn orchestrator(&self, manifest_dir: &str) -> crate::coordinator::ModelOrchestrator {
        let mut orch = crate::coordinator::ModelOrchestrator::new(manifest_dir);
        orch.engine_options = self.engine.clone();
        orch.scheduler = self.policy.name().to_string();
        orch.early_stop_median_after = self.early_stop_median_after;
        for t in &self.tasks {
            orch.add_task(t.clone());
        }
        orch
    }
}

/// Parse the `"cluster"` object shared by [`WorkloadSpec`] and
/// [`SearchWorkload`]. Returns the cluster, the optional NVMe tier, and —
/// when the pool was built from named GPU classes — the reference class
/// unit costs are calibrated on (the slowest listed class, the one whose
/// `DeviceSpec::speed` is 1.0).
fn parse_cluster(j: &Json) -> Result<(Cluster, Option<TierSpec>, Option<GpuSpec>)> {
    let c = j.get("cluster").ok_or_else(|| cerr("missing cluster"))?;
    let mib = 1u64 << 20;
    let dram_bytes = c.get("dram_mib").and_then(Json::as_u64).unwrap_or(4096) * mib;
    let nvme = match c.get("nvme") {
        None => None,
        Some(v) => {
            let text = v.as_str().ok_or_else(|| {
                cerr(r#"nvme must be a string like "4096:3.5" (GiB:GB/s)"#)
            })?;
            Some(TierSpec::parse(text)?)
        }
    };
    let mut cost_reference = None;
    let cluster = if let Some(pool) = c.get("pool") {
        // compact heterogeneous form, shared with the --pool CLI flag
        let s = pool
            .as_str()
            .ok_or_else(|| cerr("pool must be a string like \"a4000:4,a6000:2\""))?;
        let gpus = crate::sim::parse_pool(s)?;
        let reference = crate::sim::pool_reference(&gpus)
            .ok_or_else(|| cerr("pool is empty"))?;
        cost_reference = Some(reference);
        Cluster::heterogeneous(
            gpus.iter().map(|g| g.device_spec(&reference)).collect(),
            dram_bytes,
        )
    } else if let Some(classes) = c.get("device_classes") {
        // heterogeneous: named GPU classes (memory + speed + link)
        let arr = classes
            .as_arr()
            .ok_or_else(|| cerr("device_classes must be an array"))?;
        if arr.is_empty() {
            return Err(cerr("device_classes is empty"));
        }
        let mut gpus: Vec<GpuSpec> = Vec::new();
        for v in arr {
            let name = v
                .as_str()
                .ok_or_else(|| cerr("device_classes entries must be strings"))?;
            let g = GpuSpec::by_name(name)
                .ok_or_else(|| cerr(format!("unknown GPU class {name:?}")))?;
            gpus.push(g);
        }
        let reference = crate::sim::pool_reference(&gpus)
            .ok_or_else(|| cerr("device_classes is empty"))?;
        cost_reference = Some(reference);
        Cluster::heterogeneous(
            gpus.iter().map(|g| g.device_spec(&reference)).collect(),
            dram_bytes,
        )
    } else if let Some(per_dev) = c.get("device_mem_mib_each") {
        // heterogeneous in memory only: explicit per-device list
        let mems: Vec<u64> = per_dev
            .as_arr()
            .ok_or_else(|| cerr("device_mem_mib_each must be an array"))?
            .iter()
            .map(|v| v.as_u64().map(|m| m * mib).ok_or_else(|| cerr("bad mem")))
            .collect::<Result<_>>()?;
        if mems.is_empty() {
            return Err(cerr("device_mem_mib_each is empty"));
        }
        Cluster::heterogeneous(
            mems.into_iter().map(DeviceSpec::uniform).collect(),
            dram_bytes,
        )
    } else {
        let devices = c
            .get("devices")
            .and_then(Json::as_usize)
            .ok_or_else(|| cerr("cluster.devices missing"))?;
        if devices == 0 {
            return Err(cerr("cluster.devices must be > 0"));
        }
        Cluster::uniform(
            devices,
            c.get("device_mem_mib")
                .and_then(Json::as_u64)
                .ok_or_else(|| cerr("cluster.device_mem_mib missing"))?
                * mib,
            dram_bytes,
        )
    };
    Ok((cluster, nvme, cost_reference))
}

/// Parse the optional `"engine"` object shared by [`WorkloadSpec`] and
/// [`SearchWorkload`]: engine knobs, scheduler policy, the median
/// early-stop threshold, and durability (`"wal"` / `"snapshot_every"`).
fn parse_engine(
    j: &Json,
) -> Result<(EngineOptions, Policy, Option<u32>, Option<DurabilityOptions>)> {
    let mut engine = EngineOptions::default();
    let mut policy = Policy::default();
    let mut early_stop = None;
    let mut durability = None;
    if let Some(e) = j.get("engine") {
        if let Some(s) = e.get("scheduler").and_then(Json::as_str) {
            policy = s.parse::<Policy>()?;
        }
        if let Some(db) = e.get("double_buffer").and_then(Json::as_bool) {
            engine.double_buffer = db;
        }
        if let Some(seq) = e.get("sequential").and_then(Json::as_bool) {
            engine.mode = if seq {
                ParallelMode::Sequential
            } else {
                ParallelMode::Sharp
            };
        }
        if let Some(f) = e.get("buffer_frac").and_then(Json::as_f64) {
            if !(0.0..0.9).contains(&f) {
                return Err(cerr(format!("buffer_frac {f} out of [0, 0.9)")));
            }
            engine.buffer_frac = f;
        }
        if let Some(k) = e.get("prefetch_depth").and_then(Json::as_u64) {
            if k == 0 {
                return Err(cerr(
                    "prefetch_depth must be >= 1 (1 = classic double-buffering)",
                ));
            }
            engine.prefetch_depth = k as usize;
        }
        if let Some(s) = e.get("shards").and_then(Json::as_u64) {
            if s == 0 {
                return Err(cerr("shards must be >= 1"));
            }
            engine.shards = s as usize;
        }
        if let Some(t) = e.get("threads").and_then(Json::as_bool) {
            engine.threads = t;
        }
        if let Some(st) = e.get("stealing").and_then(Json::as_bool) {
            engine.stealing = st;
        }
        if let Some(d) = e.get("admission_depth").and_then(Json::as_u64) {
            if d == 0 {
                return Err(cerr(
                    "admission_depth must be >= 1 (it bounds each tenant's \
                     unfinished mid-run submissions; omit the key to disable \
                     admission control)",
                ));
            }
            engine.admission_depth = Some(d as usize);
        }
        if let Some(me) = e.get("early_stop_median_after").and_then(Json::as_u64) {
            early_stop = Some(me as u32);
        }
        // "queue" is the preferred key; "event_queue" is the legacy alias.
        let queue_key = e.get("queue").or_else(|| e.get("event_queue"));
        if let Some(q) = queue_key.and_then(Json::as_str) {
            engine.queue = match q {
                "heap" => QueueKind::Heap,
                "scan" | "linear-scan" => QueueKind::LinearScan,
                "calendar" => QueueKind::Calendar,
                other => {
                    return Err(cerr(format!(
                        "unknown queue {other:?} (heap|scan|calendar)"
                    )))
                }
            };
        }
        if let Some(w) = e.get("wal") {
            let path = w
                .as_str()
                .ok_or_else(|| cerr("engine.wal must be a path string"))?;
            durability = Some(DurabilityOptions::new(path));
        }
        if let Some(n) = e.get("snapshot_every").and_then(Json::as_u64) {
            match durability.as_mut() {
                Some(d) => d.snapshot_every = n,
                None => {
                    return Err(cerr(
                        "engine.snapshot_every needs engine.wal (snapshots \
                         are a sidecar of the event WAL)",
                    ))
                }
            }
        }
    }
    Ok((engine, policy, early_stop, durability))
}

/// A declarative model-selection search — the `"search"` counterpart of
/// [`WorkloadSpec`], consumed by `hydra search --spec <file>`:
///
/// ```json
/// {
///   "cluster": { "pool": "a4000:4", "dram_mib": 524288 },
///   "engine": { "scheduler": "sharded-lrtf" },
///   "search": { "space": "lr=1e-4..1e-2:log,layers=12,24,48",
///               "algo": "asha", "eta": 3, "min_epochs": 1,
///               "epochs": 9, "minibatches": 2, "seed": 7 }
/// }
/// ```
///
/// `algo` is `grid` | `random` | `asha`; `random` requires `trials`, and
/// `asha` halves a random cohort of `trials` samples — or the full grid
/// when `trials` is omitted. Optional keys: `stagger` (virtual seconds
/// between trial submissions), `grid_points` (resolution of continuous
/// axes, default 3). Searches run on the simulated backend; when the
/// cluster is a named-class pool, trial costs are calibrated on its
/// slowest class automatically.
#[derive(Debug, Clone)]
pub struct SearchWorkload {
    pub cluster: Cluster,
    /// Optional NVMe backing tier below DRAM.
    pub nvme: Option<TierSpec>,
    pub engine: EngineOptions,
    /// Typed scheduling policy (parsed from the spec's `"scheduler"`).
    pub policy: Policy,
    /// The search itself: space + algorithm + per-trial shape.
    pub search: Search,
    /// Durability (`engine.wal` / `engine.snapshot_every`): the spec text
    /// becomes the WAL's genesis record and every trial-driving engine
    /// event is appended, so `hydra recover` can re-drive the search.
    pub durability: Option<DurabilityOptions>,
    /// The raw spec text this workload was parsed from — what a durable
    /// search writes as its genesis.
    raw: String,
}

impl SearchWorkload {
    pub fn load(path: &str) -> Result<SearchWorkload> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<SearchWorkload> {
        let j = Json::parse(text)?;
        let (cluster, nvme, reference) = parse_cluster(&j)?;
        let (mut engine, policy, early_stop, durability) = parse_engine(&j)?;
        check_shards_fit(&engine, &cluster)?;
        if early_stop.is_some() {
            return Err(cerr(
                "engine.early_stop_median_after is a real-backend workload key \
                 and has no effect on searches — prune with the search object \
                 instead (\"algo\": \"asha\" plus eta/min_epochs)",
            ));
        }
        let s = j.get("search").ok_or_else(|| cerr("missing search object"))?;
        let space_s = s
            .get("space")
            .and_then(Json::as_str)
            .ok_or_else(|| cerr("search.space missing (e.g. \"lr=1e-4..1e-2:log\")"))?;
        let space = SearchSpace::parse(space_s)?;

        // paper-scale default: unless the spec pins buffer_frac, searches
        // use the 30% zone 1B-shard prefetch staging needs
        let explicit_frac = j
            .get("engine")
            .and_then(|e| e.get("buffer_frac"))
            .is_some();
        if !explicit_frac {
            engine.buffer_frac = 0.30;
        }

        let trials = s.get("trials").and_then(Json::as_usize);
        let eta = s.get("eta").and_then(Json::as_u64).unwrap_or(3) as u32;
        let min_epochs = s.get("min_epochs").and_then(Json::as_u64).unwrap_or(1) as u32;
        let algo = match s.get("algo").and_then(Json::as_str).unwrap_or("grid") {
            "grid" => Algo::Grid,
            "random" => Algo::Random {
                trials: trials
                    .ok_or_else(|| cerr("search.algo \"random\" needs search.trials"))?,
            },
            "asha" | "sha" => Algo::Asha { trials, eta, min_epochs },
            other => {
                return Err(cerr(format!(
                    "unknown search.algo {other:?} (grid|random|asha)"
                )))
            }
        };
        let stagger = s.get("stagger").and_then(Json::as_f64).unwrap_or(0.0);
        if !stagger.is_finite() || stagger < 0.0 {
            return Err(cerr(format!("bad search.stagger {stagger}")));
        }
        let mut search = Search::new(space);
        search.algo = algo;
        search.epochs = s.get("epochs").and_then(Json::as_u64).unwrap_or(4) as u32;
        search.minibatches_per_epoch =
            s.get("minibatches").and_then(Json::as_u64).unwrap_or(2) as u32;
        search.seed = s.get("seed").and_then(Json::as_u64).unwrap_or(0);
        search.stagger_secs = stagger;
        search.grid_points =
            s.get("grid_points").and_then(Json::as_usize).unwrap_or(3);
        search.buffer_frac = engine.buffer_frac;
        if let Some(r) = reference {
            search.reference = r;
        }
        Ok(SearchWorkload {
            cluster,
            nvme,
            engine,
            policy,
            search,
            durability,
            raw: text.to_string(),
        })
    }

    /// Build the sim-backend [`Session`] this spec searches on.
    pub fn session(&self) -> Result<Session> {
        let mut builder = Session::builder(self.cluster.clone())
            .backend(Backend::sim())
            .policy(self.policy)
            .options(self.engine.clone());
        if let Some(tier) = self.nvme {
            builder = builder.nvme(tier);
        }
        if let Some(dur) = &self.durability {
            builder = builder.durability(dur.clone());
        }
        builder.build()
    }

    /// Run the whole search ([`Session::run_search`]).
    ///
    /// With durability configured, the WAL is created first with this
    /// spec's raw JSON as its genesis record; the search driver wraps the
    /// backend, so the session appends every trial-driving engine event
    /// after it (record-only mode). [`crate::coordinator::durability::recover`]
    /// re-drives the search from the genesis text.
    pub fn run(&self) -> Result<SearchReport> {
        if let Some(dur) = &self.durability {
            let mut wal = WalWriter::create(&dur.wal)?;
            wal.append(&WalRecord::GenesisSearch(self.raw.clone()));
            wal.finish()?;
        }
        self.session()?.run_search(&self.search)
    }
}

fn parse_task(i: usize, t: &Json) -> Result<RealModelSpec> {
    let name = t
        .get("name")
        .and_then(Json::as_str)
        .map(String::from)
        .unwrap_or_else(|| format!("task-{i}"));
    let config = t
        .get("config")
        .and_then(Json::as_str)
        .ok_or_else(|| cerr(format!("task {name}: missing config")))?
        .to_string();
    let opt = OptKind::parse(t.get("opt").and_then(Json::as_str).unwrap_or("sgd"))
        .map_err(cerr)?;
    let arrival = t.get("arrival").and_then(Json::as_f64).unwrap_or(0.0);
    if !arrival.is_finite() || arrival < 0.0 {
        return Err(cerr(format!("task {name}: bad arrival {arrival}")));
    }
    let tenant = t.get("tenant").and_then(Json::as_u64).unwrap_or(0) as usize;
    if tenant > MAX_TENANT_ID {
        return Err(cerr(format!(
            "task {name}: tenant {tenant} over the {MAX_TENANT_ID} cap"
        )));
    }
    let weight = t.get("weight").and_then(Json::as_f64).unwrap_or(1.0);
    if !weight.is_finite() || weight <= 0.0 {
        return Err(cerr(format!("task {name}: bad weight {weight}")));
    }
    let deadline = match t.get("deadline").and_then(Json::as_f64) {
        Some(d) if !d.is_finite() || d <= 0.0 => {
            return Err(cerr(format!("task {name}: bad deadline {d}")))
        }
        d => d,
    };
    Ok(RealModelSpec {
        name,
        config,
        lr: t.get("lr").and_then(Json::as_f64).unwrap_or(0.01) as f32,
        opt,
        epochs: t.get("epochs").and_then(Json::as_u64).unwrap_or(1) as u32,
        minibatches_per_epoch: t
            .get("minibatches")
            .and_then(Json::as_u64)
            .ok_or_else(|| cerr("task missing minibatches"))? as u32,
        seed: t.get("seed").and_then(Json::as_u64).unwrap_or(i as u64),
        inference: t.get("inference").and_then(Json::as_bool).unwrap_or(false),
        arrival,
        tenant,
        weight,
        deadline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"{
      "cluster": { "devices": 2, "device_mem_mib": 2, "dram_mib": 1024 },
      "engine": { "scheduler": "random", "double_buffer": false,
                  "sequential": true, "buffer_frac": 0.1,
                  "early_stop_median_after": 3 },
      "tasks": [
        { "name": "a", "config": "tiny-lm-b4", "lr": 0.05, "opt": "momentum",
          "epochs": 2, "minibatches": 4, "seed": 9 },
        { "config": "tiny-cls-b8", "minibatches": 2, "inference": true }
      ]
    }"#;

    #[test]
    fn parses_full_spec() {
        let w = WorkloadSpec::parse(SPEC).unwrap();
        assert_eq!(w.cluster.device_mem(), vec![2 << 20, 2 << 20]);
        assert_eq!(w.cluster.dram_bytes, 1024 << 20);
        assert_eq!(w.policy, Policy::Random);
        assert!(!w.engine.double_buffer);
        assert_eq!(w.engine.mode, ParallelMode::Sequential);
        assert_eq!(w.engine.buffer_frac, 0.1);
        assert_eq!(w.early_stop_median_after, Some(3));
        assert_eq!(w.tasks.len(), 2);
        assert_eq!(w.tasks[0].opt, OptKind::Momentum { beta: 0.9 });
        assert_eq!(w.tasks[0].epochs, 2);
        assert_eq!(w.tasks[0].arrival, 0.0); // defaulted
        assert_eq!(w.tasks[1].name, "task-1"); // defaulted
        assert!(w.tasks[1].inference);
    }

    #[test]
    fn heterogeneous_device_list() {
        let spec = r#"{
          "cluster": { "device_mem_mib_each": [4, 2, 8] },
          "tasks": [ { "config": "tiny-lm-b4", "minibatches": 1 } ]
        }"#;
        let w = WorkloadSpec::parse(spec).unwrap();
        assert_eq!(w.cluster.device_mem(), vec![4 << 20, 2 << 20, 8 << 20]);
        assert_eq!(w.cluster.min_device_mem(), 2 << 20);
        // memory-only heterogeneity keeps reference speed
        assert!(w.cluster.devices.iter().all(|d| d.speed == 1.0));
    }

    #[test]
    fn device_classes_build_mixed_pool() {
        let spec = r#"{
          "cluster": { "device_classes": ["a4000", "a6000", "a4000"] },
          "tasks": [ { "config": "tiny-lm-b4", "minibatches": 1,
                       "arrival": 30.5 } ]
        }"#;
        let w = WorkloadSpec::parse(spec).unwrap();
        assert_eq!(w.cluster.n_devices(), 3);
        // speeds relative to the slowest listed class (A4000)
        assert_eq!(w.cluster.devices[0].speed, 1.0);
        assert!(w.cluster.devices[1].speed > 1.0);
        assert_eq!(w.cluster.min_device_mem(), 16 << 30);
        assert!(w.cluster.devices[1].link.is_some());
        assert_eq!(w.tasks[0].arrival, 30.5);
    }

    #[test]
    fn prefetch_depth_parses_and_rejects_zero() {
        let mk = |engine: &str| {
            WorkloadSpec::parse(&format!(
                r#"{{"cluster": {{"devices":1,"device_mem_mib":1}},
                     "engine": {engine},
                     "tasks":[{{"config":"x","minibatches":1}}]}}"#
            ))
        };
        // default is the classic single-slot double buffer
        assert_eq!(mk(r#"{}"#).unwrap().engine.prefetch_depth, 1);
        assert_eq!(
            mk(r#"{"prefetch_depth": 4}"#).unwrap().engine.prefetch_depth,
            4
        );
        let err = mk(r#"{"prefetch_depth": 0}"#).unwrap_err();
        assert!(format!("{err}").contains("prefetch_depth"), "{err}");
        // the shared engine parser gives searches the same key
        let s = SearchWorkload::parse(
            r#"{"cluster": {"devices":1,"device_mem_mib":16384},
                "engine": {"prefetch_depth": 2},
                "search": {"space": "lr=1e-4..1e-2:log"}}"#,
        )
        .unwrap();
        assert_eq!(s.engine.prefetch_depth, 2);
    }

    #[test]
    fn shards_key_parses_and_rejects_zero() {
        let mk = |engine: &str| {
            WorkloadSpec::parse(&format!(
                r#"{{"cluster": {{"devices":4,"device_mem_mib":1}},
                     "engine": {engine},
                     "tasks":[{{"config":"x","minibatches":1}}]}}"#
            ))
        };
        // default is the single global coordinator
        assert_eq!(mk(r#"{}"#).unwrap().engine.shards, 1);
        assert_eq!(mk(r#"{"shards": 4}"#).unwrap().engine.shards, 4);
        let err = mk(r#"{"shards": 0}"#).unwrap_err();
        assert!(format!("{err}").contains("shards"), "{err}");
        // the shared engine parser gives searches the same key
        let s = SearchWorkload::parse(
            r#"{"cluster": {"devices":4,"device_mem_mib":16384},
                "engine": {"shards": 2},
                "search": {"space": "lr=1e-4..1e-2:log"}}"#,
        )
        .unwrap();
        assert_eq!(s.engine.shards, 2);
    }

    #[test]
    fn shards_over_devices_rejected_at_parse() {
        let err = WorkloadSpec::parse(
            r#"{"cluster": {"devices":2,"device_mem_mib":1},
                "engine": {"shards": 3},
                "tasks":[{"config":"x","minibatches":1}]}"#,
        )
        .unwrap_err();
        assert!(matches!(err, HydraError::Config(_)), "{err:?}");
        assert!(format!("{err}").contains("3 shards over 2 devices"), "{err}");
        // the search spec shares the cross-check
        let err = SearchWorkload::parse(
            r#"{"cluster": {"devices":1,"device_mem_mib":16384},
                "engine": {"shards": 4},
                "search": {"space": "lr=1e-4..1e-2:log"}}"#,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("4 shards over 1 devices"), "{err}");
    }

    #[test]
    fn threads_and_stealing_keys_parse() {
        let mk = |engine: &str| {
            WorkloadSpec::parse(&format!(
                r#"{{"cluster": {{"devices":4,"device_mem_mib":1}},
                     "engine": {engine},
                     "tasks":[{{"config":"x","minibatches":1}}]}}"#
            ))
        };
        // both off by default: the sequential, hash-routed baseline
        let spec = mk(r#"{}"#).unwrap();
        assert!(!spec.engine.threads);
        assert!(!spec.engine.stealing);
        let spec = mk(r#"{"shards": 4, "threads": true, "stealing": true}"#).unwrap();
        assert!(spec.engine.threads);
        assert!(spec.engine.stealing);
        assert!(!mk(r#"{"threads": false}"#).unwrap().engine.threads);
    }

    #[test]
    fn admission_depth_parses_and_rejects_zero() {
        let mk = |engine: &str| {
            WorkloadSpec::parse(&format!(
                r#"{{"cluster": {{"devices":1,"device_mem_mib":1}},
                     "engine": {engine},
                     "tasks":[{{"config":"x","minibatches":1}}]}}"#
            ))
        };
        assert_eq!(mk(r#"{}"#).unwrap().engine.admission_depth, None);
        assert_eq!(
            mk(r#"{"admission_depth": 8}"#).unwrap().engine.admission_depth,
            Some(8)
        );
        let err = mk(r#"{"admission_depth": 0}"#).unwrap_err();
        assert!(format!("{err}").contains("admission_depth"), "{err}");
    }

    #[test]
    fn tenant_keys_parse_and_validate() {
        let mk = |task_extra: &str| {
            WorkloadSpec::parse(&format!(
                r#"{{"cluster": {{"devices":1,"device_mem_mib":1}},
                     "tasks":[{{"config":"x","minibatches":1{task_extra}}}]}}"#
            ))
        };
        // defaults: tenant 0, weight 1, no deadline
        let w = mk("").unwrap();
        assert_eq!(w.tasks[0].tenant, 0);
        assert_eq!(w.tasks[0].weight, 1.0);
        assert_eq!(w.tasks[0].deadline, None);
        let w = mk(r#", "tenant": 3, "weight": 2.5, "deadline": 90.0"#).unwrap();
        assert_eq!(w.tasks[0].tenant, 3);
        assert_eq!(w.tasks[0].weight, 2.5);
        assert_eq!(w.tasks[0].deadline, Some(90.0));
        for bad in [
            r#", "tenant": 1048577"#, // over MAX_TENANT_ID
            r#", "weight": 0.0"#,
            r#", "weight": -1.0"#,
            r#", "deadline": 0.0"#,
            r#", "deadline": -5.0"#,
        ] {
            let err = mk(bad).unwrap_err();
            assert!(matches!(err, HydraError::Config(_)), "{bad}: {err:?}");
        }
    }

    #[test]
    fn event_queue_option_parses() {
        use crate::coordinator::sharp::QueueKind;
        let mk = |key: &str, q: &str| {
            WorkloadSpec::parse(&format!(
                r#"{{"cluster": {{"devices":1,"device_mem_mib":1}},
                     "engine": {{"{key}": "{q}"}},
                     "tasks":[{{"config":"x","minibatches":1}}]}}"#
            ))
        };
        assert_eq!(mk("queue", "heap").unwrap().engine.queue, QueueKind::Heap);
        assert_eq!(mk("queue", "scan").unwrap().engine.queue, QueueKind::LinearScan);
        assert_eq!(
            mk("queue", "calendar").unwrap().engine.queue,
            QueueKind::Calendar
        );
        // legacy alias keeps parsing
        assert_eq!(
            mk("event_queue", "calendar").unwrap().engine.queue,
            QueueKind::Calendar
        );
        assert!(mk("queue", "fibheap").is_err());
    }

    #[test]
    fn nvme_key_parses_and_flows_into_the_session() {
        let spec = r#"{
          "cluster": { "devices": 1, "device_mem_mib": 1, "dram_mib": 2,
                       "nvme": "2048:3.5" },
          "tasks": [ { "config": "tiny-lm-b4", "minibatches": 1 } ]
        }"#;
        let w = WorkloadSpec::parse(spec).unwrap();
        let t = w.nvme.unwrap();
        assert_eq!(t.capacity_bytes, 2048 << 30);
        assert!((t.link.bandwidth_bytes_per_sec - 3.5e9).abs() < 1e-3);
        assert!(w.session("artifacts").is_ok());
        // no key -> no tier
        let none = r#"{
          "cluster": { "devices": 1, "device_mem_mib": 1 },
          "tasks": [ { "config": "x", "minibatches": 1 } ]
        }"#;
        assert!(WorkloadSpec::parse(none).unwrap().nvme.is_none());
        // malformed specs are rejected
        for bad in [r#""nvme": 7"#, r#""nvme": "fast""#, r#""nvme": "0:3""#] {
            let spec = format!(
                r#"{{"cluster": {{"devices":1,"device_mem_mib":1,{bad}}},
                     "tasks":[{{"config":"x","minibatches":1}}]}}"#
            );
            assert!(WorkloadSpec::parse(&spec).is_err(), "{bad}");
        }
    }

    #[test]
    fn bad_task_arrival_rejected() {
        let spec = r#"{
          "cluster": { "devices": 1, "device_mem_mib": 1 },
          "tasks": [ { "config": "x", "minibatches": 1, "arrival": -2.0 } ]
        }"#;
        assert!(WorkloadSpec::parse(spec).is_err());
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(WorkloadSpec::parse("{}").is_err());
        assert!(WorkloadSpec::parse(r#"{"cluster":{"devices":0,"device_mem_mib":1},"tasks":[]}"#).is_err());
        let no_tasks = r#"{"cluster":{"devices":1,"device_mem_mib":1},"tasks":[]}"#;
        assert!(WorkloadSpec::parse(no_tasks).is_err());
        let bad_sched = r#"{
          "cluster": {"devices":1,"device_mem_mib":1},
          "engine": {"scheduler":"gurobi"},
          "tasks":[{"config":"x","minibatches":1}]}"#;
        assert!(WorkloadSpec::parse(bad_sched).is_err());
        let bad_frac = r#"{
          "cluster": {"devices":1,"device_mem_mib":1},
          "engine": {"buffer_frac": 1.5},
          "tasks":[{"config":"x","minibatches":1}]}"#;
        assert!(WorkloadSpec::parse(bad_frac).is_err());
    }

    #[test]
    fn session_inherits_spec() {
        let w = WorkloadSpec::parse(SPEC).unwrap();
        let session = w.session("artifacts").unwrap();
        assert_eq!(session.n_jobs(), 2);
    }

    #[test]
    fn pool_key_builds_mixed_cluster() {
        let spec = r#"{
          "cluster": { "pool": "a4000:2,a6000" },
          "tasks": [ { "config": "tiny-lm-b4", "minibatches": 1 } ]
        }"#;
        let w = WorkloadSpec::parse(spec).unwrap();
        assert_eq!(w.cluster.n_devices(), 3);
        // A4000 is the slowest class -> reference speed; A6000 faster
        assert_eq!(w.cluster.devices[0].speed, 1.0);
        assert!(w.cluster.devices[2].speed > 1.0);
        assert!(WorkloadSpec::parse(
            r#"{"cluster":{"pool":"h100:1"},
                "tasks":[{"config":"x","minibatches":1}]}"#
        )
        .is_err());
    }

    #[test]
    fn search_spec_parses_with_pool_reference() {
        let spec = r#"{
          "cluster": { "pool": "a4000:4", "dram_mib": 524288 },
          "engine": { "scheduler": "fifo" },
          "search": { "space": "lr=1e-4..1e-2:log,layers=12,24,48",
                      "algo": "asha", "eta": 3, "min_epochs": 1,
                      "epochs": 9, "minibatches": 2, "seed": 7,
                      "stagger": 30.0 }
        }"#;
        let w = SearchWorkload::parse(spec).unwrap();
        assert_eq!(w.cluster.n_devices(), 4);
        assert_eq!(w.policy, Policy::Fifo);
        assert_eq!(
            w.search.algo,
            Algo::Asha { trials: None, eta: 3, min_epochs: 1 }
        );
        assert_eq!(w.search.epochs, 9);
        assert_eq!(w.search.minibatches_per_epoch, 2);
        assert_eq!(w.search.seed, 7);
        assert_eq!(w.search.stagger_secs, 30.0);
        // cost calibration follows the pool's reference class (A4000)
        assert_eq!(
            w.search.reference.mem_bytes,
            crate::sim::GpuSpec::a4000().mem_bytes
        );
        // searches default to the paper-scale 30% buffer zone
        assert_eq!(w.engine.buffer_frac, 0.30);
        assert_eq!(w.search.buffer_frac, 0.30);
        assert!(w.session().is_ok());
    }

    #[test]
    fn search_spec_defaults_and_explicit_buffer_frac() {
        let spec = r#"{
          "cluster": { "devices": 2, "device_mem_mib": 16384 },
          "engine": { "buffer_frac": 0.1 },
          "search": { "space": "lr=1e-4..1e-2:log" }
        }"#;
        let w = SearchWorkload::parse(spec).unwrap();
        assert_eq!(w.search.algo, Algo::Grid);
        assert_eq!(w.search.epochs, 4);
        assert_eq!(w.search.grid_points, 3);
        // explicit buffer_frac wins over the search default
        assert_eq!(w.engine.buffer_frac, 0.1);
        assert_eq!(w.search.buffer_frac, 0.1);
    }

    #[test]
    fn search_spec_rejects_bad_inputs() {
        let mk = |search: &str| {
            SearchWorkload::parse(&format!(
                r#"{{"cluster": {{"devices":1,"device_mem_mib":16384}},
                     "search": {search}}}"#
            ))
        };
        assert!(mk(r#"{}"#).is_err()); // no space
        assert!(mk(r#"{"space": "lr="}"#).is_err()); // malformed space
        assert!(mk(r#"{"space": "lr=1e-4..1e-2:log", "algo": "random"}"#).is_err());
        assert!(mk(r#"{"space": "lr=1e-4..1e-2:log", "algo": "bayes"}"#).is_err());
        assert!(
            mk(r#"{"space": "lr=1e-4..1e-2:log", "stagger": -3.0}"#).is_err()
        );
        // missing the search object entirely
        assert!(SearchWorkload::parse(
            r#"{"cluster": {"devices":1,"device_mem_mib":1}}"#
        )
        .is_err());
        // a real-backend-only engine key is rejected, not silently dropped
        let stale_key = r#"{
          "cluster": { "devices": 1, "device_mem_mib": 16384 },
          "engine": { "early_stop_median_after": 2 },
          "search": { "space": "lr=1e-4..1e-2:log" }
        }"#;
        let err = SearchWorkload::parse(stale_key).unwrap_err();
        assert!(
            format!("{err}").contains("early_stop_median_after"),
            "{err}"
        );
    }

    #[test]
    fn wal_keys_parse_and_gate_correctly() {
        // searches accept engine.wal + engine.snapshot_every
        let s = SearchWorkload::parse(
            r#"{"cluster": {"devices":1,"device_mem_mib":16384},
                "engine": {"wal": "/tmp/x.wal", "snapshot_every": 64},
                "search": {"space": "lr=1e-4..1e-2:log"}}"#,
        )
        .unwrap();
        let d = s.durability.as_ref().unwrap();
        assert_eq!(d.wal, std::path::PathBuf::from("/tmp/x.wal"));
        assert_eq!(d.snapshot_every, 64);
        // snapshot_every without a wal is rejected
        let err = SearchWorkload::parse(
            r#"{"cluster": {"devices":1,"device_mem_mib":16384},
                "engine": {"snapshot_every": 64},
                "search": {"space": "lr=1e-4..1e-2:log"}}"#,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("snapshot_every"), "{err}");
        // real-backend workload specs reject durability outright
        let err = WorkloadSpec::parse(
            r#"{"cluster": {"devices":1,"device_mem_mib":1},
                "engine": {"wal": "/tmp/x.wal"},
                "tasks":[{"config":"x","minibatches":1}]}"#,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("wal"), "{err}");
        // and a non-string wal is a typed config error
        assert!(SearchWorkload::parse(
            r#"{"cluster": {"devices":1,"device_mem_mib":16384},
                "engine": {"wal": 7},
                "search": {"space": "lr=1e-4..1e-2:log"}}"#,
        )
        .is_err());
    }

    #[test]
    #[allow(deprecated)]
    fn orchestrator_shim_inherits_spec() {
        let w = WorkloadSpec::parse(SPEC).unwrap();
        let orch = w.orchestrator("artifacts");
        assert_eq!(orch.n_tasks(), 2);
        assert_eq!(orch.scheduler, "random");
        assert_eq!(orch.early_stop_median_after, Some(3));
    }
}
