//! Model selection — hyperparameter / architecture search as a
//! first-class Hydra workload.
//!
//! Multi-model training exists *because* of model selection ("users often
//! need to compare dozens of models with different hyper-parameters or
//! neural architectures", §1): this module closes the loop by generating
//! and retiring jobs adaptively instead of replaying a static list.
//!
//! - [`SearchSpace`] describes the axes (`lr=1e-4..1e-2:log,layers=12,24,48`),
//! - a [`Searcher`] ([`GridSearch`], [`RandomSearch`],
//!   [`SuccessiveHalving`]) turns it into a deterministic trial cohort,
//! - [`crate::session::Session::run_search`] runs the whole search on one
//!   engine run: trials enter via `submit_at`, per-epoch losses
//!   ([`SynthLoss`]) stream through the [`TrialMonitor`] observer, and
//!   ASHA prunes rung losers mid-run so their HBM/DRAM/NVMe residency is
//!   released to the survivors immediately.
//!
//! ```no_run
//! use hydra::coordinator::Cluster;
//! use hydra::selection::{Algo, Search, SearchSpace};
//! use hydra::session::Session;
//!
//! # fn main() -> hydra::Result<()> {
//! let space = SearchSpace::parse("lr=1e-4..1e-2:log,layers=12,24,48")?;
//! let search = Search {
//!     algo: Algo::Asha { trials: None, eta: 3, min_epochs: 1 },
//!     epochs: 9,
//!     ..Search::new(space)
//! };
//! let session = Session::builder(Cluster::uniform(4, 16 << 30, 512 << 30)).build()?;
//! let report = session.run_search(&search)?;
//! println!(
//!     "best {:?}, saved {:.1} GPU-h",
//!     report.best_trial().map(|t| &t.name),
//!     report.gpu_hours_saved()
//! );
//! # Ok(())
//! # }
//! ```

pub mod driver;
pub mod loss;
pub mod searcher;
pub mod space;

pub use driver::{Algo, Rung, Search, SearchReport, Trial, TrialMonitor, TrialState};
pub use loss::SynthLoss;
pub use searcher::{GridSearch, HalvingRule, RandomSearch, Searcher, SuccessiveHalving};
pub use space::{ParamAxis, ParamSpec, SearchSpace, TrialConfig};
