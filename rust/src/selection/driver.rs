//! The selection driver: one whole hyperparameter search on one
//! [`Session`] run.
//!
//! [`Session::run_search`] submits every trial through
//! [`Session::submit_at`], streams the run through the
//! [`TrialMonitor`] observer, and prunes rung losers *mid-run* so their
//! homed parameters leave the HBM/DRAM/NVMe hierarchy immediately
//! (`finish_job` unhomes a pruned trial the moment its boundary unit
//! retires) — freed memory recirculates to the surviving trials while the
//! engine keeps running.
//!
//! ## The Trial / Rung state machine
//!
//! ```text
//!  SearchSpace --Searcher--> TrialConfig[i] --trial_task--> ModelTask[i]
//!                                                  |  submit_at(i * stagger)
//!                                                  v
//!  Trial[i]: Pending --(epoch boundary e)--> record loss(i, e)
//!      |  e == rung.epochs?                       (TrialBackend)
//!      |        in top ceil(n/eta) of the rung  -> promoted, keep running
//!      |        else -> should_early_stop = true -> Pruned { rung }
//!      |                (remaining units drop; memory unhomes now)
//!      v
//!  survivors of the last rung run to the full budget -> Completed
//! ```
//!
//! ## Synchronous halving in one engine run
//!
//! Successive halving ranks every trial that reaches a rung against the
//! *whole* cohort at that rung and promotes exactly `ceil(n / eta)`. A
//! real deployment enforces that with a barrier: trials pause at the rung
//! until the cohort reports. The engine cannot pause a job — but the
//! simulated loss curves are a pure function of `(trial, config, epoch,
//! seed)` ([`SynthLoss`]), independent of scheduling, so the driver
//! resolves each rung's cutoff from the same oracle the trials will report
//! and plants each loser's stop at its rung-boundary epoch
//! (`ExecutionBackend::should_early_stop`, the same unit-granular
//! mechanism behind tenant `cancel_at`). The rung invariants — exactly
//! `ceil(n/eta)` promotions, survivors exactly the top-k by observed loss,
//! no retired unit after a pruned trial's finish — are asserted on the
//! *observed* run by the property suite in `rust/tests/selection.rs`.

use std::cell::RefCell;
use std::rc::Rc;

use crate::coordinator::metrics::{Interval, IntervalKind};
use crate::coordinator::observer::EngineObserver;
use crate::coordinator::partitioner::{partition, PartitionPolicy};
use crate::coordinator::sharp::RunReport;
use crate::coordinator::task::ModelTask;
use crate::coordinator::unit::ShardUnit;
use crate::error::{HydraError, Result};
use crate::exec::{ExecutionBackend, SimBackend};
use crate::selection::loss::SynthLoss;
use crate::selection::searcher::{
    GridSearch, HalvingRule, RandomSearch, Searcher, SuccessiveHalving,
};
use crate::selection::space::{SearchSpace, TrialConfig};
use crate::session::{Backend, Session};
use crate::sim::cost::{GpuSpec, PaperModel};

/// Which search algorithm [`Search`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// Full cartesian grid, every trial to its full budget.
    Grid,
    /// `trials` seeded random samples, every trial to its full budget.
    Random {
        /// Number of samples.
        trials: usize,
    },
    /// Successive halving over `trials` random samples, or over the full
    /// grid when `trials` is `None` (same cohort as [`Algo::Grid`] — the
    /// apples-to-apples GPU-hours comparison).
    Asha {
        /// Random cohort size; `None` halves the full grid.
        trials: Option<usize>,
        /// Reduction factor (survivors per rung = `ceil(n / eta)`).
        eta: u32,
        /// Epoch budget of the first rung.
        min_epochs: u32,
    },
}

/// A complete search specification: the space, the algorithm, and the
/// shape every trial trains with. Run it with [`Session::run_search`].
#[derive(Debug, Clone)]
pub struct Search {
    /// Hyperparameter space the trials are drawn from.
    pub space: SearchSpace,
    /// Search algorithm.
    pub algo: Algo,
    /// Full per-trial epoch budget (ASHA's `R`).
    pub epochs: u32,
    /// Mini-batches per epoch of every trial.
    pub minibatches_per_epoch: u32,
    /// Seed of the random sampler and the synthetic loss noise.
    pub seed: u64,
    /// `submit_at` spacing between consecutive trials in virtual seconds
    /// (0.0 = the batch setting; > 0 = an online trial stream).
    pub stagger_secs: f64,
    /// Grid resolution of continuous axes (grid / grid-cohort ASHA).
    pub grid_points: usize,
    /// GPU class the trial unit costs are calibrated on. Must be the
    /// reference class of the session's pool (the class whose
    /// `DeviceSpec::speed` is 1.0) for durations to line up.
    pub reference: GpuSpec,
    /// Partitioner headroom fraction used when building tasks directly via
    /// [`Search::trial_task`]. [`Session::run_search`] *overrides* it with
    /// the session's own `EngineOptions::buffer_frac`, so shard sizing
    /// always matches the engine's real staging zone and §4.6 prefetch
    /// engages — a mismatched pair cannot be configured through the
    /// driver.
    pub buffer_frac: f64,
}

impl Search {
    /// A grid search over `space` with the paper-scale defaults: 4 epochs,
    /// 2 mini-batches/epoch, 3 grid points per continuous axis, RTX
    /// 2080 Ti cost calibration, 30% partitioner headroom.
    pub fn new(space: SearchSpace) -> Search {
        Search {
            space,
            algo: Algo::Grid,
            epochs: 4,
            minibatches_per_epoch: 2,
            seed: 0,
            stagger_secs: 0.0,
            grid_points: 3,
            reference: GpuSpec::rtx2080ti(),
            buffer_frac: 0.30,
        }
    }

    /// The [`Searcher`] this spec's algorithm denotes.
    pub fn searcher(&self) -> Result<Box<dyn Searcher>> {
        Ok(match self.algo {
            Algo::Grid => Box::new(GridSearch::new(self.grid_points)),
            Algo::Random { trials } => Box::new(RandomSearch { trials, seed: self.seed }),
            Algo::Asha { trials, eta, min_epochs } => {
                let rule = HalvingRule { eta, min_epochs };
                Box::new(match trials {
                    Some(n) => SuccessiveHalving::over_random(n, self.seed, rule),
                    None => SuccessiveHalving::over_grid(self.grid_points, rule),
                })
            }
        })
    }

    /// Deterministic task name of trial `idx`.
    pub fn trial_name(idx: usize, cfg: &TrialConfig) -> String {
        format!("trial{idx}-{}", cfg.label())
    }

    /// Build the [`ModelTask`] trial `idx` trains: a BERT-style encoder
    /// whose depth/batch come from the config (`layers`, `batch`),
    /// partitioned for `min_device_mem` (the §4.3 smallest-device bound)
    /// with costs calibrated on [`Search::reference`]. Public so the
    /// differential suite can hand-build the byte-identical `submit_at`
    /// job list.
    pub fn trial_task(
        &self,
        idx: usize,
        cfg: &TrialConfig,
        min_device_mem: u64,
    ) -> Result<ModelTask> {
        let layers = cfg.get_or("layers", 24.0).round().max(1.0) as usize;
        let batch = cfg.get_or("batch", 8.0).round().max(1.0) as usize;
        let lr = cfg.get_or("lr", 1e-3);
        let model = PaperModel::bert_depth(layers, batch);
        let probe = GpuSpec { mem_bytes: min_device_mem, ..self.reference };
        let part = partition(
            &model.layer_descs(&probe),
            min_device_mem,
            PartitionPolicy { buffer_frac: self.buffer_frac, ..Default::default() },
        )?;
        Ok(ModelTask::new(
            idx,
            Search::trial_name(idx, cfg),
            "search",
            part.shards,
            self.minibatches_per_epoch,
            self.epochs,
            lr as f32,
        )
        .with_arrival(self.stagger_secs * idx as f64))
    }
}

/// Lifecycle state of one trial.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrialState {
    /// Submitted; the run has not resolved it yet.
    Pending,
    /// Ran its full epoch budget.
    Completed,
    /// Stopped at rung `rung` (index into [`SearchReport::rungs`]).
    Pruned {
        /// Which rung retired it.
        rung: usize,
    },
}

/// One trial's full outcome.
#[derive(Debug, Clone)]
pub struct Trial {
    /// Trial id == submission index == engine model id.
    pub id: usize,
    /// Task name (`trial3-lr=0.001-layers=24`).
    pub name: String,
    /// The hyperparameter assignment.
    pub config: TrialConfig,
    /// Shards its model partitioned into.
    pub shards: u32,
    /// Observed `(epoch, loss)` pairs in completion order (epochs are
    /// 1-based).
    pub losses: Vec<(u32, f64)>,
    /// Final lifecycle state.
    pub state: TrialState,
    /// Units actually retired.
    pub units: u64,
    /// Reference GPU-seconds of the units actually executed.
    pub executed_secs: f64,
    /// Reference GPU-seconds a full (unpruned) run would execute.
    pub full_secs: f64,
    /// Virtual time the trial finished (or its pruning took effect);
    /// `NaN` if the run ended without resolving it.
    pub finished: f64,
    /// Virtual time its last unit retired (`NaN` if none ran).
    pub last_retire: f64,
}

impl Trial {
    /// The last observed loss, if any epoch completed.
    pub fn final_loss(&self) -> Option<f64> {
        self.losses.last().map(|&(_, l)| l)
    }
}

/// One successive-halving rung's outcome.
#[derive(Debug, Clone)]
pub struct Rung {
    /// Epoch budget of the rung.
    pub epochs: u32,
    /// Trial ids that reached it (ascending).
    pub entered: Vec<usize>,
    /// The exactly `ceil(entered / eta)` ids promoted past it (ascending)
    /// — the top-k by loss at `epochs`.
    pub promoted: Vec<usize>,
}

/// Everything a caller can inspect after [`Session::run_search`].
#[derive(Debug, Clone)]
pub struct SearchReport {
    /// Algorithm tag (`grid`, `random`, `asha`).
    pub algo: &'static str,
    /// The underlying engine report (makespan, utilization, per-job
    /// stats, spill traffic).
    pub run: RunReport,
    /// Per-trial outcomes, in trial-id order.
    pub trials: Vec<Trial>,
    /// Rung-by-rung survivor record (empty without pruning).
    pub rungs: Vec<Rung>,
    /// Trial id with the lowest final loss among completed trials.
    pub best: Option<usize>,
    /// Reference GPU-seconds a full no-pruning pass over the same trials
    /// would execute.
    pub full_secs: f64,
    /// Reference GPU-seconds actually executed.
    pub spent_secs: f64,
    /// Units the monitor saw retire *after* their trial finished —
    /// always 0 (asserted by the property suite).
    pub late_retires: u64,
}

impl SearchReport {
    /// GPU-hours pruning saved against the full-grid pass.
    pub fn gpu_hours_saved(&self) -> f64 {
        (self.full_secs - self.spent_secs) / 3600.0
    }

    /// The winning trial.
    pub fn best_trial(&self) -> Option<&Trial> {
        self.best.map(|i| &self.trials[i])
    }

    /// `(rung epochs, entered, promoted)` counts per rung.
    pub fn survivors_per_rung(&self) -> Vec<(u32, usize, usize)> {
        self.rungs
            .iter()
            .map(|r| (r.epochs, r.entered.len(), r.promoted.len()))
            .collect()
    }
}

/// Shared trial/rung bookkeeping the backend wrapper and the driver both
/// touch during the run.
struct SelectionState {
    trials: Vec<Trial>,
    rungs: Vec<Rung>,
    /// Per trial: `(stop after this many epochs, rung index)` for rung
    /// losers; `None` runs to the full budget.
    stop_after: Vec<Option<(u32, usize)>>,
}

impl SelectionState {
    /// Resolve the whole rung cascade from the loss oracle (see the module
    /// docs on synchronous halving) and initialise the trial records.
    fn plan(
        configs: &[TrialConfig],
        rule: Option<HalvingRule>,
        loss: &SynthLoss,
        max_epochs: u32,
    ) -> SelectionState {
        let n = configs.len();
        let trials = configs
            .iter()
            .enumerate()
            .map(|(id, cfg)| Trial {
                id,
                name: String::new(),
                config: cfg.clone(),
                shards: 0,
                losses: Vec::new(),
                state: TrialState::Pending,
                units: 0,
                executed_secs: 0.0,
                full_secs: 0.0,
                finished: f64::NAN,
                last_retire: f64::NAN,
            })
            .collect();
        let mut stop_after = vec![None; n];
        let mut rungs = Vec::new();
        if let Some(rule) = rule {
            let mut survivors: Vec<usize> = (0..n).collect();
            for (ri, &re) in rule.rung_epochs(max_epochs).iter().enumerate() {
                let entered = survivors.clone();
                let k = rule.promotions(entered.len());
                let mut ranked: Vec<(usize, f64)> = entered
                    .iter()
                    .map(|&t| (t, loss.loss(t, &configs[t], re)))
                    .collect();
                ranked.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
                let mut promoted: Vec<usize> = ranked[..k].iter().map(|&(t, _)| t).collect();
                promoted.sort_unstable();
                for &(t, _) in &ranked[k..] {
                    stop_after[t] = Some((re, ri));
                }
                rungs.push(Rung { epochs: re, entered, promoted: promoted.clone() });
                survivors = promoted;
            }
        }
        SelectionState { trials, rungs, stop_after }
    }
}

/// Execution-backend wrapper that records per-epoch losses and plants the
/// rung prunes, delegating unit durations to the wrapped backend.
struct TrialBackend {
    inner: Box<dyn ExecutionBackend>,
    loss: SynthLoss,
    state: Rc<RefCell<SelectionState>>,
}

impl ExecutionBackend for TrialBackend {
    fn execute_unit(&mut self, task: &ModelTask, unit: &ShardUnit) -> Result<f64> {
        self.inner.execute_unit(task, unit)
    }

    fn on_unit_retired(&mut self, task: &ModelTask, unit: &ShardUnit) {
        self.inner.on_unit_retired(task, unit);
        let mut st = self.state.borrow_mut();
        let Some(t) = st.trials.get_mut(unit.model) else {
            return;
        };
        t.units += 1;
        t.executed_secs += task.shard(unit.shard).cost(unit.phase);
        // the same boundary the engine consults should_early_stop at
        if task.geometry.closes_epoch(unit) {
            let e = unit.epoch + 1;
            let l = self.loss.loss(unit.model, &t.config, e);
            t.losses.push((e, l));
        }
    }

    fn should_early_stop(&mut self, task: &ModelTask, epoch: u32) -> bool {
        let mut st = self.state.borrow_mut();
        match st.stop_after.get(task.id).copied().flatten() {
            Some((stop, ri)) if epoch + 1 >= stop => {
                st.trials[task.id].state = TrialState::Pruned { rung: ri };
                true
            }
            _ => false,
        }
    }
}

/// [`EngineObserver`] that watches every trial's lifecycle live: arrival,
/// per-unit retire times, finish/cancel, and per-model compute seconds.
/// [`Session::run_search`] installs one automatically; it is public so
/// callers streaming their own observers (and the test suites) can reuse
/// the bookkeeping.
#[derive(Debug, Clone, Default)]
pub struct TrialMonitor {
    /// Per-model arrival time (`NaN` until seen).
    pub arrived: Vec<f64>,
    /// Per-model finish time (`NaN` until seen).
    pub finished: Vec<f64>,
    /// Per-model cancelled flag (tenant cancellation, not rung pruning).
    pub cancelled: Vec<bool>,
    /// Per-model last unit-retire time (`NaN` if none ran).
    pub last_retire: Vec<f64>,
    /// Per-model retired-unit count.
    pub units: Vec<u64>,
    /// Per-model device-seconds of compute.
    pub compute_secs: Vec<f64>,
    /// Units that retired *after* their model finished (must stay 0).
    pub late_retires: u64,
}

impl TrialMonitor {
    /// Monitor pre-sized for `n` models.
    pub fn new(n: usize) -> TrialMonitor {
        let mut m = TrialMonitor::default();
        m.ensure(n.saturating_sub(1));
        m
    }

    fn ensure(&mut self, model: usize) {
        if model >= self.finished.len() {
            let n = model + 1;
            self.arrived.resize(n, f64::NAN);
            self.finished.resize(n, f64::NAN);
            self.cancelled.resize(n, false);
            self.last_retire.resize(n, f64::NAN);
            self.units.resize(n, 0);
            self.compute_secs.resize(n, 0.0);
        }
    }
}

impl EngineObserver for TrialMonitor {
    fn on_job_arrived(&mut self, model: usize, _name: &str, now: f64) {
        self.ensure(model);
        self.arrived[model] = now;
    }

    fn on_unit_retired(&mut self, _device: usize, unit: &ShardUnit, now: f64) {
        self.ensure(unit.model);
        let m = unit.model;
        self.units[m] += 1;
        if self.last_retire[m].is_nan() || now > self.last_retire[m] {
            self.last_retire[m] = now;
        }
        if !self.finished[m].is_nan() && now > self.finished[m] + 1e-9 {
            self.late_retires += 1;
        }
    }

    fn on_job_finished(&mut self, model: usize, now: f64, cancelled: bool) {
        self.ensure(model);
        self.finished[model] = now;
        self.cancelled[model] = cancelled;
    }

    fn on_interval(&mut self, interval: &Interval) {
        if interval.kind == IntervalKind::Compute {
            self.ensure(interval.model);
            self.compute_secs[interval.model] += interval.end - interval.start;
        }
    }
}

/// The implementation behind [`Session::run_search`].
pub(crate) fn drive_search(mut session: Session, search: &Search) -> Result<SearchReport> {
    if session.n_jobs() != 0 {
        return Err(HydraError::Config(
            "run_search needs a fresh session (jobs were already submitted)".into(),
        ));
    }
    if search.epochs == 0 || search.minibatches_per_epoch == 0 {
        return Err(HydraError::Config(
            "search needs epochs >= 1 and minibatches >= 1".into(),
        ));
    }
    if !search.stagger_secs.is_finite() || search.stagger_secs < 0.0 {
        return Err(HydraError::Config(format!(
            "bad trial stagger {}",
            search.stagger_secs
        )));
    }
    let searcher = search.searcher()?;
    let algo = searcher.name();
    let rule = searcher.rule();
    let configs = searcher.configs(&search.space)?;
    if configs.is_empty() {
        return Err(HydraError::Config("search produced no trials".into()));
    }
    // Shards are sized against the session's *actual* buffer zone: a
    // partition headroom that disagrees with the engine's zone would
    // silently disable §4.6 staging for every trial.
    let mut search = search.clone();
    search.buffer_frac = session.engine_options().buffer_frac;
    let search = &search;

    // Swap the execution backend for the trial-aware wrapper (losses +
    // rung prunes); durations still come from the wrapped backend.
    let inner: Box<dyn ExecutionBackend> = match session.replace_backend(Backend::sim()) {
        Backend::Sim { noise, seed } => Box::new(SimBackend::new(noise, seed)),
        Backend::Custom(b) => b,
        Backend::Real { .. } => {
            return Err(HydraError::Config(
                "run_search drives the simulated backend (trial loss curves are \
                 synthetic); use Backend::Sim or Backend::Custom"
                    .into(),
            ));
        }
    };
    let loss = SynthLoss::new(search.seed);
    let mut state = SelectionState::plan(&configs, rule, &loss, search.epochs);

    // Build and submit every trial; engine model ids follow submission
    // order, so trial id == model id.
    let min_mem = session.cluster().min_device_mem();
    let mut handles = Vec::with_capacity(configs.len());
    for (i, cfg) in configs.iter().enumerate() {
        let task = search.trial_task(i, cfg, min_mem)?;
        state.trials[i].name = task.name.clone();
        state.trials[i].shards = task.shards.len() as u32;
        state.trials[i].full_secs = task.remaining_time();
        handles.push(session.submit_at(task, search.stagger_secs * i as f64)?);
    }

    let state = Rc::new(RefCell::new(state));
    session.replace_backend(Backend::Custom(Box::new(TrialBackend {
        inner,
        loss,
        state: Rc::clone(&state),
    })));
    let mut monitor = TrialMonitor::new(configs.len());
    let report = session.run_with(&mut monitor)?;
    for (i, h) in handles.iter().enumerate() {
        debug_assert_eq!(report.model_of(*h), Some(i), "trial ids follow submission");
    }

    let mut state = Rc::try_unwrap(state)
        .map_err(|_| HydraError::Sched("trial state still shared after the run".into()))
        .map(RefCell::into_inner)?;
    let mut full_secs = 0.0;
    let mut spent_secs = 0.0;
    for (i, t) in state.trials.iter_mut().enumerate() {
        full_secs += t.full_secs;
        spent_secs += t.executed_secs;
        if t.state == TrialState::Pending {
            t.state = TrialState::Completed;
        }
        t.finished = monitor.finished.get(i).copied().unwrap_or(f64::NAN);
        t.last_retire = monitor.last_retire.get(i).copied().unwrap_or(f64::NAN);
    }
    let best = state
        .trials
        .iter()
        .filter(|t| t.state == TrialState::Completed)
        .filter_map(|t| t.final_loss().map(|l| (t.id, l)))
        .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
        .map(|(id, _)| id);
    Ok(SearchReport {
        algo,
        run: report.run,
        trials: state.trials,
        rungs: state.rungs,
        best,
        full_secs,
        spent_secs,
        late_retires: monitor.late_retires,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sharp::EngineOptions;
    use crate::coordinator::Cluster;
    use crate::session::Policy;

    const GIB: u64 = 1 << 30;

    fn tiny_search(algo: Algo) -> Search {
        let space = SearchSpace::parse("lr=1e-4..1e-2:log,layers=2,4").unwrap();
        Search {
            algo,
            epochs: 4,
            minibatches_per_epoch: 1,
            seed: 7,
            reference: GpuSpec::a4000(),
            ..Search::new(space)
        }
    }

    fn session() -> Session {
        Session::builder(Cluster::uniform(2, GpuSpec::a4000().mem_bytes, 2048 * GIB))
            .backend(Backend::sim())
            .policy(Policy::ShardedLrtf)
            .options(EngineOptions { record_intervals: false, ..Default::default() })
            .build()
            .unwrap()
    }

    #[test]
    fn grid_search_runs_every_trial_to_completion() {
        let r = session().run_search(&tiny_search(Algo::Grid)).unwrap();
        assert_eq!(r.algo, "grid");
        assert_eq!(r.trials.len(), 6);
        assert!(r.rungs.is_empty());
        for t in &r.trials {
            assert_eq!(t.state, TrialState::Completed, "{t:?}");
            assert_eq!(t.losses.len(), 4);
            assert_eq!(t.units, 2 * t.shards as u64 * 4);
            assert!(t.finished.is_finite());
        }
        assert!((r.spent_secs - r.full_secs).abs() < 1e-6 * r.full_secs);
        assert_eq!(r.late_retires, 0);
        // best trial exists and carries the minimum final loss
        let best = r.best_trial().unwrap();
        for t in &r.trials {
            assert!(best.final_loss().unwrap() <= t.final_loss().unwrap() + 1e-12);
        }
    }

    #[test]
    fn asha_prunes_and_saves_gpu_time() {
        let algo = Algo::Asha { trials: None, eta: 2, min_epochs: 1 };
        let r = session().run_search(&tiny_search(algo)).unwrap();
        assert_eq!(r.algo, "asha");
        // rungs at 1 and 2 epochs: 6 -> 3 -> 2
        assert_eq!(r.survivors_per_rung(), vec![(1, 6, 3), (2, 3, 2)]);
        assert!(r.spent_secs < r.full_secs);
        assert!(r.gpu_hours_saved() > 0.0);
        let pruned = r
            .trials
            .iter()
            .filter(|t| matches!(t.state, TrialState::Pruned { .. }))
            .count();
        assert_eq!(pruned, 4);
        assert!(r.best.is_some());
    }

    #[test]
    fn run_search_rejects_real_backend_and_dirty_sessions() {
        let s = Session::builder(Cluster::uniform(1, GIB, 64 * GIB))
            .backend(Backend::Real { manifest: "artifacts".into() })
            .build()
            .unwrap();
        assert!(s.run_search(&tiny_search(Algo::Grid)).is_err());

        let mut s = session();
        let cfg = tiny_search(Algo::Grid);
        let task = cfg.trial_task(0, &cfg.space.grid(2)[0], 16 * GIB).unwrap();
        s.submit(task).unwrap();
        assert!(s.run_search(&cfg).is_err());
    }

    #[test]
    fn degenerate_rule_without_rungs_matches_grid() {
        // min_epochs >= epochs: no rung fits below the budget, nothing is
        // pruned — ASHA degenerates to the plain grid pass
        let algo = Algo::Asha { trials: None, eta: 3, min_epochs: 9 };
        let asha = session().run_search(&tiny_search(algo)).unwrap();
        let grid = session().run_search(&tiny_search(Algo::Grid)).unwrap();
        assert!(asha.rungs.is_empty());
        assert_eq!(
            format!("{:?}", asha.run),
            format!("{:?}", grid.run),
            "no-pruning ASHA must schedule exactly like grid"
        );
    }
}
