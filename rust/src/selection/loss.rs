//! Parameterized synthetic loss curves for the simulated backend.
//!
//! Model-selection decisions are only testable when the simulator produces
//! loss curves that *react to hyperparameters* the way real training does:
//! learning rate has a sweet spot (too low converges slowly, too high
//! plateaus above the optimum), capacity (depth) lowers the reachable
//! floor, and run-to-run noise is small but nonzero. [`SynthLoss`] is that
//! oracle: a pure function of `(trial, config, epoch, seed)` — deliberately
//! independent of engine scheduling, so rung decisions are deterministic
//! for a given search seed and replayable from the property suite.

use crate::selection::space::TrialConfig;
use crate::util::rng::Rng;

/// The learning rate at the bottom of the synthetic lr valley.
pub const SWEET_LR: f64 = 1e-3;

/// Deterministic synthetic loss oracle (see module docs).
#[derive(Debug, Clone, Copy)]
pub struct SynthLoss {
    /// Seed of the per-(trial, epoch) noise stream.
    pub seed: u64,
    /// Noise amplitude (standard deviation of the additive term).
    pub noise: f64,
}

impl SynthLoss {
    /// Oracle with the default ±0.02 noise band.
    pub fn new(seed: u64) -> SynthLoss {
        SynthLoss { seed, noise: 0.02 }
    }

    /// Loss of `trial` (with hyperparameters `cfg`) after completing
    /// `epoch` epochs (1-based). Recognised config keys: `lr` (sweet spot
    /// at [`SWEET_LR`], penalised in log space), `layers` (deeper models
    /// reach a lower floor). Unknown keys are ignored.
    pub fn loss(&self, trial: usize, cfg: &TrialConfig, epoch: u32) -> f64 {
        let lr = cfg.get_or("lr", SWEET_LR).max(1e-12);
        let layers = cfg.get_or("layers", 24.0).max(1.0);
        // distance from the sweet spot in log space: the classic U-shape
        let miss = (lr / SWEET_LR).ln().abs();
        // capacity floor: deeper models can fit more, mistuned lr settles
        // above the best achievable loss
        let floor = 1.2 + 8.0 / (layers + 4.0) + 0.08 * miss;
        // convergence rate: fastest at the sweet spot
        let rate = 0.8 / (1.0 + 0.6 * miss * miss);
        let start = 7.0; // ~ln(vocab): untrained LM perplexity
        let decay = (start - floor) * (-rate * epoch as f64).exp();
        (floor + decay + self.noise * self.noise_sample(trial, epoch)).max(0.0)
    }

    /// One standard-normal draw keyed by (seed, trial, epoch).
    fn noise_sample(&self, trial: usize, epoch: u32) -> f64 {
        let key = self
            .seed
            ^ (trial as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (u64::from(epoch) << 40);
        Rng::new(key).normal()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::space::SearchSpace;

    fn cfg(lr: f64, layers: f64) -> TrialConfig {
        TrialConfig { values: vec![("lr".into(), lr), ("layers".into(), layers)] }
    }

    #[test]
    fn well_tuned_curves_decrease_towards_the_floor() {
        let l = SynthLoss { seed: 7, noise: 0.0 };
        let mut last = f64::INFINITY;
        for e in 1..=12 {
            let v = l.loss(0, &cfg(SWEET_LR, 24.0), e);
            assert!(v < last, "epoch {e}: {v} >= {last}");
            last = v;
        }
        assert!(last > 1.2 && last < 2.0, "{last}");
    }

    #[test]
    fn mistuned_lr_loses_at_every_epoch() {
        let l = SynthLoss { seed: 7, noise: 0.0 };
        for e in 1..=8 {
            let good = l.loss(0, &cfg(SWEET_LR, 24.0), e);
            let low = l.loss(0, &cfg(1e-5, 24.0), e);
            let high = l.loss(0, &cfg(1e-1, 24.0), e);
            assert!(good < low, "epoch {e}: {good} vs low-lr {low}");
            assert!(good < high, "epoch {e}: {good} vs high-lr {high}");
        }
    }

    #[test]
    fn deeper_models_reach_a_lower_late_loss() {
        let l = SynthLoss { seed: 7, noise: 0.0 };
        let shallow = l.loss(0, &cfg(SWEET_LR, 12.0), 10);
        let deep = l.loss(0, &cfg(SWEET_LR, 48.0), 10);
        assert!(deep < shallow, "{deep} vs {shallow}");
    }

    #[test]
    fn noise_is_seeded_and_trial_specific() {
        let a = SynthLoss::new(3);
        let b = SynthLoss::new(3);
        let c = SynthLoss::new(4);
        let x = cfg(SWEET_LR, 24.0);
        assert_eq!(a.loss(1, &x, 2), b.loss(1, &x, 2));
        assert_ne!(a.loss(1, &x, 2), c.loss(1, &x, 2));
        // identical configs on different trial slots still differ (noise
        // keyed per trial, so duplicate random samples do not tie)
        assert_ne!(a.loss(1, &x, 2), a.loss(2, &x, 2));
    }

    #[test]
    fn defaults_apply_for_unknown_spaces() {
        let l = SynthLoss::new(0);
        let space = SearchSpace::parse("momentum=0.1..0.9").unwrap();
        let c = space.grid(2).remove(0);
        assert!(l.loss(0, &c, 1).is_finite());
    }
}
