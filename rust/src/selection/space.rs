//! Hyperparameter search spaces: named axes that are either continuous
//! ranges (linear or log-scaled) or discrete choice lists, plus the compact
//! axis syntax shared by the `hydra search --space ...` CLI flag and the
//! config layer's `"search"` spec.
//!
//! Syntax: comma-separated axes. An axis is `name=lo..hi[:log]` (range) or
//! `name=v1,v2,v3` (choices — parts without `=` extend the previous axis's
//! choice list, so the whole space stays one comma-separated string):
//!
//! ```text
//! lr=1e-4..1e-2:log,layers=12,24,48,batch=4,8,16
//! ```

use crate::error::{HydraError, Result};
use crate::util::rng::Rng;

fn serr(msg: impl Into<String>) -> HydraError {
    HydraError::Config(msg.into())
}

/// One axis of a [`SearchSpace`].
#[derive(Debug, Clone, PartialEq)]
pub enum ParamAxis {
    /// Continuous range `[lo, hi]`; `log: true` grids/samples geometrically
    /// (the right scale for learning rates).
    Range {
        /// Inclusive lower bound.
        lo: f64,
        /// Inclusive upper bound.
        hi: f64,
        /// Geometric (log-scale) spacing instead of arithmetic.
        log: bool,
    },
    /// An explicit list of discrete values (layer counts, batch sizes).
    Choices(Vec<f64>),
}

/// A named axis.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    /// Hyperparameter name (`lr`, `layers`, `batch`, ...).
    pub name: String,
    /// The values the axis spans.
    pub axis: ParamAxis,
}

/// An ordered set of named axes — the space a [`crate::selection::Searcher`]
/// draws trial configurations from.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SearchSpace {
    /// Axes in declaration order (grid enumeration keeps this order, first
    /// axis slowest).
    pub params: Vec<ParamSpec>,
}

impl SearchSpace {
    /// An empty space; add axes with [`SearchSpace::range`],
    /// [`SearchSpace::log_range`], [`SearchSpace::choices`].
    pub fn new() -> SearchSpace {
        SearchSpace::default()
    }

    /// Add a linear range axis.
    pub fn range(mut self, name: impl Into<String>, lo: f64, hi: f64) -> SearchSpace {
        self.params
            .push(ParamSpec { name: name.into(), axis: ParamAxis::Range { lo, hi, log: false } });
        self
    }

    /// Add a log-scaled range axis.
    pub fn log_range(mut self, name: impl Into<String>, lo: f64, hi: f64) -> SearchSpace {
        self.params
            .push(ParamSpec { name: name.into(), axis: ParamAxis::Range { lo, hi, log: true } });
        self
    }

    /// Add a discrete choice axis.
    pub fn choices(mut self, name: impl Into<String>, values: &[f64]) -> SearchSpace {
        self.params
            .push(ParamSpec { name: name.into(), axis: ParamAxis::Choices(values.to_vec()) });
        self
    }

    /// Parse the compact axis syntax (see the module docs).
    pub fn parse(s: &str) -> Result<SearchSpace> {
        let mut params: Vec<ParamSpec> = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                return Err(serr(format!("empty axis in search space {s:?}")));
            }
            match part.split_once('=') {
                Some((name, rest)) => {
                    let name = name.trim();
                    if name.is_empty() {
                        return Err(serr(format!("axis {part:?} has an empty name")));
                    }
                    if params.iter().any(|p| p.name == name) {
                        return Err(serr(format!("duplicate axis {name:?} in space {s:?}")));
                    }
                    let axis = if let Some((lo, hi)) = rest.split_once("..") {
                        let (hi, log) = match hi.split_once(':') {
                            Some((h, "log")) => (h, true),
                            Some((_, modifier)) => {
                                return Err(serr(format!(
                                    "unknown range modifier {modifier:?} in axis {part:?} \
                                     (only :log is supported)"
                                )));
                            }
                            None => (hi, false),
                        };
                        let lo: f64 = lo.trim().parse().map_err(|_| {
                            serr(format!("bad range bound {lo:?} in axis {part:?}"))
                        })?;
                        let hi: f64 = hi.trim().parse().map_err(|_| {
                            serr(format!("bad range bound {hi:?} in axis {part:?}"))
                        })?;
                        ParamAxis::Range { lo, hi, log }
                    } else {
                        let v: f64 = rest.trim().parse().map_err(|_| {
                            serr(format!("bad value {rest:?} in axis {part:?}"))
                        })?;
                        ParamAxis::Choices(vec![v])
                    };
                    params.push(ParamSpec { name: name.to_string(), axis });
                }
                None => {
                    // a bare value extends the previous axis's choice list
                    let Some(last) = params.last_mut() else {
                        return Err(serr(format!(
                            "space {s:?} starts with bare value {part:?} (axes are name=...)"
                        )));
                    };
                    let v: f64 = part.parse().map_err(|_| {
                        serr(format!("bad value {part:?} in axis {:?}", last.name))
                    })?;
                    match &mut last.axis {
                        ParamAxis::Choices(vs) => vs.push(v),
                        ParamAxis::Range { .. } => {
                            return Err(serr(format!(
                                "value {part:?} follows range axis {:?} (a choice list \
                                 cannot extend a range)",
                                last.name
                            )));
                        }
                    }
                }
            }
        }
        let space = SearchSpace { params };
        space.validate()?;
        Ok(space)
    }

    /// Reject malformed spaces with a clear configuration error.
    pub fn validate(&self) -> Result<()> {
        if self.params.is_empty() {
            return Err(serr("search space has no axes"));
        }
        for p in &self.params {
            match &p.axis {
                ParamAxis::Range { lo, hi, log } => {
                    if !lo.is_finite() || !hi.is_finite() || lo >= hi {
                        return Err(serr(format!(
                            "axis {:?}: range [{lo}, {hi}] needs finite lo < hi",
                            p.name
                        )));
                    }
                    if *log && *lo <= 0.0 {
                        return Err(serr(format!(
                            "axis {:?}: log range needs lo > 0 (got {lo})",
                            p.name
                        )));
                    }
                }
                ParamAxis::Choices(vs) => {
                    if vs.is_empty() {
                        return Err(serr(format!("axis {:?} has no choices", p.name)));
                    }
                    if vs.iter().any(|v| !v.is_finite()) {
                        return Err(serr(format!("axis {:?} has a non-finite choice", p.name)));
                    }
                }
            }
        }
        Ok(())
    }

    /// The values one axis contributes to a grid of `points` per range.
    fn axis_values(axis: &ParamAxis, points: usize) -> Vec<f64> {
        match axis {
            ParamAxis::Choices(vs) => vs.clone(),
            ParamAxis::Range { lo, hi, log } => {
                if points <= 1 {
                    return vec![if *log {
                        ((lo.ln() + hi.ln()) / 2.0).exp()
                    } else {
                        (lo + hi) / 2.0
                    }];
                }
                (0..points)
                    .map(|i| {
                        let f = i as f64 / (points - 1) as f64;
                        if *log {
                            (lo.ln() + f * (hi.ln() - lo.ln())).exp()
                        } else {
                            lo + f * (hi - lo)
                        }
                    })
                    .collect()
            }
        }
    }

    /// Full cartesian grid; range axes are discretised to `points` values
    /// (inclusive endpoints). First axis varies slowest — deterministic
    /// enumeration order.
    pub fn grid(&self, points: usize) -> Vec<TrialConfig> {
        let axes: Vec<(String, Vec<f64>)> = self
            .params
            .iter()
            .map(|p| (p.name.clone(), Self::axis_values(&p.axis, points)))
            .collect();
        let mut out = vec![TrialConfig { values: Vec::new() }];
        for (name, vals) in &axes {
            let mut next = Vec::with_capacity(out.len() * vals.len());
            for cfg in &out {
                for &v in vals {
                    let mut c = cfg.clone();
                    c.values.push((name.clone(), v));
                    next.push(c);
                }
            }
            out = next;
        }
        out
    }

    /// Number of configurations [`SearchSpace::grid`] would enumerate.
    pub fn n_grid(&self, points: usize) -> usize {
        self.params
            .iter()
            .map(|p| match &p.axis {
                ParamAxis::Choices(vs) => vs.len(),
                ParamAxis::Range { .. } => points.max(1),
            })
            .product()
    }

    /// Draw one uniform sample (uniform in log space for log ranges).
    pub fn sample(&self, rng: &mut Rng) -> TrialConfig {
        let values = self
            .params
            .iter()
            .map(|p| {
                let v = match &p.axis {
                    ParamAxis::Choices(vs) => vs[rng.below(vs.len() as u64) as usize],
                    ParamAxis::Range { lo, hi, log } => {
                        let f = rng.uniform();
                        if *log {
                            (lo.ln() + f * (hi.ln() - lo.ln())).exp()
                        } else {
                            lo + f * (hi - lo)
                        }
                    }
                };
                (p.name.clone(), v)
            })
            .collect();
        TrialConfig { values }
    }
}

/// One concrete assignment of every axis — what a trial trains with.
#[derive(Debug, Clone, PartialEq)]
pub struct TrialConfig {
    /// `(axis name, value)` pairs in axis order.
    pub values: Vec<(String, f64)>,
}

impl TrialConfig {
    /// Value of the named axis, if present.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.values.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    /// Value of the named axis, or `default`.
    pub fn get_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).unwrap_or(default)
    }

    /// Deterministic human-readable tag (`lr=0.001-layers=24`), used in
    /// trial task names.
    pub fn label(&self) -> String {
        self.values
            .iter()
            .map(|(n, v)| format!("{n}={v}"))
            .collect::<Vec<_>>()
            .join("-")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let s = SearchSpace::parse("lr=1e-4..1e-2:log,layers=12,24,48").unwrap();
        assert_eq!(s.params.len(), 2);
        assert_eq!(
            s.params[0].axis,
            ParamAxis::Range { lo: 1e-4, hi: 1e-2, log: true }
        );
        assert_eq!(s.params[1].axis, ParamAxis::Choices(vec![12.0, 24.0, 48.0]));
        assert_eq!(s.n_grid(3), 9);
    }

    #[test]
    fn parses_linear_ranges_and_single_choices() {
        let s = SearchSpace::parse("momentum=0.1..0.9,batch=8").unwrap();
        assert_eq!(
            s.params[0].axis,
            ParamAxis::Range { lo: 0.1, hi: 0.9, log: false }
        );
        assert_eq!(s.params[1].axis, ParamAxis::Choices(vec![8.0]));
    }

    #[test]
    fn rejects_malformed_spaces() {
        for bad in [
            "",
            "lr=",
            "12,24",                       // bare values with no axis
            "lr=1e-2..1e-4:log",           // lo >= hi
            "lr=-1e-3..1e-2:log",          // log with lo <= 0
            "lr=1e-4..1e-2:exp",           // unknown modifier
            "lr=1e-4..1e-2,3e-3",          // choices extending a range
            "lr=a..b",
            "layers=12,x",
            "lr=1e-4..1e-2:log,lr=1,2",    // duplicate axis
        ] {
            assert!(SearchSpace::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn grid_is_cartesian_and_log_spaced() {
        let s = SearchSpace::parse("lr=1e-4..1e-2:log,layers=12,24").unwrap();
        let g = s.grid(3);
        assert_eq!(g.len(), 6);
        // first axis slowest: lr constant over consecutive pairs
        assert_eq!(g[0].get("lr"), g[1].get("lr"));
        assert_eq!(g[0].get("layers"), Some(12.0));
        assert_eq!(g[1].get("layers"), Some(24.0));
        // geometric midpoint of 1e-4..1e-2 is 1e-3
        let mid = g[2].get("lr").unwrap();
        assert!((mid - 1e-3).abs() < 1e-12, "{mid}");
        // endpoints inclusive
        assert!((g[0].get("lr").unwrap() - 1e-4).abs() < 1e-15);
        assert!((g[5].get("lr").unwrap() - 1e-2).abs() < 1e-12);
    }

    #[test]
    fn single_point_grid_takes_the_midpoint() {
        let s = SearchSpace::parse("x=2.0..4.0").unwrap();
        let g = s.grid(1);
        assert_eq!(g.len(), 1);
        assert!((g[0].get("x").unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn samples_stay_in_bounds_and_are_seeded() {
        let s = SearchSpace::parse("lr=1e-4..1e-2:log,layers=12,24,48").unwrap();
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..100 {
            let ca = s.sample(&mut a);
            let cb = s.sample(&mut b);
            assert_eq!(ca, cb);
            let lr = ca.get("lr").unwrap();
            assert!((1e-4..=1e-2).contains(&lr), "{lr}");
            assert!([12.0, 24.0, 48.0].contains(&ca.get("layers").unwrap()));
        }
    }

    #[test]
    fn builder_api_matches_parse() {
        let built = SearchSpace::new()
            .log_range("lr", 1e-4, 1e-2)
            .choices("layers", &[12.0, 24.0, 48.0]);
        let parsed = SearchSpace::parse("lr=1e-4..1e-2:log,layers=12,24,48").unwrap();
        assert_eq!(built, parsed);
    }

    #[test]
    fn label_is_deterministic() {
        let s = SearchSpace::parse("lr=1e-3..1e-2:log,layers=24").unwrap();
        let g = s.grid(2);
        assert_eq!(g[0].label(), "lr=0.001-layers=24");
    }
}
