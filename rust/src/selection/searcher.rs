//! Searchers: algorithms that turn a [`SearchSpace`] into a concrete,
//! deterministically ordered list of trials — plus the successive-halving
//! rule ASHA prunes with.
//!
//! [`GridSearch`] enumerates the full cartesian grid, [`RandomSearch`]
//! draws seeded samples, and [`SuccessiveHalving`] wraps either with a
//! [`HalvingRule`] so the [`crate::selection::Search`] driver retires the
//! bottom `1 - 1/eta` of the cohort at every rung.

use crate::error::{HydraError, Result};
use crate::selection::space::{SearchSpace, TrialConfig};
use crate::util::rng::Rng;

/// Successive-halving schedule: rungs at `min_epochs * eta^k` epochs
/// (strictly below the full budget); at each rung exactly
/// `ceil(n / eta)` of the `n` trials that reached it are promoted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HalvingRule {
    /// Reduction factor (>= 2): survivors per rung = `ceil(n / eta)`.
    pub eta: u32,
    /// Epoch budget of the first rung (>= 1).
    pub min_epochs: u32,
}

impl HalvingRule {
    /// Reject degenerate rules with a configuration error.
    pub fn validate(&self) -> Result<()> {
        if self.eta < 2 {
            return Err(HydraError::Config(format!(
                "halving rule: eta {} must be >= 2",
                self.eta
            )));
        }
        if self.min_epochs == 0 {
            return Err(HydraError::Config(
                "halving rule: min_epochs must be >= 1".into(),
            ));
        }
        Ok(())
    }

    /// Rung epoch budgets strictly below `max_epochs` (survivors of the
    /// last rung run to the full budget). Empty when `min_epochs >=
    /// max_epochs` — the rule degenerates to no pruning.
    pub fn rung_epochs(&self, max_epochs: u32) -> Vec<u32> {
        let mut out = Vec::new();
        let mut r = self.min_epochs;
        while r < max_epochs {
            out.push(r);
            r = r.saturating_mul(self.eta);
        }
        out
    }

    /// Survivor count for a rung `n` trials reached: `ceil(n / eta)`.
    pub fn promotions(&self, n: usize) -> usize {
        n.div_ceil(self.eta as usize)
    }
}

/// A search algorithm: produces the trial list and (optionally) the
/// pruning schedule the driver applies while the trials run.
pub trait Searcher {
    /// Short algorithm tag (`grid`, `random`, `asha`).
    fn name(&self) -> &'static str;

    /// The trial configurations to submit, in deterministic submission
    /// order (trial id == position in this list).
    fn configs(&self, space: &SearchSpace) -> Result<Vec<TrialConfig>>;

    /// The pruning schedule; `None` runs every trial to its full budget.
    fn rule(&self) -> Option<HalvingRule> {
        None
    }
}

/// Exhaustive cartesian grid; continuous axes are discretised to `points`
/// values (inclusive endpoints).
#[derive(Debug, Clone, Copy)]
pub struct GridSearch {
    /// Grid resolution of each continuous range axis.
    pub points: usize,
}

impl GridSearch {
    /// Grid with `points` values per continuous axis.
    pub fn new(points: usize) -> GridSearch {
        GridSearch { points }
    }
}

impl Searcher for GridSearch {
    fn name(&self) -> &'static str {
        "grid"
    }

    fn configs(&self, space: &SearchSpace) -> Result<Vec<TrialConfig>> {
        space.validate()?;
        if self.points == 0 {
            return Err(HydraError::Config(
                "grid search needs >= 1 point per continuous axis".into(),
            ));
        }
        Ok(space.grid(self.points))
    }
}

/// `trials` independent seeded samples of the space (uniform; log-uniform
/// on log ranges).
#[derive(Debug, Clone, Copy)]
pub struct RandomSearch {
    /// Number of trials to draw.
    pub trials: usize,
    /// Sampling seed (deterministic trial list per seed).
    pub seed: u64,
}

impl Searcher for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn configs(&self, space: &SearchSpace) -> Result<Vec<TrialConfig>> {
        space.validate()?;
        if self.trials == 0 {
            return Err(HydraError::Config("random search needs >= 1 trial".into()));
        }
        let mut rng = Rng::new(self.seed ^ 0x5EA2C4);
        Ok((0..self.trials).map(|_| space.sample(&mut rng)).collect())
    }
}

/// Successive halving / ASHA: the wrapped sampler's trials, pruned at
/// [`HalvingRule`] rungs while they run.
pub struct SuccessiveHalving {
    /// The sampler that produces the initial cohort.
    pub base: Box<dyn Searcher>,
    /// Rung schedule + reduction factor.
    pub rule: HalvingRule,
}

impl SuccessiveHalving {
    /// Halve a full grid (the `hydra search --algo asha` default — the
    /// same cohort as `--algo grid`, which is what makes the GPU-hours
    /// comparison apples-to-apples).
    pub fn over_grid(points: usize, rule: HalvingRule) -> SuccessiveHalving {
        SuccessiveHalving { base: Box::new(GridSearch::new(points)), rule }
    }

    /// Halve `trials` random samples (classic ASHA).
    pub fn over_random(trials: usize, seed: u64, rule: HalvingRule) -> SuccessiveHalving {
        SuccessiveHalving { base: Box::new(RandomSearch { trials, seed }), rule }
    }
}

impl Searcher for SuccessiveHalving {
    fn name(&self) -> &'static str {
        "asha"
    }

    fn configs(&self, space: &SearchSpace) -> Result<Vec<TrialConfig>> {
        self.rule.validate()?;
        self.base.configs(space)
    }

    fn rule(&self) -> Option<HalvingRule> {
        Some(self.rule)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> SearchSpace {
        SearchSpace::parse("lr=1e-4..1e-2:log,layers=12,24,48").unwrap()
    }

    #[test]
    fn grid_enumerates_the_full_cartesian_product() {
        let cfgs = GridSearch::new(3).configs(&space()).unwrap();
        assert_eq!(cfgs.len(), 9);
        assert!(GridSearch::new(0).configs(&space()).is_err());
        assert!(GridSearch::new(3).rule().is_none());
    }

    #[test]
    fn random_is_seeded_and_sized() {
        let a = RandomSearch { trials: 7, seed: 3 }.configs(&space()).unwrap();
        let b = RandomSearch { trials: 7, seed: 3 }.configs(&space()).unwrap();
        let c = RandomSearch { trials: 7, seed: 4 }.configs(&space()).unwrap();
        assert_eq!(a.len(), 7);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(RandomSearch { trials: 0, seed: 0 }.configs(&space()).is_err());
    }

    #[test]
    fn halving_rule_rungs_and_promotions() {
        let r = HalvingRule { eta: 3, min_epochs: 1 };
        assert_eq!(r.rung_epochs(9), vec![1, 3]);
        assert_eq!(r.rung_epochs(10), vec![1, 3, 9]);
        assert_eq!(r.rung_epochs(1), Vec::<u32>::new());
        assert_eq!(r.promotions(27), 9);
        assert_eq!(r.promotions(9), 3);
        assert_eq!(r.promotions(4), 2);
        assert_eq!(r.promotions(1), 1);
        assert!(HalvingRule { eta: 1, min_epochs: 1 }.validate().is_err());
        assert!(HalvingRule { eta: 2, min_epochs: 0 }.validate().is_err());
    }

    #[test]
    fn asha_shares_the_grid_cohort() {
        let rule = HalvingRule { eta: 3, min_epochs: 1 };
        let asha = SuccessiveHalving::over_grid(3, rule);
        assert_eq!(asha.name(), "asha");
        assert_eq!(asha.rule(), Some(rule));
        assert_eq!(
            asha.configs(&space()).unwrap(),
            GridSearch::new(3).configs(&space()).unwrap()
        );
        let bad = SuccessiveHalving::over_grid(3, HalvingRule { eta: 0, min_epochs: 1 });
        assert!(bad.configs(&space()).is_err());
    }
}
