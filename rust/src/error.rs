//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error`/`From` impls: the `thiserror` derive crate
//! is unavailable in the offline build environment (see Cargo.toml).

use std::fmt;

/// Every way a Hydra operation can fail.
#[derive(Debug)]
pub enum HydraError {
    /// Filesystem / IO failure (manifest loading, CSV output, ...).
    Io(std::io::Error),
    /// PJRT / XLA runtime failure (or the vendored stub refusing to run).
    Xla(xla::Error),
    /// JSON parse failure (manifests, workload specs).
    Json(crate::util::json::JsonError),
    /// Artifact manifest is malformed or missing entries.
    Manifest(String),
    /// User-facing configuration problem (CLI flags, workload specs).
    Config(String),
    /// A device-memory allocation would exceed capacity. A *real* error
    /// path: Algorithm 1's pilot runs probe with it.
    DeviceOom {
        /// Device whose ledger rejected the allocation.
        device: usize,
        /// Bytes the allocation needed.
        needed: u64,
        /// Bytes that were free.
        free: u64,
    },
    /// Scheduler / engine invariant violation.
    Sched(String),
    /// Execution backend failure.
    Exec(String),
    /// A write-ahead log or snapshot failed its checksum / framing checks
    /// (torn write, bit flip, truncation). Recovery treats everything up to
    /// the last complete checksummed record as valid and surfaces this for
    /// the tail — never a panic.
    WalCorrupt(String),
}

impl fmt::Display for HydraError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HydraError::Io(e) => write!(f, "io error: {e}"),
            HydraError::Xla(e) => write!(f, "xla error: {e}"),
            HydraError::Json(e) => write!(f, "json error: {e}"),
            HydraError::Manifest(m) => write!(f, "manifest error: {m}"),
            HydraError::Config(m) => write!(f, "config error: {m}"),
            HydraError::DeviceOom { device, needed, free } => write!(
                f,
                "device out of memory: need {needed} bytes, free {free} (device {device})"
            ),
            HydraError::Sched(m) => write!(f, "scheduling error: {m}"),
            HydraError::Exec(m) => write!(f, "execution error: {m}"),
            HydraError::WalCorrupt(m) => write!(f, "wal corrupt: {m}"),
        }
    }
}

impl std::error::Error for HydraError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            HydraError::Io(e) => Some(e),
            HydraError::Xla(e) => Some(e),
            HydraError::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for HydraError {
    fn from(e: std::io::Error) -> HydraError {
        HydraError::Io(e)
    }
}

impl From<xla::Error> for HydraError {
    fn from(e: xla::Error) -> HydraError {
        HydraError::Xla(e)
    }
}

impl From<crate::util::json::JsonError> for HydraError {
    fn from(e: crate::util::json::JsonError) -> HydraError {
        HydraError::Json(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, HydraError>;
