//! Crate-wide error type.

use thiserror::Error;

#[derive(Debug, Error)]
pub enum HydraError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),

    #[error("json error: {0}")]
    Json(#[from] crate::util::json::JsonError),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("device out of memory: need {needed} bytes, free {free} (device {device})")]
    DeviceOom { device: usize, needed: u64, free: u64 },

    #[error("scheduling error: {0}")]
    Sched(String),

    #[error("execution error: {0}")]
    Exec(String),
}

pub type Result<T> = std::result::Result<T, HydraError>;
