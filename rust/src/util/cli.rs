//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]). `flag_names` lists options that take
    /// no value; everything else starting with `--` consumes one value.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{name} expects a value"))?;
                    out.options.insert(name.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args, String> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad float {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let a = Args::parse(
            &s(&["run", "--n", "4", "--fast", "--out=o.csv", "extra"]),
            &["fast"],
        )
        .unwrap();
        assert_eq!(a.positional, s(&["run", "extra"]));
        assert_eq!(a.opt("n"), Some("4"));
        assert_eq!(a.opt("out"), Some("o.csv"));
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&s(&["--n"]), &[]).is_err());
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(&s(&["--n", "8", "--lr", "0.5"]), &[]).unwrap();
        assert_eq!(a.opt_usize("n", 1).unwrap(), 8);
        assert_eq!(a.opt_usize("m", 3).unwrap(), 3);
        assert_eq!(a.opt_f64("lr", 0.0).unwrap(), 0.5);
        assert!(a.opt_usize("lr", 0).is_err() || a.opt("lr") == Some("0.5"));
    }
}
