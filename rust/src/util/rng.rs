//! Seeded PRNG (SplitMix64 + xoshiro256**) and distributions.
//!
//! The `rand` crate is unavailable offline; this is a small, well-tested
//! replacement. Determinism matters: parameter initialisation, synthetic
//! data, and the Random scheduler baseline must be bitwise reproducible
//! across runs for EXPERIMENTS.md.

/// xoshiro256** seeded via SplitMix64, as recommended by the authors.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to fill the state; avoids the all-zero state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n). Uses rejection sampling to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — init is not a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a f32 buffer with N(0, std^2).
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal() as f32 * std;
        }
    }

    /// Derive an independent stream (for per-model / per-shard seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// The raw xoshiro256** state — snapshot support for the durability
    /// subsystem (a mid-run engine snapshot must resume the exact stream).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild an [`Rng`] at a previously captured [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn state_round_trip_resumes_the_exact_stream() {
        let mut a = Rng::new(21);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
