//! Minimal JSON parser/serializer.
//!
//! `serde_json` is unavailable in this offline environment (DESIGN.md §1),
//! so Hydra ships its own small, strict JSON implementation. It supports the
//! full JSON grammar minus exotic number edge cases (we parse numbers as
//! f64, which covers every value in manifest.json and the config files).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset it occurred at.
#[derive(Debug, Clone)]
pub struct JsonError {
    /// Byte offset into the input where parsing failed.
    pub pos: usize,
    /// Human-readable description of the failure.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field access: `j.get("a")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Builder helpers.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    if self.pos > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo → world\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo → world");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"n":-3,"o":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn escapes_on_output() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(4.0).to_string(), "4");
        assert_eq!(Json::Num(4.5).to_string(), "4.5");
    }

    #[test]
    fn as_usize_rejects_negative_and_fractional() {
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
    }
}
