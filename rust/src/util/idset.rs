//! A sorted dense-id set: the hot-path replacement for the engine's
//! `BTreeSet<usize>` ready/parked/cancel-pending sets (ISSUE 8).
//!
//! Engine ids (models, devices) are small dense integers, and the sets
//! are consulted on every event, so pointer-chasing tree nodes dominate
//! the hot loop. [`IdSet`] stores the members in one sorted `Vec`:
//! membership is a binary search, insert/remove are a binary search plus
//! a memmove (cheap at engine set sizes, and cache-friendly at storm
//! sizes), and iteration is ascending — exactly the `BTreeSet` iteration
//! order, which the engine's state codec and `wake_one`/`take_eligible`
//! byte-identity proofs rely on.

/// A set of `usize` ids backed by a sorted vector.
#[derive(Clone, Default)]
pub struct IdSet {
    ids: Vec<usize>,
}

impl IdSet {
    pub fn new() -> IdSet {
        IdSet { ids: Vec::new() }
    }

    /// Insert `id`; returns true if it was not already present
    /// (`BTreeSet::insert` semantics).
    pub fn insert(&mut self, id: usize) -> bool {
        match self.ids.binary_search(&id) {
            Ok(_) => false,
            Err(pos) => {
                self.ids.insert(pos, id);
                true
            }
        }
    }

    /// Remove `id`; returns true if it was present
    /// (`BTreeSet::remove` semantics).
    pub fn remove(&mut self, id: usize) -> bool {
        match self.ids.binary_search(&id) {
            Ok(pos) => {
                self.ids.remove(pos);
                true
            }
            Err(_) => false,
        }
    }

    pub fn contains(&self, id: usize) -> bool {
        self.ids.binary_search(&id).is_ok()
    }

    /// The smallest member — what `BTreeSet::iter().next()` returned at
    /// the `wake_one` call site.
    pub fn first(&self) -> Option<usize> {
        self.ids.first().copied()
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Ascending iteration, matching `BTreeSet` order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.ids.iter().copied()
    }
}

impl std::fmt::Debug for IdSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Print as a set, like the `BTreeSet` it replaced.
        f.debug_set().entries(self.ids.iter()).finish()
    }
}

impl FromIterator<usize> for IdSet {
    fn from_iter<I: IntoIterator<Item = usize>>(iter: I) -> IdSet {
        let mut ids: Vec<usize> = iter.into_iter().collect();
        ids.sort_unstable();
        ids.dedup();
        IdSet { ids }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeSet;

    #[test]
    fn insert_remove_contains_match_btreeset_semantics() {
        let mut s = IdSet::new();
        assert!(s.insert(5));
        assert!(s.insert(1));
        assert!(!s.insert(5));
        assert!(s.contains(1) && s.contains(5) && !s.contains(3));
        assert_eq!(s.first(), Some(1));
        assert!(s.remove(1));
        assert!(!s.remove(1));
        assert_eq!(s.first(), Some(5));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn iteration_and_debug_mirror_a_btreeset() {
        let mut rng = Rng::new(0x1d5e);
        let mut ours = IdSet::new();
        let mut reference = BTreeSet::new();
        for _ in 0..2_000 {
            let id = (rng.next_u64() % 128) as usize;
            if rng.uniform() < 0.6 {
                assert_eq!(ours.insert(id), reference.insert(id));
            } else {
                assert_eq!(ours.remove(id), reference.remove(&id));
            }
            assert_eq!(ours.len(), reference.len());
            assert_eq!(ours.first(), reference.iter().next().copied());
            assert!(ours.iter().eq(reference.iter().copied()));
            assert_eq!(format!("{ours:?}"), format!("{reference:?}"));
        }
    }

    #[test]
    fn from_iterator_sorts_and_dedups() {
        let s: IdSet = [9, 1, 4, 1, 9].into_iter().collect();
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![1, 4, 9]);
    }
}
