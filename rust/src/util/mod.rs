//! Small self-contained utilities replacing unavailable external crates
//! (see Cargo.toml note and DESIGN.md §1): JSON, PRNG, CLI parsing, and a
//! property-test driver.

pub mod bench;
pub mod cli;
pub mod codec;
pub mod idset;
pub mod json;
pub mod prop;
pub mod rng;

/// Format a byte count human-readably (for logs and traces).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format seconds as h:mm:ss.ms for schedule traces.
pub fn fmt_secs(s: f64) -> String {
    let total_ms = (s * 1000.0).round() as u64;
    let ms = total_ms % 1000;
    let secs = (total_ms / 1000) % 60;
    let mins = (total_ms / 60_000) % 60;
    let hours = total_ms / 3_600_000;
    if hours > 0 {
        format!("{hours}:{mins:02}:{secs:02}")
    } else if mins > 0 {
        format!("{mins}:{secs:02}.{ms:03}")
    } else {
        format!("{secs}.{ms:03}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(11 * 1024 * 1024 * 1024), "11.00 GiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(0.5), "0.500s");
        assert_eq!(fmt_secs(75.25), "1:15.250");
        assert_eq!(fmt_secs(3661.0), "1:01:01");
    }
}
