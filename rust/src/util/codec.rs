//! Hand-rolled binary codec for the durability subsystem (no external
//! serialization crates — see the Cargo.toml note): fixed-width
//! little-endian integers, `f64` as raw IEEE-754 bits (NaN patterns such as
//! the engine's "never cancelled" sentinel survive a round trip exactly),
//! length-prefixed strings, and an IEEE CRC-32 for record checksums.
//!
//! Every decode error is a typed [`HydraError::WalCorrupt`] — a torn or
//! bit-flipped WAL must surface as a recoverable error, never a panic
//! (property-tested in rust/tests/durability.rs). Readers therefore treat
//! every length and count as untrusted: a count that could not possibly fit
//! in the remaining bytes is rejected before any allocation happens.

use crate::error::{HydraError, Result};

/// IEEE CRC-32 (reflected, polynomial 0xEDB88320) lookup table, built at
/// compile time.
const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// IEEE CRC-32 of `data` (the checksum zlib and PNG use).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Append-only byte buffer with typed little-endian writers.
#[derive(Debug, Default, Clone)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter { buf: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn into_inner(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// `f64` as raw bits: round trips every bit pattern, NaNs included.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }
}

fn corrupt(what: &str) -> HydraError {
    HydraError::WalCorrupt(format!("truncated or malformed field: {what}"))
}

/// Cursor over an immutable byte slice with typed little-endian readers.
/// Every getter fails with [`HydraError::WalCorrupt`] instead of panicking
/// when the slice runs short.
#[derive(Debug, Clone)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(corrupt(what));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1, "u8")?[0])
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(HydraError::WalCorrupt(format!("bad bool byte {b:#x}"))),
        }
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        let s = self.take(4, "u32")?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        let s = self.take(8, "u64")?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    pub fn get_usize(&mut self) -> Result<usize> {
        let v = self.get_u64()?;
        usize::try_from(v)
            .map_err(|_| HydraError::WalCorrupt(format!("usize overflow: {v}")))
    }

    /// Read an element count for a collection whose elements occupy at
    /// least `min_bytes_per_item` bytes each. Rejects counts that could not
    /// possibly fit in the remaining buffer *before* any allocation — the
    /// guard against corrupted lengths turning into allocation bombs.
    pub fn get_count(&mut self, min_bytes_per_item: usize) -> Result<usize> {
        let n = self.get_usize()?;
        let per = min_bytes_per_item.max(1);
        if n > self.remaining() / per {
            return Err(HydraError::WalCorrupt(format!(
                "impossible element count {n} ({} bytes remain)",
                self.remaining()
            )));
        }
        Ok(n)
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.get_u32()?))
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_usize()?;
        if n > self.remaining() {
            return Err(corrupt("byte string"));
        }
        self.take(n, "byte string")
    }

    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        String::from_utf8(b.to_vec())
            .map_err(|_| HydraError::WalCorrupt("invalid utf-8 string".into()))
    }

    /// The decode analogue of "trailing garbage": snapshot payloads must be
    /// consumed exactly.
    pub fn expect_end(&self) -> Result<()> {
        if self.is_empty() {
            Ok(())
        } else {
            Err(HydraError::WalCorrupt(format!(
                "{} trailing bytes after record payload",
                self.remaining()
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // standard IEEE CRC-32 check values
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"hello"), 0x3610_A686);
    }

    #[test]
    fn round_trip_all_types() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_bool(true);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_usize(42);
        w.put_f64(-0.0);
        w.put_f64(f64::NAN);
        w.put_f32(1.5);
        w.put_str("hydra");
        w.put_bytes(&[1, 2, 3]);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_usize().unwrap(), 42);
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.get_f64().unwrap().is_nan());
        assert_eq!(r.get_f32().unwrap(), 1.5);
        assert_eq!(r.get_str().unwrap(), "hydra");
        assert_eq!(r.get_bytes().unwrap(), &[1, 2, 3]);
        r.expect_end().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = ByteWriter::new();
        w.put_u64(99);
        w.put_str("tail");
        let buf = w.into_inner();
        for cut in 0..buf.len() {
            let mut r = ByteReader::new(&buf[..cut]);
            // reading the full sequence from any strict prefix must fail
            let res = r.get_u64().and_then(|_| r.get_str());
            assert!(res.is_err(), "cut at {cut} decoded successfully");
        }
    }

    #[test]
    fn bad_bool_and_trailing_bytes_rejected() {
        let mut r = ByteReader::new(&[2]);
        assert!(r.get_bool().is_err());
        let r = ByteReader::new(&[0]);
        assert!(r.expect_end().is_err());
    }

    #[test]
    fn impossible_counts_rejected_before_allocation() {
        let mut w = ByteWriter::new();
        w.put_u64(u64::MAX); // claimed count
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert!(r.get_count(1).is_err());
        // a huge length prefix on a byte string is equally rejected
        let mut w = ByteWriter::new();
        w.put_u64(1 << 40);
        w.put_u8(1);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert!(r.get_bytes().is_err());
    }
}
