//! Minimal property-based testing driver (proptest is unavailable offline).
//!
//! `check(name, cases, f)` runs `f` against `cases` seeded RNG instances.
//! On failure it retries with a fixed sequence of "simpler" seeds to give a
//! smaller reproduction hint, then panics with the failing seed so the case
//! can be replayed deterministically:
//!
//! ```ignore
//! prop::check("no overlap", 200, |rng| {
//!     let n = rng.range_u64(1, 20) as usize;
//!     ...
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

pub type PropResult = Result<(), String>;

/// Run a property `cases` times with seeds 0..cases (deterministic suite).
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    for seed in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property {name:?} failed at seed {seed}: {msg}\n\
                 replay: Rng::new(0xC0FFEE ^ {seed})"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        check("true", 50, |rng| {
            let x = rng.range_u64(0, 100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn fails_false_property_with_seed() {
        check("false", 10, |rng| {
            let x = rng.range_u64(0, 10);
            if x < 5 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }
}
