//! Minimal benchmark kit (criterion is unavailable offline): median-of-N
//! timing with warmup, ns/op reporting, and a tabular printer shared by the
//! `cargo bench` harnesses in rust/benches/.

use std::time::{Duration, Instant};

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters_per_run: u64,
    pub runs: usize,
}

impl Measurement {
    pub fn ns_per_iter(&self) -> f64 {
        self.median.as_nanos() as f64 / self.iters_per_run as f64
    }

    pub fn print(&self) {
        let per_iter = self.ns_per_iter();
        let human = if per_iter >= 1e9 {
            format!("{:.3} s", per_iter / 1e9)
        } else if per_iter >= 1e6 {
            format!("{:.3} ms", per_iter / 1e6)
        } else if per_iter >= 1e3 {
            format!("{:.3} µs", per_iter / 1e3)
        } else {
            format!("{per_iter:.1} ns")
        };
        println!(
            "{:<44} {:>12}/iter   (median of {} runs, min {:?}, max {:?})",
            self.name, human, self.runs, self.min, self.max
        );
    }
}

/// Time `f` (which performs `iters_per_run` iterations per call) `runs`
/// times after one warmup; report median/min/max.
pub fn bench<F: FnMut()>(name: &str, runs: usize, iters_per_run: u64, mut f: F) -> Measurement {
    f(); // warmup
    let mut samples: Vec<Duration> = (0..runs.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    samples.sort();
    let m = Measurement {
        name: name.to_string(),
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
        iters_per_run,
        runs: samples.len(),
    };
    m.print();
    m
}

/// Serialise measurements into a machine-readable JSON summary (the perf
/// trajectory's input: `cargo bench --bench hotpath` writes
/// `BENCH_engine.json` through this). Hand-rolled like `util::json` —
/// serde is unavailable offline.
pub fn to_json(measurements: &[Measurement]) -> String {
    let mut out = String::from("{\n  \"benches\": [\n");
    for (i, m) in measurements.iter().enumerate() {
        let mut name = String::with_capacity(m.name.len());
        for c in m.name.chars() {
            match c {
                '"' => name.push_str("\\\""),
                '\\' => name.push_str("\\\\"),
                c if (c as u32) < 0x20 => {
                    name.push_str(&format!("\\u{:04x}", c as u32));
                }
                c => name.push(c),
            }
        }
        out.push_str(&format!(
            "    {{\"name\": \"{name}\", \"ns_per_iter\": {:.1}, \
             \"median_ns\": {}, \"min_ns\": {}, \"max_ns\": {}, \
             \"iters_per_run\": {}, \"runs\": {}}}{}\n",
            m.ns_per_iter(),
            m.median.as_nanos(),
            m.min.as_nanos(),
            m.max.as_nanos(),
            m.iters_per_run,
            m.runs,
            if i + 1 == measurements.len() { "" } else { "," },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write the JSON summary of `measurements` to `path`.
pub fn write_json(path: &str, measurements: &[Measurement]) -> std::io::Result<()> {
    std::fs::write(path, to_json(measurements))
}

/// Run a whole-figure generator once and report wallclock.
pub fn run_once<F: FnOnce() -> R, R>(name: &str, f: F) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    let dt = t0.elapsed();
    println!("{name:<44} {dt:>12.2?} total");
    (r, dt)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench("spin", 3, 1000, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(m.median.as_nanos() > 0);
        assert_eq!(m.runs, 3);
    }

    #[test]
    fn run_once_returns_value() {
        let (v, dt) = run_once("id", || 42);
        assert_eq!(v, 42);
        assert!(dt.as_nanos() > 0);
    }

    #[test]
    fn json_summary_is_parseable_and_escaped() {
        let m = Measurement {
            name: "engine \"fast\"\n\\ path".into(),
            median: Duration::from_nanos(1500),
            min: Duration::from_nanos(1000),
            max: Duration::from_nanos(2000),
            iters_per_run: 3,
            runs: 5,
        };
        let text = to_json(&[m.clone(), m]);
        let j = crate::util::json::Json::parse(&text).unwrap();
        let benches = j.get("benches").and_then(|b| b.as_arr()).unwrap();
        assert_eq!(benches.len(), 2);
        assert_eq!(
            benches[0].get("name").and_then(|n| n.as_str()),
            Some("engine \"fast\"\n\\ path")
        );
        assert_eq!(benches[0].get("median_ns").and_then(|v| v.as_u64()), Some(1500));
        assert_eq!(benches[0].get("runs").and_then(|v| v.as_u64()), Some(5));
    }
}
