//! Minimal benchmark kit (criterion is unavailable offline): median-of-N
//! timing with warmup, ns/op reporting, and a tabular printer shared by the
//! `cargo bench` harnesses in rust/benches/.

use std::time::{Duration, Instant};

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters_per_run: u64,
    pub runs: usize,
}

impl Measurement {
    pub fn ns_per_iter(&self) -> f64 {
        self.median.as_nanos() as f64 / self.iters_per_run as f64
    }

    pub fn print(&self) {
        let per_iter = self.ns_per_iter();
        let human = if per_iter >= 1e9 {
            format!("{:.3} s", per_iter / 1e9)
        } else if per_iter >= 1e6 {
            format!("{:.3} ms", per_iter / 1e6)
        } else if per_iter >= 1e3 {
            format!("{:.3} µs", per_iter / 1e3)
        } else {
            format!("{per_iter:.1} ns")
        };
        println!(
            "{:<44} {:>12}/iter   (median of {} runs, min {:?}, max {:?})",
            self.name, human, self.runs, self.min, self.max
        );
    }
}

/// Time `f` (which performs `iters_per_run` iterations per call) `runs`
/// times after one warmup; report median/min/max.
pub fn bench<F: FnMut()>(name: &str, runs: usize, iters_per_run: u64, mut f: F) -> Measurement {
    f(); // warmup
    let mut samples: Vec<Duration> = (0..runs.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    samples.sort();
    let m = Measurement {
        name: name.to_string(),
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
        iters_per_run,
        runs: samples.len(),
    };
    m.print();
    m
}

/// Run a whole-figure generator once and report wallclock.
pub fn run_once<F: FnOnce() -> R, R>(name: &str, f: F) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    let dt = t0.elapsed();
    println!("{name:<44} {dt:>12.2?} total");
    (r, dt)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench("spin", 3, 1000, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(m.median.as_nanos() > 0);
        assert_eq!(m.runs, 3);
    }

    #[test]
    fn run_once_returns_value() {
        let (v, dt) = run_once("id", || 42);
        assert_eq!(v, 42);
        assert!(dt.as_nanos() > 0);
    }
}
