//! `hydra` — CLI launcher for the Hydra multi-model training system.
//!
//! Every run-producing subcommand drives the one front door,
//! [`hydra::session::Session`]:
//!   train     — real multi-model training over the PJRT runtime
//!   run       — declarative workload spec (JSON) over the real runtime
//!   figure    — regenerate a paper figure/table (or `all`)
//!   simulate  — ad-hoc paper-scale simulation with chosen knobs, including
//!               the online Poisson-arrival / heterogeneous-pool scenario
//!               (`--progress` streams job events live via EngineObserver)
//!   search    — model selection: grid/random/ASHA over a hyperparameter
//!               space, with ASHA pruning losers mid-run (selection::)
//!   partition — show Algorithm-1 partitioning for a config
//!   inspect   — list artifact configs and their executables

use std::path::Path;
use std::time::Duration;

use hydra::coordinator::durability::{
    recover, scan_wal, DurabilityOptions, Recovered, WalRecord,
};
use hydra::coordinator::memory::TierSpec;
use hydra::coordinator::partitioner::PartitionPolicy;
use hydra::coordinator::sharp::{
    EngineOptions, ParallelMode, QueueKind, RunReport, TransferModel,
};
use hydra::coordinator::Cluster;
use hydra::exec::real::RealModelSpec;
use hydra::figures;
use hydra::runtime::Manifest;
use hydra::selection::{Algo, Search, SearchReport, SearchSpace, TrialState};
use hydra::session::{Backend, Policy, Session};
use hydra::sim::{
    build_tasks, build_tasks_pool, bursty_mixed_tenants,
    diurnal_mixed_tenants, parse_pool, poisson_mixed_tenants, pool_reference,
    uniform_grid, GpuSpec,
};
use hydra::train::optimizer::OptKind;
use hydra::util::cli::Args;
use hydra::util::fmt_bytes;
use hydra::EngineObserver;

type CliResult = Result<(), Box<dyn std::error::Error>>;

const USAGE: &str = "\
hydra — large multi-model deep learning (PVLDB'22 reproduction)

USAGE:
  hydra train   [--manifest artifacts] [--config tiny-lm-b8] [--models 4]
                [--devices 2] [--device-mem-mib 4] [--minibatches 8]
                [--epochs 1] [--lr 0.05] [--opt sgd|momentum|adam]
                [--scheduler sharded-lrtf] [--no-double-buffer] [--sequential]
                [--gantt]
  hydra run     --spec configs/grid_tiny.json [--manifest artifacts] [--gantt]
  hydra figure  <table2|fig6|fig7|fig8|fig9a|fig9b|fig10|table3|all>
                [--out results] [--bnb-secs 3]
  hydra simulate [--models 12] [--params-m 1000] [--devices 8]
                [--minibatches 6] [--scheduler sharded-lrtf]
                [--no-double-buffer] [--sequential]
                [--queue heap|scan|calendar]
                [--prefetch-depth 1] [--shards 1] [--threads] [--stealing]
                [--dram-gib 500] [--nvme <cap-gib>[:<gbps>]]
                [--wal run.wal] [--snapshot-every 4096]
  hydra simulate --online [--jobs 12] [--rate 6] [--seed 7]
                [--pool a4000:4,a6000:4] [--minibatches 3]
                [--arrivals poisson|diurnal|bursty] [--burst-factor 20]
                [--tenants N | --tenant-weights 10,1,1] [--slo <secs>]
                [--admission-depth K]
                [--scheduler sharded-lrtf|weighted-fair|...]
                [--progress] [--gantt]
                [--queue heap|scan|calendar]
                [--prefetch-depth 1] [--shards 1] [--threads] [--stealing]
                [--dram-gib 500] [--nvme <cap-gib>[:<gbps>]]
                [--wal run.wal] [--snapshot-every 4096]
  hydra search  --space lr=1e-4..1e-2:log,layers=12,24,48
                [--algo grid|random|asha] [--pool a4000:4] [--trials N]
                [--eta 3] [--min-epochs 1] [--epochs 9] [--minibatches 2]
                [--grid-points 3] [--seed 7] [--stagger 0]
                [--scheduler sharded-lrtf] [--queue heap|scan|calendar]
                [--prefetch-depth 1] [--shards 1] [--threads] [--stealing]
                [--admission-depth K]
                [--dram-gib 500] [--nvme <cap-gib>[:<gbps>]]
                [--wal search.wal] [--snapshot-every 4096]
                | --spec search.json
  hydra recover <run.wal>
                replay/resume a crashed durable run or search from its
                event WAL (+ .snap sidecar when snapshots were enabled)
  hydra partition [--manifest artifacts] [--config tiny-lm-b8]
                [--device-mem-mib 2]
  hydra inspect [--manifest artifacts]
";

fn main() {
    let flags = [
        "no-double-buffer",
        "sequential",
        "gantt",
        "help",
        "online",
        "scan-queue",
        "progress",
        "threads",
        "stealing",
    ];
    let args = match Args::from_env(&flags) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return;
    }
    let result = match args.positional[0].as_str() {
        "train" => cmd_train(&args),
        "run" => cmd_run(&args),
        "figure" => cmd_figure(&args),
        "simulate" => cmd_simulate(&args),
        "search" => cmd_search(&args),
        "recover" => cmd_recover(&args),
        "partition" => cmd_partition(&args),
        "inspect" => cmd_inspect(&args),
        other => {
            eprintln!("unknown subcommand {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn engine_options(args: &Args) -> Result<EngineOptions, String> {
    let shards = args.opt_usize("shards", 1)?;
    if shards == 0 {
        return Err("shards must be >= 1".into());
    }
    let admission_depth = match args.opt("admission-depth") {
        Some(v) => {
            let d: usize = v
                .parse()
                .map_err(|_| format!("--admission-depth: bad integer {v:?}"))?;
            if d == 0 {
                return Err("--admission-depth must be >= 1 (omit the flag \
                            to disable admission control)"
                    .into());
            }
            Some(d)
        }
        None => None,
    };
    Ok(EngineOptions {
        admission_depth,
        mode: if args.flag("sequential") {
            ParallelMode::Sequential
        } else {
            ParallelMode::Sharp
        },
        double_buffer: !args.flag("no-double-buffer"),
        prefetch_depth: args.opt_usize("prefetch-depth", 1)?,
        transfer: TransferModel::pcie_gen3(),
        queue: queue_arg(args)?,
        shards,
        threads: args.flag("threads"),
        stealing: args.flag("stealing"),
        ..Default::default()
    })
}

/// `--queue heap|scan|calendar`, with `--scan-queue` as the legacy spelling
/// of `--queue scan`. All disciplines produce byte-identical reports; the
/// calendar queue is the fast choice for storm workloads with heavy
/// same-timestamp churn.
fn queue_arg(args: &Args) -> Result<QueueKind, String> {
    match args.opt("queue") {
        Some("heap") => Ok(QueueKind::Heap),
        Some("scan") | Some("linear-scan") => Ok(QueueKind::LinearScan),
        Some("calendar") => Ok(QueueKind::Calendar),
        Some(other) => Err(format!("unknown --queue {other:?} (heap|scan|calendar)")),
        None => Ok(if args.flag("scan-queue") {
            QueueKind::LinearScan
        } else {
            QueueKind::Heap
        }),
    }
}

fn policy_arg(args: &Args) -> Result<Policy, hydra::HydraError> {
    args.opt_or("scheduler", "sharded-lrtf").parse()
}

/// `--wal <path> [--snapshot-every <n>]` shared by the simulate and search
/// subcommands.
fn durability_args(args: &Args) -> Result<Option<DurabilityOptions>, String> {
    let every = args
        .opt("snapshot-every")
        .map(|v| {
            v.parse::<u64>()
                .map_err(|_| format!("--snapshot-every: bad integer {v:?}"))
        })
        .transpose()?;
    match args.opt("wal") {
        Some(path) => {
            let mut d = DurabilityOptions::new(path);
            if let Some(n) = every {
                d.snapshot_every = n;
            }
            Ok(Some(d))
        }
        None if every.is_some() => {
            Err("--snapshot-every requires --wal (snapshots are a sidecar \
                 of the event WAL)"
                .into())
        }
        None => Ok(None),
    }
}

/// Streams job lifecycle events while the engine runs — the
/// `simulate --online --progress` demo of the [`EngineObserver`] API.
struct ProgressObserver;

impl EngineObserver for ProgressObserver {
    fn on_job_arrived(&mut self, model: usize, name: &str, now: f64) {
        println!("  [{now:>9.1}s] + job {model} ({name}) arrived");
    }

    fn on_job_finished(&mut self, model: usize, now: f64, cancelled: bool) {
        let how = if cancelled { "cancelled" } else { "finished" };
        println!("  [{now:>9.1}s] - job {model} {how}");
    }
}

/// Per-tier spill traffic line shared by the simulate subcommands.
fn print_tier_traffic(r: &RunReport) {
    println!(
        "  spill traffic: DRAM<->HBM {} promoted / {} demoted | \
         NVMe<->DRAM {} fetched / {} written back ({:.2}h stalled)",
        fmt_bytes(r.promoted_bytes),
        fmt_bytes(r.demoted_bytes),
        fmt_bytes(r.nvme_promoted_bytes),
        fmt_bytes(r.nvme_demoted_bytes),
        r.nvme_secs / 3600.0,
    );
    println!(
        "  prefetch: {:.2}h stalled on staged transfers, {:.2}h queued on \
         busy staging links",
        r.stall_secs / 3600.0,
        r.prefetch_wait_secs / 3600.0,
    );
}

fn cmd_train(args: &Args) -> CliResult {
    let manifest = args.opt_or("manifest", "artifacts");
    let config = args.opt_or("config", "tiny-lm-b8");
    let n_models = args.opt_usize("models", 4)?;
    let devices = args.opt_usize("devices", 2)?;
    let mem_mib = args.opt_usize("device-mem-mib", 4)?;
    let mbs = args.opt_usize("minibatches", 8)? as u32;
    let epochs = args.opt_usize("epochs", 1)? as u32;
    let lr = args.opt_f64("lr", 0.05)? as f32;
    let opt = OptKind::parse(&args.opt_or("opt", "sgd"))?;

    let cluster = Cluster::uniform(devices, (mem_mib as u64) << 20, 32 << 30);
    let mut session = Session::builder(cluster)
        .backend(Backend::Real { manifest })
        .policy(policy_arg(args)?)
        .options(engine_options(args)?)
        .build()?;
    for i in 0..n_models {
        // a small hyperparameter grid around the requested lr
        let lr_i = lr * (1.0 + 0.5 * i as f32);
        session.submit(RealModelSpec {
            name: format!("{config}-m{i}-lr{lr_i:.4}"),
            config: config.clone(),
            lr: lr_i,
            opt,
            epochs,
            minibatches_per_epoch: mbs,
            seed: 1000 + i as u64,
            inference: false,
            arrival: 0.0,
            tenant: 0,
            weight: 1.0,
            deadline: None,
        })?;
    }
    println!(
        "training {n_models} x {config} on {devices} virtual devices ({} each)...",
        fmt_bytes((mem_mib as u64) << 20)
    );
    let t0 = std::time::Instant::now();
    let report = session.run()?;
    println!(
        "done in {:.1}s wallclock | virtual makespan {:.2}s | {} units | util {:.1}% | sched {}",
        t0.elapsed().as_secs_f64(),
        report.run.makespan,
        report.run.units_executed,
        100.0 * report.run.utilization,
        report.run.scheduler,
    );
    println!(
        "spill traffic: {} promoted, {} demoted",
        fmt_bytes(report.run.promoted_bytes),
        fmt_bytes(report.run.demoted_bytes)
    );
    for (i, losses) in report.losses.iter().enumerate() {
        let first = losses.first().map(|x| x.1).unwrap_or(f32::NAN);
        let last = losses.last().map(|x| x.1).unwrap_or(f32::NAN);
        println!("model {i}: loss {first:.4} -> {last:.4} over {} steps", losses.len());
    }
    if args.flag("gantt") {
        println!("{}", report.run.trace.gantt(100));
    }
    Ok(())
}

fn cmd_run(args: &Args) -> CliResult {
    let spec_path = args
        .opt("spec")
        .ok_or("run requires --spec <file.json>")?;
    let manifest = args.opt_or("manifest", "artifacts");
    let spec = hydra::config::WorkloadSpec::load(spec_path)?;
    let session = spec.session(&manifest)?;
    println!(
        "running spec {spec_path}: {} tasks on {} devices ({} scheduler)",
        session.n_jobs(),
        spec.cluster.n_devices(),
        spec.policy
    );
    let t0 = std::time::Instant::now();
    let report = session.run()?;
    println!(
        "done in {:.1}s wallclock | makespan {:.2}s | {} units | util {:.1}%",
        t0.elapsed().as_secs_f64(),
        report.run.makespan,
        report.run.units_executed,
        100.0 * report.run.utilization
    );
    for (i, (t, losses)) in spec.tasks.iter().zip(&report.losses).enumerate() {
        let first = losses.first().map(|x| x.1).unwrap_or(f32::NAN);
        let last = losses.last().map(|x| x.1).unwrap_or(f32::NAN);
        let stopped = if (losses.len() as u32)
            < t.epochs * t.minibatches_per_epoch && !t.inference
        {
            "  [early-stopped]"
        } else {
            ""
        };
        println!(
            "task {i} ({}): loss {first:.4} -> {last:.4} over {} steps{stopped}",
            t.name,
            losses.len()
        );
    }
    if args.flag("gantt") {
        println!("{}", report.run.trace.gantt(100));
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> CliResult {
    let id = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let out = args.opt_or("out", "results");
    let bnb = Duration::from_secs_f64(args.opt_f64("bnb-secs", 3.0)?);
    let ids: Vec<&str> = if id == "all" {
        figures::ALL_IDS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let fig = figures::by_id(id, bnb)
            .ok_or_else(|| format!("unknown figure {id:?}"))??;
        fig.print();
        fig.write_csv(&out)?;
        println!("(csv written to {out}/{id}.csv)\n");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> CliResult {
    if args.flag("online") {
        return cmd_simulate_online(args);
    }
    let models = args.opt_usize("models", 12)?;
    let params_m = args.opt_usize("params-m", 1000)?;
    let devices = args.opt_usize("devices", 8)?;
    let mbs = args.opt_usize("minibatches", 6)? as u32;
    let dram = (args.opt_usize("dram-gib", 500)? as u64) << 30;
    let nvme = args.opt("nvme").map(TierSpec::parse).transpose()?;
    let policy = policy_arg(args)?;

    let gpu = GpuSpec::rtx2080ti();
    let grid = uniform_grid(models, (params_m as u64) * 1_000_000, 8, 1, mbs);
    let tasks = build_tasks(&grid, &gpu, PartitionPolicy::default())?;
    let shards = tasks[0].shards.len();
    let opts = EngineOptions {
        buffer_frac: 0.30,
        record_intervals: false,
        ..engine_options(args)?
    };
    let mut builder = Session::builder(Cluster::uniform(devices, gpu.mem_bytes, dram))
        .backend(Backend::sim())
        .policy(policy)
        .options(opts);
    if let Some(tier) = nvme {
        builder = builder.nvme(tier);
    }
    if let Some(d) = durability_args(args)? {
        builder = builder.durability(d);
    }
    let mut session = builder.build()?;
    for t in tasks {
        session.submit(t)?;
    }
    let r = session.run()?.run;
    println!("{models} x {params_m}M models ({shards} shards each) on {devices} simulated 2080Ti:");
    println!(
        "  makespan {:.2}h | utilization {:.1}% | {} units | compute {:.2}h | transfer {:.2}h | stalls {:.2}h",
        r.makespan / 3600.0,
        100.0 * r.utilization,
        r.units_executed,
        r.compute_secs / 3600.0,
        r.transfer_secs / 3600.0,
        r.stall_secs / 3600.0,
    );
    print_tier_traffic(&r);
    Ok(())
}

/// The online multi-tenant scenario: Poisson job arrivals over a
/// heterogeneous GPU pool, scheduled by the event-heap SHARP engine.
fn cmd_simulate_online(args: &Args) -> CliResult {
    let jobs = args.opt_usize("jobs", 12)?;
    let rate = args.opt_f64("rate", 6.0)?;
    let seed = args.opt_usize("seed", 7)? as u64;
    let mbs = args.opt_usize("minibatches", 3)? as u32;
    let dram = (args.opt_usize("dram-gib", 500)? as u64) << 30;
    let nvme = args.opt("nvme").map(TierSpec::parse).transpose()?;
    let pool = parse_pool(&args.opt_or("pool", "a4000:4,a6000:4"))?;

    let arrivals = args.opt_or("arrivals", "poisson");
    let mut stream = match arrivals.as_str() {
        "poisson" => poisson_mixed_tenants(jobs, rate, seed, mbs),
        "diurnal" => diurnal_mixed_tenants(jobs, rate, seed, mbs),
        "bursty" => bursty_mixed_tenants(
            jobs,
            rate,
            args.opt_f64("burst-factor", 20.0)?,
            seed,
            mbs,
        ),
        other => {
            return Err(format!(
                "unknown --arrivals {other:?} (poisson|diurnal|bursty)"
            )
            .into())
        }
    };
    // --tenant-weights gives per-tenant fair-share weights (and implies the
    // tenant count); --tenants N is the equal-weight shorthand; --slo
    // applies a uniform deadline. Jobs go to tenants round-robin.
    let weights: Option<Vec<f64>> = match args.opt("tenant-weights") {
        Some(s) => {
            let w: Vec<f64> = s
                .split(',')
                .map(|v| {
                    v.parse::<f64>().map_err(|_| {
                        format!("--tenant-weights: bad weight {v:?}")
                    })
                })
                .collect::<Result<_, _>>()?;
            if w.is_empty() || w.iter().any(|x| !x.is_finite() || *x <= 0.0) {
                return Err(
                    "--tenant-weights must be finite and > 0".into()
                );
            }
            Some(w)
        }
        None => args
            .opt("tenants")
            .map(|v| -> Result<Vec<f64>, String> {
                let n: usize = v
                    .parse()
                    .map_err(|_| format!("--tenants: bad integer {v:?}"))?;
                if n == 0 {
                    return Err("--tenants must be >= 1".into());
                }
                Ok(vec![1.0; n])
            })
            .transpose()?,
    };
    let slo = args
        .opt("slo")
        .map(|v| {
            v.parse::<f64>()
                .map_err(|_| format!("--slo: bad seconds {v:?}"))
        })
        .transpose()?;
    if let Some(w) = &weights {
        hydra::sim::assign_tenants(&mut stream, w, slo);
    } else if slo.is_some() {
        hydra::sim::assign_tenants(&mut stream, &[1.0], slo);
    }
    let (tasks, specs) = build_tasks_pool(
        &stream,
        &pool,
        PartitionPolicy { buffer_frac: 0.30, ..Default::default() },
    )?;
    let n_devices = specs.len();
    let opts = EngineOptions { buffer_frac: 0.30, ..engine_options(args)? };
    let mut builder = Session::builder(Cluster::heterogeneous(specs, dram))
        .backend(Backend::sim())
        .policy(policy_arg(args)?)
        .options(opts);
    if let Some(tier) = nvme {
        builder = builder.nvme(tier);
    }
    if let Some(d) = durability_args(args)? {
        builder = builder.durability(d);
    }
    let mut session = builder.build()?;
    for t in tasks {
        session.submit(t)?;
    }
    let report = if args.flag("progress") {
        println!("live job stream:");
        session.run_with(&mut ProgressObserver)?
    } else {
        session.run()?
    };
    let r = report.run;

    println!(
        "{jobs} tenant jobs ({arrivals}, {rate}/h) over {n_devices} heterogeneous devices:"
    );
    println!(
        "  makespan {:.2}h | utilization {:.1}% | {} units executed",
        r.makespan / 3600.0,
        100.0 * r.utilization,
        r.units_executed
    );
    print_tier_traffic(&r);
    println!(
        "  {:<26} {:>10} {:>10} {:>10} {:>7}",
        "job", "arrival", "finish", "latency", "units"
    );
    for j in &r.jobs {
        println!(
            "  {:<26} {:>9.2}m {:>9.2}m {:>9.2}m {:>7}",
            j.name,
            j.arrival / 60.0,
            j.finished / 60.0,
            j.latency() / 60.0,
            j.units_executed
        );
    }
    if !r.tenants.is_empty() {
        println!(
            "  {:<8} {:>6} {:>12} {:>8} {:>6} {:>8}",
            "tenant", "jobs", "gpu-secs", "units", "shed", "slo"
        );
        for t in &r.tenants {
            let slo = match t.slo_attainment() {
                Some(a) => format!("{:.0}%", 100.0 * a),
                None => "-".into(),
            };
            println!(
                "  {:<8} {:>6} {:>12.1} {:>8} {:>6} {:>8}",
                t.tenant, t.jobs, t.gpu_secs, t.units, t.shed, slo
            );
        }
    }
    if args.flag("gantt") {
        println!("{}", r.trace.gantt(100));
    }
    Ok(())
}

/// Model selection over a hyperparameter space: grid / random / ASHA,
/// ASHA pruning rung losers mid-run so freed memory recirculates to the
/// surviving trials (`hydra::selection`).
fn cmd_search(args: &Args) -> CliResult {
    let report = if let Some(path) = args.opt("spec") {
        let mut spec = hydra::config::SearchWorkload::load(path)?;
        if let Some(d) = durability_args(args)? {
            // CLI flags override the spec's own engine.wal/snapshot_every
            spec.durability = Some(d);
        }
        println!(
            "search spec {path}: {}-axis space on {} devices ({} scheduler)",
            spec.search.space.params.len(),
            spec.cluster.n_devices(),
            spec.policy
        );
        spec.run()?
    } else {
        let space_s = args.opt("space").ok_or(
            "search requires --space (e.g. lr=1e-4..1e-2:log,layers=12,24,48) \
             or --spec search.json",
        )?;
        let space = SearchSpace::parse(space_s)?;
        let eta = args.opt_usize("eta", 3)? as u32;
        let min_epochs = args.opt_usize("min-epochs", 1)? as u32;
        let trials = args
            .opt("trials")
            .map(|v| {
                v.parse::<usize>()
                    .map_err(|_| format!("--trials: bad integer {v:?}"))
            })
            .transpose()?;
        let algo = match args.opt_or("algo", "asha").as_str() {
            "grid" => Algo::Grid,
            "random" => Algo::Random {
                trials: trials.ok_or("--algo random requires --trials")?,
            },
            "asha" | "sha" => Algo::Asha { trials, eta, min_epochs },
            other => {
                return Err(format!("unknown --algo {other:?} (grid|random|asha)").into())
            }
        };
        let pool_s = args.opt_or("pool", "a4000:4");
        let pool = parse_pool(&pool_s)?;
        let reference = pool_reference(&pool).ok_or("empty pool")?;
        let specs: Vec<_> = pool.iter().map(|g| g.device_spec(&reference)).collect();
        let dram = (args.opt_usize("dram-gib", 500)? as u64) << 30;
        let nvme = args.opt("nvme").map(TierSpec::parse).transpose()?;

        let mut search = Search::new(space);
        search.algo = algo;
        search.epochs = args.opt_usize("epochs", 9)? as u32;
        search.minibatches_per_epoch = args.opt_usize("minibatches", 2)? as u32;
        search.seed = args.opt_usize("seed", 7)? as u64;
        search.stagger_secs = args.opt_f64("stagger", 0.0)?;
        search.grid_points = args.opt_usize("grid-points", 3)?;
        search.reference = reference;

        // engine_options honors --sequential / --no-double-buffer /
        // --queue exactly like the simulate subcommands
        let opts = EngineOptions {
            buffer_frac: 0.30,
            record_intervals: false,
            ..engine_options(args)?
        };
        if let Some(d) = durability_args(args)? {
            // A durable search routes through the declarative spec path:
            // the synthesized spec text becomes the WAL genesis record, so
            // `hydra recover` re-drives the search from the same recipe.
            let mut engine = format!(
                r#""scheduler": "{}", "shards": {}, "prefetch_depth": {}, "buffer_frac": 0.3, "wal": "{}""#,
                args.opt_or("scheduler", "sharded-lrtf"),
                opts.shards,
                opts.prefetch_depth,
                d.wal.display(),
            );
            if d.snapshot_every > 0 {
                engine.push_str(&format!(
                    r#", "snapshot_every": {}"#,
                    d.snapshot_every
                ));
            }
            if let Some(k) = opts.admission_depth {
                engine.push_str(&format!(r#", "admission_depth": {k}"#));
            }
            if args.flag("sequential") {
                engine.push_str(r#", "sequential": true"#);
            }
            if args.flag("no-double-buffer") {
                engine.push_str(r#", "double_buffer": false"#);
            }
            if opts.threads {
                engine.push_str(r#", "threads": true"#);
            }
            if opts.stealing {
                engine.push_str(r#", "stealing": true"#);
            }
            match opts.queue {
                QueueKind::Heap => {}
                QueueKind::LinearScan => {
                    engine.push_str(r#", "queue": "scan""#);
                }
                QueueKind::Calendar => {
                    engine.push_str(r#", "queue": "calendar""#);
                }
            }
            let mut cluster =
                format!(r#""pool": "{pool_s}", "dram_mib": {}"#, dram >> 20);
            if let Some(nv) = args.opt("nvme") {
                cluster.push_str(&format!(r#", "nvme": "{nv}""#));
            }
            let mut search_obj = format!(
                r#""space": "{space_s}", "algo": "{}", "eta": {eta}, "min_epochs": {min_epochs}, "epochs": {}, "minibatches": {}, "seed": {}, "stagger": {}, "grid_points": {}"#,
                args.opt_or("algo", "asha"),
                search.epochs,
                search.minibatches_per_epoch,
                search.seed,
                search.stagger_secs,
                search.grid_points,
            );
            if let Some(t) = trials {
                search_obj.push_str(&format!(r#", "trials": {t}"#));
            }
            let text = format!(
                "{{\"cluster\": {{{cluster}}}, \"engine\": {{{engine}}}, \
                 \"search\": {{{search_obj}}}}}"
            );
            println!("durable search: event WAL at {}", d.wal.display());
            hydra::config::SearchWorkload::parse(&text)?.run()?
        } else {
            let mut builder =
                Session::builder(Cluster::heterogeneous(specs, dram))
                    .backend(Backend::sim())
                    .policy(policy_arg(args)?)
                    .options(opts);
            if let Some(tier) = nvme {
                builder = builder.nvme(tier);
            }
            builder.build()?.run_search(&search)?
        }
    };
    print_search_report(&report);
    Ok(())
}

/// Recover a crashed (or finished) durable run from its event WAL:
/// scan + forensics line, then snapshot-resume or genesis replay.
fn cmd_recover(args: &Args) -> CliResult {
    let path = args.positional.get(1).map(String::as_str).ok_or(
        "recover requires a WAL path: hydra recover <run.wal>",
    )?;
    let wal = Path::new(path);
    let scanned = scan_wal(wal)?;
    let kind = match &scanned.genesis {
        hydra::coordinator::durability::Genesis::Run(spec) => format!(
            "run ({} tasks on {} devices)",
            spec.tasks.len(),
            spec.devices.len()
        ),
        hydra::coordinator::durability::Genesis::Search(_) => {
            "search".to_string()
        }
    };
    let complete = matches!(scanned.records.last(), Some(WalRecord::RunEnd { .. }));
    println!(
        "{path}: {kind} genesis + {} event records{}{}",
        scanned.records.len(),
        if complete { ", RunEnd present (clean)" } else { ", no RunEnd (interrupted)" },
        match &scanned.torn {
            Some(e) => format!("; torn tail clipped: {e}"),
            None => String::new(),
        },
    );
    let started = std::time::Instant::now();
    match recover(wal)? {
        Recovered::Run(r) => {
            println!(
                "recovered run in {:.3}s wallclock:",
                started.elapsed().as_secs_f64()
            );
            println!(
                "  makespan {:.2}h | utilization {:.1}% | {} units executed",
                r.makespan / 3600.0,
                100.0 * r.utilization,
                r.units_executed
            );
            print_tier_traffic(&r);
            for j in &r.jobs {
                println!(
                    "  {:<26} {:>9.2}m {:>9.2}m {:>7} units{}",
                    j.name,
                    j.arrival / 60.0,
                    j.finished / 60.0,
                    j.units_executed,
                    if j.cancelled { " (cancelled)" } else { "" },
                );
            }
        }
        Recovered::Search(r) => {
            println!(
                "recovered search in {:.3}s wallclock:",
                started.elapsed().as_secs_f64()
            );
            print_search_report(&r);
        }
    }
    Ok(())
}

fn print_search_report(r: &SearchReport) {
    println!(
        "{} search: {} trials | makespan {:.2}h | utilization {:.1}%",
        r.algo,
        r.trials.len(),
        r.run.makespan / 3600.0,
        100.0 * r.run.utilization
    );
    println!(
        "  GPU time: spent {:.1}h of {:.1}h full-grid (saved {:.1}h, {:.1}%)",
        r.spent_secs / 3600.0,
        r.full_secs / 3600.0,
        r.gpu_hours_saved(),
        100.0 * (r.full_secs - r.spent_secs) / r.full_secs.max(1e-12),
    );
    for rung in &r.rungs {
        println!(
            "  rung @{} epoch{}: {} entered -> {} promoted",
            rung.epochs,
            if rung.epochs == 1 { "" } else { "s" },
            rung.entered.len(),
            rung.promoted.len()
        );
    }
    println!(
        "  {:<38} {:>9} {:>7} {:>10} {:>10}",
        "trial", "state", "epochs", "final-loss", "gpu-secs"
    );
    for t in &r.trials {
        let state = match t.state {
            TrialState::Completed => "done".to_string(),
            TrialState::Pruned { rung } => format!("pruned@{rung}"),
            TrialState::Pending => "pending".to_string(),
        };
        println!(
            "  {:<38} {:>9} {:>7} {:>10.4} {:>10.1}",
            t.name,
            state,
            t.losses.len(),
            t.final_loss().unwrap_or(f64::NAN),
            t.executed_secs
        );
    }
    match r.best_trial() {
        Some(b) => println!(
            "best: {} (final loss {:.4})",
            b.name,
            b.final_loss().unwrap_or(f64::NAN)
        ),
        None => println!("best: none (no trial completed)"),
    }
}

fn cmd_partition(args: &Args) -> CliResult {
    let manifest_dir = args.opt_or("manifest", "artifacts");
    let config = args.opt_or("config", "tiny-lm-b8");
    let mem_mib = args.opt_usize("device-mem-mib", 2)?;

    let (_backend, tasks) = hydra::exec::real::RealBackend::build(
        &manifest_dir,
        &[RealModelSpec {
            name: "probe".into(),
            config: config.clone(),
            lr: 0.01,
            opt: OptKind::Sgd,
            epochs: 1,
            minibatches_per_epoch: 1,
            seed: 0,
            inference: false,
            arrival: 0.0,
            tenant: 0,
            weight: 1.0,
            deadline: None,
        }],
        (mem_mib as u64) << 20,
        PartitionPolicy::default(),
    )?;
    let t = &tasks[0];
    println!(
        "config {config} on {} devices: {} shards",
        fmt_bytes((mem_mib as u64) << 20),
        t.shards.len()
    );
    for (i, s) in t.shards.iter().enumerate() {
        println!(
            "  shard {i}: {} layers | params {} | act {} | fwd {:.2}ms | bwd {:.2}ms",
            s.n_layers,
            fmt_bytes(s.param_bytes),
            fmt_bytes(s.activation_bytes),
            1e3 * s.fwd_cost,
            1e3 * s.bwd_cost
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> CliResult {
    let manifest_dir = args.opt_or("manifest", "artifacts");
    let m = Manifest::load(&manifest_dir)?;
    println!("manifest at {manifest_dir}: {} configs", m.configs.len());
    for (name, c) in &m.configs {
        println!(
            "  {name}: {:?} d={} h={} L={} ff={} seq={} b={} vocab={} | {} params | {} executables",
            c.config.kind,
            c.config.d_model,
            c.config.n_heads,
            c.config.n_layers,
            c.config.d_ff,
            c.config.seq,
            c.config.batch,
            c.config.vocab,
            c.total_params(),
            c.executables.len()
        );
        for (ename, e) in &c.executables {
            println!(
                "      {ename}: {} inputs -> {} outputs ({})",
                e.inputs.len(),
                e.outputs.len(),
                e.file
            );
        }
    }
    Ok(())
}
