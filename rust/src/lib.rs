//! # Hydra
//!
//! A reproduction of *"Hydra: An Optimized Data System for Large Multi-Model
//! Deep Learning"* (Nagrecha & Kumar, PVLDB'22) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! - **L3 (this crate)**: the paper's contribution — model spilling, SHARP
//!   (Shard Alternator Parallelism), Sharded-LRTF scheduling and
//!   double-buffering — plus the substrates it needs: a PJRT runtime, a
//!   memory hierarchy manager, a discrete-event simulator, baseline
//!   execution paradigms, an optimizer/training stack, and a config/CLI
//!   launcher.
//! - **L2/L1 (python/, build-time only)**: JAX shard functions calling
//!   Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//!
//! Start with [`session::Session`] — the one typed front door over both
//! backends (`Session::builder(cluster).backend(..).policy(..)
//! .submit(..)?.run()`) — or the `hydra` binary.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod exec;
pub mod figures;
pub mod runtime;
pub mod selection;
pub mod session;
pub mod sim;
pub mod tensor;
pub mod train;
pub mod util;

pub use coordinator::durability::{recover, replay, DurabilityOptions, Recovered};
pub use coordinator::memory::{MemTier, MemoryOptions, TierSpec};
pub use coordinator::observer::{EngineObserver, NoopObserver, TraceRecorder};
pub use coordinator::sched::Policy;
pub use coordinator::Cluster;
pub use error::{HydraError, Result};
pub use selection::{Search, SearchReport, SearchSpace};
pub use session::{Backend, JobHandle, JobSpec, Session, SessionBuilder, SessionReport};
