//! Offline stub of the `xla` (xla_extension) PJRT bindings.
//!
//! The build environment for this repository has no network access and no
//! prebuilt XLA runtime, so the real bindings cannot be compiled. This stub
//! keeps the crate API-compatible with the subset Hydra uses:
//!
//! - [`Literal`] / [`ArrayShape`] / [`ElementType`] are **fully functional**
//!   host-side implementations (create, inspect, round-trip), because the
//!   host-tensor conversion layer and its unit tests exercise them without a
//!   device.
//! - [`PjRtClient::cpu`] returns a descriptive [`Error`], so any path that
//!   would actually execute HLO fails fast with a clear message while every
//!   simulated path (the SHARP engine, figures, benches) works.
//!
//! Swapping this path dependency for the real `xla_extension` bindings
//! re-enables real PJRT execution with no source changes elsewhere.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`: a message, nothing more.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA/PJRT runtime unavailable (offline stub build; \
             swap rust/vendor/xla for the real xla_extension bindings)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Crate-local result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types (subset of XLA's primitive types; Hydra uses F32/S32).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    /// Predicate / boolean byte.
    Pred,
    /// 8-bit unsigned integer.
    U8,
    /// 32-bit signed integer.
    S32,
    /// 32-bit IEEE float.
    F32,
    /// 64-bit IEEE float.
    F64,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::U8 => 1,
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::F64 => 8,
        }
    }
}

/// Rust native types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    /// The XLA element type this native type corresponds to.
    const TY: ElementType;
    /// Decode one element from its little-endian byte representation.
    fn read_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn read_le(bytes: &[u8]) -> f32 {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn read_le(bytes: &[u8]) -> i32 {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// Shape of a (non-tuple) literal: element type + dimensions.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension extents.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Element type.
    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host-side literal: either a dense array or a tuple of literals.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Build a dense array literal from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        let want = elems * ty.byte_size();
        if data.len() != want {
            return Err(Error(format!(
                "literal data size mismatch: {} bytes for {:?} x {:?} (want {})",
                data.len(),
                ty,
                dims,
                want
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
            tuple: None,
        })
    }

    /// Build a tuple literal (what HLO entry points return).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::F32, dims: Vec::new(), bytes: Vec::new(), tuple: Some(parts) }
    }

    /// Shape of a dense literal; errors on tuples.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        if self.tuple.is_some() {
            return Err(Error("array_shape on tuple literal".into()));
        }
        Ok(ArrayShape { ty: self.ty, dims: self.dims.clone() })
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("to_vec on tuple literal".into()));
        }
        if self.ty != T::TY {
            return Err(Error(format!(
                "to_vec element type mismatch: literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self.bytes.chunks_exact(self.ty.byte_size()).map(T::read_le).collect())
    }

    /// Flatten a tuple literal into its parts; errors on dense literals.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.tuple {
            Some(parts) => Ok(parts.clone()),
            None => Err(Error("to_tuple on non-tuple literal".into())),
        }
    }
}

/// Parsed HLO module (stub: records the path only).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    /// Load HLO text from a file. The stub only checks the file exists; it
    /// cannot parse or execute HLO.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let p = path.as_ref();
        if !p.exists() {
            return Err(Error(format!("HLO file not found: {}", p.display())));
        }
        Ok(HloModuleProto { path: p.display().to_string() })
    }
}

/// An XLA computation handle (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    #[allow(dead_code)]
    path: String,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

/// PJRT device buffer handle (stub: never constructed).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub: never constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle. In the stub, construction always fails.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU PJRT client. Always errors in the stub build.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f32() {
        let data: Vec<u8> = [1.0f32, 2.5, -3.0]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &data).unwrap();
        let back: Vec<f32> = lit.to_vec().unwrap();
        assert_eq!(back, vec![1.0, 2.5, -3.0]);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
    }

    #[test]
    fn size_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 4]).is_err()
        );
    }

    #[test]
    fn tuple_round_trip() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[1, 0, 0, 0])
            .unwrap();
        let t = Literal::tuple(vec![a]);
        assert_eq!(t.to_tuple().unwrap().len(), 1);
        assert!(t.array_shape().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"), "{e}");
    }
}
